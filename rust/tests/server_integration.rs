//! TCP API server round-trip: spin the server up on a test port, issue
//! requests from client threads, check responses and stats, shut down.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use fasteagle::coordinator::{Server, ServerConfig};
use fasteagle::draft::make_drafter;
use fasteagle::model::TargetModel;
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::spec::Engine;
use fasteagle::util::json::Json;

fn artifacts_base() -> Option<PathBuf> {
    let candidates = [
        std::env::var("FE_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "/tmp/art_test".to_string(),
    ];
    candidates
        .iter()
        .filter(|c| !c.is_empty())
        .map(PathBuf::from)
        .find(|p| p.join("base").join("spec.json").exists())
        .map(|p| p.join("base"))
}

const ADDR: &str = "127.0.0.1:7433";

fn query(line: &str) -> Json {
    let stream = TcpStream::connect(ADDR).expect("connect");
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut r = BufReader::new(stream);
    let mut out = String::new();
    r.read_line(&mut out).unwrap();
    Json::parse(out.trim()).expect("json response")
}

#[test]
fn server_roundtrip_and_shutdown() {
    let Some(dir) = artifacts_base() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::cpu().unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let target = TargetModel::open(Rc::clone(&store)).unwrap();
        let drafter = make_drafter(Rc::clone(&store), "fasteagle").unwrap();
        let engine = Engine::new(target, drafter);
        let server = Server::new(ServerConfig { addr: ADDR.into(), queue_capacity: 8 });
        server.serve(engine).unwrap()
    });
    // wait for listener
    let mut up = false;
    for _ in 0..600 {
        if TcpStream::connect(ADDR).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(up, "server did not start");

    // malformed request -> error object, connection stays usable
    let v = query("not json at all");
    assert!(v.get("error").is_some());

    // missing prompt -> error
    let v = query(r#"{"max_new": 4}"#);
    assert!(v.get("error").is_some());

    // two real generations from separate client threads
    let handles: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let req = format!(
                    r#"{{"prompt":"USER: tell me about city transport and the steady bridge. ({i})\nASSISTANT:","max_new":16}}"#
                );
                query(&req)
            })
        })
        .collect();
    for h in handles {
        let v = h.join().unwrap();
        assert!(v.get("error").is_none(), "{v:?}");
        assert_eq!(v.get("new_tokens").and_then(Json::as_usize), Some(16));
        assert!(v.get("tau").and_then(Json::as_f64).unwrap() >= 1.0);
    }

    // stats
    let v = query(r#"{"cmd":"stats"}"#);
    assert_eq!(v.get("requests_done").and_then(Json::as_usize), Some(2));

    // shutdown
    let v = query(r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let metrics = server_thread.join().unwrap();
    assert_eq!(metrics.requests_done, 2);
}
