//! TCP API server round-trip over the continuous batcher: spin the
//! server up on a test port, issue requests from client threads, check
//! per-request generation parameters, out-of-admission-order completion
//! (batch >= 2), stats, and shutdown.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use common::artifacts_root;
use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod, Server, ServerConfig};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::util::json::Json;
use fasteagle::workload::batched_serving_target;

const ADDR: &str = "127.0.0.1:7433";

fn query_at(addr: &str, line: &str) -> Json {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut r = BufReader::new(stream);
    let mut out = String::new();
    r.read_line(&mut out).unwrap();
    Json::parse(out.trim()).expect("json response")
}

fn query(line: &str) -> Json {
    query_at(ADDR, line)
}

fn wait_for_listener(addr: &str) {
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("server did not start on {addr}");
}

#[test]
fn server_roundtrip_concurrency_and_shutdown() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: ADDR.into(),
            queue_capacity: 8,
            ..Default::default()
        });
        server.serve(engine).unwrap()
    });
    // wait for listener
    let mut up = false;
    for _ in 0..600 {
        if TcpStream::connect(ADDR).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(up, "server did not start");

    // malformed request -> error object, connection stays usable
    let v = query("not json at all");
    assert!(v.get("error").is_some());

    // missing prompt -> structured error naming the field
    let v = query(r#"{"max_new": 4}"#);
    assert!(v.get("error").is_some());
    assert_eq!(v.get("field").and_then(Json::as_str), Some("prompt"));

    // bad "draft" objects die with the offending field and a reason,
    // and the connection stays usable afterwards
    let v = query(r#"{"prompt":"p","draft":{"planner":"warp"}}"#);
    let err = v.get("error").and_then(Json::as_str).expect("error reply");
    assert!(err.contains("warp"), "reason should quote the bad value: {err}");
    assert_eq!(v.get("field").and_then(Json::as_str), Some("draft.planner"));
    let v = query(r#"{"prompt":"p","draft":{"depth":0}}"#);
    assert_eq!(v.get("field").and_then(Json::as_str), Some("draft.depth"));
    let v = query(r#"{"prompt":"p","draft":{"chaos":1}}"#);
    assert_eq!(v.get("field").and_then(Json::as_str), Some("draft"));

    // Two in-flight requests: the long one is admitted first, the short
    // one second. With batch >= 2 they decode concurrently and the short
    // one must complete first — out of admission order. Completion order
    // is observed via a shared log each client appends to on reply.
    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let o = Arc::clone(&order);
    let long = std::thread::spawn(move || {
        let v = query(
            r#"{"prompt":"USER: tell me about city transport and the steady bridge.\nASSISTANT:","max_new":40}"#,
        );
        o.lock().unwrap().push("long");
        v
    });
    // let the long request reach the engine first
    std::thread::sleep(Duration::from_millis(300));
    let o = Arc::clone(&order);
    let short = std::thread::spawn(move || {
        let v = query(
            r#"{"prompt":"USER: tell me about machine learning and the fast cache.\nASSISTANT:","max_new":4}"#,
        );
        o.lock().unwrap().push("short");
        v
    });
    let vl = long.join().unwrap();
    let vs = short.join().unwrap();
    assert!(vl.get("error").is_none(), "{vl:?}");
    assert!(vs.get("error").is_none(), "{vs:?}");
    // per-request max_new_tokens honored
    assert_eq!(vl.get("new_tokens").and_then(Json::as_usize), Some(40));
    assert_eq!(vs.get("new_tokens").and_then(Json::as_usize), Some(4));
    assert!(vl.get("tau").and_then(Json::as_f64).unwrap() >= 1.0);
    // the engine's own occupancy gauge says whether the two actually
    // overlapped in slots; only then is completion order meaningful
    // (avoids a wall-clock race on very fast machines)
    let stats = query(r#"{"cmd":"stats"}"#);
    let peak = stats.get("peak_occupancy").and_then(Json::as_f64).unwrap_or(0.0);
    if batch >= 2 && peak >= 2.0 {
        assert_eq!(
            order.lock().unwrap().as_slice(),
            ["short", "long"],
            "short request (admitted second) must complete before the long one"
        );
    } else if batch >= 2 {
        eprintln!("note: requests never overlapped (peak={peak}); order check skipped");
    }

    // per-request temperature/seed: same prompt + seed at T=1 reproduces
    // exactly, across separate requests with different server-side ids
    let stoch = r#"{"prompt":"Q: Ben has 4 coins and buys 9 more coins. how many coins does Ben have?\nA:","max_new":12,"temperature":1.0,"seed":42}"#;
    let a = query(stoch);
    let b = query(stoch);
    assert!(a.get("error").is_none(), "{a:?}");
    assert_eq!(
        a.get("text").and_then(Json::as_str),
        b.get("text").and_then(Json::as_str),
        "same per-request seed must reproduce the same stochastic stream"
    );
    assert_eq!(a.get("new_tokens").and_then(Json::as_usize), Some(12));

    // stats
    let v = query(r#"{"cmd":"stats"}"#);
    assert_eq!(v.get("requests_done").and_then(Json::as_usize), Some(4));
    assert!(v.get("mean_occupancy").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(v.get("ttfc_p50_ms").is_some());

    // shutdown
    let v = query(r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let metrics = server_thread.join().unwrap();
    assert_eq!(metrics.requests_done, 4);
    assert_eq!(metrics.requests_rejected, 0);
}

/// Streaming mode: `"stream": true` yields one `{"event":"tokens",...}`
/// frame per decode cycle before the final response. On a multi-cycle
/// generation at least two incremental frames arrive first, and the
/// concatenated frame tokens decode to exactly the non-streaming
/// output — streaming never changes what is generated.
#[test]
fn server_streams_cycle_frames_byte_identical() {
    const SADDR: &str = "127.0.0.1:7434";
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: SADDR.into(),
            queue_capacity: 8,
            ..Default::default()
        });
        server.serve(engine).unwrap()
    });
    wait_for_listener(SADDR);

    // non-streaming reference for the same prompt/params
    let reference = query_at(
        SADDR,
        r#"{"prompt":"USER: tell me about machine learning and the fast cache.\nASSISTANT:","max_new":24}"#,
    );
    assert!(reference.get("error").is_none(), "{reference:?}");
    let ref_text = reference
        .get("text")
        .and_then(Json::as_str)
        .unwrap()
        .to_string();

    // same request with "stream": true and the adaptive draft planner —
    // frames, then the final response; adaptive drafting reshapes the
    // per-cycle chains but must not change a greedy output
    let stream = TcpStream::connect(SADDR).unwrap();
    let mut w = stream.try_clone().unwrap();
    writeln!(
        w,
        r#"{{"prompt":"USER: tell me about machine learning and the fast cache.\nASSISTANT:","max_new":24,"stream":true,"draft":{{"planner":"adaptive"}}}}"#
    )
    .unwrap();
    let mut r = BufReader::new(stream);
    let mut frames = 0usize;
    let mut toks: Vec<i32> = Vec::new();
    let final_resp = loop {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).expect("json line");
        if v.get("event").and_then(Json::as_str) == Some("tokens") {
            frames += 1;
            for t in v.get("tokens").and_then(Json::as_arr).expect("tokens array") {
                toks.push(t.as_i64().unwrap() as i32);
            }
        } else {
            break v; // the final (non-event) response ends the stream
        }
    };
    assert!(
        frames >= 2,
        "multi-cycle generation must stream multiple incremental frames, got {frames}"
    );
    assert!(final_resp.get("error").is_none(), "{final_resp:?}");
    assert_eq!(final_resp.get("new_tokens").and_then(Json::as_usize), Some(24));
    let cycles = final_resp.get("cycles").and_then(Json::as_usize).unwrap();
    assert!(frames <= cycles, "at most one frame per cycle ({frames} vs {cycles})");
    assert_eq!(toks.len(), 24, "concatenated frames must cover every committed token");
    // byte-identical reassembly: decode(concat frame tokens) equals the
    // streamed final text equals the non-streaming text
    let bytes: Vec<u8> = toks
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    let concat = String::from_utf8_lossy(&bytes).into_owned();
    let streamed_text = final_resp.get("text").and_then(Json::as_str).unwrap();
    assert_eq!(concat, streamed_text, "frames must reassemble the final text exactly");
    assert_eq!(
        streamed_text, ref_text,
        "streaming (with adaptive drafting) must not change the generated output"
    );

    // the plan gauges saw the cycles (both the static reference request
    // and the adaptive streaming one record per-cycle plan decisions)
    let stats = query_at(SADDR, r#"{"cmd":"stats"}"#);
    assert!(
        stats.get("plan_depth_mean").and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
        "{stats:?}"
    );
    assert!(stats.get("plan_nodes_mean").is_some());
    assert!(stats.get("accept_window_mean").is_some());

    let v = query_at(SADDR, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    server_thread.join().unwrap();
}

/// Streaming flow control: a deliberately slow reader must not make the
/// server queue one frame per cycle without bound. With `frame_queue: 0`
/// (the hard-throttle setting: no frame may sit undelivered) every
/// cycle coalesces into the per-request backlog, and the completion
/// flush delivers exactly one merged frame that still carries every
/// committed token — byte-identical to the final text.
#[test]
fn server_coalesces_frames_for_slow_consumer() {
    const CADDR: &str = "127.0.0.1:7435";
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: CADDR.into(),
            queue_capacity: 8,
            frame_queue: 0,
        });
        server.serve(engine).unwrap()
    });
    wait_for_listener(CADDR);

    let stream = TcpStream::connect(CADDR).unwrap();
    let mut w = stream.try_clone().unwrap();
    writeln!(
        w,
        r#"{{"prompt":"USER: tell me about machine learning and the fast cache.\nASSISTANT:","max_new":24,"stream":true}}"#
    )
    .unwrap();
    // deliberately slow reader: don't touch the socket until generation
    // has certainly finished — frames must have coalesced server-side
    std::thread::sleep(Duration::from_millis(500));
    let mut r = BufReader::new(stream);
    let mut frames = 0usize;
    let mut toks: Vec<i32> = Vec::new();
    let final_resp = loop {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).expect("json line");
        if v.get("event").and_then(Json::as_str) == Some("tokens") {
            frames += 1;
            for t in v.get("tokens").and_then(Json::as_arr).expect("tokens array") {
                toks.push(t.as_i64().unwrap() as i32);
            }
        } else {
            break v;
        }
    };
    assert!(final_resp.get("error").is_none(), "{final_resp:?}");
    let cycles = final_resp.get("cycles").and_then(Json::as_usize).unwrap();
    assert_eq!(
        frames, 1,
        "frame_queue=0 must coalesce all {cycles} cycles into one flush frame"
    );
    assert!(cycles > 1, "test needs a multi-cycle generation to be meaningful");
    // coalescing loses no tokens: the merged frame reassembles the text
    assert_eq!(toks.len(), 24, "merged frame must carry every committed token");
    let bytes: Vec<u8> = toks
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    let concat = String::from_utf8_lossy(&bytes).into_owned();
    assert_eq!(
        concat,
        final_resp.get("text").and_then(Json::as_str).unwrap(),
        "coalesced frame must reassemble the final text exactly"
    );

    let v = query_at(CADDR, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    server_thread.join().unwrap();
}
