//! Property tests for the `DraftPlan` redesign: a `StaticPlanner` with
//! the resolved default plan must reproduce the pre-redesign trees
//! **byte-identically** — same nodes (token/parent/depth/level/backbone
//! flag), same attached distributions, same consumption of the sampler
//! stream — across every draft-output kind the three serving methods
//! produce (fasteagle/eagle3 emit per-level `Levels` on the engine
//! lane and pre-sampled `Chain`s on the batched lane, vanilla emits
//! `None`) and under both greedy and stochastic candidate selection.
//!
//! The reference below is an independent reimplementation of the
//! pre-`DraftPlan` rules (uniform top-k over the previous backbone
//! node, optional `max_depth` truncation), not a call into the crate's
//! expansion code, so drift in the plan wiring cannot cancel out.

use fasteagle::draft::DraftOutput;
use fasteagle::spec::tree::{sample_without_replacement, DraftTree, TreeNode};
use fasteagle::spec::{DraftPlan, Sampler};
use fasteagle::util::rng::{top_k_indices, Pcg64};

/// Pre-redesign tree construction, reimplemented: truncate the draft to
/// `max_depth` (when set), then attach the top-k (greedy) or k
/// q-samples without replacement (stochastic) of each level to the
/// previous backbone node. Chains keep one node per level; `None` is a
/// root-only tree.
fn legacy_from_draft(
    pending: i32,
    draft: DraftOutput,
    k: usize,
    max_depth: Option<usize>,
    sampler: &mut Sampler,
) -> DraftTree {
    let root = TreeNode {
        token: pending,
        parent: 0,
        depth: 0,
        level: usize::MAX,
        backbone: true,
    };
    let mut nodes = vec![root];
    match draft {
        DraftOutput::Levels(mut dists) => {
            if let Some(d) = max_depth {
                dists.truncate(d);
            }
            let mut backbone = 0usize;
            for (level, q) in dists.iter().enumerate() {
                let cand = if sampler.greedy() {
                    top_k_indices(q, k)
                } else {
                    sample_without_replacement(q, k, sampler.rng_mut())
                };
                if cand.is_empty() {
                    break;
                }
                let mut next_backbone = backbone;
                for (rank, &tok) in cand.iter().enumerate() {
                    if rank == 0 {
                        next_backbone = nodes.len();
                    }
                    nodes.push(TreeNode {
                        token: tok as i32,
                        parent: backbone,
                        depth: level + 1,
                        level,
                        backbone: rank == 0,
                    });
                }
                backbone = next_backbone;
            }
            DraftTree { nodes, dists }
        }
        DraftOutput::Chain(mut toks, mut dists) => {
            if let Some(d) = max_depth {
                toks.truncate(d);
                dists.truncate(d);
            }
            for (level, &tok) in toks.iter().enumerate() {
                let parent = nodes.len() - 1;
                nodes.push(TreeNode {
                    token: tok,
                    parent,
                    depth: level + 1,
                    level,
                    backbone: true,
                });
            }
            DraftTree { nodes, dists }
        }
        DraftOutput::None => DraftTree { nodes, dists: vec![] },
    }
}

fn assert_trees_identical(a: &DraftTree, b: &DraftTree, ctx: &str) {
    assert_eq!(a.nodes.len(), b.nodes.len(), "{ctx}: node count");
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        assert_eq!(x.token, y.token, "{ctx}: node {i} token");
        assert_eq!(x.parent, y.parent, "{ctx}: node {i} parent");
        assert_eq!(x.depth, y.depth, "{ctx}: node {i} depth");
        assert_eq!(x.level, y.level, "{ctx}: node {i} level");
        assert_eq!(x.backbone, y.backbone, "{ctx}: node {i} backbone");
    }
    assert_eq!(a.dists, b.dists, "{ctx}: attached distributions");
}

fn random_dists(rng: &mut Pcg64, levels: usize, vocab: usize) -> Vec<Vec<f32>> {
    (0..levels)
        .map(|_| {
            let mut d: Vec<f32> = (0..vocab).map(|_| rng.next_f64() as f32 + 1e-3).collect();
            let s: f32 = d.iter().sum();
            d.iter_mut().for_each(|x| *x /= s);
            d
        })
        .collect()
}

/// The plan a pre-redesign (k, max_depth) knob pair resolves to: depth
/// defaults to the draft's native level count, branching is uniform k,
/// budget non-binding — exactly what `DraftPlan::resolve` produces for
/// an unset request.
fn equivalent_plan(k: usize, max_depth: Option<usize>, native_levels: usize) -> DraftPlan {
    DraftPlan::uniform(max_depth.unwrap_or(native_levels), k)
}

#[test]
fn static_plan_reproduces_legacy_levels_trees_greedy_and_stochastic() {
    let mut rng = Pcg64::new(41, 0);
    for case in 0..300 {
        let vocab = 4 + rng.below(24);
        let levels = 1 + rng.below(6);
        let k = 1 + rng.below(4);
        let max_depth = if rng.below(2) == 0 { None } else { Some(1 + rng.below(6)) };
        let temp = if case % 2 == 0 { 0.0 } else { 1.0 };
        let seed = case as u64;
        let dists = random_dists(&mut rng, levels, vocab);
        let pending = rng.below(vocab) as i32;

        // two samplers with the same seed: one feeds the legacy rules,
        // one the plan path — identical trees must also consume the
        // stochastic candidate stream identically
        let mut s_legacy = Sampler::new(temp, seed);
        let mut s_plan = Sampler::new(temp, seed);
        let legacy = legacy_from_draft(
            pending,
            DraftOutput::Levels(dists.clone()),
            k,
            max_depth,
            &mut s_legacy,
        );
        let plan = equivalent_plan(k, max_depth, levels);
        let planned =
            DraftTree::from_draft(pending, DraftOutput::Levels(dists), &plan, &mut s_plan);
        let ctx = format!(
            "levels case {case} (v={vocab} n={levels} k={k} depth={max_depth:?} T={temp})"
        );
        assert_trees_identical(&legacy, &planned, &ctx);
        // the sampler streams stayed in lockstep: the next draw agrees
        let probe = vec![1.0f32 / vocab as f32; vocab];
        assert_eq!(
            s_legacy.sample(&probe),
            s_plan.sample(&probe),
            "{ctx}: sampler streams diverged"
        );
    }
}

#[test]
fn static_plan_reproduces_legacy_chain_and_vanilla_trees() {
    let mut rng = Pcg64::new(42, 1);
    for case in 0..200 {
        let vocab = 4 + rng.below(16);
        let levels = 1 + rng.below(5);
        let max_depth = if rng.below(2) == 0 { None } else { Some(1 + rng.below(5)) };
        let temp = if case % 2 == 0 { 0.0 } else { 0.8 };
        let dists = random_dists(&mut rng, levels, vocab);
        let toks: Vec<i32> = (0..levels).map(|_| rng.below(vocab) as i32).collect();
        let pending = rng.below(vocab) as i32;

        // batched-lane / SpS shape: a pre-sampled chain (k irrelevant)
        let mut s_legacy = Sampler::new(temp, case as u64);
        let mut s_plan = Sampler::new(temp, case as u64);
        let legacy = legacy_from_draft(
            pending,
            DraftOutput::Chain(toks.clone(), dists.clone()),
            1,
            max_depth,
            &mut s_legacy,
        );
        let plan = equivalent_plan(1, max_depth, levels);
        let planned = DraftTree::from_draft(
            pending,
            DraftOutput::Chain(toks, dists),
            &plan,
            &mut s_plan,
        );
        let ctx = format!("chain case {case} (n={levels} depth={max_depth:?})");
        assert_trees_identical(&legacy, &planned, &ctx);

        // vanilla shape: no draft at all
        let legacy = legacy_from_draft(pending, DraftOutput::None, 3, max_depth, &mut s_legacy);
        let plan = equivalent_plan(3, max_depth, 0);
        let planned = DraftTree::from_draft(pending, DraftOutput::None, &plan, &mut s_plan);
        assert_trees_identical(&legacy, &planned, &format!("vanilla case {case}"));
    }
}
