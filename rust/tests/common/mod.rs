//! Shared artifact discovery for the integration test binaries.
//!
//! Real artifacts are located via FE_ARTIFACTS, then ./artifacts, then
//! /tmp/art_test (the dev smoke build) and run on the backend named by
//! FE_BACKEND (default PJRT; an invalid value is a hard error, matching
//! `Runtime::from_env`). When no artifact tree is present, a
//! deterministic fixture tree is generated once per process and
//! everything runs through the in-process HLO interpreter — the tests
//! never skip.

// each test binary uses a subset of these helpers
#![allow(dead_code)]

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::{Arc, OnceLock};

use fasteagle::backend::{fixture, BackendKind};
use fasteagle::runtime::{ArtifactStore, Runtime};

pub const FIXTURE_SEED: u64 = 0;

fn fixture_root() -> &'static PathBuf {
    static FIXTURE: OnceLock<PathBuf> = OnceLock::new();
    FIXTURE.get_or_init(|| {
        let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
            .join(format!("fe_fixture_{}", std::process::id()));
        fixture::generate_tree(&dir, FIXTURE_SEED).expect("generate fixture artifacts");
        dir
    })
}

/// (artifact-tree root, backend): real artifacts on the env-selected
/// backend when present, else the generated fixture on the interpreter.
pub fn artifacts_root() -> (PathBuf, BackendKind) {
    let candidates = [
        std::env::var("FE_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "/tmp/art_test".to_string(),
    ];
    let found = candidates
        .iter()
        .filter(|c| !c.is_empty())
        .map(PathBuf::from)
        .find(|p| p.join("base").join("spec.json").exists());
    match found {
        Some(p) => {
            let kind = match std::env::var("FE_BACKEND") {
                Ok(v) if !v.is_empty() => {
                    BackendKind::from_str(&v).expect("invalid FE_BACKEND")
                }
                _ => BackendKind::Pjrt,
            };
            (p, kind)
        }
        None => (fixture_root().clone(), BackendKind::Interpret),
    }
}

/// Like [`artifacts_root`], resolved to the `base` target directory.
pub fn artifacts_base() -> (PathBuf, BackendKind) {
    let (root, kind) = artifacts_root();
    (root.join("base"), kind)
}

pub fn store_with(dir: &PathBuf, kind: BackendKind) -> Rc<ArtifactStore> {
    let rt = Arc::new(Runtime::new(kind).expect("create runtime"));
    Rc::new(ArtifactStore::open(rt, dir.clone()).expect("open store"))
}
