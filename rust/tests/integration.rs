//! Integration tests over artifacts (L3 ↔ backend ↔ lowered L2/L1).
//!
//! Real artifacts are located via FE_ARTIFACTS, then ./artifacts, then
//! /tmp/art_test (the dev smoke build) and run on the backend named by
//! FE_BACKEND (default PJRT). When no artifact tree is present the
//! tests no longer skip: a deterministic fixture tree is generated once
//! per process and everything runs through the in-process HLO
//! interpreter — the full draft→verify→accept pipeline in plain
//! `cargo test`, no `xla_extension` required.

mod common;

use std::path::PathBuf;
use std::rc::Rc;

use common::{artifacts_base, artifacts_root, store_with};
use fasteagle::backend::{fixture, BackendKind};
use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod, Request, ServingMetrics};
use fasteagle::draft::make_drafter;
use fasteagle::model::{BlockPool, KvCache, MaskRow, ModelSpec, TargetModel};
use fasteagle::spec::{DraftConfig, Engine, GenConfig, PlannerKind, SlotPhase};
use fasteagle::workload::batched_serving_target;


const PROMPTS: [&str; 2] = [
    "USER: tell me about machine learning and the fast cache.\nASSISTANT:",
    "Q: Ben has 4 coins and buys 9 more coins. how many coins does Ben have?\nA:",
];

/// Core paper property: greedy speculative decoding is lossless — every
/// drafter must produce token-identical output to vanilla decoding.
#[test]
fn greedy_losslessness_all_drafters() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let cfg = GenConfig { max_new_tokens: 40, ..Default::default() };
    let mut vanilla = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), "vanilla").unwrap(),
    );
    for prompt in PROMPTS {
        let reference = vanilla.generate(prompt, &cfg).unwrap();
        for dn in [
            "fasteagle",
            "eagle3",
            "eagle2",
            "medusa",
            "sps",
            "fasteagle_par",
            "fasteagle_nofeat",
        ] {
            if !dir.join("weights").join(format!("{dn}.few")).exists() {
                continue;
            }
            let mut eng = Engine::new(
                TargetModel::open(Rc::clone(&st)).unwrap(),
                make_drafter(Rc::clone(&st), dn).unwrap(),
            );
            let r = eng.generate(prompt, &cfg).unwrap();
            assert_eq!(
                r.tokens, reference.tokens,
                "drafter {dn} diverged from vanilla on {prompt:?}\n van: {:?}\n got: {:?}",
                reference.text, r.text
            );
            assert!(r.metrics.tau() >= 1.0);
        }
    }
}

/// Chain mode (the "w/o Constrained Tree" ablation) must also be lossless.
#[test]
fn greedy_losslessness_chain_mode() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let tree_cfg = GenConfig { max_new_tokens: 32, ..Default::default() };
    let chain_cfg = GenConfig {
        max_new_tokens: 32,
        draft: DraftConfig { top_k: Some(1), ..Default::default() },
        ..Default::default()
    };
    let mut vanilla = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), "vanilla").unwrap(),
    );
    let reference = vanilla.generate(PROMPTS[0], &tree_cfg).unwrap();
    let mut eng = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), "fasteagle").unwrap(),
    );
    let r = eng.generate(PROMPTS[0], &chain_cfg).unwrap();
    assert_eq!(r.tokens, reference.tokens);
}

/// Stochastic decoding must run without error and respect basic
/// invariants (tau >= 1, requested length).
#[test]
fn stochastic_generation_invariants() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    for dn in ["fasteagle", "eagle3"] {
        let mut eng = Engine::new(
            TargetModel::open(Rc::clone(&st)).unwrap(),
            make_drafter(Rc::clone(&st), dn).unwrap(),
        );
        for seed in 0..3u64 {
            let cfg = GenConfig {
                temperature: 1.0,
                max_new_tokens: 24,
                seed,
                ..Default::default()
            };
            let r = eng.generate(PROMPTS[0], &cfg).unwrap();
            assert_eq!(r.tokens.len(), 24);
            assert!(r.metrics.tau() >= 1.0);
            // same seed reproduces exactly
            let r2 = eng.generate(PROMPTS[0], &cfg).unwrap();
            assert_eq!(r.tokens, r2.tokens, "{dn} seed {seed} not reproducible");
        }
    }
}

/// Incremental-step equivalence across the PJRT boundary: prefill(P + t)
/// must equal prefill(P) followed by a single decode step of t.
#[test]
fn prefill_step_equivalence_across_chunk_boundaries() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let tm = TargetModel::open(Rc::clone(&st)).unwrap();
    for plen in [2usize, 31, 32, 33, 40] {
        let tokens: Vec<i32> =
            std::iter::once(256).chain((0..plen - 1).map(|i| 97 + (i as i32 % 26))).collect();
        // full prefill
        let mut kv_a = tm.new_kv().unwrap();
        let full = tm.prefill(&mut kv_a, &tokens).unwrap();
        // prefill all but last, then single step
        let mut kv_b = tm.new_kv().unwrap();
        let _ = tm.prefill(&mut kv_b, &tokens[..plen - 1]).unwrap();
        let base = kv_b.len(0);
        let out = tm
            .step(
                &mut kv_b,
                &tokens[plen - 1..],
                &[(plen - 1) as i32],
                &[MaskRow { prefix_upto: base, extra: vec![base] }],
            )
            .unwrap();
        for (a, b) in full.last_logits.iter().zip(out.logits.iter()) {
            assert!((a - b).abs() < 1e-3, "plen={plen}: {a} vs {b}");
        }
    }
}

/// KV compaction must be equivalent to sequential decoding: after
/// accepting a path through the tree, continuing generation matches a
/// from-scratch vanilla run (covered via full-output equality above, and
/// here via direct cache inspection).
#[test]
fn kv_compact_then_continue_matches_sequential() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let tm = TargetModel::open(Rc::clone(&st)).unwrap();
    let prompt: Vec<i32> = vec![256, 104, 105, 106];
    // path A: feed 2 extra tokens in one verify call (chain rows), keep both
    let mut kv_a: KvCache = tm.new_kv().unwrap();
    tm.prefill(&mut kv_a, &prompt).unwrap();
    let base = kv_a.len(0);
    let out_a = tm
        .step(
            &mut kv_a,
            &[110, 111],
            &[base as i32, base as i32 + 1],
            &[
                MaskRow { prefix_upto: base, extra: vec![base] },
                MaskRow { prefix_upto: base, extra: vec![base, base + 1] },
            ],
        )
        .unwrap();
    kv_a.compact(0, base, &[0, 1]).unwrap();
    // path B: feed them one at a time
    let mut kv_b = tm.new_kv().unwrap();
    tm.prefill(&mut kv_b, &prompt).unwrap();
    for (i, t) in [110i32, 111].iter().enumerate() {
        let b = kv_b.len(0);
        let _ = tm
            .step(
                &mut kv_b,
                &[*t],
                &[(base + i) as i32],
                &[MaskRow { prefix_upto: b, extra: vec![b] }],
            )
            .unwrap();
        kv_b.set_len(0, b + 1);
    }
    assert_eq!(kv_a.len(0), kv_b.len(0));
    // a further identical step on both caches must agree
    let rows = [MaskRow { prefix_upto: kv_a.len(0), extra: vec![kv_a.len(0)] }];
    let pa = tm.step(&mut kv_a, &[112], &[(base + 2) as i32], &rows).unwrap();
    let pb = tm.step(&mut kv_b, &[112], &[(base + 2) as i32], &rows).unwrap();
    for (a, b) in pa.logits.iter().zip(pb.logits.iter()) {
        assert!((a - b).abs() < 1e-3);
    }
    let _ = out_a;
}

/// The acceptance path runs end-to-end on whatever backend is active:
/// at least one full draft→verify→accept cycle completes, and greedy
/// decode is exactly reproducible — two fresh engines over the same
/// artifacts produce token-identical output.
#[test]
fn end_to_end_cycles_and_exact_greedy_reproducibility() {
    let (dir, kind) = artifacts_base();
    let cfg = GenConfig { max_new_tokens: 24, ..Default::default() };
    let mut tokens_runs = Vec::new();
    for _ in 0..2 {
        // fresh store + engine: nothing carries over but the artifacts
        let st = store_with(&dir, kind);
        let mut eng = Engine::new(
            TargetModel::open(Rc::clone(&st)).unwrap(),
            make_drafter(Rc::clone(&st), "fasteagle").unwrap(),
        );
        let r = eng.generate(PROMPTS[0], &cfg).unwrap();
        assert!(r.metrics.cycles >= 1, "no draft→verify→accept cycle ran");
        assert_eq!(r.tokens.len(), 24);
        tokens_runs.push(r.tokens);
    }
    assert_eq!(tokens_runs[0], tokens_runs[1], "greedy decode not reproducible");
}

/// Fixture generation is a pure function of the seed: two trees from
/// the same seed are byte-identical (and decode identically through the
/// interpreter); a different seed changes the weights.
#[test]
fn fixture_trees_are_seed_deterministic() {
    let base = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("fe_fixture_det_{}", std::process::id()));
    let (a, b, c) = (base.join("a"), base.join("b"), base.join("c"));
    fixture::generate_tree(&a, 7).unwrap();
    fixture::generate_tree(&b, 7).unwrap();
    fixture::generate_tree(&c, 8).unwrap();
    for rel in [
        "base/spec.json",
        "base/hlo/tgt_m8.hlo.txt",
        "base/hlo/fe_t8.io.json",
        "base/weights/target.few",
        "base/weights/fasteagle.few",
    ] {
        let fa = std::fs::read(a.join(rel)).unwrap();
        let fb = std::fs::read(b.join(rel)).unwrap();
        assert_eq!(fa, fb, "{rel} differs between same-seed trees");
    }
    assert_ne!(
        std::fs::read(a.join("base/weights/target.few")).unwrap(),
        std::fs::read(c.join("base/weights/target.few")).unwrap(),
        "different seeds must produce different weights"
    );
    // same seed ⇒ identical greedy decode through the interpreter
    let cfg = GenConfig { max_new_tokens: 12, ..Default::default() };
    let mut out = Vec::new();
    for root in [&a, &b] {
        let st = store_with(&root.join("base"), BackendKind::Interpret);
        let mut eng = Engine::new(
            TargetModel::open(Rc::clone(&st)).unwrap(),
            make_drafter(Rc::clone(&st), "fasteagle").unwrap(),
        );
        out.push(eng.generate(PROMPTS[1], &cfg).unwrap().tokens);
    }
    assert_eq!(out[0], out[1]);
}

/// Batch engine at B=1 must agree with the single-request engine's
/// vanilla output (same greedy stream), complete a multi-request queue,
/// and honor per-request generation parameters (max_new_tokens differs
/// across the queue).
#[test]
fn batch_engine_b1_matches_single_engine() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let cfg = GenConfig { max_new_tokens: 24, ..Default::default() };
    let mut vanilla = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), "vanilla").unwrap(),
    );
    let reference = vanilla.generate(PROMPTS[0], &cfg).unwrap();
    for method in [BatchMethod::Vanilla, BatchMethod::FastEagle, BatchMethod::Eagle3] {
        let mut eng =
            BatchEngine::new(Rc::clone(&st), BatchConfig::new(1, method)).unwrap();
        let reqs: Vec<Request> = (0..3)
            .map(|i| {
                let mut r = Request::new(i, PROMPTS[0]);
                // request 2 asks for a shorter generation than the rest
                r.cfg.max_new_tokens = if i == 2 { 12 } else { 24 };
                r
            })
            .collect();
        let (resps, m) = eng.run(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        for r in &resps {
            if r.id == 2 {
                assert_eq!(r.new_tokens, 12, "per-request max_new not honored");
            } else {
                assert_eq!(r.new_tokens, 24);
                assert_eq!(
                    r.text, reference.text,
                    "batch {:?} diverged from single-engine vanilla",
                    method
                );
            }
        }
        assert_eq!(m.requests_done, 3);
        assert!(m.mean_occupancy() > 0.0);
    }
}

/// The DraftPlan resolution path is identity-preserving: spelling the
/// spec defaults out as explicit static-planner knobs must reproduce
/// the default config's output byte-for-byte, greedy and stochastic.
#[test]
fn static_planner_explicit_knobs_match_default_output() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    for (dn, temp) in [("fasteagle", 0.0f32), ("eagle3", 0.0), ("fasteagle", 1.0)] {
        let mut eng = Engine::new(
            TargetModel::open(Rc::clone(&st)).unwrap(),
            make_drafter(Rc::clone(&st), dn).unwrap(),
        );
        let base_cfg = GenConfig {
            max_new_tokens: 20,
            temperature: temp,
            seed: 7,
            ..Default::default()
        };
        let reference = eng.generate(PROMPTS[0], &base_cfg).unwrap();
        let explicit = GenConfig {
            draft: DraftConfig {
                planner: Some(PlannerKind::Static),
                depth: Some(eng.drafter.depth()),
                top_k: Some(eng.target.spec.tree_top_k),
                budget: None,
            },
            ..base_cfg
        };
        let r = eng.generate(PROMPTS[0], &explicit).unwrap();
        assert_eq!(
            r.tokens, reference.tokens,
            "{dn} T={temp}: explicit static plan diverged from the defaults"
        );
    }
}

/// AdaEAGLE-style adaptive drafting on the session API: the per-cycle
/// tree shape must actually move (the planner reacts to acceptance),
/// the per-cycle events must reassemble the output byte-for-byte, and
/// greedy output must stay byte-identical to the static planner's.
#[test]
fn adaptive_planner_reshapes_cycles_and_stays_byte_identical() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let mut shapes_varied = false;
    for dn in ["fasteagle", "eagle3"] {
        for prompt in PROMPTS {
            let static_cfg = GenConfig { max_new_tokens: 32, ..Default::default() };
            let adaptive_cfg = GenConfig {
                max_new_tokens: 32,
                draft: DraftConfig {
                    planner: Some(PlannerKind::Adaptive),
                    ..Default::default()
                },
                ..Default::default()
            };
            let mut eng = Engine::new(
                TargetModel::open(Rc::clone(&st)).unwrap(),
                make_drafter(Rc::clone(&st), dn).unwrap(),
            );
            let reference = eng.generate(prompt, &static_cfg).unwrap();
            let mut session = eng.start_session(prompt, &adaptive_cfg).unwrap();
            let mut shapes = std::collections::BTreeSet::new();
            let mut streamed: Vec<i32> = Vec::new();
            while !session.finished() {
                let ev = session.step().unwrap();
                shapes.insert((session.cycle.plan.depth, session.cycle.plan.k_for(0)));
                streamed.extend(ev.committed_tokens);
            }
            let r = session.finish();
            assert_eq!(streamed, r.tokens, "cycle events must reassemble the output");
            assert_eq!(
                r.tokens, reference.tokens,
                "{dn} on {prompt:?}: adaptive drafting must stay lossless at T=0"
            );
            if shapes.len() >= 2 {
                shapes_varied = true;
            }
        }
    }
    assert!(
        shapes_varied,
        "the adaptive planner never changed the per-cycle tree shape on any run"
    );
}

/// Adaptive drafting on the continuous batcher: per-slot plans must
/// vary (observable through the plan gauges), streamed per-cycle
/// events must reassemble each request's final text byte-for-byte, and
/// greedy output must match the static-planner run exactly.
#[test]
fn adaptive_planner_varies_batched_slots_and_streams_reassemble() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let st = store_with(&dir, kind);
    let make_reqs = || -> Vec<Request> {
        (0..4)
            .map(|i| {
                let mut r = Request::new(i, PROMPTS[i as usize % 2]);
                r.cfg.max_new_tokens = 20;
                r
            })
            .collect()
    };

    // static reference: plans never move
    let mut eng_s = BatchEngine::new(
        Rc::clone(&st),
        BatchConfig::new(batch, BatchMethod::FastEagle),
    )
    .unwrap();
    let (mut ref_resps, m_s) = eng_s.run(make_reqs()).unwrap();
    ref_resps.sort_by_key(|r| r.id);
    assert!(m_s.plan_samples > 0, "static run records plan decisions");
    assert_eq!(
        m_s.plan_depth_min, m_s.plan_depth_max,
        "a static plan must never change shape"
    );

    // adaptive run, stepped manually so per-cycle events are visible
    let mut cfg = BatchConfig::new(batch, BatchMethod::FastEagle);
    cfg.draft.planner = Some(PlannerKind::Adaptive);
    let mut eng = BatchEngine::new(Rc::clone(&st), cfg).unwrap();
    for r in make_reqs() {
        eng.submit(r);
    }
    let mut metrics = ServingMetrics::default();
    let mut finished = Vec::new();
    let mut streamed: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
    while eng.has_work() {
        let out = eng.step_events(&mut metrics).unwrap();
        for ev in &out.events {
            streamed.entry(ev.id).or_default().extend(ev.tokens.iter().copied());
        }
        finished.extend(out.finished);
    }
    finished.sort_by_key(|r| r.id);
    assert_eq!(finished.len(), ref_resps.len());
    for (a, b) in finished.iter().zip(&ref_resps) {
        assert_eq!(a.id, b.id);
        assert!(a.error.is_none(), "{:?}", a.error);
        assert_eq!(
            a.text, b.text,
            "request {}: adaptive drafting must stay lossless at T=0",
            a.id
        );
        assert_eq!(
            eng.decode(&streamed[&a.id]),
            a.text,
            "request {}: streamed cycles must reassemble the text byte-for-byte",
            a.id
        );
    }
    assert!(
        metrics.plan_depth_max > metrics.plan_depth_min,
        "the adaptive planner never changed shape on any slot \
         (depth stayed at {})",
        metrics.plan_depth_max
    );
    assert!(
        metrics.accept_window_samples > 0,
        "adaptive slots must report their acceptance window"
    );
}

/// Mixed-method fleet: one pool serves a fasteagle and a vanilla
/// request side by side. Per-method KV lease accounting (fasteagle
/// leases its drafter layers, vanilla none), concurrent occupancy when
/// batched executables exist, out-of-order completion, and the vanilla
/// slot's output still matches the single-request vanilla engine.
#[test]
fn mixed_method_fleet_shares_one_pool() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let st = store_with(&dir, kind);

    // single-engine vanilla reference for the vanilla slot's output
    let short_cfg = GenConfig { max_new_tokens: 6, ..Default::default() };
    let mut vanilla = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), "vanilla").unwrap(),
    );
    let reference = vanilla.generate(PROMPTS[1], &short_cfg).unwrap();

    let mut eng = BatchEngine::new(
        Rc::clone(&st),
        BatchConfig::new(batch, BatchMethod::FastEagle),
    )
    .unwrap();
    let fe_cost = eng.request_blocks(BatchMethod::FastEagle);
    let van_cost = eng.request_blocks(BatchMethod::Vanilla);
    assert!(
        fe_cost > van_cost,
        "fasteagle leases drafter KV layers on top of the target's ({fe_cost} vs {van_cost})"
    );
    let total = eng.pool_total();

    // long fasteagle request (engine default method), short vanilla one
    // (per-request override) — the vanilla request is admitted second
    let mut r_fe = Request::new(0, PROMPTS[0]);
    r_fe.cfg.max_new_tokens = 24;
    let mut r_van = Request::new(1, PROMPTS[1]);
    r_van.method = Some(BatchMethod::Vanilla);
    r_van.cfg.max_new_tokens = 6;
    eng.submit(r_fe);
    eng.submit(r_van);

    let mut metrics = fasteagle::coordinator::ServingMetrics::default();
    let mut done = Vec::new();
    let mut saw_both_active = false;
    while done.len() < 2 {
        let step = eng.step(&mut metrics).unwrap();
        if eng.active_len() == 2 {
            saw_both_active = true;
            // both leases held at their method-specific cost
            assert_eq!(eng.pool_available(), total - fe_cost - van_cost);
        }
        done.extend(step);
        assert!(eng.has_work() || done.len() == 2);
    }
    assert_eq!(eng.pool_available(), total, "all leases released on retire");

    let van = done.iter().find(|r| r.id == 1).unwrap();
    let fe = done.iter().find(|r| r.id == 0).unwrap();
    assert!(van.error.is_none() && fe.error.is_none());
    assert_eq!(van.new_tokens, 6);
    assert_eq!(fe.new_tokens, 24);
    assert!(fe.tau >= 1.0);
    assert_eq!(
        van.text, reference.text,
        "vanilla slot in a mixed pool must match the single-request vanilla engine"
    );
    if batch >= 2 {
        assert!(saw_both_active, "mixed-method requests must occupy slots concurrently");
        assert_eq!(
            done[0].id, 1,
            "short vanilla request (admitted second) completes out of admission order"
        );
    }
    assert_eq!(metrics.requests_done, 2);
}

/// Pool-constrained batch run must still finish everything (requests
/// queue rather than fail), and with a single slot nothing is ever
/// pool-deferred (deferrals require a free slot blocked on blocks).
#[test]
fn batch_engine_respects_block_pool() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let mut cfg = BatchConfig::new(1, BatchMethod::FastEagle);
    // exactly one request's worth of blocks
    let spec = fasteagle::model::ModelSpec::parse(&st.spec_json().unwrap()).unwrap();
    let probe = fasteagle::model::BlockPool::new(1, cfg.block_slots);
    cfg.pool_blocks =
        Some(probe.blocks_for(spec.max_seq, spec.n_layers + spec.draft_depth));
    let mut eng = BatchEngine::new(Rc::clone(&st), cfg).unwrap();
    let reqs: Vec<Request> = (0..2)
        .map(|i| {
            let mut r = Request::new(i, PROMPTS[1]);
            r.cfg.max_new_tokens = 12;
            r
        })
        .collect();
    let (resps, m) = eng.run(reqs).unwrap();
    assert_eq!(resps.len(), 2);
    assert_eq!(m.requests_deferred, 0);
}

/// Step-driven scheduling: submitting mid-flight works, and a request
/// whose slot frees up is admitted on the next step.
#[test]
fn batch_engine_step_admits_mid_flight_submissions() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    let mut eng = BatchEngine::new(
        Rc::clone(&st),
        BatchConfig::new(1, BatchMethod::FastEagle),
    )
    .unwrap();
    let mut metrics = fasteagle::coordinator::ServingMetrics::default();
    let mut r0 = Request::new(0, PROMPTS[0]);
    r0.cfg.max_new_tokens = 8;
    eng.submit(r0);
    let mut done = Vec::new();
    // drive a few steps, then submit a second request while the first
    // may still be in flight
    let mut submitted_second = false;
    while done.len() < 2 {
        done.extend(eng.step(&mut metrics).unwrap());
        if !submitted_second {
            let mut r1 = Request::new(1, PROMPTS[1]);
            r1.cfg.max_new_tokens = 8;
            eng.submit(r1);
            submitted_second = true;
        }
        assert!(eng.has_work() || done.len() == 2);
    }
    assert_eq!(done.len(), 2);
    assert!(done.iter().any(|r| r.id == 0));
    assert!(done.iter().any(|r| r.id == 1));
    assert_eq!(metrics.requests_done, 2);
    assert_eq!(metrics.queue_wait.count(), 2);
    assert_eq!(metrics.ttfc.count(), 2);
}

/// Chunked prefill on the batched lane: admitting a long prompt must
/// not head-of-line-block a decoding slot. While the long request is
/// still `Prefilling` (its prompt ingested in verify-row-sized chunks),
/// the already-running request keeps committing tokens in the same
/// steps — and the long request still completes with its full output.
#[test]
fn chunked_prefill_admits_long_prompt_while_decode_commits() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    if batch < 2 {
        eprintln!("skipping: serving target has no batched executables");
        return;
    }
    let st = store_with(&dir, kind);
    let mut eng = BatchEngine::new(
        Rc::clone(&st),
        BatchConfig::new(batch, BatchMethod::FastEagle),
    )
    .unwrap();
    let mut metrics = fasteagle::coordinator::ServingMetrics::default();

    // request A: short prompt, long generation — gets decoding first
    let mut ra = Request::new(0, PROMPTS[1]);
    ra.cfg.max_new_tokens = 48;
    eng.submit(ra);
    // drive A through its own prefill into decode
    for _ in 0..200 {
        let _ = eng.step_events(&mut metrics).unwrap();
        if eng.slot_phase(0) == Some(SlotPhase::Decoding) {
            break;
        }
    }
    assert_eq!(eng.slot_phase(0), Some(SlotPhase::Decoding), "A never reached decode");

    // request B: long prompt (many chunks), short generation
    let long_prompt = "the quick brown fox jumps over the lazy dog. ".repeat(2)
        + "USER: summarize the fast cache design.\nASSISTANT:";
    let mut rb = Request::new(1, long_prompt);
    rb.cfg.max_new_tokens = 4;
    eng.submit(rb);

    let mut overlap_steps = 0usize;
    let mut a_tokens_during_b_prefill = 0usize;
    let mut done = Vec::new();
    for _ in 0..500 {
        let b_slot = (0..batch).find(|&b| {
            eng.slot_phase(b) == Some(SlotPhase::Prefilling)
        });
        let out = eng.step_events(&mut metrics).unwrap();
        if b_slot.is_some() {
            // a step where B was still ingesting prompt chunks: count
            // tokens A committed in that same step
            let a_commits: usize = out
                .events
                .iter()
                .filter(|e| e.id == 0)
                .map(|e| e.tokens.len())
                .sum();
            if a_commits > 0 {
                overlap_steps += 1;
                a_tokens_during_b_prefill += a_commits;
            }
        }
        done.extend(out.finished);
        if done.len() == 2 {
            break;
        }
    }
    assert_eq!(done.len(), 2, "both requests must complete");
    assert!(done.iter().all(|r| r.error.is_none()));
    assert!(
        overlap_steps >= 2,
        "decode must keep committing while the long prompt prefills \
         (saw {overlap_steps} overlapping steps)"
    );
    assert!(a_tokens_during_b_prefill >= 2);
    assert!(
        metrics.prefill_chunks > 2,
        "a long prompt must take multiple chunks (got {})",
        metrics.prefill_chunks
    );
    let b = done.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(b.new_tokens, 4, "chunked-prefilled request still generates fully");
}

/// Batching must be invisible to each request: a request admitted
/// mid-flight — finishing its chunked prefill in the very step another
/// same-method slot commits (and observes) — must produce the same
/// output *and the same per-cycle acceptance (tau)* as running alone.
/// Guards the drafter-state isolation between lanes: the step's
/// batched observe writes rows into every lane of the method's state
/// tensor, so a newly prefilled slot's drafter KV must be installed
/// after those writes, not before.
#[test]
fn staggered_same_method_admission_is_batch_invariant() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    if batch < 2 {
        eprintln!("skipping: needs concurrent lanes");
        return;
    }
    let st = store_with(&dir, kind);
    // B: short prompt (finalizes within a few chunks, while A decodes)
    let short_prompt = "Q: hi\nA:";
    let solo = |prompt: &str, id: u64, max_new: usize| {
        let mut eng = BatchEngine::new(
            Rc::clone(&st),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let mut r = Request::new(id, prompt);
        r.cfg.max_new_tokens = max_new;
        let (resps, _) = eng.run(vec![r]).unwrap();
        resps.into_iter().next().unwrap()
    };
    let ref_a = solo(PROMPTS[0], 0, 64);
    let ref_b = solo(short_prompt, 1, 24);

    let mut eng = BatchEngine::new(
        Rc::clone(&st),
        BatchConfig::new(batch, BatchMethod::FastEagle),
    )
    .unwrap();
    let mut metrics = fasteagle::coordinator::ServingMetrics::default();
    let mut ra = Request::new(0, PROMPTS[0]);
    ra.cfg.max_new_tokens = 64;
    eng.submit(ra);
    for _ in 0..200 {
        let _ = eng.step(&mut metrics).unwrap();
        if eng.slot_phase(0) == Some(SlotPhase::Decoding) {
            break;
        }
    }
    assert_eq!(eng.slot_phase(0), Some(SlotPhase::Decoding));
    let mut rb = Request::new(1, short_prompt);
    rb.cfg.max_new_tokens = 24;
    eng.submit(rb);
    let mut done = Vec::new();
    for _ in 0..1000 {
        done.extend(eng.step(&mut metrics).unwrap());
        if done.len() == 2 {
            break;
        }
    }
    assert_eq!(done.len(), 2);
    for (resp, reference) in [
        (done.iter().find(|r| r.id == 0).unwrap(), &ref_a),
        (done.iter().find(|r| r.id == 1).unwrap(), &ref_b),
    ] {
        assert!(resp.error.is_none());
        assert_eq!(resp.text, reference.text, "batching changed request {}", resp.id);
        assert_eq!(
            resp.cycles, reference.cycles,
            "request {}: cycle count (draft quality) changed under batching",
            resp.id
        );
        assert!(
            (resp.tau - reference.tau).abs() < 1e-9,
            "request {}: tau changed under batching ({} vs {})",
            resp.id,
            resp.tau,
            reference.tau
        );
    }
}

/// Preemption invariants, property-style across methods: pausing a
/// low-priority request under pool pressure (lease shrunk to its
/// committed tokens, state parked) and resuming it later must produce
/// byte-identical output to an undisturbed run — including the
/// stochastic sampler stream — and the block pool must balance to zero
/// leaked blocks once everything drains.
#[test]
fn preemption_pause_resume_byte_identity_and_pool_balance() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    if batch < 2 {
        eprintln!("skipping: preemption needs a second lane to admit into");
        return;
    }
    let st = store_with(&dir, kind);
    let spec = ModelSpec::parse(&st.spec_json().unwrap()).unwrap();
    let block_slots = 16usize;
    let probe = BlockPool::new(1, block_slots);
    let fe_full = probe.blocks_for(spec.max_seq, spec.n_layers + spec.draft_depth);

    for (trial, victim_method) in
        [BatchMethod::Vanilla, BatchMethod::FastEagle, BatchMethod::Eagle3]
            .into_iter()
            .enumerate()
    {
        // the victim request: low priority, stochastic (so byte-identity
        // also proves the sampler stream survives the pause)
        let make_victim = || {
            let mut r = Request::new(10, PROMPTS[0]);
            r.method = Some(victim_method);
            r.cfg.max_new_tokens = 20;
            r.cfg.temperature = 1.0;
            r.cfg.seed = 7 + trial as u64;
            r.priority = 0;
            r
        };

        // reference: the same request, alone, on an unconstrained engine
        let reference = {
            let mut eng = BatchEngine::new(
                Rc::clone(&st),
                BatchConfig::new(batch, BatchMethod::FastEagle),
            )
            .unwrap();
            let (resps, _) = eng.run(vec![make_victim()]).unwrap();
            resps.into_iter().next().unwrap()
        };

        // constrained pool: sized so the high-priority fasteagle request
        // can only be funded by shrinking the victim's lease down to its
        // committed prefix (fe_full + the victim's worst-case committed
        // cost), whatever step the preemption lands on
        let victim_layers =
            spec.n_layers + victim_method.drafter_kv_layers(&spec);
        let victim_rows_max = PROMPTS[0].len() + 1 + 20 + 8;
        let victim_cost_max = probe.blocks_for(victim_rows_max, victim_layers);
        let victim_full = probe.blocks_for(spec.max_seq, victim_layers);
        assert!(
            victim_cost_max < victim_full,
            "fixture too small for a meaningful shrink"
        );
        let mut cfg = BatchConfig::new(batch, BatchMethod::FastEagle);
        cfg.pool_blocks = Some(fe_full + victim_cost_max);
        cfg.block_slots = block_slots;
        let mut eng = BatchEngine::new(Rc::clone(&st), cfg).unwrap();
        let total = eng.pool_total();

        let mut metrics = fasteagle::coordinator::ServingMetrics::default();
        eng.submit(make_victim());
        // let the victim get decoding and commit a few cycles
        for _ in 0..300 {
            let _ = eng.step(&mut metrics).unwrap();
            if eng.slot_phase(0) == Some(SlotPhase::Decoding) {
                break;
            }
        }
        for _ in 0..3 {
            let _ = eng.step(&mut metrics).unwrap();
        }

        // high-priority fasteagle request arrives: under this pool it
        // can only admit by preempting the victim
        let mut hi = Request::new(20, PROMPTS[1]);
        hi.cfg.max_new_tokens = 8;
        hi.priority = 5;
        eng.submit(hi);

        let mut done = Vec::new();
        for _ in 0..1000 {
            done.extend(eng.step(&mut metrics).unwrap());
            if done.len() == 2 {
                break;
            }
            assert!(eng.has_work(), "engine drained without finishing both");
        }
        assert_eq!(done.len(), 2, "[{victim_method:?}] both must finish");
        assert!(done.iter().all(|r| r.error.is_none()));
        assert!(
            metrics.preemptions >= 1,
            "[{victim_method:?}] pool pressure must have preempted the victim"
        );
        assert_eq!(
            metrics.resumes, metrics.preemptions,
            "every pause must be matched by a resume"
        );
        assert!(metrics.parked_tokens_peak > 0, "parked tokens were gauged");
        assert_eq!(
            metrics.parked_tokens, 0,
            "nothing stays parked after the drain"
        );
        // the high-priority request finished first (that's what the
        // preemption bought)
        assert_eq!(done[0].id, 20, "[{victim_method:?}] priority served first");
        // byte-identity: pause/resume must not change a single token of
        // the victim's (stochastic) output
        let victim = done.iter().find(|r| r.id == 10).unwrap();
        assert_eq!(victim.new_tokens, reference.new_tokens);
        assert_eq!(
            victim.text, reference.text,
            "[{victim_method:?}] pause/resume changed the committed output"
        );
        // pool accounting balances to zero on drain: every lease —
        // full, shrunk, regrown — returned
        assert_eq!(eng.pool_available(), total, "[{victim_method:?}] leaked blocks");
        assert_eq!(eng.parked_len(), 0);
    }
}
