//! Request-lifecycle correctness: cancellation in every phase
//! (pending, mid-prefill, mid-decode, already completed) across all
//! three speculative methods, with the KV-lease and prefix-cache
//! refcount invariant checked after each storm — every pool block must
//! come home (`leaked_blocks() == 0`). A second test drives the same
//! verbs over the TCP wire: `{"cmd":"cancel","req":N}` mid-stream,
//! `"deadline_ms"` expiry, `{"cmd":"drain"}`, and the drained server's
//! clean (leak-checked) exit.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use common::{artifacts_base, artifacts_root, store_with};
use fasteagle::coordinator::{
    BatchConfig, BatchEngine, BatchMethod, CancelOutcome, Request, Server, ServerConfig,
    ServingMetrics,
};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::spec::SlotPhase;
use fasteagle::util::json::Json;
use fasteagle::workload::batched_serving_target;

const PROMPT: &str = "USER: tell me about machine learning and the fast cache.\nASSISTANT:";

fn req(id: u64, max_new: usize) -> Request {
    let mut r = Request::new(id, PROMPT);
    r.cfg.max_new_tokens = max_new;
    r
}

#[test]
fn cancel_every_phase_releases_all_blocks_for_every_method() {
    let (dir, kind) = artifacts_base();
    let st = store_with(&dir, kind);
    for method in [BatchMethod::FastEagle, BatchMethod::Eagle3, BatchMethod::Vanilla] {
        let mut cfg = BatchConfig::new(1, method);
        // tiny chunks keep the slot in Prefilling across many steps, so
        // the mid-prefill cancel is deterministic, and the cache-on
        // engine exercises the refcounted (shared-block) release path
        cfg.prefill_chunk = 2;
        cfg.prefix_cache = true;
        let mut eng = BatchEngine::new(Rc::clone(&st), cfg).unwrap();
        let mut m = ServingMetrics::default();

        // batch=1: req 1 takes the slot, req 2 stays pending
        eng.submit(req(1, 8));
        eng.submit(req(2, 8));
        let done = eng.step(&mut m).unwrap();
        assert!(done.is_empty(), "{method:?}: nothing finishes on step 1");
        assert_eq!(eng.pending_len(), 1, "{method:?}: req 2 waits behind the slot");
        assert_eq!(eng.cancel(2, &mut m), CancelOutcome::Pending, "{method:?}");
        assert_eq!(eng.pending_len(), 0);

        // mid-prefill: the prompt is far longer than one 2-token chunk
        assert_eq!(
            eng.slot_phase(0),
            Some(SlotPhase::Prefilling),
            "{method:?}: slot must still be ingesting the prompt"
        );
        assert_eq!(eng.cancel(1, &mut m), CancelOutcome::Active, "{method:?}");
        assert_eq!(eng.active_len(), 0, "{method:?}: slot freed by cancel");

        // mid-decode: step until the slot crosses into Decoding, then
        // cancel before it can finish (12 tokens need several cycles)
        eng.submit(req(3, 12));
        loop {
            let done = eng.step(&mut m).unwrap();
            assert!(done.is_empty(), "{method:?}: req 3 finished before the cancel");
            if eng.slot_phase(0) == Some(SlotPhase::Decoding) {
                break;
            }
        }
        assert_eq!(eng.cancel(3, &mut m), CancelOutcome::Active, "{method:?}");

        // completed: run req 4 to retirement, then cancel it — a
        // definitive not-found, never an error
        eng.submit(req(4, 6));
        let resp = loop {
            if let Some(r) = eng.step(&mut m).unwrap().into_iter().next() {
                break r;
            }
        };
        assert!(resp.error.is_none(), "{method:?}: {:?}", resp.error);
        assert_eq!(resp.id, 4);
        assert_eq!(resp.new_tokens, 6, "{method:?}: cancels must not corrupt the slot");
        let out = eng.cancel(4, &mut m);
        assert_eq!(out, CancelOutcome::NotFound, "{method:?}");
        assert!(!out.found());

        assert_eq!(m.requests_canceled, 3, "{method:?}");
        assert_eq!(m.requests_done, 1, "{method:?}");

        // the refcount invariant: after the cache drops its shares,
        // every lease and shared block is back in the pool
        eng.release_cache();
        assert_eq!(eng.cache_usage(), (0, 0), "{method:?}: cache cleared");
        assert_eq!(eng.leaked_blocks(), 0, "{method:?}: pool blocks leaked");
    }
}

fn query_at(addr: &str, line: &str) -> Json {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut r = BufReader::new(stream);
    let mut out = String::new();
    r.read_line(&mut out).unwrap();
    Json::parse(out.trim()).expect("json response")
}

fn wait_for_listener(addr: &str) {
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("server did not start on {addr}");
}

#[test]
fn tcp_cancel_deadline_and_drain_lifecycle() {
    const ADDR: &str = "127.0.0.1:7441";
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: ADDR.into(),
            queue_capacity: 8,
            frame_queue: 16,
            replica_id: 3,
        });
        // serve() itself enforces the drained-exit leak invariant: it
        // bails (-> this unwrap panics) if any pool block is still out
        server.serve(engine).unwrap()
    });
    wait_for_listener(ADDR);

    // stats carries the fleet-identity fields the router consumes
    let v = query_at(ADDR, r#"{"cmd":"stats"}"#);
    assert_eq!(v.get("replica_id").and_then(Json::as_usize), Some(3));
    assert!(v.get("uptime_ms").and_then(Json::as_f64).is_some());
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(false));
    assert_eq!(v.get("active").and_then(Json::as_usize), Some(0));
    assert_eq!(v.get("queued").and_then(Json::as_usize), Some(0));

    // unknown verbs die structured, naming the field
    let v = query_at(ADDR, r#"{"cmd":"reboot"}"#);
    assert!(v.get("error").and_then(Json::as_str).unwrap().contains("reboot"));
    assert_eq!(v.get("field").and_then(Json::as_str), Some("cmd"));
    let v = query_at(ADDR, r#"{"cmd":7}"#);
    assert_eq!(v.get("field").and_then(Json::as_str), Some("cmd"));

    // deadline_ms binds mid-generation: 1ms can never cover a 200-token
    // generation, so the deadline sweep evicts it with a structured
    // error (and the lease comes back — checked at drained exit below)
    let v = query_at(
        ADDR,
        &format!(r#"{{"prompt":{:?},"max_new":200,"deadline_ms":1}}"#, PROMPT),
    );
    assert_eq!(
        v.get("error").and_then(Json::as_str),
        Some("deadline exceeded"),
        "{v:?}"
    );

    // wire cancel of a live streamed request: the client must get a
    // structured "canceled" final line, not a hang or a dropped socket
    let streamer = std::thread::spawn(move || {
        let stream = TcpStream::connect(ADDR).unwrap();
        let mut w = stream.try_clone().unwrap();
        writeln!(w, r#"{{"prompt":{PROMPT:?},"max_new":200,"stream":true}}"#).unwrap();
        let mut r = BufReader::new(stream);
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let v = Json::parse(line.trim()).expect("json line");
            if v.get("event").is_none() {
                break v;
            }
        }
    });
    std::thread::sleep(Duration::from_millis(300));
    // ids are assigned in admission order: the deadline request was 1,
    // the streamed one is 2
    let v = query_at(ADDR, r#"{"cmd":"cancel","req":2}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true), "{v:?}");
    assert_eq!(v.get("req").and_then(Json::as_usize), Some(2));
    assert_eq!(v.get("was").and_then(Json::as_str), Some("active"));
    let final_resp = streamer.join().unwrap();
    assert_eq!(
        final_resp.get("error").and_then(Json::as_str),
        Some("canceled"),
        "{final_resp:?}"
    );

    // canceling it again (or any unknown id) is a definitive not_found
    let v = query_at(ADDR, r#"{"cmd":"cancel","req":2}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(v.get("was").and_then(Json::as_str), Some("not_found"));
    // and a malformed req id names the field
    let v = query_at(ADDR, r#"{"cmd":"cancel","req":-4}"#);
    assert_eq!(v.get("field").and_then(Json::as_str), Some("req"));

    // drain: admission stops, cmds still answer, and once idle the
    // server exits cleanly with every block accounted for
    let v = query_at(ADDR, r#"{"cmd":"drain"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(true));
    let v = query_at(ADDR, r#"{"prompt":"p","max_new":4}"#);
    assert!(
        v.get("error").and_then(Json::as_str).unwrap().contains("draining"),
        "{v:?}"
    );
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(true));
    let v = query_at(ADDR, r#"{"cmd":"stats"}"#);
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(true));
    assert_eq!(v.get("requests_canceled").and_then(Json::as_usize), Some(1));
    assert_eq!(v.get("requests_expired").and_then(Json::as_usize), Some(1));

    let metrics = server_thread.join().unwrap();
    assert_eq!(metrics.requests_canceled, 1);
    assert_eq!(metrics.requests_expired, 1);
    assert_eq!(metrics.requests_done, 0);
}
