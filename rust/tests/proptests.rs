//! Hand-rolled property tests (the offline registry has no proptest —
//! DESIGN.md §Substitutions) over the coordinator-side invariants that
//! don't need artifacts: tree construction (Backbone Expansion), lossless
//! acceptance, KV compaction, the paged pool, the admission queue under
//! thread contention, and the JSON substrate. Seeded PCG sweeps, hundreds
//! of cases each.

use std::sync::Arc;

use fasteagle::coordinator::{AdmissionQueue, PushError};
use fasteagle::model::{BlockPool, KvCache, Lease};
use fasteagle::spec::{verify_tree, DraftTree, Sampler};
use fasteagle::util::json::Json;
use fasteagle::util::rng::Pcg64;

fn random_dist(rng: &mut Pcg64, v: usize) -> Vec<f32> {
    let mut d: Vec<f32> = (0..v).map(|_| (rng.next_f64() as f32).powi(2) + 1e-4).collect();
    let s: f32 = d.iter().sum();
    d.iter_mut().for_each(|x| *x /= s);
    d
}

/// Acceptance over random trees/targets: the accepted slots always form
/// a root-anchored, strictly ascending path; the bonus is a valid token;
/// depth events match the path length.
#[test]
fn acceptance_path_invariants_random_sweep() {
    let mut rng = Pcg64::new(2024, 0);
    for case in 0..400 {
        let v = 8 + rng.below(48);
        let depth = 1 + rng.below(6);
        let k = 1 + rng.below(3);
        let dists: Vec<Vec<f32>> = (0..depth).map(|_| random_dist(&mut rng, v)).collect();
        let tree = DraftTree::backbone_expansion(rng.below(v) as i32, dists, k);
        let target: Vec<Vec<f32>> =
            (0..tree.len()).map(|_| random_dist(&mut rng, v)).collect();
        let greedy = case % 2 == 0;
        let mut sampler = Sampler::new(if greedy { 0.0 } else { 1.0 }, case as u64);
        let target = if greedy {
            target
                .into_iter()
                .map(|d| {
                    let mut one = vec![0.0; d.len()];
                    one[crate_argmax(&d)] = 1.0;
                    one
                })
                .collect()
        } else {
            target
        };
        let r = verify_tree(&tree, &target, &mut sampler);
        assert_eq!(r.accepted_slots[0], 0);
        assert!(r.accepted_slots.windows(2).all(|w| w[0] < w[1]));
        // the path is parent-linked
        for w in r.accepted_slots.windows(2) {
            assert_eq!(tree.nodes[w[1]].parent, w[0]);
        }
        assert!((r.bonus as usize) < v);
        assert_eq!(r.depth_events.len(), {
            // one event per attempted level = accepted levels (+1 if
            // stopped before exhausting the tree's depth along the path)
            let accepted_levels = r.accepted_slots.len() - 1;
            let last = *r.accepted_slots.last().unwrap();
            if tree.children(last).is_empty() {
                accepted_levels
            } else {
                accepted_levels + 1
            }
        });
    }
}

fn crate_argmax(xs: &[f32]) -> usize {
    fasteagle::util::rng::argmax(xs)
}

/// Backbone-Expansion invariants (§2.2), top-k and sampled variants:
/// exactly one depth-N backbone path, at most k−1 side branches per
/// level, and ancestor sets consistent with the tree-attention mask rows
/// (root-anchored, strictly ascending, depth == index along the path).
#[test]
fn backbone_expansion_invariants_random_sweep() {
    let mut rng = Pcg64::new(31, 0);
    for case in 0..300 {
        let v = 8 + rng.below(56);
        let n = 1 + rng.below(6);
        let k = 1 + rng.below(4);
        let dists: Vec<Vec<f32>> = (0..n).map(|_| random_dist(&mut rng, v)).collect();
        let root = rng.below(v) as i32;
        let tree = if case % 2 == 0 {
            DraftTree::backbone_expansion(root, dists, k)
        } else {
            DraftTree::backbone_expansion_sampled(root, dists, k, &mut rng)
        };
        tree.check_invariants(k).unwrap();
        assert_eq!(tree.max_depth(), n);

        // exactly one backbone node per level 1..=N, forming one path
        let mut backbone_path = vec![0usize];
        for depth in 1..=n {
            let nodes: Vec<usize> = (0..tree.len())
                .filter(|&i| tree.nodes[i].depth == depth && tree.nodes[i].backbone)
                .collect();
            assert_eq!(nodes.len(), 1, "depth {depth} must have one backbone node");
            assert_eq!(
                tree.nodes[nodes[0]].parent,
                *backbone_path.last().unwrap(),
                "backbone must be parent-linked"
            );
            backbone_path.push(nodes[0]);

            // at most k-1 side branches per level, all hanging off the
            // previous backbone node
            let side: Vec<usize> = (0..tree.len())
                .filter(|&i| tree.nodes[i].depth == depth && !tree.nodes[i].backbone)
                .collect();
            assert!(side.len() <= k - 1, "depth {depth}: {} side branches", side.len());
            for &s in &side {
                assert_eq!(tree.nodes[s].parent, backbone_path[depth - 1]);
                assert!(tree.children(s).is_empty(), "side branches are leaves");
            }
        }

        // ancestor-mask consistency for every slot
        for s in 0..tree.len() {
            let a = tree.ancestors(s);
            assert_eq!(a[0], 0, "mask rows are root-anchored");
            assert_eq!(*a.last().unwrap(), s, "mask rows include the row itself");
            assert!(a.windows(2).all(|w| w[0] < w[1]), "strictly ascending");
            for (j, &x) in a.iter().enumerate() {
                assert_eq!(tree.nodes[x].depth, j, "j-th ancestor sits at depth j");
            }
            for w in a.windows(2) {
                assert_eq!(tree.nodes[w[1]].parent, w[0], "consecutive = parent-linked");
            }
        }
    }
}

/// Multi-threaded admission queue: concurrent producers and consumers
/// with a mid-stream close. Every item is either consumed exactly once
/// or bounced back to its producer with a `Closed`/`Full` error — no
/// loss, no duplication — and `pop` drains then returns `None`.
#[test]
fn admission_queue_concurrent_push_pop_close() {
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 200;
    let q: Arc<AdmissionQueue<u64>> = Arc::new(AdmissionQueue::new(8));

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut accepted = Vec::new();
                for i in 0..PER_PRODUCER {
                    let item = p * 1000 + i;
                    match q.push(item) {
                        Ok(()) => accepted.push(item),
                        Err(_) => break, // queue closed mid-stream
                    }
                }
                accepted
            })
        })
        .collect();
    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = q.pop() {
                    got.push(x);
                }
                got
            })
        })
        .collect();

    // close somewhere in the middle of the stream
    std::thread::sleep(std::time::Duration::from_millis(5));
    q.close();

    let mut accepted: Vec<u64> = Vec::new();
    for p in producers {
        accepted.extend(p.join().unwrap());
    }
    let mut consumed: Vec<u64> = Vec::new();
    for c in consumers {
        consumed.extend(c.join().unwrap());
    }
    // whatever was accepted before the close is delivered exactly once
    accepted.sort_unstable();
    consumed.sort_unstable();
    assert_eq!(accepted, consumed, "no loss, no duplication");
    // post-close pushes report Closed, and pop on the drained queue ends
    assert!(matches!(q.try_push(9999), Err(PushError::Closed(9999))));
    assert_eq!(q.pop(), None);
}

/// FIFO order survives a full/empty oscillation under try_push sheds.
#[test]
fn admission_queue_sheds_preserve_fifo() {
    let q: AdmissionQueue<usize> = AdmissionQueue::new(4);
    let mut accepted = Vec::new();
    let mut popped = Vec::new();
    for i in 0..64 {
        match q.try_push(i) {
            Ok(()) => accepted.push(i),
            Err(PushError::Full(_)) => {
                // drain half on pressure, like the engine's admission pass
                for _ in 0..2 {
                    if let Some(x) = q.pop_timeout(std::time::Duration::from_millis(1)) {
                        popped.push(x);
                    }
                }
            }
            Err(PushError::Closed(_)) => unreachable!("never closed here"),
        }
    }
    while let Some(x) = q.pop_timeout(std::time::Duration::from_millis(1)) {
        popped.push(x);
    }
    assert_eq!(popped, accepted, "accepted items come out in FIFO order");
}

/// Greedy acceptance is deterministic and equals the target argmax chain
/// restricted to the tree.
#[test]
fn greedy_acceptance_is_deterministic() {
    let mut rng = Pcg64::new(7, 0);
    for _ in 0..100 {
        let v = 16;
        let dists: Vec<Vec<f32>> = (0..4).map(|_| random_dist(&mut rng, v)).collect();
        let tree = DraftTree::backbone_expansion(3, dists, 2);
        let target: Vec<Vec<f32>> =
            (0..tree.len()).map(|_| random_dist(&mut rng, v)).collect();
        let mut s1 = Sampler::new(0.0, 1);
        let mut s2 = Sampler::new(0.0, 999); // different seed, same result
        let r1 = verify_tree(&tree, &target, &mut s1);
        let r2 = verify_tree(&tree, &target, &mut s2);
        assert_eq!(r1.accepted_slots, r2.accepted_slots);
        assert_eq!(r1.bonus, r2.bonus);
    }
}

/// KV compaction: random accept patterns preserve the kept rows exactly
/// and leave other batch lanes untouched.
#[test]
fn kv_compaction_random_sweep() {
    let mut rng = Pcg64::new(11, 0);
    for _ in 0..200 {
        let planes = 1 + rng.below(4);
        let batch = 1 + rng.below(3);
        let s = 8 + rng.below(24);
        let row = 1 + rng.below(8);
        let shape = vec![planes, batch, s, 1, row];
        let mut kv = KvCache::zeros(shape).unwrap();
        let total: usize = planes * batch * s * row;
        {
            let data = kv.tensor_mut_for_tests();
            for i in 0..total {
                data[i] = i as f32;
            }
        }
        let b = rng.below(batch);
        let base = rng.below(s / 2);
        let appended = s - base;
        let mut kept: Vec<usize> = (0..appended).filter(|_| rng.below(2) == 1).collect();
        if kept.is_empty() {
            kept.push(0);
        }
        // snapshot expected rows
        let expected: Vec<Vec<f32>> = kept
            .iter()
            .flat_map(|&slot| {
                (0..planes).map(move |p| (p, slot))
            })
            .map(|(p, slot)| kv.row(p, b, base + slot).to_vec())
            .collect();
        let before_other: Vec<f32> = (0..batch)
            .filter(|&ob| ob != b)
            .flat_map(|ob| kv.row(0, ob, 0).to_vec())
            .collect();
        kv.compact(b, base, &kept).unwrap();
        assert_eq!(kv.len(b), base + kept.len());
        let mut idx = 0;
        for (i, _) in kept.iter().enumerate() {
            for p in 0..planes {
                assert_eq!(kv.row(p, b, base + i), expected[idx].as_slice());
                idx += 1;
            }
        }
        let after_other: Vec<f32> = (0..batch)
            .filter(|&ob| ob != b)
            .flat_map(|ob| kv.row(0, ob, 0).to_vec())
            .collect();
        assert_eq!(before_other, after_other);
    }
}

/// Paged pool: random alloc/release interleavings never double-lease or
/// leak blocks.
#[test]
fn block_pool_no_leaks_random_sweep() {
    let mut rng = Pcg64::new(13, 0);
    for _ in 0..100 {
        let total = 8 + rng.below(64);
        let mut pool = BlockPool::new(total, 16);
        let mut leases: Vec<Lease> = Vec::new();
        for _ in 0..50 {
            if rng.below(2) == 0 {
                let want = 1 + rng.below(8);
                let mut lease = Lease::default();
                if pool.can_alloc(want) {
                    pool.alloc(want, &mut lease).unwrap();
                    leases.push(lease);
                }
            } else if !leases.is_empty() {
                let i = rng.below(leases.len());
                let mut l = leases.swap_remove(i);
                pool.release(&mut l);
            }
            let leased: usize = leases.iter().map(|l| l.blocks.len()).sum();
            assert_eq!(pool.available() + leased, total);
            let mut all: Vec<u32> =
                leases.iter().flat_map(|l| l.blocks.iter().copied()).collect();
            all.sort_unstable();
            let n = all.len();
            all.dedup();
            assert_eq!(all.len(), n, "double-leased block");
        }
    }
}

/// JSON roundtrip on randomly generated documents.
#[test]
fn json_roundtrip_random_sweep() {
    fn gen(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| "aé\"\\\nz😀"
                    .chars().nth(rng.below(7)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg64::new(17, 0);
    for _ in 0..300 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, doc, "{text}");
    }
}
