//! Hand-rolled property tests (the offline registry has no proptest —
//! DESIGN.md §Substitutions) over the coordinator-side invariants that
//! don't need artifacts: tree construction, lossless acceptance, KV
//! compaction, the paged pool, and the JSON substrate. Seeded PCG sweeps,
//! hundreds of cases each.

use fasteagle::model::{BlockPool, KvCache, Lease};
use fasteagle::spec::{verify_tree, DraftTree, Sampler};
use fasteagle::util::json::Json;
use fasteagle::util::rng::Pcg64;

fn random_dist(rng: &mut Pcg64, v: usize) -> Vec<f32> {
    let mut d: Vec<f32> = (0..v).map(|_| (rng.next_f64() as f32).powi(2) + 1e-4).collect();
    let s: f32 = d.iter().sum();
    d.iter_mut().for_each(|x| *x /= s);
    d
}

/// Acceptance over random trees/targets: the accepted slots always form
/// a root-anchored, strictly ascending path; the bonus is a valid token;
/// depth events match the path length.
#[test]
fn acceptance_path_invariants_random_sweep() {
    let mut rng = Pcg64::new(2024, 0);
    for case in 0..400 {
        let v = 8 + rng.below(48);
        let depth = 1 + rng.below(6);
        let k = 1 + rng.below(3);
        let dists: Vec<Vec<f32>> = (0..depth).map(|_| random_dist(&mut rng, v)).collect();
        let tree = DraftTree::backbone_expansion(rng.below(v) as i32, dists, k);
        let target: Vec<Vec<f32>> =
            (0..tree.len()).map(|_| random_dist(&mut rng, v)).collect();
        let greedy = case % 2 == 0;
        let mut sampler = Sampler::new(if greedy { 0.0 } else { 1.0 }, case as u64);
        let target = if greedy {
            target
                .into_iter()
                .map(|d| {
                    let mut one = vec![0.0; d.len()];
                    one[crate_argmax(&d)] = 1.0;
                    one
                })
                .collect()
        } else {
            target
        };
        let r = verify_tree(&tree, &target, &mut sampler);
        assert_eq!(r.accepted_slots[0], 0);
        assert!(r.accepted_slots.windows(2).all(|w| w[0] < w[1]));
        // the path is parent-linked
        for w in r.accepted_slots.windows(2) {
            assert_eq!(tree.nodes[w[1]].parent, w[0]);
        }
        assert!((r.bonus as usize) < v);
        assert_eq!(r.depth_events.len(), {
            // one event per attempted level = accepted levels (+1 if
            // stopped before exhausting the tree's depth along the path)
            let accepted_levels = r.accepted_slots.len() - 1;
            let last = *r.accepted_slots.last().unwrap();
            if tree.children(last).is_empty() {
                accepted_levels
            } else {
                accepted_levels + 1
            }
        });
    }
}

fn crate_argmax(xs: &[f32]) -> usize {
    fasteagle::util::rng::argmax(xs)
}

/// Greedy acceptance is deterministic and equals the target argmax chain
/// restricted to the tree.
#[test]
fn greedy_acceptance_is_deterministic() {
    let mut rng = Pcg64::new(7, 0);
    for _ in 0..100 {
        let v = 16;
        let dists: Vec<Vec<f32>> = (0..4).map(|_| random_dist(&mut rng, v)).collect();
        let tree = DraftTree::backbone_expansion(3, dists, 2);
        let target: Vec<Vec<f32>> =
            (0..tree.len()).map(|_| random_dist(&mut rng, v)).collect();
        let mut s1 = Sampler::new(0.0, 1);
        let mut s2 = Sampler::new(0.0, 999); // different seed, same result
        let r1 = verify_tree(&tree, &target, &mut s1);
        let r2 = verify_tree(&tree, &target, &mut s2);
        assert_eq!(r1.accepted_slots, r2.accepted_slots);
        assert_eq!(r1.bonus, r2.bonus);
    }
}

/// KV compaction: random accept patterns preserve the kept rows exactly
/// and leave other batch lanes untouched.
#[test]
fn kv_compaction_random_sweep() {
    let mut rng = Pcg64::new(11, 0);
    for _ in 0..200 {
        let planes = 1 + rng.below(4);
        let batch = 1 + rng.below(3);
        let s = 8 + rng.below(24);
        let row = 1 + rng.below(8);
        let shape = vec![planes, batch, s, 1, row];
        let mut kv = KvCache::zeros(shape).unwrap();
        let total: usize = planes * batch * s * row;
        {
            let data = kv.tensor_mut_for_tests();
            for i in 0..total {
                data[i] = i as f32;
            }
        }
        let b = rng.below(batch);
        let base = rng.below(s / 2);
        let appended = s - base;
        let mut kept: Vec<usize> = (0..appended).filter(|_| rng.below(2) == 1).collect();
        if kept.is_empty() {
            kept.push(0);
        }
        // snapshot expected rows
        let expected: Vec<Vec<f32>> = kept
            .iter()
            .flat_map(|&slot| {
                (0..planes).map(move |p| (p, slot))
            })
            .map(|(p, slot)| kv.row(p, b, base + slot).to_vec())
            .collect();
        let before_other: Vec<f32> = (0..batch)
            .filter(|&ob| ob != b)
            .flat_map(|ob| kv.row(0, ob, 0).to_vec())
            .collect();
        kv.compact(b, base, &kept).unwrap();
        assert_eq!(kv.len(b), base + kept.len());
        let mut idx = 0;
        for (i, _) in kept.iter().enumerate() {
            for p in 0..planes {
                assert_eq!(kv.row(p, b, base + i), expected[idx].as_slice());
                idx += 1;
            }
        }
        let after_other: Vec<f32> = (0..batch)
            .filter(|&ob| ob != b)
            .flat_map(|ob| kv.row(0, ob, 0).to_vec())
            .collect();
        assert_eq!(before_other, after_other);
    }
}

/// Paged pool: random alloc/release interleavings never double-lease or
/// leak blocks.
#[test]
fn block_pool_no_leaks_random_sweep() {
    let mut rng = Pcg64::new(13, 0);
    for _ in 0..100 {
        let total = 8 + rng.below(64);
        let mut pool = BlockPool::new(total, 16);
        let mut leases: Vec<Lease> = Vec::new();
        for _ in 0..50 {
            if rng.below(2) == 0 {
                let want = 1 + rng.below(8);
                let mut lease = Lease::default();
                if pool.can_alloc(want) {
                    pool.alloc(want, &mut lease).unwrap();
                    leases.push(lease);
                }
            } else if !leases.is_empty() {
                let i = rng.below(leases.len());
                let mut l = leases.swap_remove(i);
                pool.release(&mut l);
            }
            let leased: usize = leases.iter().map(|l| l.blocks.len()).sum();
            assert_eq!(pool.available() + leased, total);
            let mut all: Vec<u32> =
                leases.iter().flat_map(|l| l.blocks.iter().copied()).collect();
            all.sort_unstable();
            let n = all.len();
            all.dedup();
            assert_eq!(all.len(), n, "double-leased block");
        }
    }
}

/// JSON roundtrip on randomly generated documents.
#[test]
fn json_roundtrip_random_sweep() {
    fn gen(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| "aé\"\\\nz😀"
                    .chars().nth(rng.below(7)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg64::new(17, 0);
    for _ in 0..300 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, doc, "{text}");
    }
}
