//! Soundness properties of the static HLO verifier and the engine
//! contract checker.
//!
//! Three claims from the verifier's contract:
//! 1. builder-emitted programs verify with *zero* findings (no errors,
//!    no unused-instruction warnings) across random shapes;
//! 2. a program the verifier passes evaluates without panicking on
//!    shape-conforming inputs;
//! 3. mutating a passing program (shape, dtype, attribute, or dataflow
//!    corruption) is rejected with an error that names the offending
//!    instruction and a stable rule id.
//!
//! Plus the engine-contract side: the generated fixture tree is fully
//! clean, a doctored manifest is rejected, and a spec whose planner
//! envelope has no verify lane fails `TargetModel::open` /
//! `BatchEngine::new` with the contract report.

mod common;

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use fasteagle::backend::hlo::builder::{HloBuilder, Ty};
use fasteagle::backend::hlo::eval::{evaluate, Value};
use fasteagle::backend::hlo::parser::{
    parse_module, BinOp, Computation, Instr, Op, PrimType, UnOp,
};
use fasteagle::backend::hlo::verify::{has_errors, verify_manifest, verify_module, Severity};
use fasteagle::backend::BackendKind;
use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod};
use fasteagle::model::{ModelSpec, TargetModel};
use fasteagle::runtime::{contract, ExecManifest};
use fasteagle::util::rng::Pcg64;

/// One program exercising every op the verifier knows: dot, unary,
/// binary, compare/select, transpose, both reduce kinds, broadcast,
/// gather, slice/reshape/concat, dynamic-slice + dynamic-update-slice,
/// convert, iota, and the threefry rng tuple. Every instruction feeds
/// the root, so a clean run means zero warnings too.
fn build_rich(m: usize, k: usize, n: usize, q: usize) -> String {
    let mut b = HloBuilder::new("rich");
    let a = b.param(Ty::F32, vec![m, k]);
    let w = b.param(Ty::F32, vec![k, n]);
    let idx = b.param(Ty::S32, vec![q]);
    let st0 = b.param(Ty::S32, vec![]);
    let st1 = b.param(Ty::S32, vec![]);
    let state = b.param(Ty::U64, vec![2]);

    let mm = b.matmul(&a, &w);
    let e = b.exp(&mm);
    let half = b.const_f32(0.5);
    let sp = b.splat(&half, vec![m, n]);
    let th = b.tanh(&sp);
    let s1 = b.add(&e, &th);
    let p = b.compare(&mm, &sp, "GT");
    let sel = b.select(&p, &s1, &mm);
    let tr = b.transpose(&sel, &[1, 0]);
    let sum = b.reduce_add(&tr, &[0]);
    let mx = b.reduce_max(&mm, &[1]);
    let s2 = b.add(&sum, &mx);
    let bc = b.broadcast(&s2, vec![m, k], &[0]);
    let g = b.gather_rows(&a, &idx);
    let sl = b.slice(&a, &[(1, m), (0, k)]);
    let rs = b.reshape(&sl, vec![(m - 1) * k]);
    let cc = b.concat(&[&bc, &sl], 0);
    let ds = b.dynamic_slice(&a, &[st0.clone(), st1.clone()], &[1, k]);
    let du = b.dus(&a, &ds, &[st0, st1]);
    let cv = b.convert(&idx, Ty::F32);
    let io = b.iota(Ty::S32, vec![q], 0);
    let s3 = b.add(&io, &idx);
    let (ns, bits) = b.rng_threefry(&state, vec![q]);
    b.finish(&[&cc, &rs, &g, &du, &cv, &s3, &ns, &bits])
}

fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect()
}

fn rich_dims(rng: &mut Pcg64) -> (usize, usize, usize, usize) {
    (2 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4))
}

#[test]
fn builder_programs_verify_with_zero_findings() {
    let mut rng = Pcg64::new(7, 0);
    for _ in 0..20 {
        let (m, k, n, q) = rich_dims(&mut rng);
        let module = parse_module(&build_rich(m, k, n, q)).expect("parse built module");
        let diags = verify_module(&module);
        assert!(
            diags.is_empty(),
            "builder program ({m},{k},{n},{q}) must be clean, got: {}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
        );
    }
}

#[test]
fn verified_programs_evaluate_on_conforming_inputs() {
    let mut rng = Pcg64::new(11, 0);
    for _ in 0..20 {
        let (m, k, n, q) = rich_dims(&mut rng);
        let module = parse_module(&build_rich(m, k, n, q)).expect("parse built module");
        assert!(!has_errors(&verify_module(&module)));
        let idx: Vec<i32> = (0..q).map(|_| rng.below(m) as i32).collect();
        let args: Vec<Arc<Value>> = vec![
            Arc::new(Value::f32(vec![m, k], randv(&mut rng, m * k))),
            Arc::new(Value::f32(vec![k, n], randv(&mut rng, k * n))),
            Arc::new(Value::i32(vec![q], idx)),
            Arc::new(Value::i32(vec![], vec![rng.below(m) as i32])),
            Arc::new(Value::i32(vec![], vec![0])),
            Arc::new(Value::u64(vec![2], vec![rng.next_u64(), rng.next_u64()])),
        ];
        let out = evaluate(&module, &args).expect("verified program must evaluate");
        assert_eq!(out.len(), 8);
    }
}

fn find_mut<'c>(c: &'c mut Computation, pred: impl Fn(&Instr) -> bool) -> &'c mut Instr {
    c.instrs.iter_mut().find(|i| pred(i)).expect("no matching instruction")
}

fn find_name(c: &Computation, pred: impl Fn(&Instr) -> bool) -> String {
    c.instrs.iter().find(|i| pred(i)).expect("no matching instruction").name.clone()
}

/// Apply `mutate` to the entry computation of a pristine rich program
/// and assert the verifier reports `rule` as an *error anchored at the
/// instruction name the mutation returns*.
fn assert_rejected(rule: &'static str, mutate: impl FnOnce(&mut Computation) -> String) {
    let mut module = parse_module(&build_rich(3, 2, 4, 5)).expect("parse pristine module");
    assert!(verify_module(&module).is_empty(), "pristine program must verify clean");
    let entry = module.entry.clone();
    let comp = module.computations.get_mut(&entry).expect("entry computation");
    let name = mutate(comp);
    let diags = verify_module(&module);
    assert!(
        diags
            .iter()
            .any(|d| d.severity == Severity::Error && d.rule == rule && d.instruction == name),
        "expected error[{rule}] at %{name}, got: {}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
    );
}

#[test]
fn mutations_shape_and_dtype_are_rejected() {
    // declared dot output no longer matches the inferred [m, n]
    assert_rejected("shape/dot", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Dot(_)));
        i.shape.dims[0] += 1;
        i.name.clone()
    });
    // exp re-declared as s32: inference still derives f32 from the operand
    assert_rejected("shape/unary", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Unary(UnOp::Exp)));
        i.shape.ty = PrimType::S32;
        i.name.clone()
    });
    // reduce init constant flipped to s32 disagrees with the f32 operand
    assert_rejected("dtype/reduce", |c| {
        let (red, init) = {
            let i = c.instrs.iter().find(|i| matches!(i.op, Op::Reduce { .. })).expect("reduce");
            (i.name.clone(), i.operands[1].clone())
        };
        find_mut(c, |i| i.name == init).shape.ty = PrimType::S32;
        red
    });
    // rng state parameter re-declared as u64[3] breaks the threefry signature
    assert_rejected("rng/state", |c| {
        let st = find_mut(c, |i| {
            matches!(i.op, Op::Parameter(_)) && i.shape.ty == PrimType::U64
        });
        st.shape.dims = vec![3];
        find_name(c, |i| matches!(i.op, Op::RngBitGenerator))
    });
}

#[test]
fn mutations_bad_attributes_are_rejected() {
    // broadcast mapping points past the output rank
    assert_rejected("attr/broadcast", |c| {
        let i = find_mut(c, |i| matches!(&i.op, Op::Broadcast(v) if !v.is_empty()));
        if let Op::Broadcast(v) = &mut i.op {
            v[0] = 7;
        }
        i.name.clone()
    });
    // slice limit beyond the operand dimension
    assert_rejected("attr/slice", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Slice(_)));
        if let Op::Slice(r) = &mut i.op {
            r[0].1 = 999;
        }
        i.name.clone()
    });
    // duplicate entry makes the transpose dims not a permutation
    assert_rejected("attr/transpose", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Transpose(_)));
        if let Op::Transpose(p) = &mut i.op {
            *p = vec![1, 1];
        }
        i.name.clone()
    });
    // dot contracting dim number past the operand rank
    assert_rejected("attr/dot", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Dot(_)));
        if let Op::Dot(d) = &mut i.op {
            d.lhs_contract = vec![5];
        }
        i.name.clone()
    });
    // gather slice size larger than the table dimension
    assert_rejected("attr/gather", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Gather(_)));
        if let Op::Gather(g) = &mut i.op {
            g.slice_sizes[1] += 999;
        }
        i.name.clone()
    });
    // tuple projection index past the rng tuple's two parts
    assert_rejected("tuple/index", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::GetTupleElement(0)));
        i.op = Op::GetTupleElement(7);
        i.name.clone()
    });
}

#[test]
fn mutations_broken_dataflow_is_rejected() {
    // operand renamed to a name that is never defined
    assert_rejected("dataflow/undefined", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Binary(BinOp::Add)));
        i.operands[0] = "bogus".to_string();
        i.name.clone()
    });
    // dot hoisted above its operands: defined-before-use must fire
    assert_rejected("dataflow/undefined", |c| {
        let pos = c.instrs.iter().position(|i| matches!(i.op, Op::Dot(_))).expect("dot");
        let ins = c.instrs.remove(pos);
        let name = ins.name.clone();
        c.instrs.insert(0, ins);
        name
    });
    // a later instruction stealing an earlier instruction's name
    assert_rejected("dataflow/duplicate-name", |c| {
        let dot = find_name(c, |i| matches!(i.op, Op::Dot(_)));
        find_mut(c, |i| matches!(i.op, Op::Transpose(_))).name = dot.clone();
        dot
    });
    // two parameters claiming the same number
    assert_rejected("dataflow/param-numbering", |c| {
        let i = find_mut(c, |i| matches!(i.op, Op::Parameter(1)));
        i.op = Op::Parameter(0);
        i.name.clone()
    });
}

#[test]
fn fixture_artifacts_verify_clean() {
    let (dir, _kind) = common::artifacts_base();
    let spec_text = std::fs::read_to_string(dir.join("spec.json")).expect("read spec.json");
    let spec = ModelSpec::parse(&spec_text).expect("parse spec.json");
    let single = contract::check_single(&spec);
    assert!(!single.has_errors(), "{single}");
    let inv = contract::check_inventory(&spec, &dir);
    assert!(!inv.has_errors(), "{inv}");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(dir.join("hlo")).expect("read hlo dir") {
        let path = entry.expect("dir entry").path();
        let fname = path.file_name().expect("file name").to_string_lossy().to_string();
        let Some(stem) = fname.strip_suffix(".hlo.txt") else { continue };
        let text = std::fs::read_to_string(&path).expect("read hlo");
        let module = parse_module(&text).unwrap_or_else(|e| panic!("{fname}: parse: {e:#}"));
        let diags = verify_module(&module);
        assert!(
            !has_errors(&diags),
            "{fname}: {}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
        );
        let manifest = ExecManifest::load(&path.with_file_name(format!("{stem}.io.json")))
            .expect("load manifest");
        let md = verify_manifest(&module, &manifest);
        assert!(
            !has_errors(&md),
            "{fname}: {}",
            md.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
        );
        let states = contract::check_manifest_states(&spec, &manifest);
        assert!(!states.has_errors(), "{fname}: {states}");
        checked += 1;
    }
    assert!(checked > 0, "artifact tree has no executables");
}

#[test]
fn manifest_mismatch_is_rejected() {
    let (dir, _kind) = common::artifacts_base();
    let text = std::fs::read_to_string(dir.join("hlo").join("tgt_m1.hlo.txt")).expect("read hlo");
    let module = parse_module(&text).expect("parse tgt_m1");
    let mut manifest =
        ExecManifest::load(&dir.join("hlo").join("tgt_m1.io.json")).expect("load manifest");
    assert!(!has_errors(&verify_manifest(&module, &manifest)));
    manifest.inputs[0].shape.push(3);
    let diags = verify_manifest(&module, &manifest);
    assert!(
        diags.iter().any(|d| d.severity == Severity::Error && d.rule == "manifest/params"),
        "doctored manifest must be rejected, got: {}",
        diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
    );
}

/// Spec whose default draft plan (depth 6 x top-k 3 = 19 verify rows)
/// has no lowered lane: the largest inventory entry is tgt_m8.
/// prefill_chunk 8 still fits, so `lane/b1` is the only startup error.
const BAD_SPEC: &str = r#"{
  "name": "bad",
  "d_model": 64, "n_layers": 2, "n_heads": 2, "n_kv_heads": 1,
  "head_dim": 32, "ffn": 128, "taps": [0, 1], "max_seq": 64,
  "vocab": 272, "feat_dim": 192, "bos": 256, "eos": 257, "pad": 258,
  "prefill_chunk": 8, "draft_depth": 6, "tree_top_k": 3,
  "medusa_heads": 4, "sps_chain": 5,
  "sps": {"d_model": 32, "n_layers": 1, "n_kv_heads": 1, "head_dim": 32},
  "executables": {"tgt_m1": {}, "tgt_m8": {}},
  "batch_sizes": [1]
}"#;

#[test]
fn engine_startup_fails_contract_with_report() {
    let dir = PathBuf::from(env!("CARGO_TARGET_TMPDIR"))
        .join(format!("fe_badspec_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create spec dir");
    std::fs::write(dir.join("spec.json"), BAD_SPEC).expect("write spec.json");
    let store = common::store_with(&dir, BackendKind::Interpret);

    // single-request engine: the planner envelope has no verify lane
    let err = TargetModel::open(Rc::clone(&store)).expect_err("open must fail the contract");
    let msg = format!("{err:#}");
    assert!(msg.contains("engine contract report"), "{msg}");
    assert!(msg.contains("lane/b1"), "{msg}");

    // batched engine: chain 9 needs 10 rows, largest lane is 8 — the
    // contract fires at startup, before any artifact is even opened
    let mut cfg = BatchConfig::new(1, BatchMethod::Vanilla);
    cfg.chain_len = 9;
    let err = BatchEngine::new(store, cfg).expect_err("chain 9 must fail the contract");
    let msg = format!("{err:#}");
    assert!(msg.contains("lane/chain"), "{msg}");
}
