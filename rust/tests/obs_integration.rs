//! Flight-recorder export surfaces over the real serving stack:
//! - `{"cmd":"trace"}` returns Chrome trace-event JSON whose spans
//!   reconstruct the request lifecycle (queue -> admit -> prefill ->
//!   cycles with draft/verify children -> done), properly nested per
//!   track;
//! - `{"cmd":"metrics"}` returns parseable Prometheus text exposition
//!   with per-method phase histograms (fasteagle and eagle3 as distinct
//!   series);
//! - the overhead guard: with the recorder disabled, a closed serving
//!   run records zero events and produces byte-identical outputs to a
//!   traced run.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use common::artifacts_root;
use fasteagle::coordinator::{
    BatchConfig, BatchEngine, BatchMethod, Request, Server, ServerConfig,
};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::util::json::Json;
use fasteagle::workload::batched_serving_target;

/// The recorder is process-global: serialize the tests that arm it.
fn obs_guard() -> std::sync::MutexGuard<'static, ()> {
    static M: Mutex<()> = Mutex::new(());
    M.lock().unwrap_or_else(PoisonError::into_inner)
}

fn query_at(addr: &str, line: &str) -> Json {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut r = BufReader::new(stream);
    let mut out = String::new();
    r.read_line(&mut out).unwrap();
    Json::parse(out.trim()).expect("json response")
}

/// Multi-line reply (Prometheus exposition): read through `# EOF`.
fn query_text_at(addr: &str, line: &str) -> String {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut w = stream.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut r = BufReader::new(stream);
    let mut out = String::new();
    loop {
        let mut l = String::new();
        assert!(r.read_line(&mut l).unwrap() > 0, "closed before # EOF");
        let done = l.trim_end() == "# EOF";
        out.push_str(&l);
        if done {
            return out;
        }
    }
}

fn wait_for_listener(addr: &str) {
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("server did not start on {addr}");
}

/// Minimal Prometheus text-exposition line check: every non-comment,
/// non-blank line is `name[{labels}] value` with a finite numeric value.
fn assert_prometheus_parses(text: &str) {
    let mut samples = 0usize;
    for line in text.lines() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("no value in {line:?}"));
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        assert!(v.is_finite(), "{line:?}");
        let name_end = series.find('{').unwrap_or(series.len());
        let name = &series[..name_end];
        assert!(
            !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in {line:?}"
        );
        if name_end < series.len() {
            assert!(series.ends_with('}'), "unterminated labels in {line:?}");
        }
        samples += 1;
    }
    assert!(samples > 0, "exposition had no samples");
    assert_eq!(text.lines().last().map(str::trim_end), Some("# EOF"));
}

#[derive(Debug, Clone)]
struct Span {
    name: String,
    ts: u64,
    dur: u64,
    tid: u64,
    req: u64,
}

/// Every event needs ph/ts/pid/tid; X events need dur. Returns the
/// duration spans and the instant names per request id.
fn validate_chrome(trace: &Json) -> (Vec<Span>, Vec<(String, u64)>) {
    let events = trace
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(!events.is_empty(), "trace recorded no events");
    let mut spans = Vec::new();
    let mut instants = Vec::new();
    for e in events {
        let name = e.get("name").and_then(Json::as_str).expect("name").to_string();
        let ph = e.get("ph").and_then(Json::as_str).expect("ph");
        let ts = e.get("ts").and_then(Json::as_f64).expect("ts") as u64;
        assert!(e.get("pid").and_then(Json::as_f64).is_some(), "pid missing");
        let tid = e.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        let req = e
            .path("args.req")
            .and_then(Json::as_f64)
            .map(|r| r as u64)
            .unwrap_or(0);
        match ph {
            "X" => {
                let dur = e.get("dur").and_then(Json::as_f64).expect("X needs dur") as u64;
                spans.push(Span { name, ts, dur, tid, req });
            }
            "i" => instants.push((name, req)),
            other => panic!("unexpected ph {other:?}"),
        }
    }
    (spans, instants)
}

/// On slot tracks (tid < 1000), spans must pairwise nest: disjoint or
/// contained, within the integer-microsecond truncation slop.
fn assert_nesting(spans: &[Span]) {
    const SLOP: u64 = 5;
    for (i, a) in spans.iter().enumerate() {
        for b in spans.iter().skip(i + 1) {
            if a.tid != b.tid || a.tid >= 1000 {
                continue;
            }
            let (a0, a1) = (a.ts, a.ts + a.dur);
            let (b0, b1) = (b.ts, b.ts + b.dur);
            let disjoint = a1 <= b0 + SLOP || b1 <= a0 + SLOP;
            let a_in_b = a0 + SLOP >= b0 && a1 <= b1 + SLOP;
            let b_in_a = b0 + SLOP >= a0 && b1 <= a1 + SLOP;
            assert!(
                disjoint || a_in_b || b_in_a,
                "spans overlap without nesting on tid {}: {a:?} vs {b:?}",
                a.tid
            );
        }
    }
}

#[test]
fn trace_and_metrics_export_request_lifecycle() {
    let _g = obs_guard();
    const ADDR: &str = "127.0.0.1:7436";
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    fasteagle::obs::enable();
    fasteagle::obs::reset();
    let server_thread = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: ADDR.into(),
            queue_capacity: 8,
            ..Default::default()
        });
        server.serve(engine).unwrap()
    });
    wait_for_listener(ADDR);

    // request 1: streamed, default (fasteagle) method — the lifecycle
    // under test; request 2: eagle3, so the per-method histograms get a
    // second distinct series
    let stream = TcpStream::connect(ADDR).unwrap();
    let mut w = stream.try_clone().unwrap();
    writeln!(
        w,
        r#"{{"prompt":"USER: tell me about machine learning and the fast cache.\nASSISTANT:","max_new":16,"stream":true}}"#
    )
    .unwrap();
    let mut r = BufReader::new(stream);
    let streamed = loop {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let v = Json::parse(line.trim()).expect("json line");
        if v.get("event").and_then(Json::as_str) != Some("tokens") {
            break v;
        }
    };
    assert!(streamed.get("error").is_none(), "{streamed:?}");
    let v = query_at(
        ADDR,
        r#"{"prompt":"USER: tell me about city transport and the steady bridge.\nASSISTANT:","max_new":8,"method":"eagle3"}"#,
    );
    assert!(v.get("error").is_none(), "{v:?}");

    // stats: per-method phase histograms, fasteagle and eagle3 distinct
    let stats = query_at(ADDR, r#"{"cmd":"stats"}"#);
    for method in ["fasteagle", "eagle3"] {
        for phase in ["draft", "verify"] {
            let count = stats
                .path(&format!("phase_us.{method}.{phase}.count"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            assert!(count > 0.0, "no {method}/{phase} samples in {stats:?}");
        }
    }

    // Prometheus exposition: parses, and carries both method series
    let prom = query_text_at(ADDR, r#"{"cmd":"metrics"}"#);
    assert_prometheus_parses(&prom);
    assert!(prom.contains(r#"fe_phase_us_bucket{method="fasteagle",phase="draft""#), "{prom}");
    assert!(prom.contains(r#"fe_phase_us_bucket{method="eagle3",phase="draft""#), "{prom}");
    assert!(prom.contains("fe_requests_done_total 2"), "{prom}");

    // Chrome trace: structurally valid, lifecycle reconstructible
    let trace = query_at(ADDR, r#"{"cmd":"trace"}"#);
    let (spans, instants) = validate_chrome(&trace);
    assert_nesting(&spans);
    // lifecycle of the streamed request (server-side id 1): queue span,
    // admit mark, prefill span, >=1 cycle span with draft + verify
    // children inside it, done mark
    let req = 1u64;
    let of = |name: &str| -> Vec<&Span> {
        spans.iter().filter(|s| s.name == name && s.req == req).collect()
    };
    let queue = of("queue");
    assert_eq!(queue.len(), 1, "exactly one queue span for req {req}");
    assert!(queue[0].tid >= 1000, "queue spans live on dedicated lanes");
    assert!(
        instants.iter().any(|(n, r)| n == "admit" && *r == req),
        "admit mark missing"
    );
    assert!(!of("prefill").is_empty(), "prefill span missing");
    let cycles = of("cycle");
    assert!(!cycles.is_empty(), "no cycle spans for req {req}");
    for phase in ["draft", "verify"] {
        let phase_spans = of(phase);
        assert!(!phase_spans.is_empty(), "no {phase} spans for req {req}");
        const SLOP: u64 = 5;
        for p in &phase_spans {
            assert!(
                cycles.iter().any(|c| {
                    p.ts + SLOP >= c.ts && p.ts + p.dur <= c.ts + c.dur + SLOP
                }),
                "{phase} span not inside any cycle span: {p:?} vs {cycles:?}"
            );
        }
    }
    assert!(
        instants.iter().any(|(n, r)| n == "done" && *r == req),
        "done mark missing"
    );
    // ordering: queue ends (admission) at/before the first cycle begins
    let first_cycle = cycles.iter().map(|c| c.ts).min().unwrap();
    assert!(
        queue[0].ts <= first_cycle,
        "queue must start before the first cycle"
    );
    // the verify spans carry the executable name
    let trace_text = trace.to_string();
    assert!(trace_text.contains("\"exec\""), "verify spans should name the executable");

    let v = query_at(ADDR, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let metrics = server_thread.join().unwrap();
    assert_eq!(metrics.requests_done, 2);
    fasteagle::obs::disable();
    fasteagle::obs::reset();
}

/// Overhead guard: with the recorder disabled a closed serving run
/// records zero events, and its outputs are byte-identical to the same
/// run with tracing armed — instrumentation never changes generation.
#[test]
fn tracing_disabled_records_nothing_and_changes_nothing() {
    let _g = obs_guard();
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let make_reqs = || -> Vec<Request> {
        (0..3)
            .map(|i| {
                let mut r = Request::new(
                    i + 1,
                    "USER: tell me about machine learning and the fast cache.\nASSISTANT:"
                        .to_string(),
                );
                r.cfg.max_new_tokens = 12;
                r.cfg.seed = i;
                r
            })
            .collect()
    };
    let run_once = |dir: &std::path::Path| -> Vec<(u64, String, usize)> {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir.to_path_buf()).unwrap());
        let mut engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let (resps, _m) = engine.run(make_reqs()).unwrap();
        let mut out: Vec<(u64, String, usize)> =
            resps.into_iter().map(|r| (r.id, r.text, r.new_tokens)).collect();
        out.sort();
        out
    };

    fasteagle::obs::disable();
    fasteagle::obs::reset();
    let quiet = run_once(&dir);
    assert_eq!(fasteagle::obs::recorded_total(), 0, "disabled run recorded events");
    assert!(fasteagle::obs::snapshot().is_empty());

    fasteagle::obs::enable();
    fasteagle::obs::reset();
    let traced = run_once(&dir);
    assert!(fasteagle::obs::recorded_total() > 0, "armed run recorded nothing");
    fasteagle::obs::disable();
    fasteagle::obs::reset();

    assert_eq!(quiet, traced, "tracing must not change generated outputs");
}
