//! Multi-replica router end-to-end: two in-process replica servers
//! behind a `Router`, a mixed-method trace routed with global ids, one
//! replica killed mid-test (the chaos half of the CI lane), and the
//! fleet observability surface — per-replica stats table and the
//! merged Prometheus exposition.
//!
//! The byte-identity contract under test: a client talking through the
//! router sees exactly the output it would get from a replica
//! directly, before *and after* a replica is killed out from under the
//! fleet (not-yet-started casualties are retried on the survivor).

mod common;

use std::net::TcpListener;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use common::artifacts_root;
use fasteagle::backend::BackendKind;
use fasteagle::coordinator::{
    BatchConfig, BatchEngine, BatchMethod, Server, ServerConfig, ServingMetrics,
};
use fasteagle::router::{make_policy, query_line, query_text, Router, RouterConfig};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::util::json::Json;
use fasteagle::workload::batched_serving_target;

/// Boot one replica server on an OS-assigned loopback port; the
/// returned join handle yields its metrics at clean (leak-checked)
/// exit.
fn start_replica(
    dir: std::path::PathBuf,
    kind: BackendKind,
    batch: usize,
    replica_id: usize,
) -> (String, std::thread::JoinHandle<ServingMetrics>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let addr2 = addr.clone();
    let h = std::thread::spawn(move || {
        let rt = Arc::new(Runtime::new(kind).unwrap());
        let store = Rc::new(ArtifactStore::open(rt, dir).unwrap());
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )
        .unwrap();
        let server = Server::new(ServerConfig {
            addr: addr2,
            queue_capacity: 8,
            frame_queue: 16,
            replica_id,
        });
        server.serve_on(listener, engine).unwrap()
    });
    (addr, h)
}

fn ask(addr: &str, line: &str) -> Json {
    Json::parse(&query_line(addr, line, Duration::from_secs(120)).unwrap()).unwrap()
}

/// The mixed-method trace: every speculative method in one fleet.
const REQS: [(&str, &str); 4] = [
    ("USER: tell me about machine learning and the fast cache.\nASSISTANT:", "fasteagle"),
    ("USER: tell me about city transport and the steady bridge.\nASSISTANT:", "eagle3"),
    ("Q: Ben has 4 coins and buys 9 more coins. how many coins does Ben have?\nA:", "vanilla"),
    ("Summarize cascaded drafting for speculative decoding.", "fasteagle"),
];

fn gen_line(prompt: &str, method: &str) -> String {
    format!(r#"{{"prompt":{prompt:?},"max_new":12,"method":{method:?}}}"#)
}

#[test]
fn router_mixed_trace_survives_replica_kill_with_fleet_metrics() {
    let (root, kind) = artifacts_root();
    let Some((dir, batch)) = batched_serving_target(&root) else {
        eprintln!("skipping: no serving target");
        return;
    };
    let (addr_a, ha) = start_replica(dir.clone(), kind, batch, 1);
    let (addr_b, hb) = start_replica(dir, kind, batch, 2);

    // reference outputs straight from replica A — the byte-identity bar
    let reference: Vec<String> = REQS
        .iter()
        .map(|(p, m)| {
            let v = ask(&addr_a, &gen_line(p, m));
            assert!(v.get("error").is_none(), "direct run: {v:?}");
            v.get("text").and_then(Json::as_str).unwrap().to_string()
        })
        .collect();
    assert!(reference.iter().all(|t| !t.is_empty()), "empty generations prove nothing");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let raddr = listener.local_addr().unwrap().to_string();
    let cfg = RouterConfig { addr: raddr.clone(), poll_ms: 100, ..Default::default() };
    let router = Arc::new(Router::new(
        cfg,
        vec![addr_a.clone(), addr_b.clone()],
        make_policy("rr").unwrap(),
    ));
    let r2 = Arc::clone(&router);
    let rh = std::thread::spawn(move || r2.serve_on(listener).unwrap());

    // the trace through the router: global ids assigned in order, and
    // output byte-identical to the direct run whichever replica served
    for (i, (p, m)) in REQS.iter().enumerate() {
        let v = ask(&raddr, &gen_line(p, m));
        assert!(v.get("error").is_none(), "routed run: {v:?}");
        assert_eq!(v.get("id").and_then(Json::as_usize), Some(i + 1), "global id");
        assert_eq!(
            v.get("text").and_then(Json::as_str),
            Some(reference[i].as_str()),
            "request {i} ({m}) through the router must be byte-identical"
        );
    }

    // chaos: kill replica B out from under the router, then replay the
    // trace — every request lands on the survivor (rerouted
    // transparently when the dead replica is picked first) with
    // byte-identical output
    let v = ask(&addr_b, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let mb = hb.join().unwrap(); // unwrap = B's drained-exit leak guard passed
    for (i, (p, m)) in REQS.iter().enumerate() {
        let v = ask(&raddr, &gen_line(p, m));
        assert!(v.get("error").is_none(), "after kill: {v:?}");
        assert_eq!(
            v.get("text").and_then(Json::as_str),
            Some(reference[i].as_str()),
            "request {i} ({m}) must survive the replica kill byte-identically"
        );
    }

    // fleet stats: B marked dead, every routed request accounted for
    let stats = ask(&raddr, r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("router").and_then(Json::as_bool), Some(true));
    assert_eq!(stats.get("policy").and_then(Json::as_str), Some("round-robin"));
    assert_eq!(stats.get("requests").and_then(Json::as_usize), Some(8));
    assert_eq!(stats.get("alive").and_then(Json::as_usize), Some(1));
    let rows = stats.get("replicas").and_then(Json::as_arr).unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].get("alive").and_then(Json::as_bool), Some(true));
    assert_eq!(rows[0].get("replica_id").and_then(Json::as_usize), Some(1));
    assert_eq!(rows[1].get("alive").and_then(Json::as_bool), Some(false));
    let forwarded: usize = rows
        .iter()
        .map(|r| r.get("forwarded").and_then(Json::as_usize).unwrap())
        .sum();
    assert!(forwarded >= 8, "all requests forwarded (plus any reroutes): {stats:?}");

    // merged Prometheus exposition: replica-labeled engine samples,
    // fe_router_* series, exactly one terminator
    let page = query_text(&raddr, r#"{"cmd":"metrics"}"#, Duration::from_secs(120)).unwrap();
    assert!(page.contains("fe_router_requests_total 8"), "{page}");
    assert!(page.contains("fe_requests_done_total{replica=\"0\"}"), "{page}");
    assert!(page.contains("fe_router_replica_up{replica=\"0\"} 1"), "{page}");
    assert!(page.contains("fe_router_replica_up{replica=\"1\"} 0"), "{page}");
    assert!(page.contains("fe_router_forwarded_total{replica=\"0\"}"), "{page}");
    assert_eq!(page.matches("# EOF").count(), 1, "single terminator");
    assert!(page.ends_with("# EOF\n"));

    // wind down: router first, then the surviving replica; clean joins
    // prove leak-free exits on both sides
    let v = ask(&raddr, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    rh.join().unwrap();
    let v = ask(&addr_a, r#"{"cmd":"shutdown"}"#);
    assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
    let ma = ha.join().unwrap();
    assert_eq!(
        ma.requests_done + mb.requests_done,
        4 + 8,
        "every accepted request completed exactly once across the fleet"
    );
    assert_eq!(ma.requests_failed + mb.requests_failed, 0);
}
