//! Property tests for the refcounted block pool: random op sequences
//! (alloc / ensure / shrink / retain / release / release_blocks /
//! fork_tail) are driven against a naive reference model that tracks an
//! explicit per-block refcount map. After every step the pool must
//! agree with the model on availability, issued-block count, and the
//! refcount of every held block — which pins down conservation (no
//! block is both free and live), no double-lease, and free-on-last-
//! reference-only semantics under arbitrary sharing.

use std::collections::HashMap;

use fasteagle::model::{BlockPool, Lease};
use fasteagle::util::rng::Pcg64;

const TOTAL: usize = 48;
const BLOCK_SLOTS: usize = 4;
const LAYERS: usize = 2;

/// The naive model: every live block maps to its exact reference
/// count; capacity used is simply the number of live blocks.
struct Model {
    refs: HashMap<u32, u32>,
}

impl Model {
    fn new() -> Model {
        Model { refs: HashMap::new() }
    }

    fn available(&self) -> usize {
        TOTAL - self.refs.len()
    }

    /// A fresh allocation: the block must not already be live.
    fn grant(&mut self, blocks: &[u32]) {
        for &b in blocks {
            let prev = self.refs.insert(b, 1);
            assert!(prev.is_none(), "pool double-leased block {b}");
        }
    }

    fn retain(&mut self, blocks: &[u32]) {
        for &b in blocks {
            let c = self.refs.get_mut(&b).expect("retain of a block that is not live");
            *c += 1;
        }
    }

    /// Drop one reference; true when the block became free.
    fn release(&mut self, b: u32) -> bool {
        let c = self.refs.get_mut(&b).expect("release of a block that is not live");
        *c -= 1;
        if *c == 0 {
            self.refs.remove(&b);
            true
        } else {
            false
        }
    }
}

fn run_sequence(seed: u64, ops: usize) {
    let mut rng = Pcg64::new(seed, 21);
    let mut pool = BlockPool::new(TOTAL, BLOCK_SLOTS);
    let mut model = Model::new();
    let mut leases: Vec<Lease> = Vec::new();
    for step in 0..ops {
        match rng.below(7) {
            // alloc into a fresh lease — all-or-nothing on exhaustion
            0 => {
                let n = rng.below(6) + 1;
                let fits = model.available() >= n;
                assert_eq!(pool.can_alloc(n), fits, "step {step}: can_alloc disagrees");
                let mut lease = Lease::default();
                match pool.alloc(n, &mut lease) {
                    Ok(()) => {
                        assert!(fits, "step {step}: alloc succeeded past capacity");
                        assert_eq!(lease.blocks.len(), n);
                        model.grant(&lease.blocks);
                        leases.push(lease);
                    }
                    Err(_) => {
                        assert!(!fits, "step {step}: alloc failed with room");
                        assert!(lease.blocks.is_empty(), "failed alloc partially filled");
                    }
                }
            }
            // grow a lease to cover a slot count (delta-only alloc)
            1 => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                let slots = rng.below(BLOCK_SLOTS * 8) + 1;
                let want = pool.blocks_for(slots, LAYERS);
                let have = leases[i].blocks.len();
                let before = leases[i].blocks.clone();
                match pool.ensure(&mut leases[i], slots, LAYERS) {
                    Ok(()) => {
                        assert_eq!(leases[i].blocks.len(), have.max(want));
                        assert!(
                            leases[i].blocks.starts_with(&before),
                            "step {step}: ensure reordered existing blocks"
                        );
                        model.grant(&leases[i].blocks[have..]);
                    }
                    Err(_) => {
                        assert!(
                            want.saturating_sub(have) > model.available(),
                            "step {step}: ensure failed with room"
                        );
                    }
                }
            }
            // shrink a lease; only last-reference pops become free
            2 => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                let slots = rng.below(BLOCK_SLOTS * 8);
                let want = pool.blocks_for(slots, LAYERS);
                let old_len = leases[i].blocks.len();
                let popped: Vec<u32> = if leases[i].blocks.len() > want {
                    leases[i].blocks[want..].to_vec()
                } else {
                    Vec::new()
                };
                let freed = pool.shrink(&mut leases[i], slots, LAYERS);
                let expect = popped.iter().filter(|&&b| model.release(b)).count();
                assert_eq!(freed, expect, "step {step}: shrink freed the wrong count");
                assert_eq!(leases[i].blocks.len(), old_len.min(want));
            }
            // cache-style adoption: a second holder of a block run —
            // capacity is charged once, references twice
            3 => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                if leases[i].blocks.is_empty() {
                    continue;
                }
                let k = rng.below(leases[i].blocks.len()) + 1;
                let shared = leases[i].blocks[..k].to_vec();
                pool.retain(&shared);
                model.retain(&shared);
                for &b in &shared {
                    assert!(pool.is_shared(b), "step {step}: retained block not shared");
                    assert!(pool.refcount(b) >= 2);
                }
                leases.push(Lease { blocks: shared });
            }
            // release a whole lease back to the pool
            4 => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                let mut lease = leases.swap_remove(i);
                let blocks = lease.blocks.clone();
                pool.release(&mut lease);
                assert!(lease.blocks.is_empty());
                for b in blocks {
                    model.release(b);
                }
            }
            // copy-on-write fork of a shared tail block
            5 => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                let Some(&tail) = leases[i].blocks.last() else {
                    continue;
                };
                let shared = pool.is_shared(tail);
                match pool.fork_tail(&mut leases[i]) {
                    Ok(forked) => {
                        assert_eq!(forked, shared, "step {step}: fork on a private tail");
                        if forked {
                            let new_tail = *leases[i].blocks.last().expect("tail survives fork");
                            assert_ne!(new_tail, tail, "fork must produce a private block");
                            assert!(!pool.is_shared(new_tail));
                            model.grant(&[new_tail]);
                            model.release(tail);
                        }
                    }
                    Err(_) => {
                        assert!(shared, "step {step}: private tail cannot fail to fork");
                        assert_eq!(model.available(), 0, "step {step}: fork failed with room");
                    }
                }
            }
            // cache-eviction path: release by block list, count freed
            _ => {
                if leases.is_empty() {
                    continue;
                }
                let i = rng.below(leases.len());
                let mut lease = leases.swap_remove(i);
                let blocks = std::mem::take(&mut lease.blocks);
                let freed = pool.release_blocks(&blocks);
                let expect = blocks.iter().filter(|&&b| model.release(b)).count();
                assert_eq!(freed, expect, "step {step}: release_blocks freed the wrong count");
            }
        }
        // global invariants after every mutation
        assert_eq!(pool.available(), model.available(), "step {step}: availability");
        assert_eq!(pool.leaked_blocks(), model.refs.len(), "step {step}: issued blocks");
        assert_eq!(
            pool.available() + pool.leaked_blocks(),
            TOTAL,
            "step {step}: conservation"
        );
        for lease in &leases {
            for &b in &lease.blocks {
                assert_eq!(
                    pool.refcount(b),
                    model.refs[&b],
                    "step {step}: refcount of block {b}"
                );
            }
        }
    }
    // teardown: returning every lease leaves the pool whole
    for mut lease in leases {
        pool.release(&mut lease);
    }
    assert_eq!(pool.available(), TOTAL, "teardown leaked capacity");
    assert_eq!(pool.leaked_blocks(), 0, "teardown stranded blocks");
}

#[test]
fn random_pool_sequences_match_reference_model() {
    for seed in 0..6 {
        run_sequence(seed, 2500);
    }
}

/// Deep share chains: the same run retained by many holders frees only
/// on the very last release, regardless of release order.
#[test]
fn many_holders_free_on_last_release_only() {
    let mut rng = Pcg64::new(99, 5);
    let mut pool = BlockPool::new(TOTAL, BLOCK_SLOTS);
    let mut owner = Lease::default();
    pool.alloc(4, &mut owner).unwrap();
    let run = owner.blocks.clone();
    let mut holders: Vec<Lease> = (0..5)
        .map(|_| {
            pool.retain(&run);
            Lease { blocks: run.clone() }
        })
        .collect();
    assert_eq!(pool.available(), TOTAL - 4, "sharing charges capacity once");
    assert_eq!(pool.refcount(run[0]), 6);
    holders.push(owner);
    rng.shuffle(&mut holders);
    for (i, mut h) in holders.into_iter().enumerate() {
        pool.release(&mut h);
        let expect = if i == 5 { TOTAL } else { TOTAL - 4 };
        assert_eq!(pool.available(), expect, "release {i}");
    }
    assert_eq!(pool.leaked_blocks(), 0);
}
