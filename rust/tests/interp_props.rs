//! Property tests for the HLO interpreter's op kernels (dot, reduce,
//! gather, broadcast, slice, dynamic-update-slice) against naive
//! hand-rolled references over random shapes and values. Each case goes
//! through the full text pipeline — built with the HLO builder, parsed
//! from text, then evaluated — so the parser is exercised on every
//! shape, not just the fixture graphs.

use std::rc::Rc;

use fasteagle::backend::hlo::builder::{HloBuilder, Ty};
use fasteagle::backend::hlo::eval::{evaluate, Value};
use fasteagle::backend::hlo::parser::parse_module;
use fasteagle::util::rng::Pcg64;

fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect()
}

fn run(text: &str, args: Vec<Value>) -> Vec<Value> {
    let m = parse_module(text).expect("parse built module");
    let args: Vec<Rc<Value>> = args.into_iter().map(Rc::new).collect();
    evaluate(&m, &args).expect("evaluate built module")
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-5 * (1.0 + b.abs())
}

#[test]
fn dot_matmul_matches_naive_over_random_shapes() {
    let mut rng = Pcg64::new(101, 0);
    for _ in 0..60 {
        let (m, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut hb = HloBuilder::new("dotp");
        let pa = hb.param(Ty::F32, vec![m, k]);
        let pb = hb.param(Ty::F32, vec![k, n]);
        let c = hb.matmul(&pa, &pb);
        let text = hb.finish(&[&c]);
        let out = run(
            &text,
            vec![Value::f32(vec![m, k], a.clone()), Value::f32(vec![k, n], b.clone())],
        );
        let got = out[0].f32s().unwrap();
        assert_eq!(out[0].dims, vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                assert!(close(got[i * n + j], acc), "({i},{j}): {} vs {acc}", got[i * n + j]);
            }
        }
    }
}

#[test]
fn batched_dot_matches_naive() {
    let mut rng = Pcg64::new(102, 0);
    for _ in 0..30 {
        let (bz, m, k, n) =
            (1 + rng.below(3), 1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5));
        let a = randv(&mut rng, bz * m * k);
        let b = randv(&mut rng, bz * k * n);
        let mut hb = HloBuilder::new("bdot");
        let pa = hb.param(Ty::F32, vec![bz, m, k]);
        let pb = hb.param(Ty::F32, vec![bz, k, n]);
        let c = hb.dot_general(&pa, &pb, &[0], &[0], &[2], &[1]);
        let text = hb.finish(&[&c]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![bz, m, k], a.clone()),
                Value::f32(vec![bz, k, n], b.clone()),
            ],
        );
        assert_eq!(out[0].dims, vec![bz, m, n]);
        let got = out[0].f32s().unwrap();
        for bb in 0..bz {
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f32;
                    for kk in 0..k {
                        acc += a[(bb * m + i) * k + kk] * b[(bb * k + kk) * n + j];
                    }
                    assert!(close(got[(bb * m + i) * n + j], acc));
                }
            }
        }
    }
}

#[test]
fn reduce_add_and_max_match_naive_over_random_dims() {
    let mut rng = Pcg64::new(103, 0);
    for _ in 0..60 {
        let dims = vec![1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
        let rd = rng.below(3);
        let data = randv(&mut rng, dims.iter().product());
        let mut hb = HloBuilder::new("red");
        let p = hb.param(Ty::F32, dims.clone());
        let s = hb.reduce_add(&p, &[rd]);
        let mx = hb.reduce_max(&p, &[rd]);
        let text = hb.finish(&[&s, &mx]);
        let out = run(&text, vec![Value::f32(dims.clone(), data.clone())]);
        let kept: Vec<usize> = (0..3).filter(|&d| d != rd).map(|d| dims[d]).collect();
        assert_eq!(out[0].dims, kept);
        let (gs, gm) = (out[0].f32s().unwrap(), out[1].f32s().unwrap());
        let (d0, d1, d2) = (dims[0], dims[1], dims[2]);
        let mut ns = vec![0f32; gs.len()];
        let mut nm = vec![f32::NEG_INFINITY; gm.len()];
        for i in 0..d0 {
            for j in 0..d1 {
                for k in 0..d2 {
                    let idx = [i, j, k];
                    let v = data[(i * d1 + j) * d2 + k];
                    let out_idx: Vec<usize> =
                        (0..3).filter(|&d| d != rd).map(|d| idx[d]).collect();
                    let o = out_idx[0] * kept[1] + out_idx[1];
                    ns[o] += v;
                    nm[o] = nm[o].max(v);
                }
            }
        }
        for (g, n) in gs.iter().zip(&ns) {
            assert!(close(*g, *n), "sum {g} vs {n}");
        }
        for (g, n) in gm.iter().zip(&nm) {
            assert_eq!(g, n, "max {g} vs {n}");
        }
    }
}

#[test]
fn gather_rows_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(104, 0);
    for _ in 0..60 {
        let (n, d, q) = (1 + rng.below(8), 1 + rng.below(6), 1 + rng.below(10));
        let table = randv(&mut rng, n * d);
        // indices include out-of-range values: HLO gather clamps starts
        let idx: Vec<i32> = (0..q).map(|_| rng.below(n + 4) as i32 - 2).collect();
        let mut hb = HloBuilder::new("gat");
        let pt = hb.param(Ty::F32, vec![n, d]);
        let pi = hb.param(Ty::S32, vec![q]);
        let g = hb.gather_rows(&pt, &pi);
        let text = hb.finish(&[&g]);
        let out = run(
            &text,
            vec![Value::f32(vec![n, d], table.clone()), Value::i32(vec![q], idx.clone())],
        );
        assert_eq!(out[0].dims, vec![q, d]);
        let got = out[0].f32s().unwrap();
        for (i, &ix) in idx.iter().enumerate() {
            let row = (ix.max(0) as usize).min(n - 1);
            assert_eq!(&got[i * d..(i + 1) * d], &table[row * d..(row + 1) * d]);
        }
    }
}

#[test]
fn broadcast_matches_naive_for_both_axes_and_scalar() {
    let mut rng = Pcg64::new(105, 0);
    for _ in 0..40 {
        let (a, b) = (1 + rng.below(6), 1 + rng.below(6));
        let rows = randv(&mut rng, a);
        let cols = randv(&mut rng, b);
        let mut hb = HloBuilder::new("bc");
        let pr = hb.param(Ty::F32, vec![a]);
        let pc = hb.param(Ty::F32, vec![b]);
        let br = hb.broadcast(&pr, vec![a, b], &[0]);
        let bc = hb.broadcast(&pc, vec![a, b], &[1]);
        let c = hb.const_f32(2.5);
        let bs = hb.splat(&c, vec![a, b]);
        let text = hb.finish(&[&br, &bc, &bs]);
        let out = run(
            &text,
            vec![Value::f32(vec![a], rows.clone()), Value::f32(vec![b], cols.clone())],
        );
        let (gr, gc, gs) =
            (out[0].f32s().unwrap(), out[1].f32s().unwrap(), out[2].f32s().unwrap());
        for i in 0..a {
            for j in 0..b {
                assert_eq!(gr[i * b + j], rows[i]);
                assert_eq!(gc[i * b + j], cols[j]);
                assert_eq!(gs[i * b + j], 2.5);
            }
        }
    }
}

#[test]
fn slice_matches_naive_over_random_ranges() {
    let mut rng = Pcg64::new(106, 0);
    for _ in 0..60 {
        let (a, b) = (2 + rng.below(6), 2 + rng.below(6));
        let data = randv(&mut rng, a * b);
        let s0 = rng.below(a - 1);
        let l0 = s0 + 1 + rng.below(a - s0);
        let s1 = rng.below(b - 1);
        let l1 = s1 + 1 + rng.below(b - s1);
        let mut hb = HloBuilder::new("sl");
        let p = hb.param(Ty::F32, vec![a, b]);
        let s = hb.slice(&p, &[(s0, l0), (s1, l1)]);
        let text = hb.finish(&[&s]);
        let out = run(&text, vec![Value::f32(vec![a, b], data.clone())]);
        assert_eq!(out[0].dims, vec![l0 - s0, l1 - s1]);
        let got = out[0].f32s().unwrap();
        for i in 0..(l0 - s0) {
            for j in 0..(l1 - s1) {
                assert_eq!(got[i * (l1 - s1) + j], data[(s0 + i) * b + (s1 + j)]);
            }
        }
    }
}

#[test]
fn dynamic_slice_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(109, 0);
    for _ in 0..60 {
        let (a, b) = (2 + rng.below(6), 2 + rng.below(6));
        let data = randv(&mut rng, a * b);
        let sa = 1 + rng.below(a);
        let sb = 1 + rng.below(b);
        // starts include out-of-range values: XLA clamps so the window fits
        let st_a = rng.below(a + 4) as i32 - 2;
        let st_b = rng.below(b + 4) as i32 - 2;
        let mut hb = HloBuilder::new("ds");
        let p = hb.param(Ty::F32, vec![a, b]);
        let s0 = hb.param(Ty::S32, vec![]);
        let s1 = hb.param(Ty::S32, vec![]);
        let d = hb.dynamic_slice(&p, &[s0, s1], &[sa, sb]);
        let text = hb.finish(&[&d]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![a, b], data.clone()),
                Value::i32(vec![], vec![st_a]),
                Value::i32(vec![], vec![st_b]),
            ],
        );
        assert_eq!(out[0].dims, vec![sa, sb]);
        let got = out[0].f32s().unwrap();
        let ca = (st_a.max(0) as usize).min(a - sa);
        let cb = (st_b.max(0) as usize).min(b - sb);
        for i in 0..sa {
            for j in 0..sb {
                assert_eq!(got[i * sb + j], data[(ca + i) * b + (cb + j)]);
            }
        }
    }
}

/// rng-bit-generator (threefry): output shape follows the request for
/// any length (including odd ones that split a 2x32 block), the stream
/// is a pure function of the state, distinct keys/counters produce
/// distinct streams, and the returned state advances by the blocks
/// consumed — so chaining calls through the returned state never
/// replays bits.
#[test]
fn rng_threefry_shape_determinism_and_state_advance() {
    let mut rng = Pcg64::new(108, 0);
    for _ in 0..40 {
        let n = 1 + rng.below(33);
        let key = rng.next_u64();
        let ctr = rng.next_u64();
        let mut hb = HloBuilder::new("rng");
        let st = hb.param(Ty::U64, vec![2]);
        let (ns, bits) = hb.rng_threefry(&st, vec![n]);
        let text = hb.finish(&[&ns, &bits]);
        let run1 = run(&text, vec![Value::u64(vec![2], vec![key, ctr])]);
        assert_eq!(run1[1].dims, vec![n], "bits shape follows the request");
        assert_eq!(
            run1[0].u64s().unwrap(),
            &[key, ctr.wrapping_add(n.div_ceil(2) as u64)],
            "state advances by blocks consumed"
        );
        // determinism: same state -> identical stream
        let run2 = run(&text, vec![Value::u64(vec![2], vec![key, ctr])]);
        assert_eq!(run1[1].u32s().unwrap(), run2[1].u32s().unwrap());
        // sensitivity: a different key or counter changes the stream
        // (compare the first block, which every n includes)
        let other = run(&text, vec![Value::u64(vec![2], vec![key ^ 1, ctr])]);
        assert_ne!(
            run1[1].u32s().unwrap()[0],
            other[1].u32s().unwrap()[0],
            "key must perturb the stream"
        );
        // chaining through the returned state yields fresh bits
        let next_state = run1[0].u64s().unwrap().to_vec();
        let chained = run(&text, vec![Value::u64(vec![2], next_state)]);
        assert_ne!(
            run1[1].u32s().unwrap()[0],
            chained[1].u32s().unwrap()[0],
            "advanced counter must not replay the stream"
        );
    }
}

#[test]
fn dynamic_update_slice_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(107, 0);
    for _ in 0..60 {
        let n = 2 + rng.below(10);
        let u = 1 + rng.below(n);
        let data = randv(&mut rng, n);
        let upd = randv(&mut rng, u);
        let start = rng.below(n + 4) as i32 - 2; // exercises clamping
        let mut hb = HloBuilder::new("dus");
        let p = hb.param(Ty::F32, vec![n]);
        let pu = hb.param(Ty::F32, vec![u]);
        let ps = hb.param(Ty::S32, vec![]);
        let o = hb.dus(&p, &pu, &[ps]);
        let text = hb.finish(&[&o]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![n], data.clone()),
                Value::f32(vec![u], upd.clone()),
                Value::i32(vec![], vec![start]),
            ],
        );
        let got = out[0].f32s().unwrap();
        let st = (start.max(0) as usize).min(n - u);
        let mut naive = data.clone();
        naive[st..st + u].copy_from_slice(&upd);
        assert_eq!(got, naive.as_slice());
    }
}
