//! Property tests for the HLO interpreter's op kernels (dot, reduce,
//! gather, broadcast, slice, dynamic-update-slice) against naive
//! hand-rolled references over random shapes and values. Each case goes
//! through the full text pipeline — built with the HLO builder, parsed
//! from text, then evaluated — so the parser is exercised on every
//! shape, not just the fixture graphs.
//!
//! The second half pits the compiled execution plan (`ExecPlan`:
//! fusion, buffer arena, in-place rewrites, worker pool) against the
//! naive evaluator on random whole programs and asserts *bit* equality
//! at every thread count — the interpreter's determinism contract.

mod common;

use std::rc::Rc;
use std::sync::Arc;

use fasteagle::backend::hlo::builder::{HloBuilder, Ty, H};
use fasteagle::backend::hlo::eval::{evaluate, Buf, Value};
use fasteagle::backend::hlo::parser::parse_module;
use fasteagle::backend::hlo::plan::{EvalOptions, ExecPlan};
use fasteagle::draft::make_drafter;
use fasteagle::model::TargetModel;
use fasteagle::spec::{Engine, GenConfig};
use fasteagle::util::rng::Pcg64;

fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() as f32) * 2.0 - 1.0).collect()
}

fn run(text: &str, args: Vec<Value>) -> Vec<Value> {
    let m = parse_module(text).expect("parse built module");
    let args: Vec<Arc<Value>> = args.into_iter().map(Arc::new).collect();
    evaluate(&m, &args).expect("evaluate built module")
}

/// Evaluate through the compiled plan with explicit options (no env).
fn run_planned(text: &str, args: &[Arc<Value>], threads: usize, fuse: bool) -> Vec<Value> {
    let m = Arc::new(parse_module(text).expect("parse built module"));
    let plan =
        ExecPlan::compile(&m, EvalOptions { threads, fuse }).expect("compile plan");
    plan.execute(args).expect("execute plan")
}

/// Bit-exact equality: f32 compared via `to_bits` (NaN-safe — identical
/// op order must produce identical NaN payloads too).
fn assert_bits_eq(a: &Value, b: &Value, what: &str) {
    assert_eq!(a.dims, b.dims, "{what}: dims");
    match (&a.buf, &b.buf) {
        (Buf::F32(x), Buf::F32(y)) => {
            assert_eq!(x.len(), y.len(), "{what}: f32 len");
            for (i, (u, v)) in x.iter().zip(y).enumerate() {
                assert_eq!(u.to_bits(), v.to_bits(), "{what}: f32[{i}] {u} vs {v}");
            }
        }
        (Buf::I32(x), Buf::I32(y)) => assert_eq!(x, y, "{what}: i32"),
        (Buf::U32(x), Buf::U32(y)) => assert_eq!(x, y, "{what}: u32"),
        (Buf::U64(x), Buf::U64(y)) => assert_eq!(x, y, "{what}: u64"),
        (Buf::Pred(x), Buf::Pred(y)) => assert_eq!(x, y, "{what}: pred"),
        _ => panic!("{what}: buffer dtype mismatch"),
    }
}

fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-5 * (1.0 + b.abs())
}

#[test]
fn dot_matmul_matches_naive_over_random_shapes() {
    let mut rng = Pcg64::new(101, 0);
    for _ in 0..60 {
        let (m, k, n) = (1 + rng.below(7), 1 + rng.below(7), 1 + rng.below(7));
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut hb = HloBuilder::new("dotp");
        let pa = hb.param(Ty::F32, vec![m, k]);
        let pb = hb.param(Ty::F32, vec![k, n]);
        let c = hb.matmul(&pa, &pb);
        let text = hb.finish(&[&c]);
        let out = run(
            &text,
            vec![Value::f32(vec![m, k], a.clone()), Value::f32(vec![k, n], b.clone())],
        );
        let got = out[0].f32s().unwrap();
        assert_eq!(out[0].dims, vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += a[i * k + kk] * b[kk * n + j];
                }
                assert!(close(got[i * n + j], acc), "({i},{j}): {} vs {acc}", got[i * n + j]);
            }
        }
    }
}

#[test]
fn batched_dot_matches_naive() {
    let mut rng = Pcg64::new(102, 0);
    for _ in 0..30 {
        let (bz, m, k, n) =
            (1 + rng.below(3), 1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5));
        let a = randv(&mut rng, bz * m * k);
        let b = randv(&mut rng, bz * k * n);
        let mut hb = HloBuilder::new("bdot");
        let pa = hb.param(Ty::F32, vec![bz, m, k]);
        let pb = hb.param(Ty::F32, vec![bz, k, n]);
        let c = hb.dot_general(&pa, &pb, &[0], &[0], &[2], &[1]);
        let text = hb.finish(&[&c]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![bz, m, k], a.clone()),
                Value::f32(vec![bz, k, n], b.clone()),
            ],
        );
        assert_eq!(out[0].dims, vec![bz, m, n]);
        let got = out[0].f32s().unwrap();
        for bb in 0..bz {
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0f32;
                    for kk in 0..k {
                        acc += a[(bb * m + i) * k + kk] * b[(bb * k + kk) * n + j];
                    }
                    assert!(close(got[(bb * m + i) * n + j], acc));
                }
            }
        }
    }
}

#[test]
fn reduce_add_and_max_match_naive_over_random_dims() {
    let mut rng = Pcg64::new(103, 0);
    for _ in 0..60 {
        let dims = vec![1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
        let rd = rng.below(3);
        let data = randv(&mut rng, dims.iter().product());
        let mut hb = HloBuilder::new("red");
        let p = hb.param(Ty::F32, dims.clone());
        let s = hb.reduce_add(&p, &[rd]);
        let mx = hb.reduce_max(&p, &[rd]);
        let text = hb.finish(&[&s, &mx]);
        let out = run(&text, vec![Value::f32(dims.clone(), data.clone())]);
        let kept: Vec<usize> = (0..3).filter(|&d| d != rd).map(|d| dims[d]).collect();
        assert_eq!(out[0].dims, kept);
        let (gs, gm) = (out[0].f32s().unwrap(), out[1].f32s().unwrap());
        let (d0, d1, d2) = (dims[0], dims[1], dims[2]);
        let mut ns = vec![0f32; gs.len()];
        let mut nm = vec![f32::NEG_INFINITY; gm.len()];
        for i in 0..d0 {
            for j in 0..d1 {
                for k in 0..d2 {
                    let idx = [i, j, k];
                    let v = data[(i * d1 + j) * d2 + k];
                    let out_idx: Vec<usize> =
                        (0..3).filter(|&d| d != rd).map(|d| idx[d]).collect();
                    let o = out_idx[0] * kept[1] + out_idx[1];
                    ns[o] += v;
                    nm[o] = nm[o].max(v);
                }
            }
        }
        for (g, n) in gs.iter().zip(&ns) {
            assert!(close(*g, *n), "sum {g} vs {n}");
        }
        for (g, n) in gm.iter().zip(&nm) {
            assert_eq!(g, n, "max {g} vs {n}");
        }
    }
}

#[test]
fn gather_rows_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(104, 0);
    for _ in 0..60 {
        let (n, d, q) = (1 + rng.below(8), 1 + rng.below(6), 1 + rng.below(10));
        let table = randv(&mut rng, n * d);
        // indices include out-of-range values: HLO gather clamps starts
        let idx: Vec<i32> = (0..q).map(|_| rng.below(n + 4) as i32 - 2).collect();
        let mut hb = HloBuilder::new("gat");
        let pt = hb.param(Ty::F32, vec![n, d]);
        let pi = hb.param(Ty::S32, vec![q]);
        let g = hb.gather_rows(&pt, &pi);
        let text = hb.finish(&[&g]);
        let out = run(
            &text,
            vec![Value::f32(vec![n, d], table.clone()), Value::i32(vec![q], idx.clone())],
        );
        assert_eq!(out[0].dims, vec![q, d]);
        let got = out[0].f32s().unwrap();
        for (i, &ix) in idx.iter().enumerate() {
            let row = (ix.max(0) as usize).min(n - 1);
            assert_eq!(&got[i * d..(i + 1) * d], &table[row * d..(row + 1) * d]);
        }
    }
}

#[test]
fn broadcast_matches_naive_for_both_axes_and_scalar() {
    let mut rng = Pcg64::new(105, 0);
    for _ in 0..40 {
        let (a, b) = (1 + rng.below(6), 1 + rng.below(6));
        let rows = randv(&mut rng, a);
        let cols = randv(&mut rng, b);
        let mut hb = HloBuilder::new("bc");
        let pr = hb.param(Ty::F32, vec![a]);
        let pc = hb.param(Ty::F32, vec![b]);
        let br = hb.broadcast(&pr, vec![a, b], &[0]);
        let bc = hb.broadcast(&pc, vec![a, b], &[1]);
        let c = hb.const_f32(2.5);
        let bs = hb.splat(&c, vec![a, b]);
        let text = hb.finish(&[&br, &bc, &bs]);
        let out = run(
            &text,
            vec![Value::f32(vec![a], rows.clone()), Value::f32(vec![b], cols.clone())],
        );
        let (gr, gc, gs) =
            (out[0].f32s().unwrap(), out[1].f32s().unwrap(), out[2].f32s().unwrap());
        for i in 0..a {
            for j in 0..b {
                assert_eq!(gr[i * b + j], rows[i]);
                assert_eq!(gc[i * b + j], cols[j]);
                assert_eq!(gs[i * b + j], 2.5);
            }
        }
    }
}

#[test]
fn slice_matches_naive_over_random_ranges() {
    let mut rng = Pcg64::new(106, 0);
    for _ in 0..60 {
        let (a, b) = (2 + rng.below(6), 2 + rng.below(6));
        let data = randv(&mut rng, a * b);
        let s0 = rng.below(a - 1);
        let l0 = s0 + 1 + rng.below(a - s0);
        let s1 = rng.below(b - 1);
        let l1 = s1 + 1 + rng.below(b - s1);
        let mut hb = HloBuilder::new("sl");
        let p = hb.param(Ty::F32, vec![a, b]);
        let s = hb.slice(&p, &[(s0, l0), (s1, l1)]);
        let text = hb.finish(&[&s]);
        let out = run(&text, vec![Value::f32(vec![a, b], data.clone())]);
        assert_eq!(out[0].dims, vec![l0 - s0, l1 - s1]);
        let got = out[0].f32s().unwrap();
        for i in 0..(l0 - s0) {
            for j in 0..(l1 - s1) {
                assert_eq!(got[i * (l1 - s1) + j], data[(s0 + i) * b + (s1 + j)]);
            }
        }
    }
}

#[test]
fn dynamic_slice_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(109, 0);
    for _ in 0..60 {
        let (a, b) = (2 + rng.below(6), 2 + rng.below(6));
        let data = randv(&mut rng, a * b);
        let sa = 1 + rng.below(a);
        let sb = 1 + rng.below(b);
        // starts include out-of-range values: XLA clamps so the window fits
        let st_a = rng.below(a + 4) as i32 - 2;
        let st_b = rng.below(b + 4) as i32 - 2;
        let mut hb = HloBuilder::new("ds");
        let p = hb.param(Ty::F32, vec![a, b]);
        let s0 = hb.param(Ty::S32, vec![]);
        let s1 = hb.param(Ty::S32, vec![]);
        let d = hb.dynamic_slice(&p, &[s0, s1], &[sa, sb]);
        let text = hb.finish(&[&d]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![a, b], data.clone()),
                Value::i32(vec![], vec![st_a]),
                Value::i32(vec![], vec![st_b]),
            ],
        );
        assert_eq!(out[0].dims, vec![sa, sb]);
        let got = out[0].f32s().unwrap();
        let ca = (st_a.max(0) as usize).min(a - sa);
        let cb = (st_b.max(0) as usize).min(b - sb);
        for i in 0..sa {
            for j in 0..sb {
                assert_eq!(got[i * sb + j], data[(ca + i) * b + (cb + j)]);
            }
        }
    }
}

/// rng-bit-generator (threefry): output shape follows the request for
/// any length (including odd ones that split a 2x32 block), the stream
/// is a pure function of the state, distinct keys/counters produce
/// distinct streams, and the returned state advances by the blocks
/// consumed — so chaining calls through the returned state never
/// replays bits.
#[test]
fn rng_threefry_shape_determinism_and_state_advance() {
    let mut rng = Pcg64::new(108, 0);
    for _ in 0..40 {
        let n = 1 + rng.below(33);
        let key = rng.next_u64();
        let ctr = rng.next_u64();
        let mut hb = HloBuilder::new("rng");
        let st = hb.param(Ty::U64, vec![2]);
        let (ns, bits) = hb.rng_threefry(&st, vec![n]);
        let text = hb.finish(&[&ns, &bits]);
        let run1 = run(&text, vec![Value::u64(vec![2], vec![key, ctr])]);
        assert_eq!(run1[1].dims, vec![n], "bits shape follows the request");
        assert_eq!(
            run1[0].u64s().unwrap(),
            &[key, ctr.wrapping_add(n.div_ceil(2) as u64)],
            "state advances by blocks consumed"
        );
        // determinism: same state -> identical stream
        let run2 = run(&text, vec![Value::u64(vec![2], vec![key, ctr])]);
        assert_eq!(run1[1].u32s().unwrap(), run2[1].u32s().unwrap());
        // sensitivity: a different key or counter changes the stream
        // (compare the first block, which every n includes)
        let other = run(&text, vec![Value::u64(vec![2], vec![key ^ 1, ctr])]);
        assert_ne!(
            run1[1].u32s().unwrap()[0],
            other[1].u32s().unwrap()[0],
            "key must perturb the stream"
        );
        // chaining through the returned state yields fresh bits
        let next_state = run1[0].u64s().unwrap().to_vec();
        let chained = run(&text, vec![Value::u64(vec![2], next_state)]);
        assert_ne!(
            run1[1].u32s().unwrap()[0],
            chained[1].u32s().unwrap()[0],
            "advanced counter must not replay the stream"
        );
    }
}

#[test]
fn dynamic_update_slice_matches_naive_with_clamping() {
    let mut rng = Pcg64::new(107, 0);
    for _ in 0..60 {
        let n = 2 + rng.below(10);
        let u = 1 + rng.below(n);
        let data = randv(&mut rng, n);
        let upd = randv(&mut rng, u);
        let start = rng.below(n + 4) as i32 - 2; // exercises clamping
        let mut hb = HloBuilder::new("dus");
        let p = hb.param(Ty::F32, vec![n]);
        let pu = hb.param(Ty::F32, vec![u]);
        let ps = hb.param(Ty::S32, vec![]);
        let o = hb.dus(&p, &pu, &[ps]);
        let text = hb.finish(&[&o]);
        let out = run(
            &text,
            vec![
                Value::f32(vec![n], data.clone()),
                Value::f32(vec![u], upd.clone()),
                Value::i32(vec![], vec![start]),
            ],
        );
        let got = out[0].f32s().unwrap();
        let st = (start.max(0) as usize).min(n - u);
        let mut naive = data.clone();
        naive[st..st + u].copy_from_slice(&upd);
        assert_eq!(got, naive.as_slice());
    }
}

/// Random whole programs — elementwise chains (exp/tanh/compare/select),
/// nested matmuls, reduce-then-broadcast, identity slices, handles used
/// more than once, multi-output roots — evaluated naively and through
/// the compiled plan at 1 and 4 threads, with fusion on and off. Every
/// output must match *bitwise*: the plan's fusion, arena recycling,
/// in-place rewrites, and row-parallel kernels are all required to
/// preserve the naive accumulation order exactly.
#[test]
fn random_programs_planned_vs_naive_bitwise() {
    let mut rng = Pcg64::new(110, 0);
    for case in 0..25 {
        let (r, c) = (2 + rng.below(6), 2 + rng.below(6));
        let mut hb = HloBuilder::new("randprog");
        let x = hb.param(Ty::F32, vec![r, c]);
        let w = hb.param(Ty::F32, vec![c, r]);
        let mut pool: Vec<H> = vec![x.clone()];
        let n_ops = 4 + rng.below(9);
        for _ in 0..n_ops {
            let a = pool[rng.below(pool.len())].clone();
            let b = pool[rng.below(pool.len())].clone();
            let h = match rng.below(9) {
                0 => hb.add(&a, &b),
                1 => hb.mul(&a, &b),
                2 => hb.max(&a, &b),
                3 => hb.exp(&a),
                4 => hb.tanh(&a),
                5 => {
                    let p = hb.compare(&a, &b, "GT");
                    let t = pool[rng.below(pool.len())].clone();
                    hb.select(&p, &t, &b)
                }
                6 => {
                    // reduce the last axis, broadcast the row sums back
                    let s = hb.reduce_add(&a, &[1]);
                    hb.broadcast(&s, vec![r, c], &[0])
                }
                7 => {
                    // [r,c] x [c,r] -> [r,r], then x pool elem -> [r,c]
                    let mm = hb.matmul(&a, &w);
                    hb.matmul(&mm, &b)
                }
                _ => hb.slice(&a, &[(0, r), (0, c)]),
            };
            pool.push(h);
        }
        let last = pool[pool.len() - 1].clone();
        let mid = pool[rng.below(pool.len())].clone();
        let tail = hb.reduce_max(&last, &[1]);
        let text = hb.finish(&[&last, &mid, &tail]);

        let xv = randv(&mut rng, r * c);
        let wv = randv(&mut rng, c * r);
        let args: Vec<Arc<Value>> = vec![
            Arc::new(Value::f32(vec![r, c], xv)),
            Arc::new(Value::f32(vec![c, r], wv)),
        ];
        let naive = evaluate(
            &parse_module(&text).expect("parse built module"),
            &args,
        )
        .expect("naive evaluate");
        for (threads, fuse) in [(1, true), (1, false), (4, true)] {
            let planned = run_planned(&text, &args, threads, fuse);
            assert_eq!(planned.len(), naive.len());
            for (i, (p, n)) in planned.iter().zip(&naive).enumerate() {
                assert_bits_eq(
                    p,
                    n,
                    &format!("case {case} out {i} (threads={threads}, fuse={fuse})"),
                );
            }
        }
    }
}

/// Fused elementwise chains with a *pred-typed* root: the fused loop
/// runs predicates as 0.0/1.0 masks internally and must materialize the
/// exact bools the naive path produces, alongside a converted-f32 and a
/// selected-f32 output off the same chain.
#[test]
fn fused_pred_chains_planned_vs_naive_bitwise() {
    let mut rng = Pcg64::new(111, 0);
    for case in 0..30 {
        let (r, c) = (2 + rng.below(6), 2 + rng.below(6));
        let mut hb = HloBuilder::new("predchain");
        let x = hb.param(Ty::F32, vec![r, c]);
        let y = hb.param(Ty::F32, vec![r, c]);
        let s = hb.add(&x, &y);
        let t = hb.tanh(&s);
        let p = hb.compare(&t, &y, "GT");
        let cv = hb.convert(&p, Ty::F32);
        let scaled = hb.mul(&cv, &s);
        let sel = hb.select(&p, &scaled, &x);
        let text = hb.finish(&[&p, &cv, &sel]);

        let args: Vec<Arc<Value>> = vec![
            Arc::new(Value::f32(vec![r, c], randv(&mut rng, r * c))),
            Arc::new(Value::f32(vec![r, c], randv(&mut rng, r * c))),
        ];
        let naive = evaluate(
            &parse_module(&text).expect("parse built module"),
            &args,
        )
        .expect("naive evaluate");
        for (threads, fuse) in [(1, true), (4, true), (1, false)] {
            let planned = run_planned(&text, &args, threads, fuse);
            for (i, (pv, nv)) in planned.iter().zip(&naive).enumerate() {
                assert_bits_eq(
                    pv,
                    nv,
                    &format!("case {case} out {i} (threads={threads}, fuse={fuse})"),
                );
            }
        }
    }
}

/// End-to-end identity: a full fixture generation with the compiled
/// plan (4 worker threads) emits byte-identical tokens to the naive
/// reference evaluator (`FE_INTERP_OPT=0`). This is the lossless-
/// acceptance guarantee the serving stack depends on, asserted through
/// the whole engine, not just per-op.
#[test]
fn e2e_tokens_identical_with_optimizations_on_and_off() {
    let (dir, kind) = common::artifacts_base();
    let drafter = if dir.join("weights").join("fasteagle.few").exists() {
        "fasteagle"
    } else {
        "vanilla"
    };
    let prompt = "USER: compare the optimized and reference interpreters.\nASSISTANT:";
    let cfg = GenConfig { max_new_tokens: 24, ..Default::default() };

    std::env::set_var("FE_INTERP_OPT", "0");
    let st = common::store_with(&dir, kind);
    let mut eng = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), drafter).unwrap(),
    );
    let reference = eng.generate(prompt, &cfg).unwrap();
    drop(eng);

    std::env::set_var("FE_INTERP_OPT", "1");
    std::env::set_var("FE_INTERP_THREADS", "4");
    let st = common::store_with(&dir, kind);
    let mut eng = Engine::new(
        TargetModel::open(Rc::clone(&st)).unwrap(),
        make_drafter(Rc::clone(&st), drafter).unwrap(),
    );
    let optimized = eng.generate(prompt, &cfg).unwrap();
    std::env::remove_var("FE_INTERP_OPT");
    std::env::remove_var("FE_INTERP_THREADS");

    assert_eq!(
        optimized.tokens, reference.tokens,
        "compiled plan diverged from the naive reference\n ref: {:?}\n got: {:?}",
        reference.text, optimized.text
    );
}
