//! `cargo bench` entry for the table1 harness (hand-rolled; criterion is
//! unavailable offline). FE_BENCH_QUICK=1 or `-- --quick` shrinks the
//! sweep; `-- --backend interpret` runs on the in-process HLO
//! interpreter (generating fixture artifacts if none exist), so this
//! lane runs anywhere without PJRT.
fn main() {
    fasteagle::bench::bench_main("table1");
}
