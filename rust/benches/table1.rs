//! `cargo bench` entry for the table1 harness (hand-rolled; criterion is
//! unavailable offline). FE_BENCH_QUICK=1 shrinks the sweep.
fn main() {
    let quick = std::env::var("FE_BENCH_QUICK").as_deref() == Ok("1");
    if let Err(e) = fasteagle::bench::run_named("table1", quick) {
        eprintln!("table1 failed: {e:#}");
        std::process::exit(1);
    }
}
