//! Host-side stand-in for the `xla` PJRT bindings the runtime layer
//! executes against. The real crate links `xla_extension` (PJRT CPU
//! plugin + HLO parser); this stand-in keeps the whole workspace
//! building and unit-testable in environments without that toolchain:
//!
//! * `Literal` is implemented for real (shape + dtype + bytes), so the
//!   host-tensor round-trip paths and their tests work unchanged.
//! * `PjRtClient::cpu()` and host→"device" buffer transfer work (a
//!   buffer just pins a literal).
//! * Anything that needs the actual compiler/runtime —
//!   `HloModuleProto::from_text_file`, `compile`, `execute_b` — returns
//!   a clear `Error`. The artifact-gated integration tests and benches
//!   already skip when no artifact tree is present, so the stand-in
//!   never reaches these paths under `cargo test`.
//!
//! Swap this path dependency for the real bindings to serve models.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "xla stand-in: {what} requires the real PJRT bindings (xla_extension); \
             this build uses the vendored host-side stub"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U32,
    U64,
    Bf16,
    F16,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 => 1,
            ElementType::Bf16 | ElementType::F16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Host-native element types that cross the boundary in this workspace.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn append_bytes(src: &[Self], dst: &mut Vec<u8>);
    fn from_bytes(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn append_bytes(src: &[Self], dst: &mut Vec<u8>) {
        for v in src {
            dst.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn from_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn append_bytes(src: &[Self], dst: &mut Vec<u8>) {
        for v in src {
            dst.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn from_bytes(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-resident array (or tuple) value.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        if numel * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal data length {} != shape {dims:?} x {ty:?}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(T::from_bytes(&self.data))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("tuple literals (executable outputs)"))
    }
}

/// Parsed HLO module (text is retained; real parsing needs the bindings).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        drop(text);
        Err(Error::unavailable("HLO text parsing"))
    }
}

pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "host-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let mut bytes = Vec::with_capacity(std::mem::size_of_val(data));
        T::append_bytes(data, &mut bytes);
        Ok(PjRtBuffer {
            lit: Literal::create_from_shape_and_untyped_data(T::TY, dims, &bytes)?,
        })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PJRT compilation"))
    }
}

pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data)
                .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4]
        )
        .is_err());
    }

    #[test]
    fn buffer_pins_literal() {
        let c = PjRtClient::cpu().unwrap();
        let b = c.buffer_from_host_buffer::<i32>(&[7, 8], &[2], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![7, 8]);
        assert_eq!(c.platform_name(), "host-stub");
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _p: () };
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("stand-in"));
    }
}
