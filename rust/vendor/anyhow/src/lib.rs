//! Offline stand-in for the `anyhow` crate (the crate registry in this
//! environment is empty — DESIGN.md §Substitutions). Implements the
//! subset the workspace uses: `Error` with a context chain, `Result`,
//! the `Context` extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` macros. Display mirrors upstream: `{}` prints the
//! outermost message, `{:#}` prints the full `outer: inner: ...` chain,
//! and `{:?}` prints a "Caused by:" listing.

use std::fmt;

/// `Result` with a defaulted error type, as upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message plus an optional chain of causes (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Causes from outermost to innermost, starting with this error.
    fn chain(&self) -> impl Iterator<Item = &Error> {
        std::iter::successors(Some(self), |e| e.source.as_deref())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, upstream-style.
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for e in self.chain().skip(1) {
                write!(f, "\n    {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the std source chain into our chain so `{:#}` keeps
        // the underlying cause (e.g. the io::Error under a file open).
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().unwrap());
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = io_err().into();
        let e = e.context("open spec.json").context("load artifacts");
        assert_eq!(format!("{e}"), "load artifacts");
        assert_eq!(format!("{e:#}"), "load artifacts: open spec.json: no such file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(3).context("present").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with code 7");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
    }
}
