//! Flight recorder: low-overhead tracing for the serving stack.
//!
//! Disabled by default. When off, every probe is a single relaxed atomic
//! load — no clock reads, no allocation, no locks — so instrumentation
//! stays in release builds for free. When armed (`obs::enable()`, the
//! `FE_TRACE=1` env var, `fasteagle serve --trace`, or `fasteagle
//! trace`), each recording thread lazily registers a fixed-capacity
//! lock-free ring ([`ring::Ring`]) and appends [`TraceEvent`]s to it;
//! memory is bounded at `capacity × threads` events and old events are
//! overwritten, which is exactly the flight-recorder contract: the
//! recent past is always available, arbitrarily old history is not.
//!
//! Two export formats sit on top of `snapshot()`:
//! - [`chrome::trace_json`] — Chrome trace-event JSON (load in
//!   `chrome://tracing` or <https://ui.perfetto.dev>); served by the TCP
//!   `{"cmd":"trace"}` command and written by `fasteagle trace`.
//! - [`prom::render`] — Prometheus text exposition of `ServingMetrics`
//!   (always-on counters/histograms, independent of the recorder);
//!   served by `{"cmd":"metrics"}`.
//!
//! Span/track conventions (see README "Observability"):
//! - `pid` is the replica (0 today), `tid` is the batch slot for
//!   request-lifecycle spans; `tid` 0 doubles as the engine thread for
//!   backend `execute`/`interp` spans, which always nest inside the
//!   slot-0 phase windows or sit between cycles.
//! - queue-wait spans live on `QUEUE_TID_BASE + (req % QUEUE_LANES)`
//!   lanes: a request can wait while its eventual slot is still busy
//!   with the previous occupant, so queue spans would otherwise
//!   partially overlap slot tracks.

pub mod chrome;
pub mod prom;
mod ring;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use ring::{Ring, EVENT_WORDS};

/// Events retained per recording thread.
pub const DEFAULT_CAPACITY: usize = 8192;

/// Base `tid` for queue-wait lanes (slot tids are far below this).
pub const QUEUE_TID_BASE: u32 = 1000;
/// Queue spans are spread over this many lanes by request id.
pub const QUEUE_LANES: u64 = 64;

const KIND_SPAN: u64 = 1;
const KIND_INSTANT: u64 = 2;

static ENABLED: AtomicBool = AtomicBool::new(false);
/// Bumped by `reset()`; threads holding a ring from an older generation
/// re-register a fresh one on their next record.
static GENERATION: AtomicU64 = AtomicU64::new(0);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);

fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static R: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

fn interner() -> &'static Mutex<Vec<String>> {
    static I: OnceLock<Mutex<Vec<String>>> = OnceLock::new();
    I.get_or_init(|| Mutex::new(Vec::new()))
}

fn time_origin() -> Instant {
    static T: OnceLock<Instant> = OnceLock::new();
    *T.get_or_init(Instant::now)
}

thread_local! {
    static LOCAL: RefCell<Option<(u64, Arc<Ring>)>> = const { RefCell::new(None) };
}

/// The hot-path check: a single relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm the recorder. Also pins the trace clock origin, so timestamps of
/// events (and of `span_from` starts taken after this call) are
/// microseconds since enablement.
pub fn enable() {
    let _ = time_origin();
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drop all recorded events and detach every thread's ring. Threads that
/// are mid-record keep writing to their orphaned ring until their next
/// event, which lands in a fresh one; such stragglers are lost, which is
/// fine for a flight recorder reset at a run boundary.
pub fn reset() {
    GENERATION.fetch_add(1, Ordering::SeqCst);
    registry().lock().unwrap_or_else(PoisonError::into_inner).clear();
    interner().lock().unwrap_or_else(PoisonError::into_inner).clear();
}

/// Set the per-thread ring capacity for rings created after this call.
pub fn set_capacity(events: usize) {
    CAPACITY.store(events.max(64), Ordering::SeqCst);
}

/// Microseconds since the trace clock origin.
pub fn ts_us(at: Instant) -> u64 {
    at.saturating_duration_since(time_origin()).as_micros() as u64
}

fn intern(s: &str) -> u32 {
    let mut v = interner().lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(i) = v.iter().position(|x| x == s) {
        return i as u32;
    }
    v.push(s.to_string());
    (v.len() - 1) as u32
}

/// Undecoded event fields that need no interning.
struct Raw {
    kind: u64,
    ts: u64,
    dur: u64,
    tid: u32,
    req: u64,
    arg: i64,
}

fn record(raw: Raw, name: &str, label: Option<&str>) {
    let generation = GENERATION.load(Ordering::SeqCst);
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let stale = match &*l {
            Some((g, _)) => *g != generation,
            None => true,
        };
        if stale {
            let ring = Arc::new(Ring::new(CAPACITY.load(Ordering::SeqCst)));
            registry()
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(Arc::clone(&ring));
            *l = Some((generation, ring));
        }
        let Some((_, ring)) = &*l else { return };
        let name_id = intern(name) as u64;
        let label_id = label.map(|s| intern(s) as u64 + 1).unwrap_or(0);
        ring.push(&[
            raw.ts,
            raw.dur,
            name_id | (raw.kind << 32),
            // pid (replica, low 32) | tid (high 32)
            (raw.tid as u64) << 32,
            raw.req,
            raw.arg as u64,
            label_id,
        ]);
    });
}

/// One decoded flight-recorder event.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub ts_us: u64,
    /// zero for instants
    pub dur_us: u64,
    pub name: String,
    /// true: duration span (Chrome `ph:"X"`); false: instant (`ph:"i"`)
    pub is_span: bool,
    pub pid: u32,
    pub tid: u32,
    /// request id, 0 when not request-scoped
    pub req: u64,
    /// span-specific count (tokens, rows, depth, …)
    pub arg: i64,
    /// optional detail string (e.g. executable name)
    pub label: Option<String>,
}

/// Decode and collect every live event, sorted by timestamp (ties: the
/// longer span first, so parents precede children).
pub fn snapshot() -> Vec<TraceEvent> {
    let rings: Vec<Arc<Ring>> = registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone();
    let names: Vec<String> = interner()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone();
    let mut raw: Vec<[u64; EVENT_WORDS]> = Vec::new();
    for r in &rings {
        r.drain_into(&mut raw);
    }
    let mut events: Vec<TraceEvent> = raw
        .iter()
        .filter_map(|w| {
            let name_id = (w[2] & 0xffff_ffff) as usize;
            let kind = w[2] >> 32;
            let name = names.get(name_id)?.clone();
            let label = match w[6] {
                0 => None,
                id => Some(names.get(id as usize - 1)?.clone()),
            };
            Some(TraceEvent {
                ts_us: w[0],
                dur_us: w[1],
                name,
                is_span: kind == KIND_SPAN,
                pid: (w[3] & 0xffff_ffff) as u32,
                tid: (w[3] >> 32) as u32,
                req: w[4],
                arg: w[5] as i64,
                label,
            })
        })
        .collect();
    events.sort_by(|a, b| a.ts_us.cmp(&b.ts_us).then(b.dur_us.cmp(&a.dur_us)));
    events
}

/// Total events ever recorded (including overwritten ones) in the
/// current generation.
pub fn recorded_total() -> u64 {
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .iter()
        .map(|r| r.written())
        .sum()
}

/// Convenience: snapshot and render as Chrome trace-event JSON.
pub fn chrome_trace_json() -> String {
    chrome::trace_json(&snapshot())
}

/// RAII span: records a Chrome `X` (complete) event on drop. Inactive —
/// carrying no clock read and skipping all builder work — when the
/// recorder is disabled at creation.
pub struct SpanGuard {
    start: Option<Instant>,
    fixed_dur: Option<Duration>,
    name: &'static str,
    tid: u32,
    req: u64,
    arg: i64,
    label: Option<String>,
}

/// Open a span starting now.
#[must_use = "a span records when dropped"]
pub fn span(name: &'static str) -> SpanGuard {
    let start = if enabled() { Some(Instant::now()) } else { None };
    SpanGuard { start, fixed_dur: None, name, tid: 0, req: 0, arg: 0, label: None }
}

/// Open a span back-dated to `start` (e.g. a request's arrival time).
#[must_use = "a span records when dropped"]
pub fn span_from(name: &'static str, start: Instant) -> SpanGuard {
    let start = if enabled() { Some(start) } else { None };
    SpanGuard { start, fixed_dur: None, name, tid: 0, req: 0, arg: 0, label: None }
}

impl SpanGuard {
    pub fn tid(mut self, tid: u32) -> SpanGuard {
        self.tid = tid;
        self
    }

    pub fn req(mut self, req: u64) -> SpanGuard {
        self.req = req;
        self
    }

    pub fn arg(mut self, arg: i64) -> SpanGuard {
        self.arg = arg;
        self
    }

    /// Set the count argument after the fact (e.g. once a result size is
    /// known, just before the guard drops).
    pub fn set_arg(&mut self, arg: i64) {
        self.arg = arg;
    }

    /// Attach a detail string; allocates only when the span is active.
    pub fn label(mut self, label: &str) -> SpanGuard {
        if self.start.is_some() {
            self.label = Some(label.to_string());
        }
        self
    }

    /// Fix the span's duration instead of measuring to the drop point —
    /// used to attribute one batched section's wall time to every slot
    /// that shared it.
    pub fn dur(mut self, dur: Duration) -> SpanGuard {
        if self.start.is_some() {
            self.fixed_dur = Some(dur);
        }
        self
    }

    /// Record now, consuming the guard.
    pub fn emit(self) {}
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur = self.fixed_dur.unwrap_or_else(|| start.elapsed());
        record(
            Raw {
                kind: KIND_SPAN,
                ts: ts_us(start),
                dur: dur.as_micros() as u64,
                tid: self.tid,
                req: self.req,
                arg: self.arg,
            },
            self.name,
            self.label.as_deref(),
        );
    }
}

/// Record an instant event (Chrome `ph:"i"`).
pub fn mark(name: &'static str, tid: u32, req: u64, arg: i64) {
    if !enabled() {
        return;
    }
    let raw = Raw { kind: KIND_INSTANT, ts: ts_us(Instant::now()), dur: 0, tid, req, arg };
    record(raw, name, None);
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global; serialize tests that toggle it so
    // they cannot observe each other's events.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static M: Mutex<()> = Mutex::new(());
        M.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = guard();
        disable();
        reset();
        span("obs_test_disabled").tid(7).req(1).emit();
        mark("obs_test_disabled_mark", 7, 1, 0);
        assert_eq!(recorded_total(), 0);
        assert!(snapshot().is_empty());
    }

    #[test]
    fn span_and_mark_round_trip() {
        let _g = guard();
        enable();
        reset();
        {
            let mut s = span("obs_test_outer").tid(3).req(42).label("exec_a");
            s.set_arg(9);
            std::thread::sleep(Duration::from_millis(2));
            span("obs_test_inner").tid(3).req(42).emit();
            drop(s);
        }
        mark("obs_test_mark", 3, 42, 5);
        let events = snapshot();
        disable();
        let outer = events
            .iter()
            .find(|e| e.name == "obs_test_outer")
            .expect("outer span recorded");
        assert!(outer.is_span);
        assert_eq!(outer.tid, 3);
        assert_eq!(outer.pid, 0);
        assert_eq!(outer.req, 42);
        assert_eq!(outer.arg, 9);
        assert_eq!(outer.label.as_deref(), Some("exec_a"));
        assert!(outer.dur_us >= 2000, "outer dur {}", outer.dur_us);
        let inner = events
            .iter()
            .find(|e| e.name == "obs_test_inner")
            .expect("inner span recorded");
        // inner nests within outer on the trace clock
        assert!(inner.ts_us >= outer.ts_us);
        assert!(inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1);
        let m = events
            .iter()
            .find(|e| e.name == "obs_test_mark")
            .expect("mark recorded");
        assert!(!m.is_span);
        assert_eq!(m.arg, 5);
    }

    #[test]
    fn fixed_duration_and_backdated_start() {
        let _g = guard();
        enable();
        reset();
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        span_from("obs_test_backdated", t0)
            .dur(Duration::from_micros(1234))
            .tid(1)
            .emit();
        let events = snapshot();
        disable();
        let e = events
            .iter()
            .find(|e| e.name == "obs_test_backdated")
            .expect("backdated span recorded");
        assert_eq!(e.dur_us, 1234);
    }

    #[test]
    fn reset_drops_history() {
        let _g = guard();
        enable();
        reset();
        span("obs_test_reset_victim").emit();
        assert!(snapshot().iter().any(|e| e.name == "obs_test_reset_victim"));
        reset();
        assert!(snapshot().is_empty());
        // the thread re-registers transparently after a reset
        span("obs_test_reset_survivor").emit();
        let events = snapshot();
        disable();
        assert!(events.iter().any(|e| e.name == "obs_test_reset_survivor"));
        assert!(!events.iter().any(|e| e.name == "obs_test_reset_victim"));
    }

    #[test]
    fn events_from_multiple_threads_are_collected() {
        let _g = guard();
        enable();
        reset();
        let hs: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    span("obs_test_thread").tid(100 + i).req(i as u64).emit();
                })
            })
            .collect();
        for h in hs {
            h.join().expect("thread");
        }
        let events = snapshot();
        disable();
        let n = events.iter().filter(|e| e.name == "obs_test_thread").count();
        assert_eq!(n, 3);
    }
}
