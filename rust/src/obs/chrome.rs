//! Chrome trace-event JSON rendering for flight-recorder snapshots.
//!
//! Output is the "JSON object format" of the trace-event spec: a single
//! line `{"traceEvents":[...]}` loadable in `chrome://tracing` or
//! <https://ui.perfetto.dev>. Spans are complete events (`ph:"X"` with
//! `ts`/`dur` in microseconds), instants are `ph:"i"` with thread scope.
//! `pid` is the replica, `tid` the batch slot (or a queue lane, see
//! `obs::QUEUE_TID_BASE`); the request id and span-specific counts ride
//! in `args`.

use crate::util::json::Json;

use super::TraceEvent;

fn event_json(e: &TraceEvent) -> Json {
    let mut args = vec![("req", Json::num(e.req as f64)), ("n", Json::num(e.arg as f64))];
    if let Some(label) = &e.label {
        args.push(("exec", Json::str(label)));
    }
    let mut fields = vec![
        ("name", Json::str(&e.name)),
        ("cat", Json::str("serve")),
        ("ph", Json::str(if e.is_span { "X" } else { "i" })),
        ("ts", Json::num(e.ts_us as f64)),
        ("pid", Json::num(e.pid as f64)),
        ("tid", Json::num(e.tid as f64)),
        ("args", Json::obj(args)),
    ];
    if e.is_span {
        fields.push(("dur", Json::num(e.dur_us as f64)));
    } else {
        // instant scope: thread
        fields.push(("s", Json::str("t")));
    }
    Json::obj(fields)
}

/// Render a snapshot as single-line Chrome trace-event JSON.
pub fn trace_json(events: &[TraceEvent]) -> String {
    let arr: Vec<Json> = events.iter().map(event_json).collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(arr)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, ts: u64, dur: u64, tid: u32, req: u64) -> TraceEvent {
        TraceEvent {
            ts_us: ts,
            dur_us: dur,
            name: name.to_string(),
            is_span: true,
            pid: 0,
            tid,
            req,
            arg: 0,
            label: None,
        }
    }

    #[test]
    fn trace_json_is_valid_and_complete() {
        let mut e = span("verify", 10, 20, 2, 7);
        e.label = Some("tgt_m4_b4".to_string());
        e.arg = 5;
        let mut i = span("done", 40, 0, 2, 7);
        i.is_span = false;
        let text = trace_json(&[e, i]);
        assert!(!text.contains('\n'), "trace must be a single line");
        let v = Json::parse(&text).expect("valid JSON");
        let events = v.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        assert_eq!(events.len(), 2);
        for ev in events {
            for key in ["name", "ph", "ts", "pid", "tid"] {
                assert!(ev.get(key).is_some(), "event missing {key}");
            }
        }
        assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(events[0].get("dur").and_then(Json::as_i64), Some(20));
        assert_eq!(events[0].path("args.exec").and_then(Json::as_str), Some("tgt_m4_b4"));
        assert_eq!(events[0].path("args.n").and_then(Json::as_i64), Some(5));
        assert_eq!(events[1].get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(events[1].get("s").and_then(Json::as_str), Some("t"));
    }
}
