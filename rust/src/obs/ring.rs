//! Lock-free per-thread event ring: fixed capacity, overwrite-oldest.
//!
//! Each thread owns one `Ring` for writing; readers (`drain_into`) may
//! run concurrently from other threads — the TCP `trace` command snapshots
//! live rings while the engine keeps recording. Every slot is a tiny
//! seqlock over plain `AtomicU64` words: the writer marks the slot odd,
//! stores the payload, then marks it even; a reader that observes an odd
//! or changed sequence discards the slot. Torn events are dropped, never
//! misreported, and no `unsafe` is involved. All orderings are `SeqCst` —
//! events are rare (a handful per engine cycle) so the barrier cost is
//! irrelevant next to the `Instant::now()` calls around them.

use std::sync::atomic::{AtomicU64, Ordering};

/// Words per encoded event; see `obs::encode` for the layout.
pub(crate) const EVENT_WORDS: usize = 7;

struct Slot {
    /// 0 = never written, odd = write in progress, even = generation tag
    seq: AtomicU64,
    words: [AtomicU64; EVENT_WORDS],
}

pub(crate) struct Ring {
    slots: Box<[Slot]>,
    /// total events ever pushed; low bits index the slot array
    head: AtomicU64,
}

impl Ring {
    pub fn new(capacity: usize) -> Ring {
        let cap = capacity.next_power_of_two().max(64);
        let slots: Vec<Slot> = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                words: std::array::from_fn(|_| AtomicU64::new(0)),
            })
            .collect();
        Ring { slots: slots.into_boxed_slice(), head: AtomicU64::new(0) }
    }

    /// Single designated writer per ring (the owning thread).
    pub fn push(&self, words: &[u64; EVENT_WORDS]) {
        let idx = self.head.fetch_add(1, Ordering::SeqCst);
        let slot = &self.slots[(idx as usize) & (self.slots.len() - 1)];
        // odd: writing — readers started before this store will fail the
        // generation recheck in drain_into
        slot.seq.store(idx.wrapping_mul(2).wrapping_add(1), Ordering::SeqCst);
        for (w, &v) in slot.words.iter().zip(words) {
            w.store(v, Ordering::SeqCst);
        }
        // even: stable, tagged with this write's generation
        slot.seq.store(idx.wrapping_mul(2).wrapping_add(2), Ordering::SeqCst);
    }

    /// Events ever pushed (including any since overwritten).
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::SeqCst)
    }

    /// Copy every stable slot out; torn slots are skipped.
    pub fn drain_into(&self, out: &mut Vec<[u64; EVENT_WORDS]>) {
        for slot in self.slots.iter() {
            let s1 = slot.seq.load(Ordering::SeqCst);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let mut words = [0u64; EVENT_WORDS];
            for (o, w) in words.iter_mut().zip(slot.words.iter()) {
                *o = w.load(Ordering::SeqCst);
            }
            if slot.seq.load(Ordering::SeqCst) == s1 {
                out.push(words);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_round_trips_events() {
        let r = Ring::new(64);
        r.push(&[1, 2, 3, 4, 5, 6, 7]);
        r.push(&[10, 20, 30, 40, 50, 60, 70]);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert!(out.contains(&[1, 2, 3, 4, 5, 6, 7]));
        assert!(out.contains(&[10, 20, 30, 40, 50, 60, 70]));
        assert_eq!(r.written(), 2);
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let r = Ring::new(64); // rounded to 64 slots
        for i in 0..200u64 {
            r.push(&[i, 0, 0, 0, 0, 0, 0]);
        }
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.len(), 64);
        // only the newest 64 events survive
        for w in &out {
            assert!(w[0] >= 200 - 64, "stale event {} survived", w[0]);
        }
        assert_eq!(r.written(), 200);
    }

    #[test]
    fn ring_capacity_rounds_up() {
        let r = Ring::new(100); // -> 128
        for i in 0..128u64 {
            r.push(&[i, 0, 0, 0, 0, 0, 0]);
        }
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.len(), 128);
    }
}
