//! Prometheus text-exposition rendering of `ServingMetrics`.
//!
//! Served by the TCP `{"cmd":"metrics"}` command. The body is
//! multi-line, so — to stay framable inside the JSON-lines protocol —
//! the reply is terminated by a literal `# EOF` line (the OpenMetrics
//! terminator); readers consume lines until they see it.
//!
//! Histogram buckets come from `util::stats::Histogram` via
//! `count_le_us`, which counts whole internal log-buckets whose upper
//! edge fits under the `le` bound: cumulative counts are conservative
//! (never include a sample above the bound) and monotone in the bound.
//! Phase histograms export as one `fe_phase_us` family labeled by
//! `method` (a `BatchMethod` name) and `phase`
//! (`sched|draft|verify|accept`), so fasteagle vs eagle3 draft cost is
//! a single PromQL comparison.

use std::fmt::Write as _;

use crate::coordinator::ServingMetrics;
use crate::util::stats::Histogram;

/// `le` bucket bounds in microseconds: 10µs .. 10s.
const LE_BOUNDS_US: [u64; 10] =
    [10, 50, 100, 500, 1_000, 5_000, 10_000, 100_000, 1_000_000, 10_000_000];

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn scalar(out: &mut String, name: &str, kind: &str, help: &str, v: f64) {
    header(out, name, kind, help);
    let _ = writeln!(out, "{name} {v}");
}

/// One histogram series; `labels` is either empty or a `k="v",` prefix
/// (trailing comma included) for the `le` label to follow.
fn hist_series(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    for bound in LE_BOUNDS_US {
        let _ = writeln!(
            out,
            "{name}_bucket{{{labels}le=\"{bound}\"}} {}",
            h.count_le_us(bound as f64)
        );
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}le=\"+Inf\"}} {}", h.count());
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_sum {}", h.sum_us());
        let _ = writeln!(out, "{name}_count {}", h.count());
    } else {
        let trimmed = labels.trim_end_matches(',');
        let _ = writeln!(out, "{name}_sum{{{trimmed}}} {}", h.sum_us());
        let _ = writeln!(out, "{name}_count{{{trimmed}}} {}", h.count());
    }
}

fn hist(out: &mut String, name: &str, help: &str, h: &Histogram) {
    header(out, name, "histogram", help);
    hist_series(out, name, "", h);
}

/// Render the full exposition, terminated by a `# EOF` line.
pub fn render(m: &ServingMetrics) -> String {
    let mut out = String::new();
    let counters: [(&str, &str, u64); 15] = [
        ("fe_requests_done_total", "completed generations", m.requests_done),
        ("fe_requests_rejected_total", "requests shed at admission", m.requests_rejected),
        ("fe_requests_deferred_total", "requests deferred under KV pressure", m.requests_deferred),
        ("fe_requests_failed_total", "requests answered with an error", m.requests_failed),
        ("fe_requests_canceled_total", "requests evicted by a cancel command", m.requests_canceled),
        ("fe_requests_expired_total", "requests that missed their deadline", m.requests_expired),
        ("fe_tokens_out_total", "committed output tokens", m.tokens_out),
        ("fe_cycles_total", "decode cycles run", m.cycles),
        ("fe_prefill_chunks_total", "prompt chunks ingested on the batch lane", m.prefill_chunks),
        ("fe_preemptions_total", "slots parked under pool pressure", m.preemptions),
        ("fe_resumes_total", "parked requests restored into a slot", m.resumes),
        ("fe_prefix_cache_hits_total", "admissions that adopted a cached prefix", m.cache_hits),
        ("fe_prefix_cache_misses_total", "admissions that found no cached prefix", m.cache_misses),
        (
            "fe_prefix_cache_saved_tokens_total",
            "prompt tokens adopted instead of prefilled",
            m.cache_saved_tokens,
        ),
        (
            "fe_prefix_cache_evicted_blocks_total",
            "pool blocks reclaimed from the prefix cache",
            m.cache_evicted_blocks,
        ),
    ];
    for (name, help, v) in counters {
        scalar(&mut out, name, "counter", help, v as f64);
    }
    let gauges: [(&str, &str, f64); 11] = [
        ("fe_parked_tokens", "committed tokens held by parked requests", m.parked_tokens as f64),
        ("fe_parked_tokens_peak", "peak of fe_parked_tokens", m.parked_tokens_peak as f64),
        ("fe_occupancy_mean", "mean occupied slots per scheduler step", m.mean_occupancy()),
        ("fe_occupancy_peak", "peak occupied slots", m.occupancy_peak as f64),
        ("fe_tau_mean", "mean accepted tokens per cycle", m.mean_tau()),
        ("fe_plan_depth_mean", "mean planned draft depth per run cycle", m.mean_plan_depth()),
        ("fe_plan_nodes_mean", "mean planned draft nodes per run cycle", m.mean_plan_nodes()),
        ("fe_accept_window_mean", "mean adaptive acceptance window", m.mean_accept_window()),
        (
            "fe_prefix_cache_nodes",
            "radix-index nodes held by the prefix cache",
            m.cache_nodes as f64,
        ),
        ("fe_prefix_cache_blocks", "pool blocks held by the prefix cache", m.cache_blocks as f64),
        ("fe_prefix_cache_hit_rate", "hits / (hits + misses) over admissions", m.cache_hit_rate()),
    ];
    for (name, help, v) in gauges {
        scalar(&mut out, name, "gauge", help, v);
    }
    hist(&mut out, "fe_request_latency_us", "request arrival to completion", &m.latency);
    hist(&mut out, "fe_queue_wait_us", "request arrival to slot admission", &m.queue_wait);
    hist(&mut out, "fe_ttfc_us", "request arrival to end of first decode cycle", &m.ttfc);
    header(
        &mut out,
        "fe_phase_us",
        "histogram",
        "engine section wall time by method and phase (sched|draft|verify|accept)",
    );
    for (&(method, phase), h) in &m.phase_us {
        let labels = format!("method=\"{method}\",phase=\"{phase}\",");
        hist_series(&mut out, "fe_phase_us", &labels, h);
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn sample_metrics() -> ServingMetrics {
        let mut m = ServingMetrics::default();
        m.requests_done += 3;
        m.tokens_out += 42;
        m.latency.record_us(1500.0);
        m.queue_wait.record_us(90.0);
        m.ttfc.record_us(800.0);
        m.record_phase("fasteagle", "draft", Duration::from_micros(120));
        m.record_phase("fasteagle", "verify", Duration::from_micros(900));
        m.record_phase("eagle3", "draft", Duration::from_micros(2400));
        m.cache_hits = 2;
        m.cache_misses = 2;
        m.cache_saved_tokens = 32;
        m.record_cache_gauges(3, 12);
        m.requests_canceled = 1;
        m.requests_expired = 2;
        m
    }

    #[test]
    fn render_is_parseable_exposition() {
        let text = render(&sample_metrics());
        assert!(text.ends_with("# EOF\n"));
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            // every sample line is `name[{labels}] value`
            let (series, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(!series.is_empty(), "{line}");
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in {line:?}"));
            if let Some(open) = series.find('{') {
                assert!(series.ends_with('}'), "{line}");
                let labels = &series[open + 1..series.len() - 1];
                for kv in labels.split(',') {
                    let (k, v) = kv.split_once('=').expect("label is k=v");
                    assert!(!k.is_empty() && v.starts_with('"') && v.ends_with('"'), "{line}");
                }
            }
        }
    }

    #[test]
    fn phase_series_distinguish_methods() {
        let text = render(&sample_metrics());
        let has = |s: &str| text.contains(s);
        assert!(has("fe_phase_us_bucket{method=\"fasteagle\",phase=\"draft\",le=\"500\"} 1"));
        assert!(has("fe_phase_us_count{method=\"fasteagle\",phase=\"draft\"} 1"));
        assert!(has("fe_phase_us_count{method=\"eagle3\",phase=\"draft\"} 1"));
        assert!(has("fe_phase_us_count{method=\"fasteagle\",phase=\"verify\"} 1"));
        // the 2.4ms eagle3 draft sits above the 500us bucket
        assert!(has("fe_phase_us_bucket{method=\"eagle3\",phase=\"draft\",le=\"500\"} 0"));
        assert!(has("fe_phase_us_bucket{method=\"eagle3\",phase=\"draft\",le=\"+Inf\"} 1"));
    }

    #[test]
    fn buckets_are_monotone_and_counters_present() {
        let text = render(&sample_metrics());
        assert!(text.contains("fe_requests_done_total 3"));
        assert!(text.contains("fe_requests_canceled_total 1"));
        assert!(text.contains("fe_requests_expired_total 2"));
        assert!(text.contains("fe_tokens_out_total 42"));
        assert!(text.contains("fe_prefix_cache_hits_total 2"));
        assert!(text.contains("fe_prefix_cache_saved_tokens_total 32"));
        assert!(text.contains("fe_prefix_cache_nodes 3"));
        assert!(text.contains("fe_prefix_cache_blocks 12"));
        assert!(text.contains("fe_prefix_cache_hit_rate 0.5"));
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("fe_request_latency_us_bucket{le=\"") {
                let v: u64 = rest.rsplit_once(' ').expect("value").1.parse().expect("count");
                assert!(v >= last, "{line}");
                last = v;
            }
        }
        assert_eq!(last, 1, "the 1.5ms latency sample lands under +Inf");
    }
}
