//! Resumable, step-driven generation: the draft → verify → commit
//! *cycle* as the public unit of progress.
//!
//! FastEagle's single-pass cascade makes the cycle the natural
//! scheduling quantum, and per-cycle control is what streaming partial
//! tokens and adaptive draft structures (AdaEAGLE-style) hang off. This
//! module is the **single home of the cycle state machine**:
//!
//! * [`SlotCycle`] — the per-request cycle core (sampler, pending/root
//!   token, committed output, eos/max_new termination, metrics). Both
//!   the single-request [`GenSession`] and every continuous-batcher
//!   slot drive one, so the EAGLE-family observe/accept contract lives
//!   in exactly one place.
//! * [`GenSession`] — a resumable session over a target + drafter:
//!   `Engine::start_session(..)` then repeated [`GenSession::step`],
//!   each returning a [`CycleEvent`] with the tokens committed that
//!   cycle. `Engine::generate` is a thin drain-the-session wrapper.
//! * [`prompt_budget`] / [`truncate_prompt`] / [`verify_rows`] — the
//!   shared prompt-truncation and tree→verification-row plumbing.

use std::time::Instant;

use anyhow::Result;

use crate::draft::{DraftOutput, Drafter, ObserveArgs};
use crate::model::{KvCache, MaskRow, ModelSpec, TargetModel, Tokenizer};

use super::accept::{verify_tree, AcceptResult};
use super::engine::{GenConfig, GenResult};
use super::metrics::GenMetrics;
use super::plan::{DraftPlan, DraftPlanner};
use super::sampler::Sampler;
use super::tree::DraftTree;

/// Lifecycle phase of one request's slot. A freshly admitted request
/// ingests its prompt in fixed-token chunks that ride along the batched
/// decode steps (`Prefilling`); once the last chunk lands it owns a
/// [`SlotCycle`] and runs one draft → verify → commit cycle per step
/// (`Decoding`). The single-request [`GenSession`] collapses the
/// prefill phase into its constructor, so it is always `Decoding` by
/// the time callers can observe it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPhase {
    /// prompt chunks still being ingested on the batched lane
    Prefilling,
    /// running draft → verify → commit cycles
    Decoding,
}

/// What one cycle produced. `committed_tokens` is exactly the slice
/// appended to the request's output this cycle (post eos/max_new
/// truncation), so concatenating events reproduces the final token
/// stream byte-for-byte.
#[derive(Debug, Clone)]
pub struct CycleEvent {
    pub committed_tokens: Vec<i32>,
    /// bonus token sampled from the target at the last accepted node
    /// (next cycle's pending/root token)
    pub bonus: i32,
    /// accepted path length including the root
    pub accepted_len: usize,
    /// (depth, accepted?) walk events (Fig. 3 instrumentation)
    pub depth_events: Vec<(usize, bool)>,
    pub finished: bool,
}

impl CycleEvent {
    fn noop(pending: i32) -> CycleEvent {
        CycleEvent {
            committed_tokens: Vec::new(),
            bonus: pending,
            accepted_len: 0,
            depth_events: Vec::new(),
            finished: true,
        }
    }
}

/// What [`SlotCycle::commit`] decided for one cycle.
#[derive(Debug, Clone)]
pub struct CycleCommit {
    /// full accepted path tokens (root first) — the drafter's new anchors
    pub accepted_tokens: Vec<i32>,
    /// token_{j+1} per anchor (bonus closes the last pair) — the
    /// drafter-observe `next_tokens` contract
    pub observe_next: Vec<i32>,
    /// tokens actually appended to the output this cycle
    pub committed: Vec<i32>,
    pub finished: bool,
}

/// Prompt-token budget so the worst-case cycle still fits in `max_seq`:
/// the committed output plus `worst_case_rows` temporary verification
/// rows. The single-request engine derives `worst_case_rows` from the
/// request's base [`DraftPlan`] (`total_rows() + 1` — tree rows plus
/// the bonus row), the batched lane from its executable shape
/// (`chain_len + 3`).
pub fn prompt_budget(max_seq: usize, max_new_tokens: usize, worst_case_rows: usize) -> usize {
    max_seq.saturating_sub(max_new_tokens + worst_case_rows)
}

/// Keep the newest `budget` prompt tokens (prompts are truncated from
/// the front so the generation context survives).
pub fn truncate_prompt(ptoks: &mut Vec<i32>, budget: usize) {
    if ptoks.len() > budget {
        *ptoks = ptoks[ptoks.len() - budget..].to_vec();
    }
}

/// (tokens, positions, mask rows) for one tree verification with the
/// canonical prefix ending at `base`. Slot i's row sees the prefix plus
/// its own ancestor chain in the temp region — the tree-attention mask.
pub fn verify_rows(
    tree: &DraftTree,
    base: usize,
    max_seq: usize,
) -> (Vec<i32>, Vec<i32>, Vec<MaskRow>) {
    let tokens = tree.tokens();
    let positions: Vec<i32> = tree
        .depths()
        .iter()
        .map(|&d| ((base + d) as i32).min(max_seq as i32 - 1))
        .collect();
    let rows: Vec<MaskRow> = (0..tree.len())
        .map(|i| MaskRow {
            prefix_upto: base,
            extra: tree.ancestors(i).iter().map(|&s| base + s).collect(),
        })
        .collect();
    (tokens, positions, rows)
}

/// Per-request cycle state shared by [`GenSession`] (B=1) and the
/// continuous batcher's slots: per-request sampler, pending token,
/// committed output and termination bookkeeping. Everything a request
/// carries *between* cycles, independent of how the forward passes are
/// batched.
#[derive(Debug, Clone)]
pub struct SlotCycle {
    pub cfg: GenConfig,
    pub sampler: Sampler,
    /// per-request draft-structure planner (static or adaptive),
    /// seeded from the resolved base plan
    planner: Box<dyn DraftPlanner>,
    /// the plan governing the current cycle — refreshed by
    /// [`begin_cycle`](Self::begin_cycle) before drafting
    pub plan: DraftPlan,
    /// next cycle's root: always a true target-distribution sample
    pub pending: i32,
    /// committed tokens beyond the prompt
    pub out: Vec<i32>,
    pub metrics: GenMetrics,
    pub eos_hit: bool,
    finished: bool,
}

impl SlotCycle {
    /// Start a request's cycle state from the prefill's last-token
    /// logits: seeds the per-request sampler, builds the draft planner
    /// from the resolved `base` plan, and draws the first pending token.
    pub fn start(cfg: GenConfig, base: DraftPlan, last_logits: &[f32]) -> SlotCycle {
        let mut sampler = Sampler::new(cfg.temperature, cfg.seed);
        let d0 = sampler.dist_from_logits(last_logits);
        let pending = sampler.sample(&d0);
        let finished = cfg.max_new_tokens == 0;
        let planner = cfg.draft.planner_kind().build(base.clone());
        SlotCycle {
            cfg,
            sampler,
            planner,
            plan: base,
            pending,
            out: Vec::new(),
            metrics: GenMetrics::default(),
            eos_hit: false,
            finished,
        }
    }

    /// Ask the planner for the cycle about to run and make its plan
    /// current. Callers draft to `plan.depth` levels and then feed the
    /// drafter's output to [`build_tree`](Self::build_tree).
    pub fn begin_cycle(&mut self) -> &DraftPlan {
        self.plan = self.planner.next_plan();
        &self.plan
    }

    /// Rolling acceptance-window mean, when the planner keeps one
    /// (adaptive observability — `None` for static plans).
    pub fn accept_window_mean(&self) -> Option<f64> {
        self.planner.window_mean()
    }

    pub fn finished(&self) -> bool {
        self.finished
    }

    /// Terminate externally (capacity exhaustion, abort).
    pub fn finish(&mut self) {
        self.finished = true;
    }

    /// Build this cycle's constrained tree from a drafter's output
    /// under the current [`DraftPlan`] — the one home of depth
    /// truncation, branching, the node budget and the greedy-top-k vs
    /// sampled-without-replacement candidate rule.
    pub fn build_tree(&mut self, draft: DraftOutput) -> DraftTree {
        let _g = self.metrics.timer.start("tree");
        DraftTree::from_draft(self.pending, draft, &self.plan, &mut self.sampler)
    }

    /// Lossless acceptance over `logits` (row-major, one `vocab`-sized
    /// row per tree slot). Records the cycle into the metrics and feeds
    /// the accepted draft length back to the planner.
    pub fn accept(&mut self, tree: &DraftTree, logits: &[f32], vocab: usize) -> AcceptResult {
        let acc = {
            let _g = self.metrics.timer.start("accept");
            let target_dists: Vec<Vec<f32>> = (0..tree.len())
                .map(|i| self.sampler.dist_from_logits(&logits[i * vocab..(i + 1) * vocab]))
                .collect();
            verify_tree(tree, &target_dists, &mut self.sampler)
        };
        self.metrics
            .record_cycle(acc.accepted_slots.len(), &acc.depth_events);
        self.planner
            .observe(acc.accepted_slots.len().saturating_sub(1));
        acc
    }

    /// Fold an acceptance into the request: append the accepted path to
    /// the output (honoring `stop_on_eos` and `max_new_tokens`), advance
    /// the pending token to the bonus, and report what this cycle
    /// committed plus the drafter-observe token pairs.
    pub fn commit(&mut self, tree: &DraftTree, acc: &AcceptResult, eos: i32) -> CycleCommit {
        let accepted_tokens: Vec<i32> = acc
            .accepted_slots
            .iter()
            .map(|&s| tree.nodes[s].token)
            .collect();
        let mut observe_next: Vec<i32> = accepted_tokens[1..].to_vec();
        observe_next.push(acc.bonus);
        self.pending = acc.bonus;
        let start = self.out.len();
        self.out.extend_from_slice(&accepted_tokens);
        if self.cfg.stop_on_eos && !self.eos_hit {
            if let Some(p) = self.out[start..].iter().position(|&t| t == eos) {
                self.out.truncate(start + p + 1);
                self.eos_hit = true;
            }
        }
        if self.out.len() >= self.cfg.max_new_tokens {
            self.out.truncate(self.cfg.max_new_tokens);
            self.finished = true;
        }
        if self.eos_hit {
            self.finished = true;
        }
        CycleCommit {
            accepted_tokens,
            observe_next,
            committed: self.out[start..].to_vec(),
            finished: self.finished,
        }
    }
}

/// A resumable single-request generation session: prefill happens in
/// [`GenSession::new`], then each [`step`](GenSession::step) runs one
/// draft → verify → commit cycle and yields a [`CycleEvent`]. Dropping
/// the session abandons the generation; [`finish`](GenSession::finish)
/// assembles the same [`GenResult`] the blocking `Engine::generate`
/// returns.
pub struct GenSession<'e> {
    target: &'e TargetModel,
    drafter: &'e mut Box<dyn Drafter>,
    tokenizer: Tokenizer,
    spec: ModelSpec,
    kv: KvCache,
    pub cycle: SlotCycle,
    /// worst-case rows one cycle may append (base plan + bonus row) —
    /// the capacity-guard margin
    worst_rows: usize,
    t_start: Instant,
    sealed: bool,
}

impl<'e> GenSession<'e> {
    pub fn new(
        target: &'e TargetModel,
        drafter: &'e mut Box<dyn Drafter>,
        tokenizer: Tokenizer,
        prompt: &str,
        cfg: &GenConfig,
    ) -> Result<GenSession<'e>> {
        let t_start = Instant::now();
        let spec = target.spec.clone();
        let mut metrics = GenMetrics::default();
        drafter.reset()?;
        let mut kv = target.new_kv()?;

        // resolve the request's draft knobs into the base plan: the
        // depth default is this drafter's own level count, so an unset
        // plan never truncates what the drafter natively emits
        let base = DraftPlan::resolve(&cfg.draft, &spec, drafter.depth());
        let worst_rows = base.total_rows() + 1;

        // prompt, truncated so the worst-case cycle still fits in max_seq
        let mut ptoks = tokenizer.encode_prompt(prompt);
        let budget = prompt_budget(spec.max_seq, cfg.max_new_tokens, worst_rows);
        truncate_prompt(&mut ptoks, budget);
        metrics.prompt_tokens = ptoks.len();

        // prefill + initial pending token
        let pre = {
            let _g = metrics.timer.start("prefill");
            let _sp = crate::obs::span("prefill").arg(ptoks.len() as i64);
            target.prefill(&mut kv, &ptoks)?
        };
        let mut cycle = SlotCycle::start(cfg.clone(), base, &pre.last_logits);
        cycle.metrics = metrics;
        {
            let _g = cycle.metrics.timer.start("observe");
            let mut next: Vec<i32> = ptoks[1..].to_vec();
            next.push(cycle.pending);
            drafter.observe(ObserveArgs {
                feats: &pre.feats,
                anchor_tokens: &ptoks,
                next_tokens: &next,
                first_pos: 0,
            })?;
        }
        Ok(GenSession {
            target,
            drafter,
            tokenizer,
            spec,
            kv,
            cycle,
            worst_rows,
            t_start,
            sealed: false,
        })
    }

    pub fn finished(&self) -> bool {
        self.cycle.finished()
    }

    /// Committed tokens so far.
    pub fn tokens(&self) -> &[i32] {
        &self.cycle.out
    }

    pub fn metrics(&self) -> &GenMetrics {
        &self.cycle.metrics
    }

    fn seal(&mut self) {
        if !self.sealed {
            self.cycle.metrics.new_tokens = self.cycle.out.len();
            self.cycle.metrics.wall = self.t_start.elapsed();
            self.sealed = true;
        }
    }

    /// Run one draft → verify → commit cycle. On a finished session this
    /// is a no-op event with `finished: true`.
    pub fn step(&mut self) -> Result<CycleEvent> {
        if self.cycle.finished() {
            self.seal();
            return Ok(CycleEvent::noop(self.cycle.pending));
        }
        let c = self.kv.len(0);
        // capacity guard: pending + worst-case tree rows must fit
        if c + self.worst_rows > self.spec.max_seq {
            self.cycle.finish();
            self.seal();
            return Ok(CycleEvent::noop(self.cycle.pending));
        }

        let _cycle_span = crate::obs::span("cycle");
        // 1. plan, then draft to the planned depth (a level costs real
        // work for sequential drafters — EAGLE's eg_next chain, SpS's
        // LM steps — so levels the plan would drop are never drafted)
        let levels = {
            let plan = self.cycle.begin_cycle();
            plan.depth.min(plan.node_budget)
        };
        let draft_out = {
            let _g = self.cycle.metrics.timer.start("draft");
            let _sp = crate::obs::span("draft").arg(levels as i64);
            self.drafter
                .draft(self.cycle.pending, c - 1, self.cycle.cfg.temperature, levels)?
        };
        let tree = self.cycle.build_tree(draft_out);

        // 2. verify: one target forward over all tree rows
        let (tokens, positions, rows) = verify_rows(&tree, c, self.spec.max_seq);
        let vout = {
            let _g = self.cycle.metrics.timer.start("verify");
            let _sp = crate::obs::span("verify").arg(tree.len() as i64);
            self.target.step(&mut self.kv, &tokens, &positions, &rows)?
        };

        // 3. accept (lossless)
        let accept = {
            let _sp = crate::obs::span("accept");
            self.cycle.accept(&tree, &vout.logits, self.spec.vocab)
        };

        // 4. commit: compact accepted rows into the canonical prefix
        {
            let _g = self.cycle.metrics.timer.start("commit");
            let _sp = crate::obs::span("commit").arg(accept.accepted_slots.len() as i64);
            self.kv.compact(0, c, &accept.accepted_slots)?;
        }
        let commit = self.cycle.commit(&tree, &accept, self.spec.eos);

        // 5. drafter observes the new anchors (verified features)
        {
            let _g = self.cycle.metrics.timer.start("observe");
            let fd = self.spec.feat_dim;
            let mut feats = Vec::with_capacity(accept.accepted_slots.len() * fd);
            for &s in &accept.accepted_slots {
                feats.extend_from_slice(&vout.feats[s * fd..(s + 1) * fd]);
            }
            self.drafter.observe(ObserveArgs {
                feats: &feats,
                anchor_tokens: &commit.accepted_tokens,
                next_tokens: &commit.observe_next,
                first_pos: c,
            })?;
        }
        if self.cycle.finished() {
            self.seal();
        }
        Ok(CycleEvent {
            committed_tokens: commit.committed,
            bonus: accept.bonus,
            accepted_len: accept.accepted_slots.len(),
            depth_events: accept.depth_events,
            finished: self.cycle.finished(),
        })
    }

    /// Consume the session into the blocking-API result.
    pub fn finish(mut self) -> GenResult {
        self.seal();
        let text = self.tokenizer.decode(&self.cycle.out);
        GenResult {
            tokens: std::mem::take(&mut self.cycle.out),
            text,
            metrics: std::mem::take(&mut self.cycle.metrics),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_budget_and_truncation() {
        assert_eq!(prompt_budget(256, 64, 20), 172);
        assert_eq!(prompt_budget(16, 64, 20), 0);
        let mut toks: Vec<i32> = (0..10).collect();
        truncate_prompt(&mut toks, 4);
        assert_eq!(toks, vec![6, 7, 8, 9]);
        let mut toks: Vec<i32> = (0..3).collect();
        truncate_prompt(&mut toks, 4);
        assert_eq!(toks, vec![0, 1, 2]);
    }

    #[test]
    fn verify_rows_mirror_tree_ancestry() {
        let dists = vec![vec![0.6f32, 0.4], vec![0.7, 0.3]];
        let tree = DraftTree::backbone_expansion(1, dists, 2);
        let (tokens, positions, rows) = verify_rows(&tree, 10, 64);
        assert_eq!(tokens, tree.tokens());
        assert_eq!(positions[0], 10);
        assert_eq!(rows.len(), tree.len());
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.prefix_upto, 10);
            let anc: Vec<usize> = tree.ancestors(i).iter().map(|&s| 10 + s).collect();
            assert_eq!(r.extra, anc);
        }
        // positions clamp at max_seq - 1
        let (_, positions, _) = verify_rows(&tree, 63, 64);
        assert!(positions.iter().all(|&p| p <= 63));
    }

    fn one_hot(v: usize, hot: usize) -> Vec<f32> {
        let mut d = vec![0.0; v];
        d[hot] = 1.0;
        d
    }

    #[test]
    fn slot_cycle_commits_and_terminates() {
        let cfg = GenConfig { max_new_tokens: 3, ..Default::default() };
        let mut cy = SlotCycle::start(cfg, DraftPlan::uniform(4, 1), &one_hot(8, 5));
        assert_eq!(cy.pending, 5);
        assert!(!cy.finished());

        // greedy chain 5 -> 2 accepted, bonus 7
        let draft = DraftOutput::Levels(vec![one_hot(8, 2)]);
        let tree = cy.build_tree(draft);
        let mut logits = Vec::new();
        for slot in 0..tree.len() {
            let hot = match tree.nodes[slot].token {
                5 => 2usize,
                2 => 7,
                _ => 0,
            };
            logits.extend(one_hot(8, hot));
        }
        let acc = cy.accept(&tree, &logits, 8);
        assert_eq!(acc.accepted_slots.len(), 2);
        let commit = cy.commit(&tree, &acc, 999);
        assert_eq!(commit.committed, vec![5, 2]);
        assert_eq!(commit.accepted_tokens, vec![5, 2]);
        assert_eq!(commit.observe_next, vec![2, 7]);
        assert!(!commit.finished);
        assert_eq!(cy.pending, 7);
        assert_eq!(cy.metrics.cycles, 1);
        assert_eq!(cy.metrics.tau_sum, 2);

        // next cycle overflows max_new: committed truncated to 1 token
        let draft = DraftOutput::Levels(vec![one_hot(8, 4)]);
        let tree = cy.build_tree(draft);
        let mut logits = Vec::new();
        for slot in 0..tree.len() {
            let hot = match tree.nodes[slot].token {
                7 => 4usize,
                4 => 6,
                _ => 0,
            };
            logits.extend(one_hot(8, hot));
        }
        let acc = cy.accept(&tree, &logits, 8);
        let commit = cy.commit(&tree, &acc, 999);
        assert_eq!(commit.committed, vec![7]);
        assert!(commit.finished);
        assert!(cy.finished());
        assert_eq!(cy.out, vec![5, 2, 7]);
    }

    #[test]
    fn slot_cycle_stops_on_eos_inclusive() {
        let eos = 3;
        let cfg = GenConfig { max_new_tokens: 10, stop_on_eos: true, ..Default::default() };
        let mut cy = SlotCycle::start(cfg, DraftPlan::uniform(4, 1), &one_hot(8, 1));
        let draft = DraftOutput::Levels(vec![one_hot(8, eos as usize), one_hot(8, 6)]);
        let tree = cy.build_tree(draft);
        let mut logits = Vec::new();
        for slot in 0..tree.len() {
            let hot = match tree.nodes[slot].token {
                1 => eos as usize,
                3 => 6usize,
                _ => 0,
            };
            logits.extend(one_hot(8, hot));
        }
        let acc = cy.accept(&tree, &logits, 8);
        let commit = cy.commit(&tree, &acc, eos);
        // eos itself is committed, nothing after it
        assert_eq!(*commit.committed.last().unwrap(), eos);
        assert!(cy.eos_hit);
        assert!(cy.finished());
    }

    #[test]
    fn zero_budget_request_finishes_without_a_cycle() {
        let cfg = GenConfig { max_new_tokens: 0, ..Default::default() };
        let cy = SlotCycle::start(cfg, DraftPlan::uniform(4, 1), &one_hot(4, 2));
        assert!(cy.finished());
    }

    #[test]
    fn adaptive_slot_cycle_shrinks_its_plan_after_rejections() {
        use crate::spec::plan::{DraftConfig, PlannerKind};
        let cfg = GenConfig {
            max_new_tokens: 100,
            draft: DraftConfig { planner: Some(PlannerKind::Adaptive), ..Default::default() },
            ..Default::default()
        };
        let mut cy = SlotCycle::start(cfg, DraftPlan::uniform(4, 1), &one_hot(8, 5));
        // first cycle plans the full base shape
        assert_eq!(cy.begin_cycle().depth, 4);
        assert!(cy.accept_window_mean().is_none());
        // a draft the target rejects outright: root committed, 0 drafts
        let draft = DraftOutput::Levels(vec![one_hot(8, 2)]);
        let tree = cy.build_tree(draft);
        // target wants 6 everywhere: draft token 2 is rejected
        let mut logits = Vec::new();
        for _ in 0..tree.len() {
            logits.extend(one_hot(8, 6));
        }
        let acc = cy.accept(&tree, &logits, 8);
        assert_eq!(acc.accepted_slots.len(), 1, "only the root survives");
        assert_eq!(cy.accept_window_mean(), Some(0.0));
        // the planner saw the rejection: the next plan is shallower
        assert_eq!(cy.begin_cycle().depth, 1);
    }
}
