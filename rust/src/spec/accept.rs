//! Lossless verification over the draft tree (paper §2.4 "Parallel
//! Verification").
//!
//! Greedy (T=0): walk the backbone/side candidates, accepting the child
//! whose token equals the target argmax at the current node — output is
//! token-identical to vanilla greedy decoding (asserted by the
//! `losslessness` integration test).
//!
//! Stochastic (T>0): multi-round speculative sampling (Leviathan et al.,
//! extended to sibling candidates as in SpecInfer/EAGLE): each candidate
//! x is accepted with prob min(1, p(x)/q(x)); on rejection the target
//! residual p ← norm(relu(p − q)) and the draft q zeroes the rejected
//! token, so the committed token is always an exact sample from the
//! target distribution.

use super::sampler::Sampler;
use super::tree::DraftTree;

#[derive(Debug, Clone)]
pub struct AcceptResult {
    /// accepted path slots (ascending), always starting with the root 0
    pub accepted_slots: Vec<usize>,
    /// bonus token sampled from the target distribution at the last
    /// accepted node (becomes the next cycle's pending/root token)
    pub bonus: i32,
    /// (depth, accepted?) for every level the walk attempted — feeds the
    /// Fig. 3 per-depth acceptance-rate curves
    pub depth_events: Vec<(usize, bool)>,
}

/// `target_dists[slot]` = temperature-adjusted target distribution at
/// tree slot `slot` (i.e. the distribution of the token *after* that
/// node's token).
pub fn verify_tree(
    tree: &DraftTree,
    target_dists: &[Vec<f32>],
    sampler: &mut Sampler,
) -> AcceptResult {
    assert_eq!(target_dists.len(), tree.len());
    let mut accepted = vec![0usize];
    let mut events = Vec::new();
    let mut cur = 0usize;
    loop {
        let children = tree.children(cur);
        if children.is_empty() {
            let bonus = sampler.sample(&target_dists[cur]);
            return AcceptResult { accepted_slots: accepted, bonus, depth_events: events };
        }
        let depth = tree.nodes[children[0]].depth;
        if sampler.greedy() {
            let p = &target_dists[cur];
            let best = crate::util::rng::argmax(p) as i32;
            if let Some(&c) = children.iter().find(|&&c| tree.nodes[c].token == best) {
                events.push((depth, true));
                accepted.push(c);
                cur = c;
            } else {
                events.push((depth, false));
                return AcceptResult {
                    accepted_slots: accepted,
                    bonus: best,
                    depth_events: events,
                };
            }
        } else {
            let mut p = target_dists[cur].clone();
            let level = tree.nodes[children[0]].level;
            let mut q = tree.dists[level].clone();
            let mut hit = None;
            for &c in &children {
                let tok = tree.nodes[c].token as usize;
                let (px, qx) = (p[tok], q[tok]);
                let a = if qx <= 0.0 {
                    if px > 0.0 {
                        1.0
                    } else {
                        0.0
                    }
                } else {
                    (px / qx).min(1.0)
                };
                if sampler.coin() < a {
                    hit = Some(c);
                    break;
                }
                // reject: residualize p, remove tok from q
                residualize(&mut p, &q, tok);
                q[tok] = 0.0;
                normalize(&mut q);
            }
            match hit {
                Some(c) => {
                    events.push((depth, true));
                    accepted.push(c);
                    cur = c;
                }
                None => {
                    events.push((depth, false));
                    let bonus = sampler.sample(&p);
                    return AcceptResult {
                        accepted_slots: accepted,
                        bonus,
                        depth_events: events,
                    };
                }
            }
        }
    }
}

/// p ← norm(relu(p − q)), with fallbacks that keep p a valid
/// distribution and never resurrect the rejected token.
fn residualize(p: &mut [f32], q: &[f32], rejected: usize) {
    for (pi, qi) in p.iter_mut().zip(q.iter()) {
        *pi = (*pi - *qi).max(0.0);
    }
    p[rejected] = 0.0;
    if !normalize(p) {
        // degenerate residual (p == q): fall back to p minus the
        // rejected token
        for (i, pi) in p.iter_mut().enumerate() {
            *pi = if i == rejected { 0.0 } else { q[i] };
        }
        if !normalize(p) {
            // everything concentrated on the rejected token: uniform
            let u = 1.0 / (p.len() - 1) as f32;
            for (i, pi) in p.iter_mut().enumerate() {
                *pi = if i == rejected { 0.0 } else { u };
            }
        }
    }
}

fn normalize(d: &mut [f32]) -> bool {
    let s: f32 = d.iter().sum();
    if s <= 0.0 {
        return false;
    }
    let inv = 1.0 / s;
    for v in d.iter_mut() {
        *v *= inv;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot(v: usize, hot: usize) -> Vec<f32> {
        let mut d = vec![0.0; v];
        d[hot] = 1.0;
        d
    }

    fn mix(v: usize, pairs: &[(usize, f32)]) -> Vec<f32> {
        let mut d = vec![0.0; v];
        for &(i, p) in pairs {
            d[i] = p;
        }
        d
    }

    #[test]
    fn greedy_accepts_matching_backbone() {
        let v = 8;
        // drafter predicts 1 then 2; target agrees
        let dists = vec![mix(v, &[(1, 0.9), (3, 0.1)]), mix(v, &[(2, 0.9), (4, 0.1)])];
        let tree = DraftTree::backbone_expansion(0, dists, 2);
        let mut s = Sampler::new(0.0, 7);
        // target: after root -> 1; after node(1) -> 2; after node(2) -> 5
        let tds: Vec<Vec<f32>> = (0..tree.len())
            .map(|slot| match tree.nodes[slot].token {
                0 => one_hot(v, 1),
                1 => one_hot(v, 2),
                2 => one_hot(v, 5),
                _ => one_hot(v, 7),
            })
            .collect();
        let r = verify_tree(&tree, &tds, &mut s);
        assert_eq!(r.accepted_slots.len(), 3); // root + both levels
        assert_eq!(r.bonus, 5);
        assert_eq!(r.depth_events, vec![(1, true), (2, true)]);
    }

    #[test]
    fn greedy_takes_side_branch() {
        let v = 8;
        let dists = vec![mix(v, &[(1, 0.6), (3, 0.4)])];
        let tree = DraftTree::backbone_expansion(0, dists, 2);
        let mut s = Sampler::new(0.0, 7);
        // target wants 3 (the side candidate), then 6
        let tds: Vec<Vec<f32>> = (0..tree.len())
            .map(|slot| match tree.nodes[slot].token {
                0 => one_hot(v, 3),
                3 => one_hot(v, 6),
                _ => one_hot(v, 7),
            })
            .collect();
        let r = verify_tree(&tree, &tds, &mut s);
        assert_eq!(r.accepted_slots.len(), 2);
        assert_eq!(tree.nodes[r.accepted_slots[1]].token, 3);
        assert_eq!(r.bonus, 6);
    }

    #[test]
    fn greedy_rejects_all() {
        let v = 8;
        let dists = vec![mix(v, &[(1, 0.6), (3, 0.4)])];
        let tree = DraftTree::backbone_expansion(0, dists, 2);
        let mut s = Sampler::new(0.0, 7);
        let tds: Vec<Vec<f32>> = (0..tree.len()).map(|_| one_hot(v, 5)).collect();
        let r = verify_tree(&tree, &tds, &mut s);
        assert_eq!(r.accepted_slots, vec![0]);
        assert_eq!(r.bonus, 5);
        assert_eq!(r.depth_events, vec![(1, false)]);
    }

    /// Core losslessness property: with q == p the committed-token
    /// distribution must equal p exactly; here we check the acceptance
    /// never changes the marginal of the first committed token.
    #[test]
    fn stochastic_first_token_marginal_is_lossless() {
        let v = 4;
        let q = mix(v, &[(0, 0.45), (1, 0.35), (2, 0.15), (3, 0.05)]);
        let p = mix(v, &[(0, 0.2), (1, 0.3), (2, 0.4), (3, 0.1)]);
        let n = 200_000;
        let mut counts = vec![0usize; v];
        let mut s = Sampler::new(1.0, 42);
        for _ in 0..n {
            // candidates must be re-sampled per draw (without
            // replacement) for the multi-round rule to be lossless
            let tree = DraftTree::backbone_expansion_sampled(
                9, vec![q.clone()], 2, s.rng_mut());
            let tds: Vec<Vec<f32>> = vec![p.clone(); tree.len()];
            let r = verify_tree(&tree, &tds, &mut s);
            // first token after root: either an accepted level-1 node or
            // the residual bonus
            let tok = if r.accepted_slots.len() > 1 {
                tree.nodes[r.accepted_slots[1]].token
            } else {
                r.bonus
            };
            counts[tok as usize] += 1;
        }
        for i in 0..v {
            let freq = counts[i] as f64 / n as f64;
            assert!(
                (freq - p[i] as f64).abs() < 0.01,
                "token {i}: freq {freq} vs p {}",
                p[i]
            );
        }
    }

    #[test]
    fn stochastic_q_equals_p_accepts_everything_eventually() {
        // When q == p and k == V (all tokens are candidates), some child
        // must always be accepted (total acceptance mass = 1).
        let v = 4;
        let p = mix(v, &[(0, 0.25), (1, 0.25), (2, 0.25), (3, 0.25)]);
        let mut s = Sampler::new(1.0, 9);
        for _ in 0..2000 {
            let tree = DraftTree::backbone_expansion_sampled(
                0, vec![p.clone()], v, s.rng_mut());
            let tds: Vec<Vec<f32>> = vec![p.clone(); tree.len()];
            let r = verify_tree(&tree, &tds, &mut s);
            assert_eq!(r.accepted_slots.len(), 2, "must accept one of k=V candidates");
        }
    }
}
