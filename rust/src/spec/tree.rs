//! Constrained draft tree — the paper's §2.2 "Backbone Expansion".
//!
//! Naive expansion of N draft distributions is k^N paths; Backbone
//! Expansion keeps verification linear: sample the top-k candidates of
//! q_{t+1} (most probable = backbone, rest = side branches), then for
//! each level i = 2..N attach the top-k of q_{t+i} as children of the
//! *previous backbone node* only. Exactly one backbone path of length N,
//! ≤ k−1 side branches per level, O(N·k) nodes. k = 1 degenerates to a
//! chain ("w/o Constrained Tree" ablation).
//!
//! Slot 0 is the **root**: the pending token (sampled from the true
//! target distribution last cycle, hence always committed). Tree slots
//! map 1:1 to rows of the verification call and to the temporary KV
//! rows appended at `cache_len` — ancestor sets double as tree-attention
//! mask rows (§2.4).

use crate::draft::DraftOutput;
use crate::util::rng::{top_k_indices, Pcg64};

use super::plan::DraftPlan;
use super::sampler::Sampler;

/// Draw up to k distinct indices from a probability vector, each drawn
/// from the remaining renormalized mass (sampling without replacement).
pub fn sample_without_replacement(q: &[f32], k: usize, rng: &mut Pcg64) -> Vec<usize> {
    let mut rem = q.to_vec();
    let mut out = Vec::with_capacity(k);
    for _ in 0..k.min(q.len()) {
        let sum: f32 = rem.iter().sum();
        if sum <= 0.0 {
            break;
        }
        let r = rng.next_f64() as f32 * sum;
        let mut acc = 0.0f32;
        let mut pick = rem.iter().rposition(|&p| p > 0.0).unwrap_or(0);
        for (i, &p) in rem.iter().enumerate() {
            acc += p;
            if r < acc && p > 0.0 {
                pick = i;
                break;
            }
        }
        out.push(pick);
        rem[pick] = 0.0;
    }
    out
}

#[derive(Debug, Clone)]
pub struct TreeNode {
    pub token: i32,
    /// parent slot index; the root's parent is itself (slot 0)
    pub parent: usize,
    /// distance from the root (root = 0)
    pub depth: usize,
    /// index into `dists` of the distribution this node was drawn from
    /// (usize::MAX for the root)
    pub level: usize,
    /// whether this node lies on the backbone path
    pub backbone: bool,
}

#[derive(Debug, Clone)]
pub struct DraftTree {
    pub nodes: Vec<TreeNode>,
    /// per-level draft distributions (temperature-adjusted, normalized);
    /// needed by lossless stochastic verification
    pub dists: Vec<Vec<f32>>,
}

impl DraftTree {
    /// Root-only tree (vanilla decoding).
    pub fn root_only(pending: i32) -> DraftTree {
        DraftTree {
            nodes: vec![TreeNode {
                token: pending,
                parent: 0,
                depth: 0,
                level: usize::MAX,
                backbone: true,
            }],
            dists: vec![],
        }
    }

    /// Backbone Expansion from per-level draft distributions, candidates
    /// chosen by top-k (greedy decoding: acceptance compares against the
    /// target argmax, so the k most probable candidates are optimal).
    /// Uniform-k convenience over [`Self::backbone_expansion_planned`].
    pub fn backbone_expansion(pending: i32, dists: Vec<Vec<f32>>, k: usize) -> DraftTree {
        let plan = DraftPlan::uniform(dists.len(), k);
        Self::backbone_expansion_impl(pending, dists, &plan, None)
    }

    /// Backbone Expansion with candidates *sampled without replacement*
    /// from each level's q. Required for stochastic (T>0) decoding: the
    /// multi-round speculative-sampling acceptance rule is only lossless
    /// when sibling candidates are q-samples (EAGLE-2's theorem); with
    /// deterministic top-k the committed marginal is biased toward the
    /// drafter's favourites (caught by the
    /// `stochastic_first_token_marginal_is_lossless` test).
    pub fn backbone_expansion_sampled(
        pending: i32,
        dists: Vec<Vec<f32>>,
        k: usize,
        rng: &mut crate::util::rng::Pcg64,
    ) -> DraftTree {
        let plan = DraftPlan::uniform(dists.len(), k);
        Self::backbone_expansion_impl(pending, dists, &plan, Some(rng))
    }

    /// Backbone Expansion under an explicit [`DraftPlan`]: level `i`
    /// attaches `plan.k_for(i)` candidates, expansion stops at
    /// `plan.depth` levels or when the node budget is spent.
    pub fn backbone_expansion_planned(
        pending: i32,
        dists: Vec<Vec<f32>>,
        plan: &DraftPlan,
        rng: Option<&mut crate::util::rng::Pcg64>,
    ) -> DraftTree {
        Self::backbone_expansion_impl(pending, dists, plan, rng)
    }

    fn backbone_expansion_impl(
        pending: i32,
        dists: Vec<Vec<f32>>,
        plan: &DraftPlan,
        mut rng: Option<&mut crate::util::rng::Pcg64>,
    ) -> DraftTree {
        let mut tree = DraftTree::root_only(pending);
        let mut backbone = 0usize; // slot of the current backbone tail
        let mut budget = plan.node_budget;
        for (level, q) in dists.iter().enumerate() {
            if level >= plan.depth || budget == 0 {
                break;
            }
            let k = plan.k_for(level).min(budget);
            let cand = match rng.as_deref_mut() {
                None => top_k_indices(q, k),
                Some(rng) => sample_without_replacement(q, k, rng),
            };
            if cand.is_empty() {
                break;
            }
            budget -= cand.len();
            let mut next_backbone = None;
            for (rank, &tok) in cand.iter().enumerate() {
                let slot = tree.nodes.len();
                tree.nodes.push(TreeNode {
                    token: tok as i32,
                    parent: backbone,
                    depth: level + 1,
                    level,
                    backbone: rank == 0,
                });
                if rank == 0 {
                    next_backbone = Some(slot);
                }
            }
            backbone = next_backbone.unwrap();
        }
        tree.dists = dists;
        tree
    }

    /// Truncate a drafter's output to at most `depth` levels —
    /// [`from_draft`](Self::from_draft) applies it under the cycle's
    /// [`DraftPlan`] (Table 3 effectively plans depth 2).
    pub fn truncate_draft(draft: &mut DraftOutput, depth: usize) {
        match draft {
            DraftOutput::Levels(dists) => dists.truncate(depth),
            DraftOutput::Chain(toks, dists) => {
                toks.truncate(depth);
                dists.truncate(depth);
            }
            DraftOutput::None => {}
        }
    }

    /// Build the cycle's tree from a drafter's output under the cycle's
    /// [`DraftPlan`] — the one home of depth truncation, per-level
    /// branching and the node budget, with top-k candidates (greedy) or
    /// q-samples without replacement (stochastic — required for
    /// lossless multi-round acceptance). Shared by the single-request
    /// session and every continuous-batcher slot.
    pub fn from_draft(
        pending: i32,
        draft: DraftOutput,
        plan: &DraftPlan,
        sampler: &mut Sampler,
    ) -> DraftTree {
        match draft {
            DraftOutput::Levels(mut dists) => {
                dists.truncate(plan.depth);
                if sampler.greedy() {
                    DraftTree::backbone_expansion_planned(pending, dists, plan, None)
                } else {
                    DraftTree::backbone_expansion_planned(
                        pending,
                        dists,
                        plan,
                        Some(sampler.rng_mut()),
                    )
                }
            }
            DraftOutput::Chain(mut toks, mut dists) => {
                // a chain holds one node per level: both the depth and
                // the node budget cap its length
                let cap = plan.depth.min(plan.node_budget);
                toks.truncate(cap);
                dists.truncate(cap);
                DraftTree::chain(pending, &toks, dists)
            }
            DraftOutput::None => DraftTree::root_only(pending),
        }
    }

    /// Chain from pre-sampled tokens (SpS drafting, Table-3 chains);
    /// `dists` must hold one distribution per chain token for stochastic
    /// acceptance.
    pub fn chain(pending: i32, tokens: &[i32], dists: Vec<Vec<f32>>) -> DraftTree {
        assert_eq!(tokens.len(), dists.len());
        let mut tree = DraftTree::root_only(pending);
        for (level, &tok) in tokens.iter().enumerate() {
            let parent = tree.nodes.len() - 1;
            tree.nodes.push(TreeNode {
                token: tok,
                parent,
                depth: level + 1,
                level,
                backbone: true,
            });
        }
        tree.dists = dists;
        tree
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn tokens(&self) -> Vec<i32> {
        self.nodes.iter().map(|n| n.token).collect()
    }

    pub fn depths(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n.depth).collect()
    }

    pub fn max_depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// Ancestor slot set of `slot`, **including itself**, ascending.
    /// This is the tree-attention visibility row within the temp region.
    pub fn ancestors(&self, slot: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nodes[slot].depth + 1);
        let mut cur = slot;
        loop {
            out.push(cur);
            let p = self.nodes[cur].parent;
            if p == cur {
                break;
            }
            cur = p;
        }
        out.reverse();
        out
    }

    /// Children of `slot` in candidate order (construction order ==
    /// descending draft probability).
    pub fn children(&self, slot: usize) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&i| i != slot && self.nodes[i].parent == slot)
            .collect()
    }

    /// Structural invariants (used by the property tests).
    pub fn check_invariants(&self, k: usize) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty tree".into());
        }
        if self.nodes[0].depth != 0 || self.nodes[0].parent != 0 {
            return Err("bad root".into());
        }
        let mut backbone_per_depth = std::collections::BTreeMap::new();
        for (i, n) in self.nodes.iter().enumerate() {
            if i > 0 {
                if n.parent >= i {
                    return Err(format!("node {i} parent {} not earlier", n.parent));
                }
                if self.nodes[n.parent].depth + 1 != n.depth {
                    return Err(format!("node {i} depth mismatch"));
                }
                if !self.nodes[n.parent].backbone {
                    return Err(format!("node {i} hangs off a side branch"));
                }
            }
            if n.backbone {
                *backbone_per_depth.entry(n.depth).or_insert(0usize) += 1;
            }
            if self.children(i).len() > k {
                return Err(format!("node {i} has more than k children"));
            }
        }
        for (d, c) in backbone_per_depth {
            if c != 1 {
                return Err(format!("depth {d} has {c} backbone nodes"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn dist(v: usize, hot: usize) -> Vec<f32> {
        let mut d = vec![0.5 / (v as f32 - 1.0); v];
        d[hot] = 0.5;
        d
    }

    #[test]
    fn node_count_formula() {
        let dists: Vec<_> = (0..6).map(|i| dist(16, i)).collect();
        let t = DraftTree::backbone_expansion(9, dists, 3);
        assert_eq!(t.len(), 1 + 6 * 3); // root + N*k
        t.check_invariants(3).unwrap();
        assert_eq!(t.max_depth(), 6);
    }

    #[test]
    fn k1_degenerates_to_chain() {
        let dists: Vec<_> = (0..4).map(|i| dist(8, i)).collect();
        let t = DraftTree::backbone_expansion(1, dists, 1);
        assert_eq!(t.len(), 5);
        for (i, n) in t.nodes.iter().enumerate().skip(1) {
            assert_eq!(n.parent, i - 1);
            assert!(n.backbone);
        }
        t.check_invariants(1).unwrap();
    }

    #[test]
    fn backbone_is_most_probable() {
        let mut q1 = vec![0.0f32; 8];
        q1[3] = 0.9;
        q1[5] = 0.1;
        let t = DraftTree::backbone_expansion(0, vec![q1], 2);
        assert_eq!(t.nodes[1].token, 3);
        assert!(t.nodes[1].backbone);
        assert_eq!(t.nodes[2].token, 5);
        assert!(!t.nodes[2].backbone);
    }

    #[test]
    fn ancestors_follow_backbone() {
        let dists: Vec<_> = (0..3).map(|i| dist(8, i)).collect();
        let t = DraftTree::backbone_expansion(7, dists, 2);
        // slots: 0 root, 1-2 level1, 3-4 level2 (children of 1), 5-6 level3
        let anc = t.ancestors(6);
        assert_eq!(anc, vec![0, 1, 3, 6]);
        assert_eq!(t.ancestors(0), vec![0]);
    }

    #[test]
    fn sampled_candidates_are_distinct_and_q_weighted() {
        let mut rng = Pcg64::new(5, 0);
        let q = vec![0.7f32, 0.2, 0.05, 0.05];
        let mut first_counts = [0usize; 4];
        for _ in 0..20_000 {
            let c = sample_without_replacement(&q, 2, &mut rng);
            assert_eq!(c.len(), 2);
            assert_ne!(c[0], c[1]);
            first_counts[c[0]] += 1;
        }
        // the first draw follows q
        assert!((first_counts[0] as f64 / 20_000.0 - 0.7).abs() < 0.02);
    }

    #[test]
    fn sampled_tree_keeps_invariants() {
        let mut rng = Pcg64::new(6, 0);
        for _ in 0..100 {
            let dists: Vec<Vec<f32>> = (0..4)
                .map(|_| {
                    let mut d: Vec<f32> = (0..16).map(|_| rng.next_f64() as f32 + 0.01).collect();
                    let s: f32 = d.iter().sum();
                    d.iter_mut().for_each(|x| *x /= s);
                    d
                })
                .collect();
            let t = DraftTree::backbone_expansion_sampled(1, dists, 3, &mut rng);
            t.check_invariants(3).unwrap();
            assert_eq!(t.len(), 13);
        }
    }

    #[test]
    fn from_draft_truncates_every_output_kind() {
        let mut s = Sampler::new(0.0, 1);
        let dists: Vec<_> = (0..6).map(|i| dist(8, i)).collect();
        let plan = DraftPlan::uniform(2, 2);
        let t = DraftTree::from_draft(0, DraftOutput::Levels(dists.clone()), &plan, &mut s);
        assert_eq!(t.max_depth(), 2);
        assert_eq!(t.len(), 1 + 2 * 2);
        let chain = DraftOutput::Chain(vec![1, 2, 3, 4], dists[..4].to_vec());
        let plan = DraftPlan::uniform(3, 2);
        let t = DraftTree::from_draft(0, chain, &plan, &mut s);
        assert_eq!(t.max_depth(), 3);
        assert_eq!(t.tokens(), vec![0, 1, 2, 3]);
        let plan = DraftPlan::uniform(1, 2);
        let t = DraftTree::from_draft(7, DraftOutput::None, &plan, &mut s);
        assert_eq!(t.len(), 1);
        // plan deeper than the draft: untouched
        let plan = DraftPlan::uniform(9, 3);
        let t = DraftTree::from_draft(0, DraftOutput::Levels(dists), &plan, &mut s);
        assert_eq!(t.max_depth(), 6);
    }

    #[test]
    fn from_draft_honors_budget_and_per_level_branching() {
        let mut s = Sampler::new(0.0, 1);
        let dists: Vec<_> = (0..4).map(|i| dist(8, i)).collect();
        // budget 5 stops expansion mid-tree: 3 + 2 nodes, 2 levels deep
        let plan = DraftPlan { depth: 4, branching: vec![3], node_budget: 5 };
        let t = DraftTree::from_draft(0, DraftOutput::Levels(dists.clone()), &plan, &mut s);
        assert_eq!(t.len(), 1 + 5);
        assert_eq!(t.max_depth(), 2);
        t.check_invariants(3).unwrap();
        // per-level branching narrows with depth
        let plan = DraftPlan { depth: 3, branching: vec![3, 1, 1], node_budget: 9 };
        let t = DraftTree::from_draft(0, DraftOutput::Levels(dists.clone()), &plan, &mut s);
        assert_eq!(t.len(), 1 + 3 + 1 + 1);
        t.check_invariants(3).unwrap();
        // a chain is capped by the node budget too
        let chain = DraftOutput::Chain(vec![1, 2, 3, 4], dists);
        let plan = DraftPlan { depth: 4, branching: vec![1], node_budget: 2 };
        let t = DraftTree::from_draft(0, chain, &plan, &mut s);
        assert_eq!(t.tokens(), vec![0, 1, 2]);
    }

    #[test]
    fn from_draft_samples_without_replacement_when_stochastic() {
        let mut s = Sampler::new(1.0, 3);
        for _ in 0..50 {
            let dists: Vec<Vec<f32>> = (0..3)
                .map(|_| {
                    let mut d: Vec<f32> =
                        (0..8).map(|_| s.rng_mut().next_f64() as f32 + 0.01).collect();
                    let sum: f32 = d.iter().sum();
                    d.iter_mut().for_each(|x| *x /= sum);
                    d
                })
                .collect();
            let plan = DraftPlan::uniform(3, 3);
            let t = DraftTree::from_draft(0, DraftOutput::Levels(dists), &plan, &mut s);
            t.check_invariants(3).unwrap();
        }
    }

    #[test]
    fn property_random_dists_keep_invariants() {
        let mut rng = Pcg64::new(99, 0);
        for _ in 0..200 {
            let v = 8 + rng.below(64);
            let n = 1 + rng.below(6);
            let k = 1 + rng.below(4);
            let dists: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut d: Vec<f32> =
                        (0..v).map(|_| rng.next_f64() as f32).collect();
                    let s: f32 = d.iter().sum();
                    d.iter_mut().for_each(|x| *x /= s);
                    d
                })
                .collect();
            let t = DraftTree::backbone_expansion(0, dists, k);
            t.check_invariants(k).unwrap();
            assert_eq!(t.len(), 1 + n * k.min(v));
            // every slot's ancestors are strictly ascending
            for s in 0..t.len() {
                let a = t.ancestors(s);
                assert!(a.windows(2).all(|w| w[0] < w[1]));
                assert_eq!(*a.last().unwrap(), s);
            }
        }
    }
}
