//! Generation metrics: τ (average acceptance length per verification,
//! the paper's second headline metric), per-depth acceptance rates
//! (Fig. 3), and the phase latency breakdown.

use std::time::Duration;

use crate::util::timer::PhaseTimer;

#[derive(Debug, Clone, Default)]
pub struct GenMetrics {
    /// verification cycles run
    pub cycles: usize,
    /// tokens committed beyond the prompt
    pub new_tokens: usize,
    /// Σ accepted-per-cycle (acceptance length includes the root/pending
    /// token, as in the paper: τ = tokens per target forward)
    pub tau_sum: usize,
    /// index d-1 = tree depth d attempts / accepts
    pub depth_attempts: Vec<u64>,
    pub depth_accepts: Vec<u64>,
    pub timer: PhaseTimer,
    pub wall: Duration,
    pub prompt_tokens: usize,
}

impl GenMetrics {
    pub fn record_cycle(&mut self, accepted: usize, depth_events: &[(usize, bool)]) {
        self.cycles += 1;
        self.tau_sum += accepted;
        for &(depth, ok) in depth_events {
            if self.depth_attempts.len() < depth {
                self.depth_attempts.resize(depth, 0);
                self.depth_accepts.resize(depth, 0);
            }
            self.depth_attempts[depth - 1] += 1;
            if ok {
                self.depth_accepts[depth - 1] += 1;
            }
        }
    }

    /// Average acceptance length τ.
    pub fn tau(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.tau_sum as f64 / self.cycles as f64
        }
    }

    /// Acceptance rate at tree depth d (1-based), as plotted in Fig. 3.
    pub fn accept_rate(&self, depth: usize) -> Option<f64> {
        let a = *self.depth_attempts.get(depth - 1)?;
        if a == 0 {
            return None;
        }
        Some(*self.depth_accepts.get(depth - 1)? as f64 / a as f64)
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.new_tokens as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn merge(&mut self, other: &GenMetrics) {
        self.cycles += other.cycles;
        self.new_tokens += other.new_tokens;
        self.tau_sum += other.tau_sum;
        if self.depth_attempts.len() < other.depth_attempts.len() {
            self.depth_attempts.resize(other.depth_attempts.len(), 0);
            self.depth_accepts.resize(other.depth_accepts.len(), 0);
        }
        for (i, (&a, &c)) in other
            .depth_attempts
            .iter()
            .zip(&other.depth_accepts)
            .enumerate()
        {
            self.depth_attempts[i] += a;
            self.depth_accepts[i] += c;
        }
        self.timer.merge(&other.timer);
        self.wall += other.wall;
        self.prompt_tokens += other.prompt_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_and_depth_rates() {
        let mut m = GenMetrics::default();
        m.record_cycle(3, &[(1, true), (2, true), (3, false)]);
        m.record_cycle(1, &[(1, false)]);
        assert!((m.tau() - 2.0).abs() < 1e-12);
        assert_eq!(m.accept_rate(1), Some(0.5));
        assert_eq!(m.accept_rate(2), Some(1.0));
        assert_eq!(m.accept_rate(3), Some(0.0));
        assert_eq!(m.accept_rate(4), None);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = GenMetrics::default();
        a.record_cycle(2, &[(1, true)]);
        let mut b = GenMetrics::default();
        b.record_cycle(4, &[(1, true), (2, false)]);
        b.new_tokens = 4;
        a.merge(&b);
        assert_eq!(a.cycles, 2);
        assert_eq!(a.tau_sum, 6);
        assert_eq!(a.depth_attempts[0], 2);
        assert_eq!(a.new_tokens, 4);
    }
}
