//! AdaEAGLE-style adaptive draft structures: size the next cycle's
//! draft from recent acceptance instead of drafting a fixed tree.
//!
//! The planner keeps a rolling window of the last `WINDOW` cycles'
//! accepted draft lengths. Each cycle it plans
//!
//! * `depth  = clamp(⌊ā⌋ + 1, 1, base.depth)` — one level of headroom
//!   over the mean acceptance length ā, so a request whose drafts keep
//!   dying stops paying for deep drafts while one whose drafts land
//!   plans right back up to the base depth;
//! * `k = 1 + round((base_k − 1) · min(ā / base.depth, 1))` — branching
//!   shrinks toward a chain as acceptance collapses.
//!
//! Both maps are nondecreasing in ā, which gives the planner its core
//! guarantee (unit-tested below): **low acceptance never grows the
//! plan** — if the window mean does not rise, neither does any plan
//! dimension. The first cycle (empty window) optimistically plans the
//! full base shape; the plan never exceeds the base in any dimension,
//! so capacity accounting done against the base plan stays sound.

use std::collections::VecDeque;

use super::planner::DraftPlanner;
use super::{DraftPlan, PlannerKind};

/// Rolling-window size: long enough to smooth cycle-to-cycle acceptance
/// noise, short enough to track phase changes within one generation.
const WINDOW: usize = 8;

#[derive(Debug, Clone)]
pub struct AdaptivePlanner {
    /// ceiling shape (the resolved static plan)
    base: DraftPlan,
    /// accepted draft nodes of the last `WINDOW` cycles
    window: VecDeque<usize>,
}

impl AdaptivePlanner {
    pub fn new(base: DraftPlan) -> AdaptivePlanner {
        AdaptivePlanner { base, window: VecDeque::with_capacity(WINDOW) }
    }

    fn mean(&self) -> Option<f64> {
        if self.window.is_empty() {
            return None;
        }
        Some(self.window.iter().sum::<usize>() as f64 / self.window.len() as f64)
    }
}

impl DraftPlanner for AdaptivePlanner {
    fn kind(&self) -> PlannerKind {
        PlannerKind::Adaptive
    }

    fn next_plan(&mut self) -> DraftPlan {
        let Some(a) = self.mean() else {
            // no evidence yet: optimistic full-shape start
            return self.base.clone();
        };
        if self.base.depth == 0 {
            return self.base.clone();
        }
        let depth = ((a.floor() as usize) + 1).clamp(1, self.base.depth);
        let base_k = self.base.k_for(0);
        let ratio = (a / self.base.depth as f64).min(1.0);
        let k = 1 + ((base_k - 1) as f64 * ratio).round() as usize;
        let mut plan = DraftPlan::uniform(depth, k);
        plan.node_budget = plan.node_budget.min(self.base.node_budget);
        plan
    }

    fn observe(&mut self, accepted_drafts: usize) {
        if self.window.len() == WINDOW {
            self.window.pop_front();
        }
        self.window.push_back(accepted_drafts);
    }

    fn window_mean(&self) -> Option<f64> {
        self.mean()
    }

    fn box_clone(&self) -> Box<dyn DraftPlanner> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> DraftPlan {
        DraftPlan::uniform(6, 3)
    }

    /// Core monotonicity guarantee: under persistently low acceptance
    /// the plan never grows — every successive plan is <= the previous
    /// one in depth, branching, and node count.
    #[test]
    fn low_acceptance_never_grows_the_plan() {
        let mut p = AdaptivePlanner::new(base());
        let mut prev = p.next_plan();
        assert_eq!(prev, base(), "empty window starts at the base shape");
        for _ in 0..20 {
            p.observe(0);
            let plan = p.next_plan();
            assert!(plan.depth <= prev.depth, "depth grew under zero acceptance");
            assert!(plan.k_for(0) <= prev.k_for(0), "branching grew");
            assert!(plan.draft_nodes() <= prev.draft_nodes(), "nodes grew");
            prev = plan;
        }
        // fully collapsed: a 1-deep chain, but never below one level
        assert_eq!(prev.depth, 1);
        assert_eq!(prev.k_for(0), 1);
    }

    /// The plan is a nondecreasing function of the window mean: a
    /// planner fed strictly lower acceptance never plans bigger than
    /// one fed higher acceptance.
    #[test]
    fn plan_is_monotone_in_window_mean() {
        for (lo, hi) in [(0usize, 1usize), (1, 2), (0, 6), (2, 5), (3, 6)] {
            let mut p_lo = AdaptivePlanner::new(base());
            let mut p_hi = AdaptivePlanner::new(base());
            for _ in 0..WINDOW {
                p_lo.observe(lo);
                p_hi.observe(hi);
            }
            let (a, b) = (p_lo.next_plan(), p_hi.next_plan());
            assert!(a.depth <= b.depth, "{lo} vs {hi}: depth {} > {}", a.depth, b.depth);
            assert!(a.k_for(0) <= b.k_for(0), "{lo} vs {hi}: branching inverted");
            assert!(a.draft_nodes() <= b.draft_nodes());
        }
    }

    #[test]
    fn never_exceeds_the_base_plan() {
        let mut p = AdaptivePlanner::new(base());
        for pattern in [[9usize, 9, 9, 9], [0, 9, 0, 9], [6, 6, 6, 6]] {
            for &a in &pattern {
                p.observe(a);
                let plan = p.next_plan();
                assert!(plan.depth <= 6);
                assert!(plan.k_for(0) <= 3);
                assert!(plan.draft_nodes() <= base().draft_nodes());
                assert!(plan.node_budget <= base().node_budget);
            }
        }
    }

    #[test]
    fn recovers_when_acceptance_returns() {
        let mut p = AdaptivePlanner::new(base());
        for _ in 0..WINDOW {
            p.observe(0);
        }
        assert_eq!(p.next_plan().depth, 1);
        for _ in 0..WINDOW {
            p.observe(6);
        }
        let plan = p.next_plan();
        assert_eq!(plan.depth, 6, "full acceptance grows back to the base depth");
        assert_eq!(plan.k_for(0), 3);
    }

    #[test]
    fn window_is_rolling() {
        let mut p = AdaptivePlanner::new(base());
        for _ in 0..100 {
            p.observe(6);
        }
        for _ in 0..WINDOW {
            p.observe(0);
        }
        assert_eq!(p.window_mean(), Some(0.0), "old samples age out");
        let mut q = AdaptivePlanner::new(DraftPlan::root_only());
        q.observe(3);
        assert_eq!(q.next_plan(), DraftPlan::root_only(), "degenerate base is stable");
    }
}
