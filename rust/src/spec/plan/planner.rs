//! The per-cycle planning contract and the fixed-shape planner.

use super::{DraftPlan, PlannerKind};

/// Produces one [`DraftPlan`] per cycle for one request and hears back
/// how the cycle went. Each request (engine session or batcher slot)
/// owns its planner, so adaptive state is per slot.
pub trait DraftPlanner: std::fmt::Debug {
    fn kind(&self) -> PlannerKind;

    /// The plan for the cycle about to run.
    fn next_plan(&mut self) -> DraftPlan;

    /// Feed back one finished cycle: how many *draft* nodes (beyond the
    /// always-committed root) the verifier accepted.
    fn observe(&mut self, accepted_drafts: usize);

    /// Mean of the rolling acceptance window, if this planner keeps one
    /// (observability — surfaced in `ServingMetrics`).
    fn window_mean(&self) -> Option<f64>;

    fn box_clone(&self) -> Box<dyn DraftPlanner>;
}

impl Clone for Box<dyn DraftPlanner> {
    fn clone(&self) -> Self {
        self.box_clone()
    }
}

/// Fixed shape every cycle. With the spec-default plan this reproduces
/// the pre-`DraftPlan` engine byte for byte (property-tested in
/// `tests/plan_props.rs`).
#[derive(Debug, Clone)]
pub struct StaticPlanner {
    plan: DraftPlan,
}

impl StaticPlanner {
    pub fn new(plan: DraftPlan) -> StaticPlanner {
        StaticPlanner { plan }
    }
}

impl DraftPlanner for StaticPlanner {
    fn kind(&self) -> PlannerKind {
        PlannerKind::Static
    }

    fn next_plan(&mut self) -> DraftPlan {
        self.plan.clone()
    }

    fn observe(&mut self, _accepted_drafts: usize) {}

    fn window_mean(&self) -> Option<f64> {
        None
    }

    fn box_clone(&self) -> Box<dyn DraftPlanner> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_planner_is_constant() {
        let base = DraftPlan::uniform(4, 2);
        let mut p = StaticPlanner::new(base.clone());
        assert_eq!(p.kind(), PlannerKind::Static);
        assert_eq!(p.next_plan(), base);
        p.observe(0);
        p.observe(4);
        assert_eq!(p.next_plan(), base, "feedback never changes a static plan");
        assert_eq!(p.window_mean(), None);
        let c: Box<dyn DraftPlanner> = p.box_clone();
        assert_eq!(c.kind(), PlannerKind::Static);
    }
}
