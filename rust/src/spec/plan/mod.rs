//! First-class draft-structure planning: the per-cycle [`DraftPlan`].
//!
//! The paper's "constrained draft tree that preserves lossless
//! verification cost" used to be a scatter of knobs (`use_tree`,
//! `max_depth`, `spec.tree_top_k`, truncation inlined in
//! `tree::from_draft`). This module makes the draft *shape* a value: a
//! [`DraftPlan`] — depth, per-level branching, node budget — is the
//! single source of truth for the tree a cycle may build and therefore
//! for its verify-lane cost (tree slots map 1:1 to verification rows
//! and temporary KV rows). A [`DraftPlanner`](planner::DraftPlanner)
//! produces one plan per cycle:
//!
//! * [`StaticPlanner`](planner::StaticPlanner) — a fixed plan; with the
//!   spec's defaults it reproduces the pre-plan behavior byte for byte.
//! * [`AdaptivePlanner`](adaptive::AdaptivePlanner) — AdaEAGLE-style:
//!   sizes the next cycle's draft from a rolling window of recent
//!   acceptance lengths, shrinking depth/branching when drafts keep
//!   getting rejected and growing back (never beyond the base plan)
//!   when acceptance recovers.
//!
//! Requests carry a [`DraftConfig`] (every field optional; the JSON
//! protocol's `"draft"` object and the CLI's `--planner`/`--draft-*`
//! flags fill it) which is resolved against the model spec into the
//! base plan at session/slot start.

pub mod adaptive;
pub mod planner;

pub use adaptive::AdaptivePlanner;
pub use planner::{DraftPlanner, StaticPlanner};

use crate::model::ModelSpec;

/// Upper bound on user-supplied draft knobs (depth / top-k / budget).
/// Far above any lowered executable's row count, but small enough that
/// a typo'd huge value is a validation error instead of an
/// out-of-memory abort (plans allocate `vec![k; depth]`).
pub const MAX_DRAFT_KNOB: usize = 1024;

/// The shape one cycle's constrained draft tree may take — and, through
/// the 1:1 slot↔row mapping, the cycle's verification cost.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DraftPlan {
    /// maximum draft levels below the root (0 = root-only / vanilla)
    pub depth: usize,
    /// candidates attached at each level; level `i` uses
    /// `branching[i]`, levels past the end reuse the last entry
    pub branching: Vec<usize>,
    /// hard cap on non-root tree nodes — the verify-lane budget
    pub node_budget: usize,
}

impl DraftPlan {
    /// Uniform tree: `depth` levels of `k` candidates, budget non-binding.
    pub fn uniform(depth: usize, k: usize) -> DraftPlan {
        let k = k.max(1);
        DraftPlan {
            depth,
            branching: vec![k; depth],
            node_budget: depth.saturating_mul(k),
        }
    }

    /// Chain plan: one candidate per level (the batched serving lane's
    /// shape — its lowered executables verify `1 + depth` rows).
    pub fn chain_of(depth: usize) -> DraftPlan {
        DraftPlan::uniform(depth, 1)
    }

    /// Root-only plan (vanilla decoding).
    pub fn root_only() -> DraftPlan {
        DraftPlan { depth: 0, branching: Vec::new(), node_budget: 0 }
    }

    /// The spec's default draft shape — the one home of the
    /// depth/top-k pair that `spec.json`, `GenConfig` and the fixture
    /// generator used to hard-code independently.
    pub fn default_for(spec: &ModelSpec) -> DraftPlan {
        DraftPlan::uniform(spec.draft_depth, spec.tree_top_k)
    }

    /// Resolve request knobs against the spec: unset fields fall back
    /// to `native_depth` (the drafter's own level count, or the batched
    /// lane's chain length) and `spec.tree_top_k`.
    pub fn resolve(cfg: &DraftConfig, spec: &ModelSpec, native_depth: usize) -> DraftPlan {
        let depth = cfg.depth.unwrap_or(native_depth);
        let k = cfg.top_k.unwrap_or(spec.tree_top_k).max(1);
        let mut plan = DraftPlan::uniform(depth, k);
        if let Some(b) = cfg.budget {
            plan.node_budget = plan.node_budget.min(b);
        }
        plan
    }

    /// Branching factor at `level` (levels past the end reuse the last
    /// entry; an empty plan branches 1).
    pub fn k_for(&self, level: usize) -> usize {
        self.branching
            .get(level)
            .or_else(|| self.branching.last())
            .copied()
            .unwrap_or(1)
            .max(1)
    }

    /// Non-root nodes this plan admits (per-level branching summed,
    /// capped by the node budget).
    pub fn draft_nodes(&self) -> usize {
        let sum: usize = (0..self.depth).map(|l| self.k_for(l)).sum();
        sum.min(self.node_budget)
    }

    /// Verification rows a tree built under this plan needs: the root
    /// plus every admissible draft node.
    pub fn total_rows(&self) -> usize {
        1 + self.draft_nodes()
    }

    /// Clamp in place to an executable's limits: at most `depth_cap`
    /// levels and `node_cap` non-root nodes.
    pub fn clamp_to(&mut self, depth_cap: usize, node_cap: usize) {
        self.depth = self.depth.min(depth_cap);
        self.branching.truncate(self.depth);
        self.node_budget = self.node_budget.min(node_cap);
    }
}

/// Which [`DraftPlanner`] a request runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerKind {
    Static,
    Adaptive,
}

impl PlannerKind {
    pub fn name(self) -> &'static str {
        match self {
            PlannerKind::Static => "static",
            PlannerKind::Adaptive => "adaptive",
        }
    }

    pub fn from_name(name: &str) -> Option<PlannerKind> {
        Some(match name {
            "static" => PlannerKind::Static,
            "adaptive" => PlannerKind::Adaptive,
            _ => return None,
        })
    }

    /// Build the planner for a request whose resolved base plan is
    /// `base` (the adaptive planner never grows beyond it).
    pub fn build(self, base: DraftPlan) -> Box<dyn DraftPlanner> {
        match self {
            PlannerKind::Static => Box::new(StaticPlanner::new(base)),
            PlannerKind::Adaptive => Box::new(AdaptivePlanner::new(base)),
        }
    }

    /// The largest plan this planner can ever emit for base plan `base`
    /// — its reachable envelope, which the engine contract checker
    /// sizes verify lanes against. Both kinds are bounded by the base
    /// plan: `Static` always emits exactly it, `Adaptive` only ever
    /// shrinks below it (see [`AdaptivePlanner`]).
    pub fn envelope(self, base: &DraftPlan) -> DraftPlan {
        match self {
            PlannerKind::Static | PlannerKind::Adaptive => base.clone(),
        }
    }
}

/// Per-request draft-structure knobs, every field optional: `None`
/// falls back to the serving default and ultimately to the model spec.
/// Carried on `GenConfig`, filled by the protocol's `"draft"` object or
/// the CLI's `--planner`/`--draft-depth`/`--draft-top-k`/`--draft-budget`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DraftConfig {
    pub planner: Option<PlannerKind>,
    pub depth: Option<usize>,
    pub top_k: Option<usize>,
    pub budget: Option<usize>,
}

impl DraftConfig {
    /// Field-wise fallback: every unset knob takes `fallback`'s value
    /// (request over serving default).
    pub fn merged(&self, fallback: &DraftConfig) -> DraftConfig {
        DraftConfig {
            planner: self.planner.or(fallback.planner),
            depth: self.depth.or(fallback.depth),
            top_k: self.top_k.or(fallback.top_k),
            budget: self.budget.or(fallback.budget),
        }
    }

    pub fn planner_kind(&self) -> PlannerKind {
        self.planner.unwrap_or(PlannerKind::Static)
    }
}

/// The default draft-node count for a (depth, top-k) pair — shared by
/// `ModelSpec` (derives `tree_nodes`) and the fixture generator so the
/// shape arithmetic has one home.
pub fn default_draft_nodes(depth: usize, top_k: usize) -> usize {
    DraftPlan::uniform(depth, top_k).draft_nodes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_shape_and_rows() {
        let p = DraftPlan::uniform(6, 3);
        assert_eq!(p.depth, 6);
        assert_eq!(p.k_for(0), 3);
        assert_eq!(p.k_for(5), 3);
        assert_eq!(p.draft_nodes(), 18);
        assert_eq!(p.total_rows(), 19);
        let c = DraftPlan::chain_of(4);
        assert_eq!(c.draft_nodes(), 4);
        assert_eq!(DraftPlan::root_only().total_rows(), 1);
    }

    #[test]
    fn budget_binds_nodes() {
        let mut p = DraftPlan::uniform(6, 3);
        p.node_budget = 7;
        assert_eq!(p.draft_nodes(), 7);
        assert_eq!(p.total_rows(), 8);
    }

    #[test]
    fn k_for_extends_last_level_and_floors_at_one() {
        let p = DraftPlan { depth: 4, branching: vec![3, 2], node_budget: 100 };
        assert_eq!(p.k_for(0), 3);
        assert_eq!(p.k_for(1), 2);
        assert_eq!(p.k_for(3), 2, "past-the-end levels reuse the last entry");
        assert_eq!(p.draft_nodes(), 3 + 2 + 2 + 2);
        assert_eq!(DraftPlan::root_only().k_for(0), 1);
    }

    #[test]
    fn clamp_to_caps_depth_and_budget() {
        let mut p = DraftPlan::uniform(6, 3);
        p.clamp_to(2, 4);
        assert_eq!(p.depth, 2);
        assert_eq!(p.branching.len(), 2);
        assert_eq!(p.draft_nodes(), 4);
    }

    #[test]
    fn resolve_defaults_come_from_spec() {
        let spec = ModelSpec::parse(crate::model::spec::tests_sample::SAMPLE).unwrap();
        let p = DraftPlan::resolve(&DraftConfig::default(), &spec, spec.draft_depth);
        assert_eq!(p, DraftPlan::default_for(&spec));
        assert_eq!(p.draft_nodes(), spec.tree_nodes);
        // explicit knobs win
        let cfg = DraftConfig {
            depth: Some(2),
            top_k: Some(1),
            budget: Some(1),
            planner: None,
        };
        let p = DraftPlan::resolve(&cfg, &spec, spec.draft_depth);
        assert_eq!(p.depth, 2);
        assert_eq!(p.k_for(0), 1);
        assert_eq!(p.draft_nodes(), 1, "explicit budget binds");
        // native depth (the drafter's own level count) is the depth default
        let p = DraftPlan::resolve(&DraftConfig::default(), &spec, 4);
        assert_eq!(p.depth, 4);
    }

    #[test]
    fn merged_prefers_request_fields() {
        let server = DraftConfig {
            planner: Some(PlannerKind::Adaptive),
            depth: Some(4),
            top_k: None,
            budget: Some(9),
        };
        let req = DraftConfig { depth: Some(2), ..Default::default() };
        let m = req.merged(&server);
        assert_eq!(m.planner, Some(PlannerKind::Adaptive));
        assert_eq!(m.depth, Some(2));
        assert_eq!(m.top_k, None);
        assert_eq!(m.budget, Some(9));
        assert_eq!(DraftConfig::default().planner_kind(), PlannerKind::Static);
    }

    #[test]
    fn envelope_is_the_base_plan() {
        let base = DraftPlan::uniform(3, 2);
        for k in [PlannerKind::Static, PlannerKind::Adaptive] {
            assert_eq!(k.envelope(&base), base, "{}", k.name());
        }
    }

    #[test]
    fn planner_names_roundtrip() {
        for k in [PlannerKind::Static, PlannerKind::Adaptive] {
            assert_eq!(PlannerKind::from_name(k.name()), Some(k));
        }
        assert_eq!(PlannerKind::from_name("magic"), None);
    }

    #[test]
    fn default_nodes_helper_matches_plan() {
        assert_eq!(default_draft_nodes(6, 3), 18);
        assert_eq!(default_draft_nodes(0, 3), 0);
        assert_eq!(default_draft_nodes(5, 0), 5, "top-k floors at 1");
    }
}
