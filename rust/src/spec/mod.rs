//! Speculative-decoding core: constrained draft trees (§2.2), lossless
//! verification (§2.4), per-cycle draft planning ([`plan`]), sampling,
//! the per-request cycle core + resumable session, the blocking engine,
//! and metrics.

pub mod accept;
pub mod engine;
pub mod metrics;
pub mod plan;
pub mod sampler;
pub mod session;
pub mod tree;

pub use accept::{verify_tree, AcceptResult};
pub use engine::{Engine, GenConfig, GenResult};
pub use metrics::GenMetrics;
pub use plan::{AdaptivePlanner, DraftConfig, DraftPlan, DraftPlanner, PlannerKind, StaticPlanner};
pub use sampler::Sampler;
pub use session::{
    prompt_budget, truncate_prompt, verify_rows, CycleCommit, CycleEvent, GenSession, SlotCycle,
    SlotPhase,
};
pub use tree::{DraftTree, TreeNode};
