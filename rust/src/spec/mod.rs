//! Speculative-decoding core: constrained draft trees (§2.2), lossless
//! verification (§2.4), sampling, per-request engine and metrics.

pub mod accept;
pub mod engine;
pub mod metrics;
pub mod sampler;
pub mod tree;

pub use accept::{verify_tree, AcceptResult};
pub use engine::{Engine, GenConfig, GenResult};
pub use metrics::GenMetrics;
pub use sampler::Sampler;
pub use tree::{DraftTree, TreeNode};
