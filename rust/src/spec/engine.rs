//! Single-request generation engine: the paper's §2.4 inference pipeline.
//!
//! Per cycle:
//! 1. **Non-autoregressive drafting** — the drafter emits per-level
//!    distributions; Backbone Expansion builds the constrained tree with
//!    the pending token as root.
//! 2. **Parallel verification** — one target forward over all tree rows
//!    with the ancestor mask (tree attention); lossless acceptance picks
//!    the longest valid path and the bonus token.
//! 3. **Update** — accepted rows are compacted into the canonical KV
//!    prefix, the drafter observes the newly-committed anchors (real
//!    verified features), and the bonus becomes the next pending token.

use std::time::Instant;

use anyhow::Result;

use crate::draft::{DraftOutput, Drafter, ObserveArgs};
use crate::model::{KvCache, MaskRow, TargetModel, Tokenizer};

use super::accept::verify_tree;
use super::metrics::GenMetrics;
use super::sampler::Sampler;
use super::tree::DraftTree;

#[derive(Debug, Clone)]
pub struct GenConfig {
    pub temperature: f32,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// tree top-k (1 = chain); `use_tree = false` forces a chain — the
    /// "w/o Constrained Tree" ablation
    pub use_tree: bool,
    /// truncate the draft to this depth (Table 3 uses 2)
    pub max_depth: Option<usize>,
    pub stop_on_eos: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            temperature: 0.0,
            max_new_tokens: 64,
            seed: 0,
            use_tree: true,
            max_depth: None,
            stop_on_eos: false,
        }
    }
}

#[derive(Debug)]
pub struct GenResult {
    pub tokens: Vec<i32>,
    pub text: String,
    pub metrics: GenMetrics,
}

pub struct Engine {
    pub target: TargetModel,
    pub drafter: Box<dyn Drafter>,
    pub tokenizer: Tokenizer,
}

impl Engine {
    pub fn new(target: TargetModel, drafter: Box<dyn Drafter>) -> Engine {
        let tokenizer = Tokenizer::new(target.spec.bos, target.spec.eos, target.spec.pad);
        Engine { target, drafter, tokenizer }
    }

    pub fn generate(&mut self, prompt: &str, cfg: &GenConfig) -> Result<GenResult> {
        let t_start = Instant::now();
        let mut metrics = GenMetrics::default();
        let spec = self.target.spec.clone();
        let fd = spec.feat_dim;
        let mut sampler = Sampler::new(cfg.temperature, cfg.seed);
        self.drafter.reset()?;
        let mut kv: KvCache = self.target.new_kv()?;

        // prompt, truncated so the worst-case cycle still fits in max_seq
        let mut ptoks = self.tokenizer.encode_prompt(prompt);
        let budget = spec
            .max_seq
            .saturating_sub(cfg.max_new_tokens + spec.tree_nodes + 2);
        if ptoks.len() > budget {
            ptoks = ptoks[ptoks.len() - budget..].to_vec();
        }
        metrics.prompt_tokens = ptoks.len();

        // 1. prefill + initial pending token
        let pre = {
            let _g = metrics.timer.start("prefill");
            self.target.prefill(&mut kv, &ptoks)?
        };
        let first_dist = sampler.dist_from_logits(&pre.last_logits);
        let mut pending = sampler.sample(&first_dist);
        {
            let _g = metrics.timer.start("observe");
            let mut next: Vec<i32> = ptoks[1..].to_vec();
            next.push(pending);
            self.drafter.observe(ObserveArgs {
                feats: &pre.feats,
                anchor_tokens: &ptoks,
                next_tokens: &next,
                first_pos: 0,
            })?;
        }

        let mut out_tokens: Vec<i32> = Vec::with_capacity(cfg.max_new_tokens);
        let eff_k = if cfg.use_tree { spec.tree_top_k } else { 1 };

        'outer: while out_tokens.len() < cfg.max_new_tokens {
            let c = kv.len(0);
            // capacity guard: pending + tree rows must fit
            if c + spec.tree_nodes + 2 > spec.max_seq {
                break;
            }
            // 2. draft
            let draft_out = {
                let _g = metrics.timer.start("draft");
                self.drafter.draft(pending, c - 1, cfg.temperature)?
            };
            let tree = {
                let _g = metrics.timer.start("tree");
                match draft_out {
                    DraftOutput::Levels(mut dists) => {
                        if let Some(d) = cfg.max_depth {
                            dists.truncate(d);
                        }
                        if sampler.greedy() {
                            DraftTree::backbone_expansion(pending, dists, eff_k)
                        } else {
                            // stochastic: candidates must be q-samples
                            // without replacement for lossless acceptance
                            DraftTree::backbone_expansion_sampled(
                                pending, dists, eff_k, sampler.rng_mut())
                        }
                    }
                    DraftOutput::Chain(mut toks, mut dists) => {
                        if let Some(d) = cfg.max_depth {
                            toks.truncate(d);
                            dists.truncate(d);
                        }
                        DraftTree::chain(pending, &toks, dists)
                    }
                    DraftOutput::None => DraftTree::root_only(pending),
                }
            };
            // 3. verify
            let tokens = tree.tokens();
            let positions: Vec<i32> =
                tree.depths().iter().map(|&d| (c + d) as i32).collect();
            let rows: Vec<MaskRow> = (0..tree.len())
                .map(|i| MaskRow {
                    prefix_upto: c,
                    extra: tree.ancestors(i).iter().map(|&s| c + s).collect(),
                })
                .collect();
            let vout = {
                let _g = metrics.timer.start("verify");
                self.target.step(&mut kv, &tokens, &positions, &rows)?
            };
            let v = spec.vocab;

            // 4. accept (lossless)
            let accept = {
                let _g = metrics.timer.start("accept");
                let target_dists: Vec<Vec<f32>> = (0..tree.len())
                    .map(|i| sampler.dist_from_logits(&vout.logits[i * v..(i + 1) * v]))
                    .collect();
                verify_tree(&tree, &target_dists, &mut sampler)
            };
            metrics.record_cycle(accept.accepted_slots.len(), &accept.depth_events);

            // 5. commit: compact accepted rows into the canonical prefix
            {
                let _g = metrics.timer.start("commit");
                kv.compact(0, c, &accept.accepted_slots)?;
            }
            let accepted_tokens: Vec<i32> = accept
                .accepted_slots
                .iter()
                .map(|&s| tree.nodes[s].token)
                .collect();

            // 6. drafter observes the new anchors (verified features)
            {
                let _g = metrics.timer.start("observe");
                let mut feats = Vec::with_capacity(accept.accepted_slots.len() * fd);
                for &s in &accept.accepted_slots {
                    feats.extend_from_slice(&vout.feats[s * fd..(s + 1) * fd]);
                }
                let mut next: Vec<i32> = accepted_tokens[1..].to_vec();
                next.push(accept.bonus);
                self.drafter.observe(ObserveArgs {
                    feats: &feats,
                    anchor_tokens: &accepted_tokens,
                    next_tokens: &next,
                    first_pos: c,
                })?;
            }

            pending = accept.bonus;
            for t in accepted_tokens {
                out_tokens.push(t);
                if cfg.stop_on_eos && t == spec.eos {
                    break 'outer;
                }
                if out_tokens.len() >= cfg.max_new_tokens {
                    break 'outer;
                }
            }
        }

        metrics.new_tokens = out_tokens.len();
        metrics.wall = t_start.elapsed();
        let text = self.tokenizer.decode(&out_tokens);
        Ok(GenResult { tokens: out_tokens, text, metrics })
    }
}
