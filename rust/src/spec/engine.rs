//! Single-request generation engine: the paper's §2.4 inference pipeline.
//!
//! Per cycle (see [`super::session`] — the cycle state machine itself
//! lives there, shared with the continuous batcher):
//! 1. **Non-autoregressive drafting** — the drafter emits per-level
//!    distributions; Backbone Expansion builds the constrained tree with
//!    the pending token as root.
//! 2. **Parallel verification** — one target forward over all tree rows
//!    with the ancestor mask (tree attention); lossless acceptance picks
//!    the longest valid path and the bonus token.
//! 3. **Update** — accepted rows are compacted into the canonical KV
//!    prefix, the drafter observes the newly-committed anchors (real
//!    verified features), and the bonus becomes the next pending token.
//!
//! [`Engine::generate`] is a thin drain-the-session wrapper over
//! [`GenSession`]; callers that want per-cycle control (streaming,
//! adaptive draft schedules) use [`Engine::start_session`] directly.

use anyhow::Result;

use crate::draft::Drafter;
use crate::model::{TargetModel, Tokenizer};

use super::metrics::GenMetrics;
use super::plan::DraftConfig;
use super::session::GenSession;

#[derive(Debug, Clone)]
pub struct GenConfig {
    pub temperature: f32,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// draft-structure knobs (planner, depth, top-k, node budget); all
    /// optional — unset fields resolve to the model spec's defaults.
    /// `top_k: Some(1)` forces a chain — the "w/o Constrained Tree"
    /// ablation; `depth: Some(2)` is Table 3's truncation.
    pub draft: DraftConfig,
    pub stop_on_eos: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            temperature: 0.0,
            max_new_tokens: 64,
            seed: 0,
            draft: DraftConfig::default(),
            stop_on_eos: false,
        }
    }
}

#[derive(Debug)]
pub struct GenResult {
    pub tokens: Vec<i32>,
    pub text: String,
    pub metrics: GenMetrics,
}

pub struct Engine {
    pub target: TargetModel,
    pub drafter: Box<dyn Drafter>,
    pub tokenizer: Tokenizer,
}

impl Engine {
    pub fn new(target: TargetModel, drafter: Box<dyn Drafter>) -> Engine {
        let tokenizer = Tokenizer::new(target.spec.bos, target.spec.eos, target.spec.pad);
        Engine { target, drafter, tokenizer }
    }

    /// Begin a resumable session: prefill now, then one cycle per
    /// [`GenSession::step`].
    pub fn start_session(&mut self, prompt: &str, cfg: &GenConfig) -> Result<GenSession<'_>> {
        GenSession::new(&self.target, &mut self.drafter, self.tokenizer, prompt, cfg)
    }

    /// Blocking generation: drain a session to completion.
    pub fn generate(&mut self, prompt: &str, cfg: &GenConfig) -> Result<GenResult> {
        // one flight-recorder span per request: prefill + every cycle
        let mut span = crate::obs::span("generate");
        let mut session =
            GenSession::new(&self.target, &mut self.drafter, self.tokenizer, prompt, cfg)?;
        while !session.finished() {
            session.step()?;
        }
        let result = session.finish();
        span.set_arg(result.tokens.len() as i64);
        drop(span);
        Ok(result)
    }
}
