//! Token sampling from target/draft distributions: temperature softmax,
//! greedy argmax, top-p filtering, and seeded categorical draws.

use crate::util::rng::{argmax, softmax_temp, Pcg64};

#[derive(Debug, Clone)]
pub struct Sampler {
    pub temperature: f32,
    pub top_p: f32,
    rng: Pcg64,
}

impl Sampler {
    pub fn new(temperature: f32, seed: u64) -> Sampler {
        Sampler { temperature, top_p: 1.0, rng: Pcg64::new(seed, 0xfa57_ea91e) }
    }

    pub fn greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// logits -> normalized distribution at this sampler's temperature
    /// (one-hot argmax in the greedy limit).
    pub fn dist_from_logits(&self, logits: &[f32]) -> Vec<f32> {
        let mut d = logits.to_vec();
        softmax_temp(&mut d, self.temperature);
        if self.top_p < 1.0 && self.temperature > 0.0 {
            apply_top_p(&mut d, self.top_p);
        }
        d
    }

    /// Draw a token from a normalized distribution.
    pub fn sample(&mut self, dist: &[f32]) -> i32 {
        if self.greedy() {
            argmax(dist) as i32
        } else {
            self.rng.categorical(dist) as i32
        }
    }

    /// Uniform draw in [0,1) (speculative accept/reject coin).
    pub fn coin(&mut self) -> f32 {
        self.rng.next_f64() as f32
    }

    /// Direct access to the underlying stream (tree-candidate sampling).
    pub fn rng_mut(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Nucleus filtering in place: keep the smallest prefix of
/// probability-sorted tokens with cumulative mass >= p, renormalize.
pub fn apply_top_p(dist: &mut [f32], p: f32) {
    let mut idx: Vec<usize> = (0..dist.len()).collect();
    idx.sort_by(|&a, &b| dist[b].partial_cmp(&dist[a]).unwrap());
    let mut acc = 0.0f32;
    let mut cut = dist.len();
    for (rank, &i) in idx.iter().enumerate() {
        acc += dist[i];
        if acc >= p {
            cut = rank + 1;
            break;
        }
    }
    let keep: std::collections::HashSet<usize> = idx[..cut].iter().copied().collect();
    let mut sum = 0.0f32;
    for (i, v) in dist.iter_mut().enumerate() {
        if !keep.contains(&i) {
            *v = 0.0;
        } else {
            sum += *v;
        }
    }
    if sum > 0.0 {
        for v in dist.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(0.0, 1);
        let d = s.dist_from_logits(&[0.1, 2.0, 1.0]);
        assert_eq!(d, vec![0.0, 1.0, 0.0]);
        assert_eq!(s.sample(&d), 1);
    }

    #[test]
    fn stochastic_matches_frequencies() {
        let mut s = Sampler::new(1.0, 2);
        let d = s.dist_from_logits(&[0.0, (4.0f32).ln(), 0.0]);
        // probs = [1/6, 4/6, 1/6]
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[s.sample(&d) as usize] += 1;
        }
        assert!((counts[1] as f64 / 30_000.0 - 4.0 / 6.0).abs() < 0.02);
    }

    #[test]
    fn top_p_filters_tail() {
        let mut d = vec![0.5f32, 0.3, 0.15, 0.05];
        apply_top_p(&mut d, 0.8);
        assert_eq!(d[3], 0.0);
        assert_eq!(d[2], 0.0);
        let sum: f32 = d.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!((d[0] - 0.625).abs() < 1e-6);
    }

    #[test]
    fn temperature_sharpens() {
        let s_hot = Sampler::new(2.0, 3);
        let s_cold = Sampler::new(0.5, 3);
        let hot = s_hot.dist_from_logits(&[1.0, 2.0]);
        let cold = s_cold.dist_from_logits(&[1.0, 2.0]);
        assert!(cold[1] > hot[1]);
    }
}
