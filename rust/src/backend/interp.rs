//! HLO interpreter backend: parse the `.hlo.txt` executable once at
//! "compile" time, evaluate it on the CPU at call time.
//!
//! This is the backend that makes the artifact-gated integration tests
//! and benches run in CI: no `xla_extension`, no network, deterministic
//! arithmetic (fixed accumulation order in `backend::hlo::eval`), so a
//! fixed fixture seed reproduces greedy decodes bit-for-bit.

use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::{HostTensor, TensorData};

use super::hlo::eval::{evaluate, Buf, Value};
use super::hlo::parser::{parse_module, HloModule};
use super::hlo::verify;
use super::{Backend, BackendBound, BackendExec};

#[derive(Default)]
pub struct HloInterpreter;

impl HloInterpreter {
    pub fn new() -> HloInterpreter {
        HloInterpreter
    }
}

fn to_value(t: &HostTensor) -> Value {
    match &t.data {
        TensorData::F32(v) => Value::f32(t.shape.clone(), v.clone()),
        TensorData::I32(v) => Value::i32(t.shape.clone(), v.clone()),
    }
}

fn to_host(v: Value) -> Result<HostTensor> {
    match v.buf {
        Buf::F32(data) => Ok(HostTensor::f32(v.dims, data)),
        Buf::I32(data) => Ok(HostTensor::i32(v.dims, data)),
        Buf::U32(_) | Buf::U64(_) => {
            bail!("executable output is unsigned-typed (convert before the root)")
        }
        Buf::Pred(_) => bail!("executable output is pred-typed"),
    }
}

impl Backend for HloInterpreter {
    fn platform_name(&self) -> String {
        "hlo-interpreter".to_string()
    }

    fn compile(&self, hlo_path: &Path, manifest: &ExecManifest) -> Result<Box<dyn BackendExec>> {
        let text = std::fs::read_to_string(hlo_path)
            .with_context(|| format!("read {hlo_path:?}"))?;
        let module =
            parse_module(&text).with_context(|| format!("parse {hlo_path:?}"))?;
        // statically verify the program and cross-check the manifest
        // against the entry signature now, so a drifted or ill-typed
        // artifact fails at compile, not mid-serve
        let mut diags = verify::verify_module(&module);
        diags.extend(verify::verify_manifest(&module, manifest));
        verify::ensure_ok(&manifest.name, &diags)?;
        Ok(Box::new(InterpExec { module: Arc::new(module), name: manifest.name.clone() }))
    }
}

pub struct InterpExec {
    module: Arc<HloModule>,
    name: String,
}

impl BackendExec for InterpExec {
    fn bind(&self, weights: &[Option<&HostTensor>]) -> Result<Box<dyn BackendBound>> {
        let pinned = weights
            .iter()
            .map(|w| w.map(|t| Rc::new(to_value(t))))
            .collect();
        Ok(Box::new(InterpBound {
            module: Arc::clone(&self.module),
            name: self.name.clone(),
            weights: pinned,
        }))
    }
}

pub struct InterpBound {
    module: Arc<HloModule>,
    name: String,
    weights: Vec<Option<Rc<Value>>>,
}

impl BackendBound for InterpBound {
    fn call(&self, args: &[Option<&HostTensor>]) -> Result<Vec<HostTensor>> {
        let _sp = crate::obs::span("interp").label(&self.name);
        if args.len() != self.weights.len() {
            bail!(
                "{}: {} positional args, executable has {} inputs",
                self.name,
                args.len(),
                self.weights.len()
            );
        }
        let mut full: Vec<Rc<Value>> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match (a, &self.weights[i]) {
                (Some(t), None) => full.push(Rc::new(to_value(t))),
                (None, Some(w)) => full.push(Rc::clone(w)),
                (Some(_), Some(_)) => {
                    bail!("{}: input {i} is weight-bound and passed at call", self.name)
                }
                (None, None) => bail!("{}: input {i} missing", self.name),
            }
        }
        let outs = evaluate(&self.module, &full)
            .with_context(|| format!("interpret {}", self.name))?;
        outs.into_iter().map(to_host).collect()
    }
}
