//! HLO interpreter backend: parse, verify, and *plan* the `.hlo.txt`
//! executable once at "compile" time, then run the compiled
//! [`ExecPlan`] at call time.
//!
//! This is the backend that makes the artifact-gated integration tests
//! and benches run in CI: no `xla_extension`, no network, deterministic
//! arithmetic (fixed accumulation order in `backend::hlo::{eval,plan}`),
//! so a fixed fixture seed reproduces greedy decodes bit-for-bit.
//!
//! Compiled plans are cached per executable name (keyed by a hash of
//! the HLO text), so engine restarts and bench sweeps that re-`compile`
//! the same artifact skip the parse + verify + plan work. Environment
//! knobs: `FE_INTERP_THREADS` / `FE_INTERP_FUSE` (see
//! [`EvalOptions::from_env`]) and `FE_INTERP_OPT=0` to fall back to the
//! naive reference evaluator (the plan is property-tested bit-identical
//! to it, so outputs do not change — only speed).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::{HostTensor, TensorData};

use super::hlo::eval::{evaluate, Buf, Value};
use super::hlo::parser::parse_module;
use super::hlo::plan::{EvalOptions, ExecPlan};
use super::hlo::verify;
use super::{Backend, BackendBound, BackendExec};

/// FNV-1a over the HLO text: cheap cache-invalidation fingerprint so a
/// regenerated fixture with the same executable name recompiles.
fn text_hash(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct CachedPlan {
    text_hash: u64,
    plan: Arc<ExecPlan>,
}

pub struct HloInterpreter {
    opts: EvalOptions,
    plans: Mutex<HashMap<String, CachedPlan>>,
}

impl Default for HloInterpreter {
    fn default() -> Self {
        Self::new()
    }
}

impl HloInterpreter {
    pub fn new() -> HloInterpreter {
        HloInterpreter { opts: EvalOptions::from_env(), plans: Mutex::new(HashMap::new()) }
    }
}

fn to_value(t: &HostTensor) -> Value {
    match &t.data {
        TensorData::F32(v) => Value::f32(t.shape.clone(), v.clone()),
        TensorData::I32(v) => Value::i32(t.shape.clone(), v.clone()),
    }
}

fn to_host(v: Value) -> Result<HostTensor> {
    match v.buf {
        Buf::F32(data) => Ok(HostTensor::f32(v.dims, data)),
        Buf::I32(data) => Ok(HostTensor::i32(v.dims, data)),
        Buf::U32(_) | Buf::U64(_) => {
            bail!("executable output is unsigned-typed (convert before the root)")
        }
        Buf::Pred(_) => bail!("executable output is pred-typed"),
    }
}

impl Backend for HloInterpreter {
    fn platform_name(&self) -> String {
        "hlo-interpreter".to_string()
    }

    fn compile(&self, hlo_path: &Path, manifest: &ExecManifest) -> Result<Box<dyn BackendExec>> {
        let text = std::fs::read_to_string(hlo_path)
            .with_context(|| format!("read {hlo_path:?}"))?;
        let hash = text_hash(&text);
        {
            let plans = match self.plans.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if let Some(c) = plans.get(&manifest.name) {
                if c.text_hash == hash {
                    return Ok(Box::new(InterpExec {
                        plan: Arc::clone(&c.plan),
                        name: manifest.name.clone(),
                    }));
                }
            }
        }
        let module =
            parse_module(&text).with_context(|| format!("parse {hlo_path:?}"))?;
        // statically verify the program and cross-check the manifest
        // against the entry signature now, so a drifted or ill-typed
        // artifact fails at compile, not mid-serve
        let mut diags = verify::verify_module(&module);
        diags.extend(verify::verify_manifest(&module, manifest));
        verify::ensure_ok(&manifest.name, &diags)?;
        let module = Arc::new(module);
        let plan = Arc::new(
            ExecPlan::compile(&module, self.opts)
                .with_context(|| format!("plan {hlo_path:?}"))?,
        );
        let mut plans = match self.plans.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        plans.insert(
            manifest.name.clone(),
            CachedPlan { text_hash: hash, plan: Arc::clone(&plan) },
        );
        Ok(Box::new(InterpExec { plan, name: manifest.name.clone() }))
    }
}

pub struct InterpExec {
    plan: Arc<ExecPlan>,
    name: String,
}

impl BackendExec for InterpExec {
    fn bind(&self, weights: &[Option<&HostTensor>]) -> Result<Box<dyn BackendBound>> {
        let pinned = weights
            .iter()
            .map(|w| w.map(|t| Arc::new(to_value(t))))
            .collect();
        Ok(Box::new(InterpBound {
            plan: Arc::clone(&self.plan),
            name: self.name.clone(),
            weights: pinned,
            naive: std::env::var("FE_INTERP_OPT").is_ok_and(|v| v == "0"),
        }))
    }
}

pub struct InterpBound {
    plan: Arc<ExecPlan>,
    name: String,
    weights: Vec<Option<Arc<Value>>>,
    /// `FE_INTERP_OPT=0`: run the naive reference walk instead of the
    /// compiled plan (byte-identical output, used by the on/off e2e
    /// identity test and as an escape hatch).
    naive: bool,
}

impl BackendBound for InterpBound {
    fn call(&self, args: &[Option<&HostTensor>]) -> Result<Vec<HostTensor>> {
        let _sp = crate::obs::span("interp").label(&self.name);
        if args.len() != self.weights.len() {
            bail!(
                "{}: {} positional args, executable has {} inputs",
                self.name,
                args.len(),
                self.weights.len()
            );
        }
        let mut full: Vec<Arc<Value>> = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            match (a, &self.weights[i]) {
                (Some(t), None) => full.push(Arc::new(to_value(t))),
                (None, Some(w)) => full.push(Arc::clone(w)),
                (Some(_), Some(_)) => {
                    bail!("{}: input {i} is weight-bound and passed at call", self.name)
                }
                (None, None) => bail!("{}: input {i} missing", self.name),
            }
        }
        let outs = if self.naive {
            evaluate(self.plan.module(), &full)
        } else {
            self.plan.execute(&full)
        }
        .with_context(|| format!("interpret {}", self.name))?;
        outs.into_iter().map(to_host).collect()
    }
}
