//! Execution backends behind the runtime layer.
//!
//! `runtime::client::Runtime` used to hard-code the PJRT bindings; the
//! [`Backend`] trait extracts the three operations the serving stack
//! actually needs — *compile* an HLO-text executable, *bind* a weight
//! set once, *execute* with per-call inputs — so the same draft→verify
//! pipeline runs against either implementation:
//!
//! * [`pjrt::PjrtBackend`] — the original path through the `xla` crate
//!   (real PJRT when linked against `xla_extension`, the vendored host
//!   stub otherwise).
//! * [`interp::HloInterpreter`] — an in-process HLO-text parser +
//!   CPU evaluator (`backend::hlo`). No native toolchain, runs
//!   everywhere `cargo test` runs; this is the backend the CI
//!   integration lane and the fixture artifacts use.
//!
//! [`fixture`] generates a tiny but complete artifact tree (target +
//! cascaded drafter + EAGLE baseline) the interpreter can execute, so
//! `SpecEngine` drives real draft→verify→accept cycles without PJRT.

pub mod fixture;
pub mod hlo;
pub mod interp;
pub mod pjrt;

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::HostTensor;

/// Which backend executes the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT via the `xla` crate (vendored host stub unless the real
    /// bindings are linked).
    Pjrt,
    /// In-process HLO interpreter (always available).
    Interpret,
}

impl BackendKind {
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "pjrt" | "cpu" | "xla" => BackendKind::Pjrt,
            "interpret" | "interpreter" | "interp" => BackendKind::Interpret,
            other => bail!("unknown backend {other:?} (want pjrt|interpret)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Interpret => "interpret",
        }
    }
}

/// A device/execution substrate: compiles HLO-text executables.
pub trait Backend: Send + Sync {
    fn platform_name(&self) -> String;

    /// Compile the HLO text at `hlo_path` against its IO manifest.
    fn compile(&self, hlo_path: &Path, manifest: &ExecManifest) -> Result<Box<dyn BackendExec>>;
}

/// A compiled executable (backend-specific state).
pub trait BackendExec {
    /// Stage the weight-kind inputs once: `weights[i]` is `Some` exactly
    /// for manifest input `i` of kind Weight (PJRT uploads device
    /// buffers here; the interpreter pins host values).
    fn bind(&self, weights: &[Option<&HostTensor>]) -> Result<Box<dyn BackendBound>>;
}

/// An executable bound to a weight set.
pub trait BackendBound {
    /// Execute with per-call inputs: `args[i]` is `Some` exactly for the
    /// non-weight manifest inputs, in manifest (= HLO parameter) order.
    /// Returns outputs in module tuple order.
    fn call(&self, args: &[Option<&HostTensor>]) -> Result<Vec<HostTensor>>;
}

/// Construct a backend by kind.
pub fn make_backend(kind: BackendKind) -> Result<Box<dyn Backend>> {
    Ok(match kind {
        BackendKind::Pjrt => Box::new(pjrt::PjrtBackend::new()?),
        BackendKind::Interpret => Box::new(interp::HloInterpreter::new()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(BackendKind::from_str("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::from_str("interpret").unwrap(), BackendKind::Interpret);
        assert_eq!(BackendKind::from_str("interp").unwrap(), BackendKind::Interpret);
        assert!(BackendKind::from_str("tpu").is_err());
        assert_eq!(BackendKind::Interpret.name(), "interpret");
    }
}
