//! Fixture artifact generator: a tiny (d_model=16, 2-layer) target +
//! cascaded-drafter (+ EAGLE baseline) artifact tree the HLO interpreter
//! can execute, emitted **deterministically from a seed** — same seed,
//! bit-identical tree, bit-identical greedy decodes.
//!
//! The tree has exactly the layout `aot.py` produces (`spec.json`,
//! `hlo/<exec>.hlo.txt` + `.io.json`, `weights/<set>.few`,
//! `prompts/<task>.json`, root `manifest.json`), so `ArtifactStore`,
//! `SpecEngine`, `BatchEngine`, the TCP server and the benches all run
//! on it unmodified — this is what un-skips the artifact-gated
//! integration tests in CI.
//!
//! The drafters are not trained; they are *constructed* to correlate
//! with the target (shared token embeddings and output head, drafter
//! position table shifted by one so an anchor's draft mimics the
//! target's next row), which yields τ > 1 level-1 acceptance and
//! realistic depth falloff while staying fully deterministic.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::HostTensor;
use crate::runtime::weights::write_few;
use crate::util::rng::Pcg64;

use super::hlo::builder::{H, HloBuilder, Ty};
use super::hlo::parser::parse_module;
use super::hlo::verify;

// fixture model dimensions (single head keeps the lowered graphs small;
// everything downstream reads them from spec.json, not from here)
const D: usize = 16;
const L: usize = 2;
const KH: usize = 1;
const HD: usize = 16;
const FFN: usize = 32;
const V: usize = 272;
const S: usize = 128;
const N_CASCADE: usize = 3;
const PREFILL_CHUNK: usize = 16;
const TREE_TOP_K: usize = 2;
const VERIFY_MS: [usize; 4] = [1, 3, 8, 16];
const CHUNK_TS: [usize; 3] = [1, 8, 32];
const BATCHED_MS: [usize; 2] = [1, 3];
const BATCHED_TS: [usize; 2] = [1, 8];

const TASKS: [&str; 5] = ["dialog", "code", "math", "inst", "news"];

// ---------------------------------------------------------------------------
// weight specs + values
// ---------------------------------------------------------------------------

type NamedTensors = Vec<(String, HostTensor)>;

fn layer_specs(prefix: &str) -> Vec<(String, Vec<usize>)> {
    vec![
        (format!("{prefix}/wq"), vec![D, D]),
        (format!("{prefix}/wk"), vec![D, D]),
        (format!("{prefix}/wv"), vec![D, D]),
        (format!("{prefix}/wo"), vec![D, D]),
        (format!("{prefix}/w1"), vec![D, FFN]),
        (format!("{prefix}/w2"), vec![FFN, D]),
    ]
}

fn target_weight_specs() -> Vec<(String, Vec<usize>)> {
    let mut w = vec![("emb".to_string(), vec![V, D]), ("pos".to_string(), vec![S, D])];
    for l in 0..L {
        w.extend(layer_specs(&format!("l{l}")));
    }
    w.push(("w_out".to_string(), vec![D, V]));
    w
}

fn fe_weight_specs() -> Vec<(String, Vec<usize>)> {
    let mut w = vec![
        ("fe/in".to_string(), vec![3 * D, D]),
        ("fe/emb".to_string(), vec![V, D]),
        ("fe/pos".to_string(), vec![S, D]),
    ];
    for i in 0..N_CASCADE {
        w.extend(layer_specs(&format!("fe/l{i}")));
    }
    w.push(("fe/head".to_string(), vec![D, V]));
    w
}

/// Weight inputs of one EAGLE executable (`first` selects the input
/// projection; the rest is shared with the other variant).
fn eagle_weight_specs(first: bool) -> Vec<(String, Vec<usize>)> {
    let proj = if first {
        ("eg/first_in".to_string(), vec![3 * D, D])
    } else {
        ("eg/next_in".to_string(), vec![D, D])
    };
    let mut w = vec![
        proj,
        ("eg/emb".to_string(), vec![V, D]),
        ("eg/pos".to_string(), vec![S, D]),
    ];
    w.extend(layer_specs("eg/l"));
    w.push(("eg/head".to_string(), vec![D, V]));
    w
}

fn rand_tensor(rng: &mut Pcg64, dims: Vec<usize>, scale: f32) -> HostTensor {
    let n: usize = dims.iter().product();
    let data: Vec<f32> =
        (0..n).map(|_| (rng.next_f64() as f32 * 2.0 - 1.0) * scale).collect();
    HostTensor::f32(dims, data)
}

fn rand_layer(rng: &mut Pcg64, prefix: &str, s_qkv: f32, s_out: f32) -> NamedTensors {
    vec![
        (format!("{prefix}/wq"), rand_tensor(rng, vec![D, D], s_qkv)),
        (format!("{prefix}/wk"), rand_tensor(rng, vec![D, D], s_qkv)),
        (format!("{prefix}/wv"), rand_tensor(rng, vec![D, D], s_qkv)),
        (format!("{prefix}/wo"), rand_tensor(rng, vec![D, D], s_out)),
        (format!("{prefix}/w1"), rand_tensor(rng, vec![D, FFN], s_qkv)),
        (format!("{prefix}/w2"), rand_tensor(rng, vec![FFN, D], s_out)),
    ]
}

/// The drafter position table is the target's shifted by one: the draft
/// for anchor position p mimics the target's row at p+1.
fn shifted_pos(pos: &HostTensor) -> HostTensor {
    let src = pos.as_f32().unwrap();
    let mut data = vec![0.0f32; S * D];
    for p in 0..S {
        let q = (p + 1).min(S - 1);
        data[p * D..(p + 1) * D].copy_from_slice(&src[q * D..(q + 1) * D]);
    }
    HostTensor::f32(vec![S, D], data)
}

/// All three weight sets from one seed.
fn gen_weights(seed: u64) -> (NamedTensors, NamedTensors, NamedTensors) {
    let mut rng = Pcg64::new(seed, 17);
    // target: token/pos embeddings dominate, attention/FFN perturb —
    // predictable enough that a head-sharing drafter gets accepted
    let emb = rand_tensor(&mut rng, vec![V, D], 1.0);
    let pos = rand_tensor(&mut rng, vec![S, D], 0.3);
    let mut target: NamedTensors =
        vec![("emb".to_string(), emb.clone()), ("pos".to_string(), pos.clone())];
    for l in 0..L {
        target.extend(rand_layer(&mut rng, &format!("l{l}"), 0.125, 0.06));
    }
    let w_out = rand_tensor(&mut rng, vec![D, V], 0.5);
    target.push(("w_out".to_string(), w_out.clone()));

    // fasteagle: shared embeddings/head, shifted positions, small cascade
    let mut fe: NamedTensors = vec![
        ("fe/in".to_string(), rand_tensor(&mut rng, vec![3 * D, D], 0.02)),
        ("fe/emb".to_string(), emb.clone()),
        ("fe/pos".to_string(), shifted_pos(&pos)),
    ];
    for i in 0..N_CASCADE {
        fe.extend(rand_layer(&mut rng, &format!("fe/l{i}"), 0.06, 0.03));
    }
    fe.push(("fe/head".to_string(), w_out.clone()));

    // eagle: one layer, same construction
    let mut eg: NamedTensors = vec![
        ("eg/first_in".to_string(), rand_tensor(&mut rng, vec![3 * D, D], 0.02)),
        ("eg/next_in".to_string(), rand_tensor(&mut rng, vec![D, D], 0.02)),
        ("eg/emb".to_string(), emb),
        ("eg/pos".to_string(), shifted_pos(&pos)),
    ];
    eg.extend(rand_layer(&mut rng, "eg/l", 0.06, 0.03));
    eg.push(("eg/head".to_string(), w_out));
    (target, fe, eg)
}

// ---------------------------------------------------------------------------
// HLO emission
// ---------------------------------------------------------------------------

struct LayerWH {
    wq: H,
    wk: H,
    wv: H,
    wo: H,
    w1: H,
    w2: H,
}

fn io_entry(name: &str, kind: Option<&str>, shape: &[usize], dtype: &str) -> String {
    let kind_s = kind.map(|k| format!("\"kind\": \"{k}\", ")).unwrap_or_default();
    format!("{{\"name\": \"{name}\", {kind_s}\"shape\": {shape:?}, \"dtype\": \"{dtype}\"}}")
}

fn io_json(name: &str, inputs: &[String], outputs: &[String]) -> String {
    format!(
        "{{\"name\": \"{name}\", \"inputs\": [{}], \"outputs\": [{}]}}",
        inputs.join(", "),
        outputs.join(", ")
    )
}

/// Declare the weight parameters in spec order; returns name -> handle.
fn weight_params(
    hb: &mut HloBuilder,
    specs: &[(String, Vec<usize>)],
    io_in: &mut Vec<String>,
) -> HashMap<String, H> {
    let mut map = HashMap::new();
    for (name, dims) in specs {
        let h = hb.param(Ty::F32, dims.clone());
        io_in.push(io_entry(name, Some("weight"), dims, "float32"));
        map.insert(name.clone(), h);
    }
    map
}

fn layer_handles(w: &HashMap<String, H>, prefix: &str) -> LayerWH {
    let g = |k: &str| w[&format!("{prefix}/{k}")].clone();
    LayerWH { wq: g("wq"), wk: g("wk"), wv: g("wv"), wo: g("wo"), w1: g("w1"), w2: g("w2") }
}

/// One pre-norm-free attention + tanh-FFN block over a KV cache slice.
///
/// `kv` has dims `[layer?, 2, B, S, KH, HD]`; the block writes this
/// call's K/V rows at `clen..clen+rows` of (layer, batch), attends over
/// the full S slots under the additive `mask2d`, and returns the
/// residual-updated activations plus the updated cache.
#[allow(clippy::too_many_arguments)]
fn attn_ffn_layer(
    hb: &mut HloBuilder,
    x: H,
    w: &LayerWH,
    kv: H,
    layer: Option<usize>,
    batch: usize,
    clen: &H,
    mask2d: &H,
) -> (H, H) {
    let rows = x.dims[0];
    let d = x.dims[1];
    let q = hb.matmul(&x, &w.wq);
    let k = hb.matmul(&x, &w.wk);
    let v = hb.matmul(&x, &w.wv);

    let mut upd_dims = if layer.is_some() { vec![1, 1, 1] } else { vec![1, 1] };
    upd_dims.extend([rows, KH, HD]);
    let starts = |hb: &mut HloBuilder, plane: i32| -> Vec<H> {
        let mut st = Vec::new();
        if let Some(l) = layer {
            st.push(hb.const_s32(l as i32));
        }
        st.push(hb.const_s32(plane));
        st.push(hb.const_s32(batch as i32));
        st.push(clen.clone());
        st.push(hb.const_s32(0));
        st.push(hb.const_s32(0));
        st
    };
    let k6 = hb.reshape(&k, upd_dims.clone());
    let sk = starts(hb, 0);
    let kv = hb.dus(&kv, &k6, &sk);
    let v6 = hb.reshape(&v, upd_dims);
    let sv = starts(hb, 1);
    let kv = hb.dus(&kv, &v6, &sv);

    let read = |hb: &mut HloBuilder, kv: &H, plane: usize| -> H {
        let mut ranges = Vec::new();
        if let Some(l) = layer {
            ranges.push((l, l + 1));
        }
        ranges.push((plane, plane + 1));
        ranges.push((batch, batch + 1));
        ranges.extend([(0, S), (0, KH), (0, HD)]);
        let sl = hb.slice(kv, &ranges);
        hb.reshape(&sl, vec![S, KH * HD])
    };
    let k_all = read(hb, &kv, 0);
    let v_all = read(hb, &kv, 1);

    // scores + masked softmax over all S slots (masked-out slots get
    // exactly-zero probability: exp(-1e9 - max) underflows to 0.0)
    let scores = hb.matmul_nt(&q, &k_all);
    let scale = hb.const_f32(1.0 / (HD as f32).sqrt());
    let scale_b = hb.splat(&scale, vec![rows, S]);
    let scores = hb.mul(&scores, &scale_b);
    let scores = hb.add(&scores, mask2d);
    let rmax = hb.reduce_max(&scores, &[1]);
    let rmax_b = hb.broadcast(&rmax, vec![rows, S], &[0]);
    let shifted = hb.sub(&scores, &rmax_b);
    let e = hb.exp(&shifted);
    let rsum = hb.reduce_add(&e, &[1]);
    let rsum_b = hb.broadcast(&rsum, vec![rows, S], &[0]);
    let p = hb.div(&e, &rsum_b);
    let attn = hb.matmul(&p, &v_all);

    let proj = hb.matmul(&attn, &w.wo);
    let x = hb.add(&x, &proj);
    let h1m = hb.matmul(&x, &w.w1);
    let h1 = hb.tanh(&h1m);
    let ff = hb.matmul(&h1, &w.w2);
    let x = hb.add(&x, &ff);
    debug_assert_eq!(x.dims, vec![rows, d]);
    (x, kv)
}

fn concat_or_single(hb: &mut HloBuilder, parts: Vec<H>, dim: usize) -> H {
    if parts.len() == 1 {
        parts.into_iter().next().unwrap()
    } else {
        let refs: Vec<&H> = parts.iter().collect();
        hb.concat(&refs, dim)
    }
}

/// Per-batch-element views of the shared runtime inputs.
struct BatchView {
    toks: H,
    pos: H,
    mask: H,
    clen: H,
}

fn batch_view(
    hb: &mut HloBuilder,
    b: usize,
    rows: usize,
    toks: &H,
    pos: &H,
    mask: &H,
    clen: &H,
) -> BatchView {
    let tb = hb.slice(toks, &[(b, b + 1), (0, rows)]);
    let tb = hb.reshape(&tb, vec![rows]);
    let pb = hb.slice(pos, &[(b, b + 1), (0, rows)]);
    let pb = hb.reshape(&pb, vec![rows]);
    let mb = hb.slice(mask, &[(b, b + 1), (0, rows), (0, S)]);
    let mb = hb.reshape(&mb, vec![rows, S]);
    let cb = hb.slice(clen, &[(b, b + 1)]);
    let cb = hb.reshape(&cb, vec![]);
    BatchView { toks: tb, pos: pb, mask: mb, clen: cb }
}

/// `tgt_m{m}[_b{b}]`: verify/prefill forward with feature taps.
fn emit_tgt(name: &str, m: usize, bsz: usize) -> (String, String) {
    let mut hb = HloBuilder::new(name);
    let mut io_in = Vec::new();
    let w = weight_params(&mut hb, &target_weight_specs(), &mut io_in);
    let layers: Vec<LayerWH> = (0..L).map(|l| layer_handles(&w, &format!("l{l}"))).collect();

    let tokens = hb.param(Ty::S32, vec![bsz, m]);
    io_in.push(io_entry("tokens", Some("arg"), &[bsz, m], "int32"));
    let positions = hb.param(Ty::S32, vec![bsz, m]);
    io_in.push(io_entry("positions", Some("arg"), &[bsz, m], "int32"));
    let mask = hb.param(Ty::F32, vec![bsz, m, S]);
    io_in.push(io_entry("mask", Some("arg"), &[bsz, m, S], "float32"));
    let cache_len = hb.param(Ty::S32, vec![bsz]);
    io_in.push(io_entry("cache_len", Some("arg"), &[bsz], "int32"));
    let kv_dims = vec![L, 2, bsz, S, KH, HD];
    let mut kv = hb.param(Ty::F32, kv_dims.clone());
    io_in.push(io_entry("kv", Some("state"), &kv_dims, "float32"));

    let mut feats_parts = Vec::new();
    let mut logits_parts = Vec::new();
    for b in 0..bsz {
        let view = batch_view(&mut hb, b, m, &tokens, &positions, &mask, &cache_len);
        let te = hb.gather_rows(&w["emb"], &view.toks);
        let pe = hb.gather_rows(&w["pos"], &view.pos);
        let mut x = hb.add(&te, &pe);
        let mut taps = vec![x.clone()];
        for (l, lw) in layers.iter().enumerate() {
            let (nx, nkv) =
                attn_ffn_layer(&mut hb, x, lw, kv, Some(l), b, &view.clen, &view.mask);
            x = nx;
            kv = nkv;
            taps.push(x.clone());
        }
        let tap_refs: Vec<&H> = taps.iter().collect();
        let f = hb.concat(&tap_refs, 1);
        let lg = hb.matmul(&x, &w["w_out"]);
        let f3 = hb.reshape(&f, vec![1, m, 3 * D]);
        feats_parts.push(f3);
        let l3 = hb.reshape(&lg, vec![1, m, V]);
        logits_parts.push(l3);
    }
    let feats = concat_or_single(&mut hb, feats_parts, 0);
    let logits = concat_or_single(&mut hb, logits_parts, 0);
    let io_out = vec![
        io_entry("feats", None, &[bsz, m, 3 * D], "float32"),
        io_entry("kv", None, &kv_dims, "float32"),
        io_entry("logits", None, &[bsz, m, V], "float32"),
    ];
    (hb.finish(&[&feats, &kv, &logits]), io_json(name, &io_in, &io_out))
}

/// `fe_t{t}[_b{b}]`: the cascaded drafter — one pass over the anchors
/// yields all N_CASCADE per-level draft logits.
fn emit_fe(name: &str, t: usize, bsz: usize) -> (String, String) {
    let mut hb = HloBuilder::new(name);
    let mut io_in = Vec::new();
    let w = weight_params(&mut hb, &fe_weight_specs(), &mut io_in);
    let layers: Vec<LayerWH> =
        (0..N_CASCADE).map(|i| layer_handles(&w, &format!("fe/l{i}"))).collect();

    let feats = hb.param(Ty::F32, vec![bsz, t, 3 * D]);
    io_in.push(io_entry("feats", Some("arg"), &[bsz, t, 3 * D], "float32"));
    let next_tokens = hb.param(Ty::S32, vec![bsz, t]);
    io_in.push(io_entry("next_tokens", Some("arg"), &[bsz, t], "int32"));
    let anchor_pos = hb.param(Ty::S32, vec![bsz, t]);
    io_in.push(io_entry("anchor_pos", Some("arg"), &[bsz, t], "int32"));
    let mask = hb.param(Ty::F32, vec![bsz, t, S]);
    io_in.push(io_entry("mask", Some("arg"), &[bsz, t, S], "float32"));
    let ctx_len = hb.param(Ty::S32, vec![bsz]);
    io_in.push(io_entry("ctx_len", Some("arg"), &[bsz], "int32"));
    let dkv_dims = vec![N_CASCADE, 2, bsz, S, KH, HD];
    let mut dkv = hb.param(Ty::F32, dkv_dims.clone());
    io_in.push(io_entry("dkv", Some("state"), &dkv_dims, "float32"));

    let mut logits_parts = Vec::new();
    for b in 0..bsz {
        let view = batch_view(&mut hb, b, t, &next_tokens, &anchor_pos, &mask, &ctx_len);
        let fb = hb.slice(&feats, &[(b, b + 1), (0, t), (0, 3 * D)]);
        let fb = hb.reshape(&fb, vec![t, 3 * D]);
        let fp = hb.matmul(&fb, &w["fe/in"]);
        let te = hb.gather_rows(&w["fe/emb"], &view.toks);
        let pe = hb.gather_rows(&w["fe/pos"], &view.pos);
        let x0 = hb.add(&fp, &te);
        let mut x = hb.add(&x0, &pe);
        let mut levels = Vec::new();
        for (i, lw) in layers.iter().enumerate() {
            let (nx, nkv) =
                attn_ffn_layer(&mut hb, x, lw, dkv, Some(i), b, &view.clen, &view.mask);
            x = nx;
            dkv = nkv;
            let lv = hb.matmul(&x, &w["fe/head"]);
            let lv = hb.reshape(&lv, vec![t, 1, V]);
            levels.push(lv);
        }
        let lb = concat_or_single(&mut hb, levels, 1);
        let lb = hb.reshape(&lb, vec![1, t, N_CASCADE, V]);
        logits_parts.push(lb);
    }
    let logits = concat_or_single(&mut hb, logits_parts, 0);
    let io_out = vec![
        io_entry("dkv", None, &dkv_dims, "float32"),
        io_entry("logits", None, &[bsz, t, N_CASCADE, V], "float32"),
    ];
    (hb.finish(&[&dkv, &logits]), io_json(name, &io_in, &io_out))
}

/// `eg3_first_t{t}` / `eg_next_t1` (`[_b{b}]`): the single-layer
/// autoregressive EAGLE baseline drafter.
fn emit_eagle(name: &str, first: bool, t: usize, bsz: usize) -> (String, String) {
    let fin = if first { 3 * D } else { D };
    let proj_name = if first { "eg/first_in" } else { "eg/next_in" };
    let mut hb = HloBuilder::new(name);
    let mut io_in = Vec::new();
    let w = weight_params(&mut hb, &eagle_weight_specs(first), &mut io_in);
    let lw = layer_handles(&w, "eg/l");

    let feat_in = hb.param(Ty::F32, vec![bsz, t, fin]);
    io_in.push(io_entry("feat_in", Some("arg"), &[bsz, t, fin], "float32"));
    let tokens = hb.param(Ty::S32, vec![bsz, t]);
    io_in.push(io_entry("tokens", Some("arg"), &[bsz, t], "int32"));
    let anchor_pos = hb.param(Ty::S32, vec![bsz, t]);
    io_in.push(io_entry("anchor_pos", Some("arg"), &[bsz, t], "int32"));
    let mask = hb.param(Ty::F32, vec![bsz, t, S]);
    io_in.push(io_entry("mask", Some("arg"), &[bsz, t, S], "float32"));
    let ctx_len = hb.param(Ty::S32, vec![bsz]);
    io_in.push(io_entry("ctx_len", Some("arg"), &[bsz], "int32"));
    let ekv_dims = vec![2, bsz, S, KH, HD];
    let mut ekv = hb.param(Ty::F32, ekv_dims.clone());
    io_in.push(io_entry("ekv", Some("state"), &ekv_dims, "float32"));

    let mut h_parts = Vec::new();
    let mut logits_parts = Vec::new();
    for b in 0..bsz {
        let view = batch_view(&mut hb, b, t, &tokens, &anchor_pos, &mask, &ctx_len);
        let fb = hb.slice(&feat_in, &[(b, b + 1), (0, t), (0, fin)]);
        let fb = hb.reshape(&fb, vec![t, fin]);
        let fp = hb.matmul(&fb, &w[proj_name]);
        let te = hb.gather_rows(&w["eg/emb"], &view.toks);
        let pe = hb.gather_rows(&w["eg/pos"], &view.pos);
        let x0 = hb.add(&fp, &te);
        let x = hb.add(&x0, &pe);
        let (x, nekv) = attn_ffn_layer(&mut hb, x, &lw, ekv, None, b, &view.clen, &view.mask);
        ekv = nekv;
        let hh = hb.reshape(&x, vec![1, t, D]);
        h_parts.push(hh);
        let lg = hb.matmul(&x, &w["eg/head"]);
        let lg = hb.reshape(&lg, vec![1, t, V]);
        logits_parts.push(lg);
    }
    let h = concat_or_single(&mut hb, h_parts, 0);
    let logits = concat_or_single(&mut hb, logits_parts, 0);
    let io_out = vec![
        io_entry("ekv", None, &ekv_dims, "float32"),
        io_entry("h", None, &[bsz, t, D], "float32"),
        io_entry("logits", None, &[bsz, t, V], "float32"),
    ];
    (hb.finish(&[&ekv, &h, &logits]), io_json(name, &io_in, &io_out))
}

// ---------------------------------------------------------------------------
// tree assembly
// ---------------------------------------------------------------------------

fn spec_json(target: &str, exec_names: &[String], batch_sizes: &[usize]) -> String {
    let execs: Vec<String> = exec_names.iter().map(|n| format!("\"{n}\": {{}}")).collect();
    let batches: Vec<String> = batch_sizes.iter().map(|b| b.to_string()).collect();
    format!(
        r#"{{
 "name": "{target}", "stands_for": "interpreter-fixture",
 "d_model": {D}, "n_layers": {L}, "n_heads": {KH}, "n_kv_heads": {KH},
 "head_dim": {HD}, "ffn": {FFN}, "taps": [0, 1, 2], "max_seq": {S},
 "vocab": {V}, "feat_dim": {fd}, "bos": 256, "eos": 257, "pad": 258,
 "prefill_chunk": {PREFILL_CHUNK}, "draft_depth": {N_CASCADE},
 "tree_top_k": {TREE_TOP_K}, "tree_nodes": {nodes},
 "medusa_heads": 4, "sps_chain": 5,
 "sps": {{"d_model": {D}, "n_layers": 1, "n_kv_heads": {KH}, "head_dim": {HD}}},
 "drafter_sets": ["fasteagle", "eagle3"],
 "executables": {{{execs}}},
 "batch_sizes": [{batches}]
}}
"#,
        fd = 3 * D,
        // emitted for external tooling; ModelSpec re-derives it from
        // the same DraftPlan helper, so the two can never drift
        nodes = crate::spec::plan::default_draft_nodes(N_CASCADE, TREE_TOP_K),
        execs = execs.join(", "),
        batches = batches.join(", "),
    )
}

fn prompt_set(task: &str) -> Vec<String> {
    let topics: [(&str, &str); 8] = match task {
        "code" => [
            ("write a function to add numbers", "return the sum"),
            ("sort a list fast", "use quicksort"),
            ("parse a config file", "read each line"),
            ("reverse a string", "swap the ends"),
            ("hash a password", "salt it first"),
            ("walk a tree", "visit children"),
            ("open a socket", "bind the port"),
            ("cache a result", "key by input"),
        ],
        "math" => [
            ("Ben has 4 coins and buys 9 more coins", "how many coins"),
            ("a train goes 60 miles in 2 hours", "how fast is it"),
            ("12 apples split among 3 kids", "how many each"),
            ("a square has side 5", "what is the area"),
            ("7 times 8 minus 6", "what is the value"),
            ("half of 90 plus 13", "what is the total"),
            ("a jar holds 24 candies, 9 eaten", "how many left"),
            ("3 packs of 11 pens", "how many pens"),
        ],
        "inst" => [
            ("make tea", "steps please"),
            ("plant a seed", "short guide"),
            ("fold a letter", "explain simply"),
            ("clean a lens", "what to avoid"),
            ("pack a bag", "list the items"),
            ("tie a knot", "step by step"),
            ("draw a map", "where to start"),
            ("store apples", "keep them fresh"),
        ],
        "news" => [
            ("the harbor opened a new bridge", "summarize"),
            ("rain flooded the old market", "summarize"),
            ("the team won the spring cup", "summarize"),
            ("a library added night hours", "summarize"),
            ("the mill hired ten workers", "summarize"),
            ("buses switched to new routes", "summarize"),
            ("the fair drew record crowds", "summarize"),
            ("a bakery won the town prize", "summarize"),
        ],
        _ => [
            ("machine learning and the fast cache", "tell me more"),
            ("city transport and the steady bridge", "tell me more"),
            ("summer rain and the quiet river", "tell me more"),
            ("old maps and the long road", "tell me more"),
            ("night trains and the far lights", "tell me more"),
            ("warm bread and the small shop", "tell me more"),
            ("deep caves and the cold air", "tell me more"),
            ("tall ships and the wide bay", "tell me more"),
        ],
    };
    topics
        .iter()
        .map(|(a, b)| format!("USER: {a}. {b}.\nASSISTANT:"))
        .collect()
}

fn write_json(path: &Path, text: &str) -> Result<()> {
    std::fs::write(path, text).with_context(|| format!("write {path:?}"))
}

/// Emit one `<root>/<target>/` artifact directory.
pub fn generate_target_dir(dir: &Path, target: &str, seed: u64, batch_sizes: &[usize]) -> Result<()> {
    let hlo_dir = dir.join("hlo");
    let wdir = dir.join("weights");
    std::fs::create_dir_all(&hlo_dir)?;
    std::fs::create_dir_all(&wdir)?;

    let (target_w, fe_w, eg_w) = gen_weights(seed);
    write_few(&wdir.join("target.few"), &target_w)?;
    write_few(&wdir.join("fasteagle.few"), &fe_w)?;
    write_few(&wdir.join("eagle3.few"), &eg_w)?;

    let mut plan: Vec<(String, String, String)> = Vec::new(); // (name, hlo, io)
    for m in VERIFY_MS {
        let name = format!("tgt_m{m}");
        let (h, io) = emit_tgt(&name, m, 1);
        plan.push((name, h, io));
    }
    for t in CHUNK_TS {
        let name = format!("fe_t{t}");
        let (h, io) = emit_fe(&name, t, 1);
        plan.push((name, h, io));
        let name = format!("eg3_first_t{t}");
        let (h, io) = emit_eagle(&name, true, t, 1);
        plan.push((name, h, io));
    }
    {
        let (h, io) = emit_eagle("eg_next_t1", false, 1, 1);
        plan.push(("eg_next_t1".to_string(), h, io));
    }
    for &b in batch_sizes.iter().filter(|&&b| b > 1) {
        for m in BATCHED_MS {
            let name = format!("tgt_m{m}_b{b}");
            let (h, io) = emit_tgt(&name, m, b);
            plan.push((name, h, io));
        }
        for t in BATCHED_TS {
            let name = format!("fe_t{t}_b{b}");
            let (h, io) = emit_fe(&name, t, b);
            plan.push((name, h, io));
            let name = format!("eg3_first_t{t}_b{b}");
            let (h, io) = emit_eagle(&name, true, t, b);
            plan.push((name, h, io));
        }
        let name = format!("eg_next_t1_b{b}");
        let (h, io) = emit_eagle(&name, false, 1, b);
        plan.push((name, h, io));
    }

    let mut names = Vec::new();
    for (name, hlo, io) in &plan {
        // verify every emitted executable before it lands on disk — a
        // builder regression should fail generation, not a later test
        let module = parse_module(hlo).with_context(|| format!("fixture {name}: parse"))?;
        let manifest =
            ExecManifest::parse(io).with_context(|| format!("fixture {name}: manifest"))?;
        let mut diags = verify::verify_module(&module);
        diags.extend(verify::verify_manifest(&module, &manifest));
        verify::ensure_ok(&format!("fixture {name}"), &diags)?;
        std::fs::write(hlo_dir.join(format!("{name}.hlo.txt")), hlo)?;
        std::fs::write(hlo_dir.join(format!("{name}.io.json")), io)?;
        names.push(name.clone());
    }
    write_json(&dir.join("spec.json"), &spec_json(target, &names, batch_sizes))
}

/// Emit a full artifact tree (`manifest.json`, `prompts/`, targets
/// `base` (B=1) and `mid` (adds B=2 serving executables)).
pub fn generate_tree(root: &Path, seed: u64) -> Result<()> {
    std::fs::create_dir_all(root.join("prompts"))?;
    for task in TASKS {
        let prompts = prompt_set(task);
        let quoted: Vec<String> =
            prompts.iter().map(|p| format!("{:?}", p)).collect();
        write_json(
            &root.join("prompts").join(format!("{task}.json")),
            &format!("[{}]", quoted.join(", ")),
        )?;
    }
    let tasks_q: Vec<String> = TASKS.iter().map(|t| format!("\"{t}\"")).collect();
    let stands: Vec<String> = TASKS
        .iter()
        .map(|t| format!("\"{t}\": \"fixture\""))
        .collect();
    write_json(
        &root.join("manifest.json"),
        &format!(
            r#"{{
 "targets": ["base", "mid"],
 "tasks": [{tasks}],
 "task_stands_for": {{{stands}}},
 "vocab": {V},
 "fast_build": true,
 "fixture_seed": {seed},
 "tree": {{"depth": {N_CASCADE}, "top_k": {TREE_TOP_K}, "nodes": {nodes}}}
}}
"#,
            tasks = tasks_q.join(", "),
            stands = stands.join(", "),
            nodes = crate::spec::plan::default_draft_nodes(N_CASCADE, TREE_TOP_K),
        ),
    )?;
    generate_target_dir(&root.join("base"), "base", seed, &[1])?;
    generate_target_dir(&root.join("mid"), "mid", seed.wrapping_add(1), &[1, 2])?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::hlo::eval::{evaluate, Value};
    use crate::backend::hlo::parser::parse_module;
    use std::sync::Arc;

    #[test]
    fn weight_specs_match_generated_values() {
        let (t, f, e) = gen_weights(7);
        let tspec = target_weight_specs();
        assert_eq!(t.len(), tspec.len());
        for ((name, tensor), (sname, sdims)) in t.iter().zip(&tspec) {
            assert_eq!(name, sname);
            assert_eq!(&tensor.shape, sdims);
        }
        let fspec = fe_weight_specs();
        assert_eq!(f.len(), fspec.len());
        // the eagle set is the union of both variants' specs
        let first: Vec<_> = eagle_weight_specs(true);
        let next: Vec<_> = eagle_weight_specs(false);
        for (name, _) in first.iter().chain(&next) {
            assert!(e.iter().any(|(n, _)| n == name), "missing {name}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let (a, _, _) = gen_weights(42);
        let (b, _, _) = gen_weights(42);
        let (c, _, _) = gen_weights(43);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    /// The emitted tgt module parses, evaluates, and its KV write is
    /// visible to the row that owns it.
    #[test]
    fn tgt_module_runs_through_interpreter() {
        let (hlo, _io) = emit_tgt("tgt_m1", 1, 1);
        let module = parse_module(&hlo).unwrap();
        let (tw, _, _) = gen_weights(5);
        let mut args: Vec<Arc<Value>> = tw
            .iter()
            .map(|(_, t)| {
                Arc::new(Value::f32(t.shape.clone(), t.as_f32().unwrap().to_vec()))
            })
            .collect();
        args.push(Arc::new(Value::i32(vec![1, 1], vec![97])));
        args.push(Arc::new(Value::i32(vec![1, 1], vec![0])));
        let mut mask = vec![-1e9f32; S];
        mask[0] = 0.0;
        args.push(Arc::new(Value::f32(vec![1, 1, S], mask)));
        args.push(Arc::new(Value::i32(vec![1], vec![0])));
        args.push(Arc::new(Value::f32(
            vec![L, 2, 1, S, KH, HD],
            vec![0.0; L * 2 * S * KH * HD],
        )));
        let out = evaluate(&module, &args).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].dims, vec![1, 1, 3 * D]); // feats
        assert_eq!(out[2].dims, vec![1, 1, V]); // logits
        let logits = out[2].f32s().unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        // K row 0 of layer 0 was written
        let kv = out[1].f32s().unwrap();
        assert!(kv[..HD].iter().any(|&v| v != 0.0));
    }
}
