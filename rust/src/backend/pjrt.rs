//! PJRT backend: the original execution path, adapted from
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format (the
//! text parser reassigns the 64-bit instruction ids jax ≥ 0.5 emits,
//! which xla_extension 0.5.1 would otherwise reject).
//!
//! Weights are transferred to device buffers **once** per
//! (executable, weight-set) pair (`bind`); per-call inputs go through
//! `buffer_from_host_buffer` and everything executes via `execute_b`, so
//! the multi-MB parameter tensors never cross the host boundary on the
//! request path.

use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::{HostTensor, TensorData};

use super::{Backend, BackendBound, BackendExec};

pub struct PjrtBackend {
    client: Arc<xla::PjRtClient>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtBackend { client: Arc::new(client) })
    }
}

fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    let buf = match &t.data {
        TensorData::F32(v) => client.buffer_from_host_buffer::<f32>(v, &t.shape, None),
        TensorData::I32(v) => client.buffer_from_host_buffer::<i32>(v, &t.shape, None),
    };
    buf.context("host->device transfer")
}

impl Backend for PjrtBackend {
    fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, hlo_path: &Path, manifest: &ExecManifest) -> Result<Box<dyn BackendExec>> {
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(hlo_path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parse {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", manifest.name))?;
        crate::log_debug!(
            "pjrt compiled {} in {:.0}ms",
            manifest.name,
            t0.elapsed().as_secs_f64() * 1e3
        );
        Ok(Box::new(PjrtExec {
            inner: Rc::new(PjrtExecInner {
                client: Arc::clone(&self.client),
                exe,
                name: manifest.name.clone(),
                n_outputs: manifest.outputs.len(),
            }),
        }))
    }
}

struct PjrtExecInner {
    client: Arc<xla::PjRtClient>,
    exe: xla::PjRtLoadedExecutable,
    name: String,
    n_outputs: usize,
}

pub struct PjrtExec {
    inner: Rc<PjrtExecInner>,
}

impl BackendExec for PjrtExec {
    fn bind(&self, weights: &[Option<&HostTensor>]) -> Result<Box<dyn BackendBound>> {
        let mut wbufs = Vec::with_capacity(weights.len());
        for w in weights {
            wbufs.push(match w {
                Some(t) => Some(upload(&self.inner.client, t)?),
                None => None,
            });
        }
        Ok(Box::new(PjrtBound { inner: Rc::clone(&self.inner), wbufs }))
    }
}

pub struct PjrtBound {
    inner: Rc<PjrtExecInner>,
    wbufs: Vec<Option<xla::PjRtBuffer>>,
}

impl BackendBound for PjrtBound {
    fn call(&self, args: &[Option<&HostTensor>]) -> Result<Vec<HostTensor>> {
        if args.len() != self.wbufs.len() {
            bail!(
                "{}: {} positional args, executable has {} inputs",
                self.inner.name,
                args.len(),
                self.wbufs.len()
            );
        }
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        for (i, a) in args.iter().enumerate() {
            match (a, &self.wbufs[i]) {
                (Some(t), None) => owned.push(upload(&self.inner.client, t)?),
                (None, Some(_)) => {}
                (Some(_), Some(_)) => {
                    bail!("{}: input {i} is weight-bound and passed at call", self.inner.name)
                }
                (None, None) => bail!("{}: input {i} missing", self.inner.name),
            }
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        let mut o = 0usize;
        for (i, a) in args.iter().enumerate() {
            if a.is_some() {
                bufs.push(&owned[o]);
                o += 1;
            } else {
                bufs.push(self.wbufs[i].as_ref().unwrap());
            }
        }
        let result = self
            .inner
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .with_context(|| format!("execute {}", self.inner.name))?;
        let tuple = result[0][0].to_literal_sync().context("fetch result literal")?;
        let parts = tuple.to_tuple().context("untuple result")?;
        if parts.len() != self.inner.n_outputs {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.inner.name,
                parts.len(),
                self.inner.n_outputs
            );
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}
