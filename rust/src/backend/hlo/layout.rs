//! Row-major layout and shape-inference arithmetic shared by the
//! evaluator, the execution-plan compiler, the static verifier, and the
//! HLO builder.
//!
//! Before this module each of those files carried its own copy of the
//! stride/index walk and of the dot/reduce/slice output-shape formulas;
//! a fix in one copy silently missed the others. Everything here is
//! pure arithmetic over `&[usize]` so it stays unit-testable without a
//! parsed module.

use super::parser::DotDims;

/// Row-major strides: `strides([a,b,c]) == [b*c, c, 1]`.
pub fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// Advance a row-major multi-index; returns false after the last one.
pub fn next_index(idx: &mut [usize], dims: &[usize]) -> bool {
    for d in (0..dims.len()).rev() {
        idx[d] += 1;
        if idx[d] < dims[d] {
            return true;
        }
        idx[d] = 0;
    }
    false
}

/// Linear offset of a multi-index under the given strides.
pub fn linear(idx: &[usize], strides: &[usize]) -> usize {
    idx.iter().zip(strides).map(|(i, s)| i * s).sum()
}

/// Output dims of `slice(in_dims)` under `(start, limit, stride)`
/// ranges. `Err` carries a human-readable reason (bad range); the
/// caller supplies rank agreement.
pub fn slice_output_dims(
    in_dims: &[usize],
    ranges: &[(usize, usize, usize)],
) -> Result<Vec<usize>, String> {
    if ranges.len() != in_dims.len() {
        return Err(format!("{} ranges for rank {}", ranges.len(), in_dims.len()));
    }
    let mut dims = Vec::with_capacity(ranges.len());
    for (d, &(s, l, st)) in ranges.iter().enumerate() {
        if st == 0 || l > in_dims[d] || s > l {
            return Err(format!("bad range {:?} for dim {d} of {in_dims:?}", ranges[d]));
        }
        dims.push((l - s).div_ceil(st));
    }
    Ok(dims)
}

/// Axes of `rank` not reduced over.
pub fn reduce_kept_axes(rank: usize, red_dims: &[usize]) -> Vec<usize> {
    (0..rank).filter(|d| !red_dims.contains(d)).collect()
}

/// Output dims of a reduce over `red_dims` (kept axes, in order).
pub fn reduce_output_dims(in_dims: &[usize], red_dims: &[usize]) -> Vec<usize> {
    reduce_kept_axes(in_dims.len(), red_dims)
        .into_iter()
        .map(|d| in_dims[d])
        .collect()
}

/// A dot's derived geometry: free axes per side, the [batch, M, K, N]
/// sizes the packed kernel contracts over, and the output dims
/// (batch ++ lhs-free ++ rhs-free).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DotLayout {
    /// lhs axes that are neither batch nor contracting, in order.
    pub lhs_free: Vec<usize>,
    /// rhs axes that are neither batch nor contracting, in order.
    pub rhs_free: Vec<usize>,
    pub batch_dims: Vec<usize>,
    pub lhs_free_dims: Vec<usize>,
    pub rhs_free_dims: Vec<usize>,
    pub contract_dims: Vec<usize>,
    pub out_dims: Vec<usize>,
}

impl DotLayout {
    pub fn bsz(&self) -> usize {
        self.batch_dims.iter().product()
    }
    pub fn m(&self) -> usize {
        self.lhs_free_dims.iter().product()
    }
    pub fn k(&self) -> usize {
        self.contract_dims.iter().product()
    }
    pub fn n(&self) -> usize {
        self.rhs_free_dims.iter().product()
    }
}

/// Why a [`dot_layout`] request is invalid: `rule` is "attr" for bad
/// dimension numbers, "shape" for operand-dim disagreements — the split
/// the verifier's diagnostic rules use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DotLayoutError {
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for DotLayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

/// Validate dot_dimension_numbers against the operand shapes and derive
/// the contraction geometry. Single home for the formula `output =
/// batch ++ lhs-free ++ rhs-free` used by the evaluator, the plan
/// compiler, the verifier, and the builder.
pub fn dot_layout(
    lhs_dims: &[usize],
    rhs_dims: &[usize],
    d: &DotDims,
) -> Result<DotLayout, DotLayoutError> {
    let attr = |msg: String| DotLayoutError { rule: "attr", msg };
    let shape = |msg: String| DotLayoutError { rule: "shape", msg };
    if d.lhs_batch.len() != d.rhs_batch.len() || d.lhs_contract.len() != d.rhs_contract.len() {
        return Err(attr("dimension-number arity mismatch".to_string()));
    }
    let lhs_oob = d.lhs_batch.iter().chain(&d.lhs_contract).any(|&i| i >= lhs_dims.len());
    let rhs_oob = d.rhs_batch.iter().chain(&d.rhs_contract).any(|&i| i >= rhs_dims.len());
    if lhs_oob || rhs_oob {
        return Err(attr(format!(
            "dimension numbers out of range for operand ranks {}/{}",
            lhs_dims.len(),
            rhs_dims.len()
        )));
    }
    if d.lhs_batch.iter().any(|i| d.lhs_contract.contains(i))
        || d.rhs_batch.iter().any(|i| d.rhs_contract.contains(i))
    {
        return Err(attr("batch and contracting dims overlap".to_string()));
    }
    for (&a, &b) in d.lhs_contract.iter().zip(&d.rhs_contract) {
        if lhs_dims[a] != rhs_dims[b] {
            return Err(shape(format!(
                "contracting dims differ: {} vs {}",
                lhs_dims[a], rhs_dims[b]
            )));
        }
    }
    for (&a, &b) in d.lhs_batch.iter().zip(&d.rhs_batch) {
        if lhs_dims[a] != rhs_dims[b] {
            return Err(shape(format!("batch dims differ: {} vs {}", lhs_dims[a], rhs_dims[b])));
        }
    }
    let lhs_free: Vec<usize> = (0..lhs_dims.len())
        .filter(|i| !d.lhs_batch.contains(i) && !d.lhs_contract.contains(i))
        .collect();
    let rhs_free: Vec<usize> = (0..rhs_dims.len())
        .filter(|i| !d.rhs_batch.contains(i) && !d.rhs_contract.contains(i))
        .collect();
    let batch_dims: Vec<usize> = d.lhs_batch.iter().map(|&i| lhs_dims[i]).collect();
    let lhs_free_dims: Vec<usize> = lhs_free.iter().map(|&i| lhs_dims[i]).collect();
    let rhs_free_dims: Vec<usize> = rhs_free.iter().map(|&i| rhs_dims[i]).collect();
    let contract_dims: Vec<usize> = d.lhs_contract.iter().map(|&i| lhs_dims[i]).collect();
    let mut out_dims = batch_dims.clone();
    out_dims.extend(&lhs_free_dims);
    out_dims.extend(&rhs_free_dims);
    Ok(DotLayout {
        lhs_free,
        rhs_free,
        batch_dims,
        lhs_free_dims,
        rhs_free_dims,
        contract_dims,
        out_dims,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_are_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
        assert_eq!(strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn next_index_walks_row_major_order() {
        let dims = [2, 3];
        let st = strides(&dims);
        let mut idx = [0usize; 2];
        let mut seen = vec![linear(&idx, &st)];
        while next_index(&mut idx, &dims) {
            seen.push(linear(&idx, &st));
        }
        assert_eq!(seen, (0..6).collect::<Vec<_>>());
        // rank-0: a single element, no successor
        let mut empty: [usize; 0] = [];
        assert!(!next_index(&mut empty, &[]));
    }

    #[test]
    fn slice_output_dims_match_div_ceil_semantics() {
        // [0:5:2] over 6 -> 3 elements; [1:6:2] -> 3; [2:2] -> 0
        assert_eq!(
            slice_output_dims(&[6, 6, 6], &[(0, 5, 2), (1, 6, 2), (2, 2, 1)]),
            Ok(vec![3, 3, 0])
        );
        assert!(slice_output_dims(&[4], &[(3, 2, 1)]).is_err(), "start past limit");
        assert!(slice_output_dims(&[4], &[(0, 5, 1)]).is_err(), "limit past dim");
        assert!(slice_output_dims(&[4], &[(0, 4, 0)]).is_err(), "zero stride");
        assert!(slice_output_dims(&[4, 4], &[(0, 4, 1)]).is_err(), "rank mismatch");
    }

    #[test]
    fn reduce_output_dims_keep_unreduced_axes_in_order() {
        assert_eq!(reduce_output_dims(&[2, 3, 4], &[1]), vec![2, 4]);
        assert_eq!(reduce_output_dims(&[2, 3, 4], &[0, 2]), vec![3]);
        assert_eq!(reduce_output_dims(&[2, 3], &[0, 1]), Vec::<usize>::new());
        assert_eq!(reduce_kept_axes(3, &[1]), vec![0, 2]);
    }

    #[test]
    fn dot_layout_matmul_and_batched_forms() {
        // plain [m,k] x [k,n]
        let d = DotDims {
            lhs_batch: vec![],
            rhs_batch: vec![],
            lhs_contract: vec![1],
            rhs_contract: vec![0],
        };
        let l = dot_layout(&[2, 3], &[3, 5], &d).unwrap();
        assert_eq!(l.out_dims, vec![2, 5]);
        assert_eq!((l.bsz(), l.m(), l.k(), l.n()), (1, 2, 3, 5));
        assert_eq!(l.lhs_free, vec![0]);
        assert_eq!(l.rhs_free, vec![1]);
        // batched [b,m,k] x [b,k,n]
        let d = DotDims {
            lhs_batch: vec![0],
            rhs_batch: vec![0],
            lhs_contract: vec![2],
            rhs_contract: vec![1],
        };
        let l = dot_layout(&[4, 2, 3], &[4, 3, 5], &d).unwrap();
        assert_eq!(l.out_dims, vec![4, 2, 5]);
        assert_eq!((l.bsz(), l.m(), l.k(), l.n()), (4, 2, 3, 5));
    }

    #[test]
    fn dot_layout_rejects_bad_dimension_numbers() {
        let base = DotDims {
            lhs_batch: vec![],
            rhs_batch: vec![],
            lhs_contract: vec![1],
            rhs_contract: vec![0],
        };
        // contracting dims disagree
        let e = dot_layout(&[2, 3], &[4, 5], &base).unwrap_err();
        assert_eq!(e.rule, "shape");
        // out-of-range dimension number
        let mut oob = base.clone();
        oob.lhs_contract = vec![7];
        assert_eq!(dot_layout(&[2, 3], &[3, 5], &oob).unwrap_err().rule, "attr");
        // arity mismatch
        let mut arity = base.clone();
        arity.rhs_contract = vec![0, 1];
        assert_eq!(dot_layout(&[2, 3], &[3, 5], &arity).unwrap_err().rule, "attr");
        // batch/contract overlap
        let overlap = DotDims {
            lhs_batch: vec![1],
            rhs_batch: vec![0],
            lhs_contract: vec![1],
            rhs_contract: vec![0],
        };
        assert_eq!(dot_layout(&[2, 3], &[3, 5], &overlap).unwrap_err().rule, "attr");
    }
}
