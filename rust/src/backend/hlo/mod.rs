//! In-process HLO substrate: text parser, CPU evaluator, compiled
//! execution plans, static verifier, and a programmatic HLO-text
//! builder (used by the fixture generator and the interpreter property
//! tests).

// This layer is the substrate everything else evaluates on; a stray
// unwrap here turns a shape bug into a panic instead of a diagnostic.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod builder;
pub mod eval;
pub mod layout;
pub mod parser;
pub mod plan;
pub mod verify;
