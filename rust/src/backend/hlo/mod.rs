//! In-process HLO substrate: text parser, CPU evaluator, and a
//! programmatic HLO-text builder (used by the fixture generator and the
//! interpreter property tests).

pub mod builder;
pub mod eval;
pub mod parser;
