//! Programmatic HLO-*text* builder.
//!
//! Emits modules in the same dependency-ordered, one-instruction-per-line
//! form `aot.py` produces, restricted to the interpreter's op set. The
//! fixture generator uses it to lower the tiny target/drafter graphs;
//! the interpreter property tests use it to generate op-level programs
//! against naive references. Shapes are tracked per handle so a fixture
//! bug surfaces as a builder panic, not a silent wrong artifact.

use std::collections::BTreeSet;
use std::fmt::Write as _;

use super::layout;
use super::parser::DotDims;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ty {
    F32,
    S32,
    U32,
    U64,
    Pred,
}

impl Ty {
    fn text(self) -> &'static str {
        match self {
            Ty::F32 => "f32",
            Ty::S32 => "s32",
            Ty::U32 => "u32",
            Ty::U64 => "u64",
            Ty::Pred => "pred",
        }
    }
}

/// Handle to an emitted instruction (name + tracked shape).
#[derive(Debug, Clone)]
pub struct H {
    pub name: String,
    pub ty: Ty,
    pub dims: Vec<usize>,
}

impl H {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

fn shape_text(ty: Ty, dims: &[usize]) -> String {
    let dims_s: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
    format!("{}[{}]", ty.text(), dims_s.join(","))
}

fn list_text(xs: &[usize]) -> String {
    let s: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("{{{}}}", s.join(","))
}

pub struct HloBuilder {
    module: String,
    body: Vec<String>,
    /// (name, text) of reduce-body computations, emitted before ENTRY
    aux: Vec<(String, String)>,
    aux_names: BTreeSet<String>,
    next: usize,
    nparams: usize,
}

impl HloBuilder {
    pub fn new(module: &str) -> HloBuilder {
        HloBuilder {
            module: module.to_string(),
            body: Vec::new(),
            aux: Vec::new(),
            aux_names: BTreeSet::new(),
            next: 0,
            nparams: 0,
        }
    }

    fn fresh(&mut self) -> String {
        let n = self.next;
        self.next += 1;
        format!("v{n}")
    }

    fn push(&mut self, ty: Ty, dims: Vec<usize>, expr: String) -> H {
        let name = self.fresh();
        self.body.push(format!("  %{name} = {} {expr}", shape_text(ty, &dims)));
        H { name, ty, dims }
    }

    pub fn param(&mut self, ty: Ty, dims: Vec<usize>) -> H {
        let n = self.nparams;
        self.nparams += 1;
        self.push(ty, dims, format!("parameter({n})"))
    }

    pub fn const_f32(&mut self, v: f32) -> H {
        // `{v:?}` prints the shortest round-tripping decimal for f32
        self.push(Ty::F32, vec![], format!("constant({v:?})"))
    }

    pub fn const_s32(&mut self, v: i32) -> H {
        self.push(Ty::S32, vec![], format!("constant({v})"))
    }

    fn binary(&mut self, op: &str, a: &H, b: &H) -> H {
        assert_eq!(a.dims, b.dims, "{op}: operand shapes differ");
        assert_eq!(a.ty, b.ty, "{op}: operand dtypes differ");
        self.push(a.ty, a.dims.clone(), format!("{op}(%{}, %{})", a.name, b.name))
    }

    pub fn add(&mut self, a: &H, b: &H) -> H {
        self.binary("add", a, b)
    }

    pub fn sub(&mut self, a: &H, b: &H) -> H {
        self.binary("subtract", a, b)
    }

    pub fn mul(&mut self, a: &H, b: &H) -> H {
        self.binary("multiply", a, b)
    }

    pub fn div(&mut self, a: &H, b: &H) -> H {
        self.binary("divide", a, b)
    }

    pub fn max(&mut self, a: &H, b: &H) -> H {
        self.binary("maximum", a, b)
    }

    pub fn min(&mut self, a: &H, b: &H) -> H {
        self.binary("minimum", a, b)
    }

    pub fn exp(&mut self, a: &H) -> H {
        self.push(a.ty, a.dims.clone(), format!("exponential(%{})", a.name))
    }

    pub fn tanh(&mut self, a: &H) -> H {
        self.push(a.ty, a.dims.clone(), format!("tanh(%{})", a.name))
    }

    pub fn compare(&mut self, a: &H, b: &H, dir: &str) -> H {
        assert_eq!(a.dims, b.dims, "compare: operand shapes differ");
        self.push(
            Ty::Pred,
            a.dims.clone(),
            format!("compare(%{}, %{}), direction={dir}", a.name, b.name),
        )
    }

    pub fn select(&mut self, p: &H, t: &H, f: &H) -> H {
        assert_eq!(p.ty, Ty::Pred);
        assert_eq!(t.dims, f.dims);
        self.push(
            t.ty,
            t.dims.clone(),
            format!("select(%{}, %{}, %{})", p.name, t.name, f.name),
        )
    }

    pub fn convert(&mut self, a: &H, to: Ty) -> H {
        self.push(to, a.dims.clone(), format!("convert(%{})", a.name))
    }

    pub fn iota(&mut self, ty: Ty, dims: Vec<usize>, dim: usize) -> H {
        self.push(ty, dims, format!("iota(), iota_dimension={dim}"))
    }

    pub fn reshape(&mut self, a: &H, dims: Vec<usize>) -> H {
        assert_eq!(a.numel(), dims.iter().product::<usize>(), "reshape numel");
        self.push(a.ty, dims, format!("reshape(%{})", a.name))
    }

    /// `mapping[i]` = output dim that input dim i maps to.
    pub fn broadcast(&mut self, a: &H, dims: Vec<usize>, mapping: &[usize]) -> H {
        assert_eq!(mapping.len(), a.dims.len(), "broadcast mapping rank");
        self.push(
            a.ty,
            dims,
            format!("broadcast(%{}), dimensions={}", a.name, list_text(mapping)),
        )
    }

    pub fn transpose(&mut self, a: &H, perm: &[usize]) -> H {
        let dims: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
        self.push(
            a.ty,
            dims,
            format!("transpose(%{}), dimensions={}", a.name, list_text(perm)),
        )
    }

    /// (start, limit) per dim, stride 1.
    pub fn slice(&mut self, a: &H, ranges: &[(usize, usize)]) -> H {
        assert_eq!(ranges.len(), a.dims.len(), "slice rank");
        let dims: Vec<usize> = ranges.iter().map(|&(s, l)| l - s).collect();
        let parts: Vec<String> = ranges.iter().map(|&(s, l)| format!("[{s}:{l}]")).collect();
        self.push(
            a.ty,
            dims,
            format!("slice(%{}), slice={{{}}}", a.name, parts.join(", ")),
        )
    }

    pub fn concat(&mut self, parts: &[&H], dim: usize) -> H {
        assert!(!parts.is_empty());
        let mut dims = parts[0].dims.clone();
        dims[dim] = parts.iter().map(|p| p.dims[dim]).sum();
        let names: Vec<String> = parts.iter().map(|p| format!("%{}", p.name)).collect();
        self.push(
            parts[0].ty,
            dims,
            format!("concatenate({}), dimensions={{{dim}}}", names.join(", ")),
        )
    }

    pub fn dot_general(
        &mut self,
        a: &H,
        b: &H,
        lhs_batch: &[usize],
        rhs_batch: &[usize],
        lhs_contract: &[usize],
        rhs_contract: &[usize],
    ) -> H {
        let dn = DotDims {
            lhs_batch: lhs_batch.to_vec(),
            rhs_batch: rhs_batch.to_vec(),
            lhs_contract: lhs_contract.to_vec(),
            rhs_contract: rhs_contract.to_vec(),
        };
        let dims = match layout::dot_layout(&a.dims, &b.dims, &dn) {
            Ok(lay) => lay.out_dims,
            Err(e) => panic!("dot_general: {}", e.msg),
        };
        let mut attrs = String::new();
        if !lhs_batch.is_empty() {
            let _ = write!(
                attrs,
                "lhs_batch_dims={}, rhs_batch_dims={}, ",
                list_text(lhs_batch),
                list_text(rhs_batch)
            );
        }
        let _ = write!(
            attrs,
            "lhs_contracting_dims={}, rhs_contracting_dims={}",
            list_text(lhs_contract),
            list_text(rhs_contract)
        );
        self.push(Ty::F32, dims, format!("dot(%{}, %{}), {attrs}", a.name, b.name))
    }

    /// [m,k] x [k,n] -> [m,n]
    pub fn matmul(&mut self, a: &H, b: &H) -> H {
        assert_eq!(a.dims.len(), 2);
        assert_eq!(b.dims.len(), 2);
        assert_eq!(a.dims[1], b.dims[0], "matmul inner dim");
        self.dot_general(a, b, &[], &[], &[1], &[0])
    }

    /// [m,k] x [n,k] -> [m,n] (contract both trailing dims)
    pub fn matmul_nt(&mut self, a: &H, b: &H) -> H {
        assert_eq!(a.dims[1], b.dims[1], "matmul_nt inner dim");
        self.dot_general(a, b, &[], &[], &[1], &[1])
    }

    /// Row gather: `table[n, d...]` indexed by `idx` (s32, any rank)
    /// -> `[idx.dims..., d...]`.
    pub fn gather_rows(&mut self, table: &H, idx: &H) -> H {
        assert_eq!(idx.ty, Ty::S32);
        let row_dims = &table.dims[1..];
        let mut dims = idx.dims.clone();
        dims.extend_from_slice(row_dims);
        let offset_dims: Vec<usize> =
            (idx.dims.len()..idx.dims.len() + row_dims.len()).collect();
        let mut slice_sizes = vec![1usize];
        slice_sizes.extend_from_slice(row_dims);
        self.push(
            table.ty,
            dims,
            format!(
                "gather(%{}, %{}), offset_dims={}, collapsed_slice_dims={{0}}, \
                 start_index_map={{0}}, index_vector_dim={}, slice_sizes={}",
                table.name,
                idx.name,
                list_text(&offset_dims),
                idx.dims.len(),
                list_text(&slice_sizes),
            ),
        )
    }

    fn reducer(&mut self, op: &str, ty: Ty) -> String {
        let name = format!("red_{op}_{}", ty.text());
        if self.aux_names.insert(name.clone()) {
            let t = shape_text(ty, &[]);
            let text = format!(
                "%{name} {{\n  %a = {t} parameter(0)\n  %b = {t} parameter(1)\n  ROOT %r = {t} {op}(%a, %b)\n}}\n"
            );
            self.aux.push((name.clone(), text));
        }
        name
    }

    fn reduce(&mut self, a: &H, init: &H, dims: &[usize], op: &str) -> H {
        let body = self.reducer(op, a.ty);
        let out_dims = layout::reduce_output_dims(&a.dims, dims);
        self.push(
            a.ty,
            out_dims,
            format!(
                "reduce(%{}, %{}), dimensions={}, to_apply=%{body}",
                a.name,
                init.name,
                list_text(dims)
            ),
        )
    }

    pub fn reduce_add(&mut self, a: &H, dims: &[usize]) -> H {
        let init = self.const_f32(0.0);
        self.reduce(a, &init, dims, "add")
    }

    pub fn reduce_max(&mut self, a: &H, dims: &[usize]) -> H {
        // finite lower bound: avoids printing/parsing infinities
        let init = self.const_f32(-3.0e38);
        self.reduce(a, &init, dims, "maximum")
    }

    /// dynamic-update-slice with one scalar s32 start per dimension.
    pub fn dus(&mut self, operand: &H, update: &H, starts: &[H]) -> H {
        assert_eq!(starts.len(), operand.dims.len(), "dus starts rank");
        assert_eq!(update.dims.len(), operand.dims.len(), "dus update rank");
        let idx: Vec<String> = starts.iter().map(|s| format!("%{}", s.name)).collect();
        self.push(
            operand.ty,
            operand.dims.clone(),
            format!(
                "dynamic-update-slice(%{}, %{}, {})",
                operand.name,
                update.name,
                idx.join(", ")
            ),
        )
    }

    /// dynamic-slice with one scalar s32 start per dimension; the
    /// output shape is `sizes`.
    pub fn dynamic_slice(&mut self, a: &H, starts: &[H], sizes: &[usize]) -> H {
        assert_eq!(starts.len(), a.dims.len(), "dynamic-slice starts rank");
        assert_eq!(sizes.len(), a.dims.len(), "dynamic-slice sizes rank");
        for (d, (&sz, &od)) in sizes.iter().zip(&a.dims).enumerate() {
            assert!(sz <= od, "dynamic-slice size {sz} exceeds dim {d} ({od})");
        }
        let idx: Vec<String> = starts.iter().map(|s| format!("%{}", s.name)).collect();
        self.push(
            a.ty,
            sizes.to_vec(),
            format!(
                "dynamic-slice(%{}, {}), dynamic_slice_sizes={}",
                a.name,
                idx.join(", "),
                list_text(sizes)
            ),
        )
    }

    /// Tuple projection (for tuple-valued ops like rng-bit-generator).
    pub fn get_tuple_element(&mut self, tuple: &H, index: usize, ty: Ty, dims: Vec<usize>) -> H {
        self.push(ty, dims, format!("get-tuple-element(%{}), index={index}", tuple.name))
    }

    /// Deterministic Threefry bit generator over a `u64[2]`
    /// `[key, counter]` state: emits the tuple-shaped
    /// `rng-bit-generator` plus its two projections and returns
    /// `(new_state, bits)` — `bits` is `u32[dims]`.
    pub fn rng_threefry(&mut self, state: &H, dims: Vec<usize>) -> (H, H) {
        assert_eq!(state.ty, Ty::U64, "threefry state is u64[2]");
        assert_eq!(state.dims, vec![2], "threefry state is u64[2]");
        let name = self.fresh();
        let bits_shape = shape_text(Ty::U32, &dims);
        self.body.push(format!(
            "  %{name} = (u64[2], {bits_shape}) rng-bit-generator(%{}), algorithm=rng_threefry",
            state.name
        ));
        let tuple = H { name, ty: Ty::U64, dims: vec![2] };
        let new_state = self.get_tuple_element(&tuple, 0, Ty::U64, vec![2]);
        let bits = self.get_tuple_element(&tuple, 1, Ty::U32, dims);
        (new_state, bits)
    }

    /// Broadcast a scalar to `dims`.
    pub fn splat(&mut self, scalar: &H, dims: Vec<usize>) -> H {
        assert!(scalar.dims.is_empty(), "splat wants a scalar");
        self.broadcast(scalar, dims, &[])
    }

    /// Finish the module with a ROOT tuple over `outs`.
    pub fn finish(self, outs: &[&H]) -> String {
        let mut text = format!("HloModule {}\n\n", self.module);
        for (_, aux) in &self.aux {
            text.push_str(aux);
            text.push('\n');
        }
        text.push_str("ENTRY %main {\n");
        for line in &self.body {
            text.push_str(line);
            text.push('\n');
        }
        let shapes: Vec<String> =
            outs.iter().map(|h| shape_text(h.ty, &h.dims)).collect();
        let names: Vec<String> = outs.iter().map(|h| format!("%{}", h.name)).collect();
        let _ = writeln!(
            text,
            "  ROOT %out = ({}) tuple({})",
            shapes.join(", "),
            names.join(", ")
        );
        text.push_str("}\n");
        text
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::hlo::eval::{evaluate, Value};
    use crate::backend::hlo::parser::parse_module;
    use std::sync::Arc;

    #[test]
    fn built_module_parses_and_runs() {
        let mut b = HloBuilder::new("toy");
        let x = b.param(Ty::F32, vec![2, 3]);
        let w = b.param(Ty::F32, vec![3, 2]);
        let y = b.matmul(&x, &w);
        let t = b.tanh(&y);
        let s = b.reduce_add(&t, &[1]);
        let text = b.finish(&[&t, &s]);
        let m = parse_module(&text).unwrap();
        let xs = Arc::new(Value::f32(vec![2, 3], vec![0.1; 6]));
        let ws = Arc::new(Value::f32(vec![3, 2], vec![0.5; 6]));
        let out = evaluate(&m, &[xs, ws]).unwrap();
        assert_eq!(out[0].dims, vec![2, 2]);
        assert_eq!(out[1].dims, vec![2]);
        let expect = (0.15f32).tanh();
        for v in out[0].f32s().unwrap() {
            assert!((v - expect).abs() < 1e-6);
        }
        for v in out[1].f32s().unwrap() {
            assert!((v - 2.0 * expect).abs() < 1e-6);
        }
    }

    #[test]
    fn dynamic_slice_roundtrips_through_text() {
        let mut b = HloBuilder::new("ds");
        let x = b.param(Ty::F32, vec![3, 2]);
        let i = b.param(Ty::S32, vec![]);
        let j = b.const_s32(0);
        let d = b.dynamic_slice(&x, &[i, j], &[1, 2]);
        let text = b.finish(&[&d]);
        let m = parse_module(&text).unwrap();
        let xs = Arc::new(Value::f32(vec![3, 2], vec![0., 1., 10., 11., 20., 21.]));
        let is = Arc::new(Value::i32(vec![], vec![2]));
        let out = evaluate(&m, &[xs, is]).unwrap();
        assert_eq!(out[0].dims, vec![1, 2]);
        assert_eq!(out[0].f32s().unwrap(), &[20., 21.]);
    }

    #[test]
    fn rng_threefry_roundtrips_through_text() {
        let mut b = HloBuilder::new("rng");
        let st = b.param(Ty::U64, vec![2]);
        let (ns, bits) = b.rng_threefry(&st, vec![5]);
        let f = b.convert(&bits, Ty::F32);
        let text = b.finish(&[&ns, &bits, &f]);
        let m = parse_module(&text).unwrap();
        let state = Arc::new(Value::u64(vec![2], vec![42, 0]));
        let out = evaluate(&m, &[Arc::clone(&state)]).unwrap();
        assert_eq!(out[0].dims, vec![2]);
        // 5 u32s = 3 blocks -> counter advances by 3
        assert_eq!(out[0].u64s().unwrap(), &[42, 3]);
        assert_eq!(out[1].dims, vec![5]);
        let bits1 = out[1].u32s().unwrap().to_vec();
        // deterministic: same state, same stream
        let out2 = evaluate(&m, &[state]).unwrap();
        assert_eq!(out2[1].u32s().unwrap(), bits1.as_slice());
        // converts to f32 value-wise
        assert_eq!(out[2].f32s().unwrap()[0], bits1[0] as f32);
    }

    #[test]
    fn f32_constants_roundtrip_exactly() {
        let mut b = HloBuilder::new("c");
        let c = b.const_f32(0.1234567);
        let d = b.splat(&c, vec![2]);
        let text = b.finish(&[&d]);
        let m = parse_module(&text).unwrap();
        let out = evaluate(&m, &[]).unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[0.1234567f32; 2]);
    }
}
