//! Compiled execution plans for the HLO interpreter.
//!
//! [`ExecPlan::compile`] lowers a parsed (and, on the backend path,
//! verified) module once into a flat step schedule: operand names are
//! resolved to slot indices, output shapes/strides and dot/reduce/
//! broadcast geometry are precomputed, elementwise chains are fused
//! into single chunked loops, and a liveness pass records each value's
//! last use so buffers recycle through a per-call arena (with in-place
//! elementwise updates when the input uniquely owns its buffer).
//! [`ExecPlan::execute`] then runs the schedule with no per-op name
//! lookups and almost no per-op allocation.
//!
//! Numerics contract: every optimized path applies the same scalar
//! operations in the same order as the naive [`super::eval::evaluate`]
//! walk, so results are *bit-identical* to the reference — including at
//! `FE_INTERP_THREADS > 1`, where threads only ever split disjoint
//! output rows and each row keeps its sequential accumulation order.
//! `tests/interp_props.rs` property-tests this against random programs.

use std::borrow::Cow;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::eval::{self, Buf, Value};
use super::layout::{self, strides};
use super::parser::{
    BinOp, CmpDir, Computation, DotDims, GatherDims, HloModule, Op, PrimType, UnOp,
};
use crate::obs;

/// Elementwise chunk size: registers stay L1-resident.
const CHUNK: usize = 1024;
/// Max recycled buffers kept per dtype in the arena.
const ARENA_KEEP: usize = 8;
/// Minimum `bsz*m*k*n` before a dot fans out across threads.
const PAR_MIN_DOT: usize = 1 << 15;
/// Minimum input numel before a reduce fans out across threads.
const PAR_MIN_REDUCE: usize = 1 << 15;
/// Minimum output numel before a broadcast fans out across threads.
const PAR_MIN_BCAST: usize = 1 << 16;

/// Knobs for plan compilation and execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalOptions {
    /// Worker count for dot/reduce/broadcast outer rows. 1 (the
    /// default) runs everything on the calling thread; results are
    /// byte-identical at any setting.
    pub threads: usize,
    /// Fuse elementwise chains into single chunked loops.
    pub fuse: bool,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { threads: 1, fuse: true }
    }
}

impl EvalOptions {
    /// Read `FE_INTERP_THREADS` (clamped to 1..=64) and
    /// `FE_INTERP_FUSE` (any value but "0" keeps fusion on).
    pub fn from_env() -> EvalOptions {
        let threads = std::env::var("FE_INTERP_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .map(|t| t.clamp(1, 64))
            .unwrap_or(1);
        let fuse = std::env::var("FE_INTERP_FUSE").map(|s| s != "0").unwrap_or(true);
        EvalOptions { threads, fuse }
    }
}

/// Wall-clock attribution per step kind: (invocations, total ns).
#[derive(Debug, Default, Clone, Copy)]
pub struct OpTime {
    pub count: u64,
    pub total_ns: u64,
}

pub type OpTimes = BTreeMap<&'static str, OpTime>;

/// One fused-loop operation; operands index earlier registers.
#[derive(Debug, Clone)]
enum FOp {
    /// Copy chunk of load `i` (preds become a 0.0/1.0 mask).
    Load(usize),
    /// Splat an inlined f32 constant.
    Imm(f32),
    Un(UnOp, usize),
    Bin(BinOp, usize, usize),
    /// Compare producing a 0.0/1.0 mask.
    Cmp(CmpDir, usize, usize),
    /// `sel(cond, t, f)`: cond is a mask, tested `!= 0.0`.
    Sel(usize, usize, usize),
    /// pred->f32 convert: identity on the mask representation.
    Cvt(usize),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LoadTy {
    F32,
    Pred,
}

/// A fused elementwise chain: a straight-line register program run
/// chunk-by-chunk over the operands.
#[derive(Debug, Clone)]
struct Fused {
    prog: Vec<FOp>,
    /// (slot, dtype) per distinct external input.
    loads: Vec<(usize, LoadTy)>,
    out_pred: bool,
}

/// Precomputed gather of one dot operand into a dense blocked layout.
#[derive(Debug, Clone)]
struct PackPlan {
    /// The operand is already in blocked layout — skip the pack.
    identity: bool,
    /// Input stride per packed-output dim.
    strides: Vec<usize>,
    out_dims: Vec<usize>,
}

impl PackPlan {
    fn new(dims: &[usize], groups: [&[usize]; 3]) -> PackPlan {
        let in_st = strides(dims);
        let perm: Vec<usize> = groups.iter().flat_map(|g| g.iter().copied()).collect();
        let identity = perm.iter().enumerate().all(|(i, &p)| i == p);
        PackPlan {
            identity,
            strides: perm.iter().map(|&p| in_st[p]).collect(),
            out_dims: perm.iter().map(|&p| dims[p]).collect(),
        }
    }

    /// Gather `data` into the packed layout (rows of the last packed
    /// axis copied contiguously when unit-stride).
    fn pack(&self, data: &[f32]) -> Vec<f32> {
        let n: usize = self.out_dims.iter().product();
        let mut out = Vec::with_capacity(n);
        if n == 0 {
            return out;
        }
        let rank = self.out_dims.len();
        if rank == 0 {
            out.push(data[0]);
            return out;
        }
        let last_n = self.out_dims[rank - 1];
        let last_st = self.strides[rank - 1];
        let outer = &self.out_dims[..rank - 1];
        let mut idx = vec![0usize; rank - 1];
        loop {
            let base: usize = idx.iter().zip(&self.strides).map(|(i, s)| i * s).sum();
            if last_st == 1 {
                out.extend_from_slice(&data[base..base + last_n]);
            } else {
                for j in 0..last_n {
                    out.push(data[base + j * last_st]);
                }
            }
            if outer.is_empty() || !layout::next_index(&mut idx, outer) {
                break;
            }
        }
        out
    }
}

/// Precomputed dot geometry: pack plans plus the [B, M, K, N] sizes the
/// blocked i-k-j kernel contracts over.
#[derive(Debug, Clone)]
struct DotPlan {
    lhs_dims: Vec<usize>,
    rhs_dims: Vec<usize>,
    bsz: usize,
    m: usize,
    k: usize,
    n: usize,
    lhs: PackPlan,
    rhs: PackPlan,
}

#[derive(Debug, Clone)]
struct ReducePlan {
    red_dims: Vec<usize>,
    op: BinOp,
    /// Single f32 add/max/min reduction over the last axis: rows are
    /// contiguous, folded with the interleaved fast kernel.
    last_axis: bool,
}

#[derive(Debug, Clone)]
struct BroadcastPlan {
    mapping: Vec<usize>,
    /// Input stride per output dim (0 where the dim is new).
    eff: Vec<usize>,
    /// Row-major strides of the output dims *before* the last one,
    /// for decoding a flat row number back to a source offset.
    outer_st: Vec<usize>,
}

#[derive(Debug, Clone)]
enum StepOp {
    Param(usize),
    /// Constants and iota are materialized once at compile time.
    Const(Arc<Value>),
    Fused(Fused),
    Unary(UnOp),
    Binary(BinOp),
    Compare(CmpDir),
    Select,
    Convert,
    Dot(DotPlan),
    Reduce(ReducePlan),
    Broadcast(BroadcastPlan),
    Reshape,
    Transpose(Vec<usize>),
    Slice(Vec<(usize, usize, usize)>),
    Concat(usize),
    Gather(GatherDims),
    Dus,
    DynamicSlice(Vec<usize>),
    Rng,
    Tuple,
    Gte(usize),
}

#[derive(Debug, Clone)]
struct PlanStep {
    op: StepOp,
    /// Operand slot indices (pre-resolved; no name lookups at run
    /// time). For [`StepOp::Fused`] these are the load slots.
    operands: Vec<usize>,
    out: usize,
    dims: Vec<usize>,
    ty: PrimType,
    /// Step-kind label for `backend.op` spans and time attribution.
    kind: &'static str,
    /// Slots whose last use is this step: cleared (and their buffers
    /// recycled into the arena) right after the step runs.
    frees: Vec<usize>,
    /// Index of the source instruction in the entry computation.
    instr: usize,
}

#[derive(Debug, Clone)]
enum Root {
    Slot(usize),
    /// Root is a `tuple(...)` instruction: return these slots as parts
    /// without materializing the tuple.
    Parts(Vec<usize>),
}

#[derive(Debug)]
enum SlotVal {
    Empty,
    One(Arc<Value>),
    Tuple(Vec<Arc<Value>>),
}

/// Recycled output buffers, keyed by dtype. Per-execute-call: freed
/// buffers from early steps back later steps' outputs.
#[derive(Default)]
struct Arena {
    f32s: Vec<Vec<f32>>,
}

impl Arena {
    fn take_f32(&mut self, n: usize, fill: f32) -> Vec<f32> {
        match self.f32s.pop() {
            Some(mut v) => {
                v.clear();
                v.resize(n, fill);
                v
            }
            None => vec![fill; n],
        }
    }

    fn give(&mut self, v: Value) {
        if let Buf::F32(b) = v.buf {
            if self.f32s.len() < ARENA_KEEP && b.capacity() > 0 {
                self.f32s.push(b);
            }
        }
    }

    fn give_f32(&mut self, b: Vec<f32>) {
        if self.f32s.len() < ARENA_KEEP && b.capacity() > 0 {
            self.f32s.push(b);
        }
    }
}

/// A module lowered to a flat, allocation-lean step schedule.
#[derive(Debug)]
pub struct ExecPlan {
    module: Arc<HloModule>,
    steps: Vec<PlanStep>,
    n_params: usize,
    n_slots: usize,
    root: Root,
    opts: EvalOptions,
}

impl ExecPlan {
    pub fn module(&self) -> &Arc<HloModule> {
        &self.module
    }

    pub fn opts(&self) -> EvalOptions {
        self.opts
    }

    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Number of schedule steps with the given kind label (tests use
    /// this to assert fusion/constant-folding actually happened).
    pub fn count_kind(&self, kind: &str) -> usize {
        self.steps.iter().filter(|s| s.kind == kind).count()
    }

    pub fn execute(&self, args: &[Arc<Value>]) -> Result<Vec<Value>> {
        self.run(args, None)
    }

    /// Like [`execute`](Self::execute) but attributes wall-clock to
    /// each step kind (microbench per-op reporting).
    pub fn execute_timed(&self, args: &[Arc<Value>], times: &mut OpTimes) -> Result<Vec<Value>> {
        self.run(args, Some(times))
    }
}

// ---------------------------------------------------------------------
// Compilation
// ---------------------------------------------------------------------

struct Compiler<'m> {
    entry: &'m Computation,
    module: &'m HloModule,
    /// Operand slot ids per instruction.
    ops: Vec<Vec<usize>>,
    uses: Vec<usize>,
    /// Sole consumer instr index, when uses == 1.
    sole: Vec<Option<usize>>,
    root_slots: Vec<bool>,
}

impl<'m> Compiler<'m> {
    fn new(module: &'m HloModule) -> Result<Compiler<'m>> {
        let entry = module.entry_computation();
        let n = entry.instrs.len();
        let by_name: HashMap<&str, usize> =
            entry.instrs.iter().enumerate().map(|(i, ins)| (ins.name.as_str(), i)).collect();
        let mut ops = Vec::with_capacity(n);
        let mut uses = vec![0usize; n];
        let mut sole: Vec<Option<usize>> = vec![None; n];
        for (i, ins) in entry.instrs.iter().enumerate() {
            let mut o = Vec::with_capacity(ins.operands.len());
            for name in &ins.operands {
                let &j = by_name.get(name.as_str()).with_context(|| {
                    format!("instruction {:?}: operand {name:?} undefined", ins.name)
                })?;
                uses[j] += 1;
                sole[j] = if uses[j] == 1 { Some(i) } else { None };
                o.push(j);
            }
            ops.push(o);
        }
        let mut root_slots = vec![false; n];
        if matches!(entry.instrs[entry.root].op, Op::Tuple) {
            for &o in &ops[entry.root] {
                root_slots[o] = true;
            }
        } else {
            root_slots[entry.root] = true;
        }
        Ok(Compiler { entry, module, ops, uses, sole, root_slots })
    }

    fn dims(&self, i: usize) -> &[usize] {
        &self.entry.instrs[i].shape.dims
    }

    fn ty(&self, i: usize) -> PrimType {
        self.entry.instrs[i].shape.ty
    }

    /// Can instruction `i` participate in a fused elementwise loop?
    fn fusable(&self, i: usize) -> bool {
        let ins = &self.entry.instrs[i];
        let same_shape = |j: usize| self.dims(j) == ins.shape.dims;
        match &ins.op {
            Op::ConstF32(_) => ins.shape.ty == PrimType::F32,
            Op::Unary(UnOp::Exp | UnOp::Tanh | UnOp::Neg) => {
                ins.shape.ty == PrimType::F32 && self.ops[i].iter().all(|&j| same_shape(j))
            }
            Op::Binary(b) => {
                let tys_ok = match b {
                    BinOp::And | BinOp::Or => {
                        ins.shape.ty == PrimType::Pred
                            && self.ops[i].iter().all(|&j| self.ty(j) == PrimType::Pred)
                    }
                    _ => {
                        ins.shape.ty == PrimType::F32
                            && self.ops[i].iter().all(|&j| self.ty(j) == PrimType::F32)
                    }
                };
                tys_ok && self.ops[i].iter().all(|&j| same_shape(j))
            }
            Op::Compare(_) => {
                self.ops[i].iter().all(|&j| self.ty(j) == PrimType::F32 && same_shape(j))
            }
            Op::Select => {
                self.ops[i].len() == 3
                    && self.ty(self.ops[i][0]) == PrimType::Pred
                    && ins.shape.ty == PrimType::F32
                    && self.ops[i][1..].iter().all(|&j| self.ty(j) == PrimType::F32)
                    && self.ops[i].iter().all(|&j| same_shape(j))
            }
            Op::Convert => {
                ins.shape.ty == PrimType::F32
                    && self.ops[i].len() == 1
                    && self.ty(self.ops[i][0]) == PrimType::Pred
                    && same_shape(self.ops[i][0])
            }
            _ => false,
        }
    }

    /// Will `i` disappear into its sole consumer's fused loop?
    fn will_inline(&self, i: usize) -> bool {
        if self.root_slots[i] || !self.fusable(i) {
            return false;
        }
        if matches!(self.entry.instrs[i].op, Op::ConstF32(_)) {
            // splats inline as immediates into every fusable consumer,
            // but only vanish if *all* consumers fused them — let DCE
            // decide; a const is never a fusion root either way.
            return false;
        }
        match self.sole[i] {
            Some(c) => self.fusable(c) && self.dims(c) == self.dims(i),
            None => false,
        }
    }

    /// Build the fused program rooted at `r`. Returns None when the
    /// chain has fewer than two compute ops (not worth a loop).
    fn build_fused(&self, r: usize) -> Option<Fused> {
        struct B<'c, 'm> {
            c: &'c Compiler<'m>,
            root_dims: &'c [usize],
            prog: Vec<FOp>,
            loads: Vec<(usize, LoadTy)>,
            load_map: HashMap<usize, usize>,
        }
        impl B<'_, '_> {
            fn can_inline(&self, i: usize) -> bool {
                if self.c.root_slots[i] || self.c.dims(i) != self.root_dims {
                    return false;
                }
                if matches!(self.c.entry.instrs[i].op, Op::ConstF32(_)) {
                    return self.c.fusable(i);
                }
                self.c.fusable(i) && self.c.uses[i] == 1
            }

            fn emit(&mut self, i: usize) -> usize {
                if let Some(&reg) = self.load_map.get(&i) {
                    return reg;
                }
                let inlined = self.can_inline(i);
                let fop = if inlined {
                    match &self.c.entry.instrs[i].op {
                        Op::ConstF32(v) => FOp::Imm(*v),
                        Op::Unary(u) => {
                            let a = self.emit(self.c.ops[i][0]);
                            FOp::Un(*u, a)
                        }
                        Op::Binary(b) => {
                            let a = self.emit(self.c.ops[i][0]);
                            let c = self.emit(self.c.ops[i][1]);
                            FOp::Bin(*b, a, c)
                        }
                        Op::Compare(d) => {
                            let a = self.emit(self.c.ops[i][0]);
                            let c = self.emit(self.c.ops[i][1]);
                            FOp::Cmp(*d, a, c)
                        }
                        Op::Select => {
                            let p = self.emit(self.c.ops[i][0]);
                            let t = self.emit(self.c.ops[i][1]);
                            let f = self.emit(self.c.ops[i][2]);
                            FOp::Sel(p, t, f)
                        }
                        Op::Convert => {
                            let a = self.emit(self.c.ops[i][0]);
                            FOp::Cvt(a)
                        }
                        // can_inline admits only the forms above
                        _ => {
                            let lt = if self.c.ty(i) == PrimType::Pred {
                                LoadTy::Pred
                            } else {
                                LoadTy::F32
                            };
                            self.loads.push((i, lt));
                            FOp::Load(self.loads.len() - 1)
                        }
                    }
                } else {
                    let lt =
                        if self.c.ty(i) == PrimType::Pred { LoadTy::Pred } else { LoadTy::F32 };
                    self.loads.push((i, lt));
                    FOp::Load(self.loads.len() - 1)
                };
                self.prog.push(fop);
                let reg = self.prog.len() - 1;
                // memoize multi-use nodes (loads; inlined consts are
                // uses==1 or splats, sharing regs either way is fine)
                self.load_map.insert(i, reg);
                reg
            }
        }
        let root_dims = self.dims(r).to_vec();
        let mut b = B {
            c: self,
            root_dims: &root_dims,
            prog: Vec::new(),
            loads: Vec::new(),
            load_map: HashMap::new(),
        };
        // emit the root's own op unconditionally (it is the fusion root)
        let root_fop = match &self.entry.instrs[r].op {
            Op::Unary(u) => {
                let a = b.emit(self.ops[r][0]);
                FOp::Un(*u, a)
            }
            Op::Binary(op) => {
                let a = b.emit(self.ops[r][0]);
                let c = b.emit(self.ops[r][1]);
                FOp::Bin(*op, a, c)
            }
            Op::Compare(d) => {
                let a = b.emit(self.ops[r][0]);
                let c = b.emit(self.ops[r][1]);
                FOp::Cmp(*d, a, c)
            }
            Op::Select => {
                let p = b.emit(self.ops[r][0]);
                let t = b.emit(self.ops[r][1]);
                let f = b.emit(self.ops[r][2]);
                FOp::Sel(p, t, f)
            }
            Op::Convert => {
                let a = b.emit(self.ops[r][0]);
                FOp::Cvt(a)
            }
            _ => return None,
        };
        b.prog.push(root_fop);
        let compute = b
            .prog
            .iter()
            .filter(|f| !matches!(f, FOp::Load(_) | FOp::Imm(_)))
            .count();
        if compute < 2 {
            return None;
        }
        Some(Fused { prog: b.prog, loads: b.loads, out_pred: self.ty(r) == PrimType::Pred })
    }
}

impl ExecPlan {
    /// Lower the module's entry computation into a flat schedule.
    ///
    /// The module is assumed shape-consistent (the interpreter backend
    /// verifies before planning); remaining dynamic properties are
    /// checked per step at run time by the shared kernels.
    pub fn compile(module: &Arc<HloModule>, opts: EvalOptions) -> Result<ExecPlan> {
        let c = Compiler::new(module)?;
        let entry = c.entry;
        let n = entry.instrs.len();

        // 1. lower every instruction to a (pre-fusion) step
        let mut steps: Vec<Option<PlanStep>> = Vec::with_capacity(n);
        for (i, ins) in entry.instrs.iter().enumerate() {
            let step = lower_instr(&c, i)
                .with_context(|| format!("planning instruction {:?}", ins.name))?;
            steps.push(Some(step));
        }

        // 2. fuse elementwise chains
        if opts.fuse {
            for i in 0..n {
                let is_chain_root = matches!(
                    entry.instrs[i].op,
                    Op::Unary(_) | Op::Binary(_) | Op::Compare(_) | Op::Select | Op::Convert
                ) && c.fusable(i)
                    && !c.will_inline(i);
                if !is_chain_root {
                    continue;
                }
                if let Some(fused) = c.build_fused(i) {
                    let operands: Vec<usize> = fused.loads.iter().map(|&(s, _)| s).collect();
                    if let Some(s) = steps[i].as_mut() {
                        s.op = StepOp::Fused(fused);
                        s.operands = operands;
                        s.kind = "fused";
                    }
                }
            }
        }

        // 3. dead-step elimination: keep params and everything the
        // root (transitively) references
        let root = if matches!(entry.instrs[entry.root].op, Op::Tuple) {
            Root::Parts(c.ops[entry.root].clone())
        } else {
            Root::Slot(entry.root)
        };
        let mut live = vec![false; n];
        let mut stack: Vec<usize> = match &root {
            Root::Slot(s) => vec![*s],
            Root::Parts(ps) => ps.clone(),
        };
        for &p in &entry.params {
            stack.push(p);
        }
        while let Some(i) = stack.pop() {
            if std::mem::replace(&mut live[i], true) {
                continue;
            }
            if let Some(s) = &steps[i] {
                stack.extend(s.operands.iter().copied());
            }
        }
        let mut final_steps: Vec<PlanStep> =
            steps.into_iter().flatten().filter(|s| live[s.out]).collect();

        // 4. liveness: frees = slots whose last use is this step
        let mut last_use: Vec<Option<usize>> = vec![None; n];
        for (si, s) in final_steps.iter().enumerate() {
            for &o in &s.operands {
                last_use[o] = Some(si);
            }
        }
        for (si, s) in final_steps.iter_mut().enumerate() {
            let mut frees: Vec<usize> = s
                .operands
                .iter()
                .copied()
                .filter(|&o| last_use[o] == Some(si) && !c.root_slots[o])
                .collect();
            frees.sort_unstable();
            frees.dedup();
            s.frees = frees;
        }

        Ok(ExecPlan {
            module: Arc::clone(module),
            steps: final_steps,
            n_params: entry.params.len(),
            n_slots: n,
            root,
            opts,
        })
    }
}

/// Lower one instruction to its pre-fusion step.
fn lower_instr(c: &Compiler<'_>, i: usize) -> Result<PlanStep> {
    let ins = &c.entry.instrs[i];
    let dims = ins.shape.dims.clone();
    let numel: usize = dims.iter().product();
    let (op, kind): (StepOp, &'static str) = match &ins.op {
        Op::Parameter(p) => (StepOp::Param(*p), "param"),
        Op::ConstF32(v) => {
            (StepOp::Const(Arc::new(Value::f32(dims.clone(), vec![*v; numel]))), "const")
        }
        Op::ConstS32(v) => {
            (StepOp::Const(Arc::new(Value::i32(dims.clone(), vec![*v; numel]))), "const")
        }
        Op::ConstU32(v) => (
            StepOp::Const(Arc::new(Value { dims: dims.clone(), buf: Buf::U32(vec![*v; numel]) })),
            "const",
        ),
        Op::ConstU64(v) => {
            (StepOp::Const(Arc::new(Value::u64(dims.clone(), vec![*v; numel]))), "const")
        }
        Op::ConstPred(v) => (
            StepOp::Const(Arc::new(Value {
                dims: dims.clone(),
                buf: Buf::Pred(vec![*v; numel]),
            })),
            "const",
        ),
        Op::Iota { dim } => (
            StepOp::Const(Arc::new(eval::eval_iota(*dim, ins.shape.ty, dims.clone())?)),
            "const",
        ),
        Op::Convert => (StepOp::Convert, "convert"),
        Op::Unary(u) => (StepOp::Unary(*u), "unary"),
        Op::Binary(b) => (StepOp::Binary(*b), "binary"),
        Op::Compare(d) => (StepOp::Compare(*d), "compare"),
        Op::Select => (StepOp::Select, "select"),
        Op::Dot(d) => (StepOp::Dot(lower_dot(c, i, d)?), "dot"),
        Op::Reshape => {
            let in_numel: usize = c.dims(c.ops[i][0]).iter().product();
            if in_numel != numel {
                bail!("reshape numel mismatch: {:?} -> {dims:?}", c.dims(c.ops[i][0]));
            }
            (StepOp::Reshape, "reshape")
        }
        Op::Broadcast(mapping) => {
            (StepOp::Broadcast(lower_broadcast(c, i, mapping, &dims)?), "broadcast")
        }
        Op::Transpose(p) => (StepOp::Transpose(p.clone()), "transpose"),
        Op::Slice(r) => (StepOp::Slice(r.clone()), "slice"),
        Op::Concatenate(d) => (StepOp::Concat(*d), "concat"),
        Op::Gather(g) => (StepOp::Gather(g.clone()), "gather"),
        Op::Reduce { dims: rd, to_apply } => {
            let comp = c
                .module
                .computations
                .get(to_apply)
                .with_context(|| format!("reduce body {to_apply:?} missing"))?;
            let op = eval::reducer_of(comp)?;
            let in_dims = c.dims(c.ops[i][0]);
            let last_axis = rd.len() == 1
                && !in_dims.is_empty()
                && rd[0] == in_dims.len() - 1
                && in_dims[in_dims.len() - 1] > 0
                && ins.shape.ty == PrimType::F32
                && matches!(op, BinOp::Add | BinOp::Max | BinOp::Min);
            (StepOp::Reduce(ReducePlan { red_dims: rd.clone(), op, last_axis }), "reduce")
        }
        Op::DynamicUpdateSlice => (StepOp::Dus, "dus"),
        Op::DynamicSlice(s) => (StepOp::DynamicSlice(s.clone()), "dynamic-slice"),
        Op::RngBitGenerator => (StepOp::Rng, "rng"),
        Op::GetTupleElement(k) => (StepOp::Gte(*k), "gte"),
        Op::Tuple => (StepOp::Tuple, "tuple"),
    };
    Ok(PlanStep {
        op,
        operands: c.ops[i].clone(),
        out: i,
        dims,
        ty: ins.shape.ty,
        kind,
        frees: Vec::new(),
        instr: i,
    })
}

fn lower_dot(c: &Compiler<'_>, i: usize, d: &DotDims) -> Result<DotPlan> {
    let lhs_dims = c.dims(c.ops[i][0]).to_vec();
    let rhs_dims = c.dims(c.ops[i][1]).to_vec();
    let lay = match layout::dot_layout(&lhs_dims, &rhs_dims, d) {
        Ok(l) => l,
        Err(e) => bail!("dot: {e}"),
    };
    if lay.out_dims != c.dims(i) {
        bail!("dot output shape {:?} != computed {:?}", c.dims(i), lay.out_dims);
    }
    let lhs = PackPlan::new(
        &lhs_dims,
        [d.lhs_batch.as_slice(), lay.lhs_free.as_slice(), d.lhs_contract.as_slice()],
    );
    let rhs = PackPlan::new(
        &rhs_dims,
        [d.rhs_batch.as_slice(), d.rhs_contract.as_slice(), lay.rhs_free.as_slice()],
    );
    Ok(DotPlan {
        lhs_dims,
        rhs_dims,
        bsz: lay.bsz(),
        m: lay.m(),
        k: lay.k(),
        n: lay.n(),
        lhs,
        rhs,
    })
}

fn lower_broadcast(
    c: &Compiler<'_>,
    i: usize,
    mapping: &[usize],
    out_dims: &[usize],
) -> Result<BroadcastPlan> {
    let in_dims = c.dims(c.ops[i][0]);
    if mapping.len() != in_dims.len() {
        bail!("broadcast dims {mapping:?} rank-mismatch input {in_dims:?}");
    }
    let in_st = strides(in_dims);
    let mut eff = vec![0usize; out_dims.len()];
    let mut used = vec![false; out_dims.len()];
    for (in_d, &out_d) in mapping.iter().enumerate() {
        if out_d >= out_dims.len() || in_dims[in_d] != out_dims[out_d] {
            bail!("broadcast mapping {mapping:?}: input {in_dims:?} -> output {out_dims:?}");
        }
        if std::mem::replace(&mut used[out_d], true) {
            bail!("broadcast mapping {mapping:?} repeats output dim {out_d}");
        }
        eff[out_d] = in_st[in_d];
    }
    let outer_st = if out_dims.is_empty() {
        Vec::new()
    } else {
        strides(&out_dims[..out_dims.len() - 1])
    };
    Ok(BroadcastPlan { mapping: mapping.to_vec(), eff, outer_st })
}

// ---------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------

fn slot_one<'s>(slots: &'s [SlotVal], i: usize) -> Result<&'s Arc<Value>> {
    match &slots[i] {
        SlotVal::One(a) => Ok(a),
        SlotVal::Tuple(_) => bail!("slot {i} holds a tuple where an array was expected"),
        SlotVal::Empty => bail!("slot {i} read after free (plan liveness bug)"),
    }
}

/// Take the value out of `slot` for in-place reuse — only when this
/// step is its last use and the Arc uniquely owns the buffer.
fn take_dying_unique(slots: &mut [SlotVal], slot: usize, frees: &[usize]) -> Option<Value> {
    if !frees.contains(&slot) {
        return None;
    }
    match std::mem::replace(&mut slots[slot], SlotVal::Empty) {
        SlotVal::One(a) => match Arc::try_unwrap(a) {
            Ok(v) => Some(v),
            Err(a) => {
                slots[slot] = SlotVal::One(a);
                None
            }
        },
        other => {
            slots[slot] = other;
            None
        }
    }
}

/// Split `out` into row chunks and run `f(first_row, chunk)` on up to
/// `threads` scoped workers. Rows never split, so per-row accumulation
/// order — and therefore every output bit — is thread-count-invariant.
fn par_rows<F>(out: &mut [f32], row_w: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_w == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / row_w;
    if threads <= 1 || rows < 2 {
        f(0, out);
        return;
    }
    let t = threads.min(rows);
    let per = rows.div_ceil(t);
    std::thread::scope(|s| {
        let fr = &f;
        let mut rest = out;
        let mut r0 = 0usize;
        while rest.len() > per * row_w {
            let (chunk, tail) = rest.split_at_mut(per * row_w);
            s.spawn(move || fr(r0, chunk));
            r0 += per;
            rest = tail;
        }
        fr(r0, rest);
    });
}

/// Fold each contiguous `k`-row of `data` into one output element,
/// four rows in flight for ILP. Per-row fold order is strictly
/// ascending — bit-identical to the naive reference.
fn fold_rows(data: &[f32], k: usize, init: f32, apply: fn(f32, f32) -> f32, out: &mut [f32]) {
    let rows = out.len();
    let mut r = 0usize;
    while r + 4 <= rows {
        let b = r * k;
        let (mut a0, mut a1, mut a2, mut a3) = (init, init, init, init);
        let (r0, r1) = (&data[b..b + k], &data[b + k..b + 2 * k]);
        let (r2, r3) = (&data[b + 2 * k..b + 3 * k], &data[b + 3 * k..b + 4 * k]);
        for (((&x0, &x1), &x2), &x3) in r0.iter().zip(r1).zip(r2).zip(r3) {
            a0 = apply(a0, x0);
            a1 = apply(a1, x1);
            a2 = apply(a2, x2);
            a3 = apply(a3, x3);
        }
        out[r] = a0;
        out[r + 1] = a1;
        out[r + 2] = a2;
        out[r + 3] = a3;
        r += 4;
    }
    while r < rows {
        let mut acc = init;
        for &x in &data[r * k..(r + 1) * k] {
            acc = apply(acc, x);
        }
        out[r] = acc;
        r += 1;
    }
}

fn unary_in_place(v: &mut [f32], u: UnOp) {
    match u {
        UnOp::Exp => v.iter_mut().for_each(|x| *x = x.exp()),
        UnOp::Tanh => v.iter_mut().for_each(|x| *x = x.tanh()),
        UnOp::Neg => v.iter_mut().for_each(|x| *x = -*x),
    }
}

fn binary_in_place(a: &mut [f32], b: &[f32], op: BinOp) -> Result<()> {
    let f: fn(f32, f32) -> f32 = match op {
        BinOp::Add => |x, y| x + y,
        BinOp::Sub => |x, y| x - y,
        BinOp::Mul => |x, y| x * y,
        BinOp::Div => |x, y| x / y,
        BinOp::Max => f32::max,
        BinOp::Min => f32::min,
        BinOp::And | BinOp::Or => bail!("logical op on f32"),
    };
    for (x, &y) in a.iter_mut().zip(b) {
        *x = f(*x, y);
    }
    Ok(())
}

impl ExecPlan {
    fn run(&self, args: &[Arc<Value>], mut times: Option<&mut OpTimes>) -> Result<Vec<Value>> {
        if args.len() != self.n_params {
            bail!("plan wants {} parameters, got {}", self.n_params, args.len());
        }
        let entry = self.module.entry_computation();
        let mut slots: Vec<SlotVal> = (0..self.n_slots).map(|_| SlotVal::Empty).collect();
        let mut arena = Arena::default();
        for step in &self.steps {
            let _sp = obs::span("backend.op").label(step.kind);
            let t0 = times.as_ref().map(|_| Instant::now());
            let v = self
                .run_step(step, args, &mut slots, &mut arena, entry)
                .with_context(|| format!("step {:?}", entry.instrs[step.instr].name))?;
            slots[step.out] = v;
            for &f in &step.frees {
                if let SlotVal::One(a) = std::mem::replace(&mut slots[f], SlotVal::Empty) {
                    if let Ok(val) = Arc::try_unwrap(a) {
                        arena.give(val);
                    }
                }
            }
            if let (Some(t0), Some(times)) = (t0, times.as_deref_mut()) {
                let e = times.entry(step.kind).or_default();
                e.count += 1;
                e.total_ns += t0.elapsed().as_nanos() as u64;
            }
        }
        match &self.root {
            Root::Slot(s) => match std::mem::replace(&mut slots[*s], SlotVal::Empty) {
                SlotVal::One(a) => Ok(vec![Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone())]),
                SlotVal::Tuple(parts) => Ok(parts
                    .into_iter()
                    .map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()))
                    .collect()),
                SlotVal::Empty => bail!("root slot empty after execution"),
            },
            Root::Parts(ps) => ps
                .iter()
                .map(|&p| slot_one(&slots, p).map(|a| (**a).clone()))
                .collect(),
        }
    }

    fn run_step(
        &self,
        step: &PlanStep,
        args: &[Arc<Value>],
        slots: &mut [SlotVal],
        arena: &mut Arena,
        entry: &Computation,
    ) -> Result<SlotVal> {
        let one = |v: Value| SlotVal::One(Arc::new(v));
        Ok(match &step.op {
            StepOp::Param(p) => {
                let a = args.get(*p).with_context(|| format!("parameter {p} out of range"))?;
                eval::check_shape(a, &entry.instrs[step.instr].shape, "parameter")?;
                SlotVal::One(Arc::clone(a))
            }
            StepOp::Const(v) => SlotVal::One(Arc::clone(v)),
            StepOp::Fused(f) => one(self.run_fused(f, step, slots, arena)?),
            StepOp::Unary(u) => {
                if step.ty == PrimType::F32 {
                    if let Some(mut v) = take_dying_unique(slots, step.operands[0], &step.frees) {
                        if let Buf::F32(d) = &mut v.buf {
                            unary_in_place(d, *u);
                            return Ok(SlotVal::One(Arc::new(v)));
                        }
                        slots[step.operands[0]] = SlotVal::One(Arc::new(v));
                    }
                }
                let a = slot_one(slots, step.operands[0])?;
                one(eval::eval_unary(a, *u, step.dims.clone())?)
            }
            StepOp::Binary(b) => {
                if step.ty == PrimType::F32 && step.operands[0] != step.operands[1] {
                    if let Some(mut v) = take_dying_unique(slots, step.operands[0], &step.frees) {
                        let done = {
                            let rhs = slot_one(slots, step.operands[1])?;
                            match (&mut v.buf, &rhs.buf) {
                                (Buf::F32(a), Buf::F32(c))
                                    if rhs.dims == v.dims && c.len() == a.len() =>
                                {
                                    binary_in_place(a, c, *b)?;
                                    true
                                }
                                _ => false,
                            }
                        };
                        if done {
                            return Ok(SlotVal::One(Arc::new(v)));
                        }
                        slots[step.operands[0]] = SlotVal::One(Arc::new(v));
                    }
                }
                let x = slot_one(slots, step.operands[0])?;
                let y = slot_one(slots, step.operands[1])?;
                one(eval::eval_binary(x, y, *b, step.dims.clone())?)
            }
            StepOp::Compare(d) => {
                let x = slot_one(slots, step.operands[0])?;
                let y = slot_one(slots, step.operands[1])?;
                one(eval::eval_compare(x, y, *d, step.dims.clone())?)
            }
            StepOp::Select => {
                let p = slot_one(slots, step.operands[0])?;
                let t = slot_one(slots, step.operands[1])?;
                let f = slot_one(slots, step.operands[2])?;
                one(eval::eval_select(p, t, f, step.dims.clone())?)
            }
            StepOp::Convert => {
                let a = slot_one(slots, step.operands[0])?;
                one(eval::eval_convert(a, step.ty, step.dims.clone())?)
            }
            StepOp::Dot(dp) => one(self.run_dot(dp, step, slots, arena)?),
            StepOp::Reduce(rp) => one(self.run_reduce(rp, step, slots, arena)?),
            StepOp::Broadcast(bp) => one(self.run_broadcast(bp, step, slots, arena)?),
            StepOp::Reshape => {
                let numel: usize = step.dims.iter().product();
                if let Some(mut v) = take_dying_unique(slots, step.operands[0], &step.frees) {
                    if v.buf.len() == numel {
                        v.dims = step.dims.clone();
                        return Ok(SlotVal::One(Arc::new(v)));
                    }
                    slots[step.operands[0]] = SlotVal::One(Arc::new(v));
                }
                let a = slot_one(slots, step.operands[0])?;
                if a.numel() != numel {
                    bail!("reshape numel mismatch: {:?} -> {:?}", a.dims, step.dims);
                }
                one(Value { dims: step.dims.clone(), buf: a.buf.clone() })
            }
            StepOp::Transpose(perm) => {
                let a = slot_one(slots, step.operands[0])?;
                one(eval::eval_transpose(a, perm, step.dims.clone())?)
            }
            StepOp::Slice(ranges) => {
                let a = slot_one(slots, step.operands[0])?;
                one(eval::eval_slice(a, ranges, step.dims.clone())?)
            }
            StepOp::Concat(dim) => {
                let vals: Vec<&Value> = step
                    .operands
                    .iter()
                    .map(|&o| slot_one(slots, o).map(|a| &**a))
                    .collect::<Result<Vec<_>>>()?;
                one(eval::eval_concat(&vals, *dim, step.dims.clone())?)
            }
            StepOp::Gather(g) => {
                let a = slot_one(slots, step.operands[0])?;
                let idx = slot_one(slots, step.operands[1])?;
                one(eval::eval_gather(a, idx, g, step.dims.clone())?)
            }
            StepOp::Dus => {
                let starts = scalar_starts(slots, &step.operands[2..], "dus")?;
                let a = slot_one(slots, step.operands[0])?;
                let u = slot_one(slots, step.operands[1])?;
                one(eval::eval_dus(a, u, &starts)?)
            }
            StepOp::DynamicSlice(sizes) => {
                let starts = scalar_starts(slots, &step.operands[1..], "dynamic-slice")?;
                let a = slot_one(slots, step.operands[0])?;
                one(eval::eval_dynamic_slice(a, &starts, sizes, step.dims.clone())?)
            }
            StepOp::Rng => {
                let state = slot_one(slots, step.operands[0])?;
                let (new_state, bits) =
                    eval::eval_rng_threefry(state, &entry.instrs[step.instr])?;
                SlotVal::Tuple(vec![Arc::new(new_state), Arc::new(bits)])
            }
            StepOp::Tuple => {
                let parts: Vec<Arc<Value>> = step
                    .operands
                    .iter()
                    .map(|&o| slot_one(slots, o).map(Arc::clone))
                    .collect::<Result<Vec<_>>>()?;
                SlotVal::Tuple(parts)
            }
            StepOp::Gte(k) => match &slots[step.operands[0]] {
                SlotVal::Tuple(parts) => SlotVal::One(Arc::clone(
                    parts
                        .get(*k)
                        .with_context(|| format!("tuple index {k} out of range"))?,
                )),
                _ => bail!("get-tuple-element source is not a tuple"),
            },
        })
    }

    fn run_fused(
        &self,
        f: &Fused,
        step: &PlanStep,
        slots: &[SlotVal],
        arena: &mut Arena,
    ) -> Result<Value> {
        enum Src<'a> {
            F(&'a [f32]),
            P(&'a [bool]),
        }
        let n: usize = step.dims.iter().product();
        let mut out_f = if f.out_pred { Vec::new() } else { arena.take_f32(n, 0.0) };
        let mut out_p: Vec<bool> = if f.out_pred { Vec::with_capacity(n) } else { Vec::new() };
        let mut regs: Vec<Vec<f32>> =
            (0..f.prog.len()).map(|_| arena.take_f32(CHUNK, 0.0)).collect();
        {
            let mut srcs: Vec<Src<'_>> = Vec::with_capacity(f.loads.len());
            for &(slot, lt) in &f.loads {
                let v = slot_one(slots, slot)?;
                if v.numel() != n {
                    bail!("fused load shape mismatch: {:?} vs {:?}", v.dims, step.dims);
                }
                match (lt, &v.buf) {
                    (LoadTy::F32, Buf::F32(d)) => srcs.push(Src::F(d)),
                    (LoadTy::Pred, Buf::Pred(d)) => srcs.push(Src::P(d)),
                    (_, b) => bail!("fused load dtype mismatch: {:?}", b.ty()),
                }
            }
            let mut start = 0usize;
            while start < n {
                let len = CHUNK.min(n - start);
                for i in 0..f.prog.len() {
                    let (prev, cur) = regs.split_at_mut(i);
                    let r = &mut cur[0][..len];
                    match f.prog[i] {
                        FOp::Load(j) => match srcs[j] {
                            Src::F(s) => r.copy_from_slice(&s[start..start + len]),
                            Src::P(s) => {
                                for (d, &b) in r.iter_mut().zip(&s[start..start + len]) {
                                    *d = if b { 1.0 } else { 0.0 };
                                }
                            }
                        },
                        FOp::Imm(v) => r.fill(v),
                        FOp::Un(u, a) => {
                            let av = &prev[a][..len];
                            match u {
                                UnOp::Exp => {
                                    for (d, &x) in r.iter_mut().zip(av) {
                                        *d = x.exp();
                                    }
                                }
                                UnOp::Tanh => {
                                    for (d, &x) in r.iter_mut().zip(av) {
                                        *d = x.tanh();
                                    }
                                }
                                UnOp::Neg => {
                                    for (d, &x) in r.iter_mut().zip(av) {
                                        *d = -x;
                                    }
                                }
                            }
                        }
                        FOp::Bin(b, x, y) => {
                            let (xv, yv) = (&prev[x][..len], &prev[y][..len]);
                            let g: fn(f32, f32) -> f32 = match b {
                                BinOp::Add => |p, q| p + q,
                                BinOp::Sub => |p, q| p - q,
                                BinOp::Mul => |p, q| p * q,
                                BinOp::Div => |p, q| p / q,
                                BinOp::Max => f32::max,
                                BinOp::Min => f32::min,
                                // masks are 0.0/1.0; nonzero == true
                                BinOp::And => {
                                    |p, q| if p != 0.0 && q != 0.0 { 1.0 } else { 0.0 }
                                }
                                BinOp::Or => |p, q| if p != 0.0 || q != 0.0 { 1.0 } else { 0.0 },
                            };
                            for ((d, &p), &q) in r.iter_mut().zip(xv).zip(yv) {
                                *d = g(p, q);
                            }
                        }
                        FOp::Cmp(dir, x, y) => {
                            let (xv, yv) = (&prev[x][..len], &prev[y][..len]);
                            let g: fn(f32, f32) -> bool = match dir {
                                CmpDir::Eq => |p, q| p == q,
                                CmpDir::Ne => |p, q| p != q,
                                CmpDir::Lt => |p, q| p < q,
                                CmpDir::Le => |p, q| p <= q,
                                CmpDir::Gt => |p, q| p > q,
                                CmpDir::Ge => |p, q| p >= q,
                            };
                            for ((d, &p), &q) in r.iter_mut().zip(xv).zip(yv) {
                                *d = if g(p, q) { 1.0 } else { 0.0 };
                            }
                        }
                        FOp::Sel(cr, tr, er) => {
                            let (cv, tv, ev) =
                                (&prev[cr][..len], &prev[tr][..len], &prev[er][..len]);
                            for (((d, &cc), &tt), &ee) in
                                r.iter_mut().zip(cv).zip(tv).zip(ev)
                            {
                                *d = if cc != 0.0 { tt } else { ee };
                            }
                        }
                        FOp::Cvt(a) => r.copy_from_slice(&prev[a][..len]),
                    }
                }
                let last = &regs[f.prog.len() - 1][..len];
                if f.out_pred {
                    out_p.extend(last.iter().map(|&x| x != 0.0));
                } else {
                    out_f[start..start + len].copy_from_slice(last);
                }
                start += len;
            }
        }
        for r in regs {
            arena.give_f32(r);
        }
        Ok(if f.out_pred {
            Value { dims: step.dims.clone(), buf: Buf::Pred(out_p) }
        } else {
            Value { dims: step.dims.clone(), buf: Buf::F32(out_f) }
        })
    }

    fn run_dot(
        &self,
        dp: &DotPlan,
        step: &PlanStep,
        slots: &[SlotVal],
        arena: &mut Arena,
    ) -> Result<Value> {
        let (bsz, m, k, n) = (dp.bsz, dp.m, dp.k, dp.n);
        let mut out = arena.take_f32(bsz * m * n, 0.0);
        {
            let lhs = slot_one(slots, step.operands[0])?;
            let rhs = slot_one(slots, step.operands[1])?;
            if lhs.dims != dp.lhs_dims || rhs.dims != dp.rhs_dims {
                bail!(
                    "dot operand shapes {:?}/{:?} differ from planned {:?}/{:?}",
                    lhs.dims,
                    rhs.dims,
                    dp.lhs_dims,
                    dp.rhs_dims
                );
            }
            let a = lhs.f32s().context("dot lhs must be f32")?;
            let b = rhs.f32s().context("dot rhs must be f32")?;
            // the common matmul case needs no packing at all: both
            // operands are already in blocked row-major layout
            let pa: Cow<'_, [f32]> =
                if dp.lhs.identity { Cow::Borrowed(a) } else { Cow::Owned(dp.lhs.pack(a)) };
            let pb: Cow<'_, [f32]> =
                if dp.rhs.identity { Cow::Borrowed(b) } else { Cow::Owned(dp.rhs.pack(b)) };
            let threads =
                if bsz * m * k * n >= PAR_MIN_DOT { self.opts.threads } else { 1 };
            let (pa, pb) = (&*pa, &*pb);
            par_rows(&mut out, n, threads, |r0, chunk| {
                for (ri, orow) in chunk.chunks_mut(n).enumerate() {
                    let r = r0 + ri;
                    let (bb, i) = (r / m, r % m);
                    let arow = &pa[bb * m * k + i * k..][..k];
                    let bmat = &pb[bb * k * n..][..k * n];
                    for (kk, &av) in arow.iter().enumerate() {
                        let brow = &bmat[kk * n..][..n];
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            });
        }
        Ok(Value::f32(step.dims.clone(), out))
    }

    fn run_reduce(
        &self,
        rp: &ReducePlan,
        step: &PlanStep,
        slots: &[SlotVal],
        arena: &mut Arena,
    ) -> Result<Value> {
        let a = slot_one(slots, step.operands[0])?;
        let init = slot_one(slots, step.operands[1])?;
        if rp.last_axis {
            if let (Buf::F32(data), Buf::F32(iv)) = (&a.buf, &init.buf) {
                let init_v = *iv.first().context("empty reduce init")?;
                let k = *a.dims.last().context("reduce input is rank-0")?;
                let n_out: usize = step.dims.iter().product();
                let apply: fn(f32, f32) -> f32 = match rp.op {
                    BinOp::Add => |x, y| x + y,
                    BinOp::Max => f32::max,
                    BinOp::Min => f32::min,
                    other => bail!("fast reduce planned for unsupported op {other:?}"),
                };
                let threads =
                    if a.numel() >= PAR_MIN_REDUCE { self.opts.threads } else { 1 };
                // arena borrow ends before we re-borrow `a`'s data
                let mut out = arena.take_f32(n_out, init_v);
                par_rows(&mut out, 1, threads, |r0, chunk| {
                    fold_rows(
                        &data[r0 * k..r0 * k + chunk.len() * k],
                        k,
                        init_v,
                        apply,
                        chunk,
                    );
                });
                return Ok(Value::f32(step.dims.clone(), out));
            }
        }
        eval::eval_reduce(a, init, &rp.red_dims, rp.op, step.dims.clone())
    }

    fn run_broadcast(
        &self,
        bp: &BroadcastPlan,
        step: &PlanStep,
        slots: &[SlotVal],
        arena: &mut Arena,
    ) -> Result<Value> {
        let a = slot_one(slots, step.operands[0])?;
        let n: usize = step.dims.iter().product();
        let rank = step.dims.len();
        if !matches!(a.buf, Buf::F32(_)) || rank == 0 || n == 0 {
            // non-f32/degenerate broadcasts are rare and small; the
            // reference kernel revalidates the mapping as it goes
            return eval::eval_broadcast(a, &bp.mapping, step.dims.clone());
        }
        let v = a.f32s().context("broadcast fast path expects f32")?;
        let inner = step.dims[rank - 1];
        let e_last = bp.eff[rank - 1];
        let outer_dims = &step.dims[..rank - 1];
        let mut out = arena.take_f32(n, 0.0);
        let threads = if n >= PAR_MIN_BCAST { self.opts.threads } else { 1 };
        par_rows(&mut out, inner.max(1), threads, |r0, chunk| {
            for (ri, row) in chunk.chunks_mut(inner.max(1)).enumerate() {
                let r = r0 + ri;
                let mut base = 0usize;
                for (d, &st) in bp.outer_st.iter().enumerate() {
                    base += ((r / st) % outer_dims[d]) * bp.eff[d];
                }
                if e_last == 0 {
                    row.fill(v[base]);
                } else if e_last == 1 {
                    row.copy_from_slice(&v[base..base + inner]);
                } else {
                    for (j, o) in row.iter_mut().enumerate() {
                        *o = v[base + j * e_last];
                    }
                }
            }
        });
        Ok(Value::f32(step.dims.clone(), out))
    }
}

fn scalar_starts(slots: &[SlotVal], idx_slots: &[usize], what: &str) -> Result<Vec<i64>> {
    let mut starts = Vec::with_capacity(idx_slots.len());
    for (i, &s) in idx_slots.iter().enumerate() {
        let v = slot_one(slots, s)?;
        if !v.dims.is_empty() {
            bail!("{what} start {i} is not a scalar: {:?}", v.dims);
        }
        let d = v.i32s().with_context(|| format!("{what} start index"))?;
        starts.push(*d.first().with_context(|| format!("empty {what} start"))? as i64);
    }
    Ok(starts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::hlo::parser::parse_module;

    fn plan_run(text: &str, args: Vec<Value>, opts: EvalOptions) -> Vec<Value> {
        let m = Arc::new(parse_module(text).unwrap());
        let plan = ExecPlan::compile(&m, opts).unwrap();
        let args: Vec<Arc<Value>> = args.into_iter().map(Arc::new).collect();
        plan.execute(&args).unwrap()
    }

    fn naive_run(text: &str, args: Vec<Value>) -> Vec<Value> {
        let m = parse_module(text).unwrap();
        let args: Vec<Arc<Value>> = args.into_iter().map(Arc::new).collect();
        eval::evaluate(&m, &args).unwrap()
    }

    fn assert_bits_eq(a: &[Value], b: &[Value]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.dims, y.dims);
            match (&x.buf, &y.buf) {
                (Buf::F32(p), Buf::F32(q)) => {
                    assert_eq!(p.len(), q.len());
                    for (u, v) in p.iter().zip(q) {
                        assert_eq!(u.to_bits(), v.to_bits());
                    }
                }
                (p, q) => assert_eq!(p, q),
            }
        }
    }

    const SOFTMAX: &str = r#"
HloModule t
%red_max {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %m = f32[] maximum(%a, %b)
}
%red_add {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main {
  %x = f32[4,7] parameter(0)
  %ninf = f32[] constant(-1e30)
  %zero = f32[] constant(0)
  %mx = f32[4] reduce(%x, %ninf), dimensions={1}, to_apply=%red_max
  %mb = f32[4,7] broadcast(%mx), dimensions={0}
  %sh = f32[4,7] subtract(%x, %mb)
  %e = f32[4,7] exponential(%sh)
  %se = f32[4] reduce(%e, %zero), dimensions={1}, to_apply=%red_add
  %sb = f32[4,7] broadcast(%se), dimensions={0}
  ROOT %p = f32[4,7] divide(%e, %sb)
}
"#;

    #[test]
    fn plan_matches_naive_softmax_bitwise() {
        let x = Value::f32(vec![4, 7], (0..28).map(|i| (i as f32).sin() * 3.0).collect());
        let want = naive_run(SOFTMAX, vec![x.clone()]);
        for threads in [1, 4] {
            for fuse in [false, true] {
                let got = plan_run(SOFTMAX, vec![x.clone()], EvalOptions { threads, fuse });
                assert_bits_eq(&got, &want);
            }
        }
    }

    #[test]
    fn fusion_collapses_elementwise_chains() {
        let m = Arc::new(parse_module(SOFTMAX).unwrap());
        let fused = ExecPlan::compile(&m, EvalOptions::default()).unwrap();
        // sub+exp fuse into one loop; div stays (its operands differ
        // in provenance but sub/exp chain is single-use)
        assert!(fused.count_kind("fused") >= 1, "expected at least one fused step");
        let plain = ExecPlan::compile(&m, EvalOptions { threads: 1, fuse: false }).unwrap();
        assert_eq!(plain.count_kind("fused"), 0);
        assert!(fused.n_steps() < plain.n_steps());
    }

    #[test]
    fn plan_handles_tuple_roots_and_rng() {
        let text = r#"
HloModule t
ENTRY %main {
  %state = u64[2] parameter(0)
  %r = (u64[2], u32[6]) rng-bit-generator(%state), algorithm=rng_threefry
  %ns = u64[2] get-tuple-element(%r), index=0
  %bits = u32[6] get-tuple-element(%r), index=1
  ROOT %t = (u64[2], u32[6]) tuple(%ns, %bits)
}
"#;
        let st = Value::u64(vec![2], vec![42, 7]);
        let want = naive_run(text, vec![st.clone()]);
        let got = plan_run(text, vec![st], EvalOptions::default());
        assert_eq!(got.len(), 2);
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn pred_output_fusion_materializes_bools() {
        // compare feeding and: fused chain with a pred output
        let text = r#"
HloModule t
ENTRY %main {
  %x = f32[8] parameter(0)
  %y = f32[8] parameter(1)
  %z = f32[8] parameter(2)
  %p = pred[8] compare(%x, %y), direction=LT
  %q = pred[8] compare(%y, %z), direction=LT
  ROOT %b = pred[8] and(%p, %q)
}
"#;
        let x = Value::f32(vec![8], (0..8).map(|i| i as f32).collect());
        let y = Value::f32(vec![8], (0..8).map(|i| (7 - i) as f32).collect());
        let z = Value::f32(vec![8], vec![5.0; 8]);
        let want = naive_run(text, vec![x.clone(), y.clone(), z.clone()]);
        let got = plan_run(text, vec![x, y, z], EvalOptions::default());
        assert_bits_eq(&got, &want);
    }

    #[test]
    fn dot_identity_pack_and_parallel_rows_are_bit_identical() {
        let text = r#"
HloModule t
ENTRY %main {
  %a = f32[33,17] parameter(0)
  %b = f32[17,29] parameter(1)
  ROOT %c = f32[33,29] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let a = Value::f32(vec![33, 17], (0..33 * 17).map(|i| (i as f32).cos()).collect());
        let b = Value::f32(vec![17, 29], (0..17 * 29).map(|i| (i as f32).sin()).collect());
        let want = naive_run(text, vec![a.clone(), b.clone()]);
        for threads in [1, 4] {
            let got =
                plan_run(text, vec![a.clone(), b.clone()], EvalOptions { threads, fuse: true });
            assert_bits_eq(&got, &want);
        }
    }

    #[test]
    fn multi_use_values_survive_arena_recycling() {
        // %e is used twice (numerator and reduce input): the arena must
        // not recycle it until its true last use
        let text = r#"
HloModule t
%red_add {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main {
  %x = f32[3,5] parameter(0)
  %e = f32[3,5] exponential(%x)
  %zero = f32[] constant(0)
  %se = f32[3] reduce(%e, %zero), dimensions={1}, to_apply=%red_add
  %sb = f32[3,5] broadcast(%se), dimensions={0}
  ROOT %p = f32[3,5] divide(%e, %sb)
}
"#;
        let x = Value::f32(vec![3, 5], (0..15).map(|i| (i as f32) * 0.3 - 2.0).collect());
        let want = naive_run(text, vec![x.clone()]);
        let got = plan_run(text, vec![x], EvalOptions::default());
        assert_bits_eq(&got, &want);
    }

    #[test]
    fn options_read_env() {
        // default when unset
        std::env::remove_var("FE_INTERP_THREADS");
        std::env::remove_var("FE_INTERP_FUSE");
        assert_eq!(EvalOptions::from_env(), EvalOptions { threads: 1, fuse: true });
        std::env::set_var("FE_INTERP_THREADS", "4");
        std::env::set_var("FE_INTERP_FUSE", "0");
        assert_eq!(EvalOptions::from_env(), EvalOptions { threads: 4, fuse: false });
        std::env::remove_var("FE_INTERP_THREADS");
        std::env::remove_var("FE_INTERP_FUSE");
    }
}
