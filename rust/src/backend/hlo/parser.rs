//! Parser for the HLO-*text* subset our lowered graphs use.
//!
//! HLO text is the artifact interchange format (see `runtime::client`);
//! this parser understands the instruction forms the fixture generator
//! emits and that `aot.py`-lowered modules of the same op set use:
//! one module, N named computations (reduce bodies + ENTRY), one
//! instruction per line in dependency order. Layout annotations
//! (`{1,0}`), `metadata={...}` and typed operands (`f32[2]{0} %a`) are
//! accepted and ignored, so real XLA printouts of supported ops parse
//! too. Unsupported opcodes are a hard, named error at compile time —
//! never a silent wrong answer at execution time.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimType {
    F32,
    S32,
    U32,
    U64,
    Pred,
}

impl PrimType {
    fn from_str(s: &str) -> Result<PrimType> {
        Ok(match s {
            "f32" => PrimType::F32,
            "s32" => PrimType::S32,
            "u32" => PrimType::U32,
            "u64" => PrimType::U64,
            "pred" => PrimType::Pred,
            other => bail!("unsupported element type {other:?}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Shape {
    pub ty: PrimType,
    pub dims: Vec<usize>,
}

impl Shape {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    And,
    Or,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Exp,
    Tanh,
    Neg,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

#[derive(Debug, Clone)]
pub struct DotDims {
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub lhs_contract: Vec<usize>,
    pub rhs_contract: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct GatherDims {
    pub offset_dims: Vec<usize>,
    pub collapsed_slice_dims: Vec<usize>,
    pub start_index_map: Vec<usize>,
    pub index_vector_dim: usize,
    pub slice_sizes: Vec<usize>,
}

#[derive(Debug, Clone)]
pub enum Op {
    Parameter(usize),
    /// scalar constants only (weights arrive as parameters)
    ConstF32(f32),
    ConstS32(i32),
    ConstU32(u32),
    ConstU64(u64),
    ConstPred(bool),
    Iota {
        dim: usize,
    },
    Convert,
    Unary(UnOp),
    Binary(BinOp),
    Compare(CmpDir),
    Select,
    Dot(DotDims),
    Reshape,
    Broadcast(Vec<usize>),
    Transpose(Vec<usize>),
    /// (start, limit, stride) per dimension
    Slice(Vec<(usize, usize, usize)>),
    Concatenate(usize),
    Gather(GatherDims),
    Reduce {
        dims: Vec<usize>,
        to_apply: String,
    },
    DynamicUpdateSlice,
    /// slice sizes per dimension; start indices arrive as scalar s32
    /// operands (one per dimension), clamped like XLA's dynamic-slice
    DynamicSlice(Vec<usize>),
    /// deterministic counter-based RNG (Threefry-2x32): consumes a
    /// u64[2] `[key, counter]` state, produces `(new_state, u32 bits)`
    /// as a tuple — projected out with get-tuple-element
    RngBitGenerator,
    /// tuple projection: operand must be a tuple-valued instruction
    GetTupleElement(usize),
    Tuple,
}

#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    /// element shape; tuple-typed instructions carry their parts here
    pub shape: Shape,
    pub tuple_shapes: Option<Vec<Shape>>,
    pub op: Op,
    pub operands: Vec<String>,
    /// carried the `ROOT` marker in the source text
    pub is_root: bool,
}

#[derive(Debug)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    /// instruction index per parameter number
    pub params: Vec<usize>,
    pub root: usize,
}

impl Computation {
    /// Resolve a reduce body to its binary op: the computation must be
    /// a single binary instruction over its two parameters. Shared by
    /// the evaluator and the execution-plan compiler, which both lower
    /// `to_apply` bodies to a plain combiner at different times.
    pub fn as_binary_reducer(&self) -> Option<BinOp> {
        match self.instrs[self.root].op {
            Op::Binary(b) => Some(b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct HloModule {
    pub name: String,
    pub computations: HashMap<String, Computation>,
    pub entry: String,
}

impl HloModule {
    pub fn entry_computation(&self) -> &Computation {
        &self.computations[&self.entry]
    }
}

/// Split at `sep` occurring at bracket depth 0 (wrt `{[(`).
fn split_top(s: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '{' | '[' | '(' => depth += 1,
            '}' | ']' | ')' => depth -= 1,
            c if c == sep && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn strip_pct(s: &str) -> &str {
    s.trim().trim_start_matches('%')
}

/// Parse one non-tuple shape like `f32[1,8]{1,0}` or `pred[]`; layout
/// suffix is ignored.
fn parse_shape(s: &str) -> Result<Shape> {
    let s = s.trim();
    let open = s.find('[').with_context(|| format!("shape {s:?} has no '['"))?;
    let close = s.find(']').with_context(|| format!("shape {s:?} has no ']'"))?;
    let ty = PrimType::from_str(&s[..open])?;
    let inner = &s[open + 1..close];
    let dims = if inner.trim().is_empty() {
        Vec::new()
    } else {
        inner
            .split(',')
            .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim in {s:?}")))
            .collect::<Result<Vec<_>>>()?
    };
    Ok(Shape { ty, dims })
}

/// Parse a shape that may be a tuple. Returns (element-or-first shape,
/// optional tuple parts, rest-of-line after the shape text).
fn parse_shape_prefix(s: &str) -> Result<(Shape, Option<Vec<Shape>>, &str)> {
    let s = s.trim_start();
    if let Some(stripped) = s.strip_prefix('(') {
        let close = {
            let mut depth = 1i32;
            let mut idx = None;
            for (i, c) in stripped.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            idx = Some(i);
                            break;
                        }
                    }
                    _ => {}
                }
            }
            idx.context("unterminated tuple shape")?
        };
        let parts = split_top(&stripped[..close], ',')
            .iter()
            .map(|p| parse_shape(p))
            .collect::<Result<Vec<_>>>()?;
        let first = parts.first().cloned().context("empty tuple shape")?;
        return Ok((first, Some(parts), &stripped[close + 1..]));
    }
    // scan to the end of `ty[dims]{layout?}`
    let close = s.find(']').with_context(|| format!("no shape in {s:?}"))?;
    let mut end = close + 1;
    let bytes = s.as_bytes();
    if bytes.get(end) == Some(&b'{') {
        let rest = &s[end..];
        let c = rest.find('}').context("unterminated layout")?;
        end += c + 1;
    }
    Ok((parse_shape(&s[..end])?, None, &s[end..]))
}

fn parse_usize_list(v: &str) -> Result<Vec<usize>> {
    let v = v.trim();
    let inner = v
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .with_context(|| format!("expected braced list, got {v:?}"))?;
    if inner.trim().is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad int in {v:?}")))
        .collect()
}

/// `{[0:1], [2:18:1]}` -> [(0,1,1), (2,18,1)]
fn parse_slice_attr(v: &str) -> Result<Vec<(usize, usize, usize)>> {
    let inner = v
        .trim()
        .strip_prefix('{')
        .and_then(|x| x.strip_suffix('}'))
        .with_context(|| format!("bad slice attr {v:?}"))?;
    split_top(inner, ',')
        .iter()
        .map(|part| {
            let p = part.trim();
            let p = p
                .strip_prefix('[')
                .and_then(|x| x.strip_suffix(']'))
                .with_context(|| format!("bad slice range {part:?}"))?;
            let nums: Vec<usize> = p
                .split(':')
                .map(|n| n.trim().parse().with_context(|| format!("bad slice bound {p:?}")))
                .collect::<Result<Vec<_>>>()?;
            Ok(match nums.len() {
                2 => (nums[0], nums[1], 1),
                3 => (nums[0], nums[1], nums[2]),
                _ => bail!("bad slice range {part:?}"),
            })
        })
        .collect()
}

fn attr_map(attrs: &str) -> Vec<(String, String)> {
    split_top(attrs, ',')
        .iter()
        .filter_map(|a| {
            let a = a.trim();
            if a.is_empty() {
                return None;
            }
            let (k, v) = a.split_once('=')?;
            Some((k.trim().to_string(), v.trim().to_string()))
        })
        .collect()
}

fn get_attr<'a>(attrs: &'a [(String, String)], key: &str) -> Option<&'a str> {
    attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn req_attr<'a>(attrs: &'a [(String, String)], key: &str, op: &str) -> Result<&'a str> {
    get_attr(attrs, key).with_context(|| format!("{op}: missing attribute {key}"))
}

fn parse_instr(line: &str) -> Result<Instr> {
    let line = line.trim();
    let is_root = line.starts_with("ROOT ");
    let line = line.trim_start_matches("ROOT ").trim();
    let (lhs, rhs) = line.split_once('=').with_context(|| format!("no '=' in {line:?}"))?;
    let name = strip_pct(lhs).to_string();
    let (shape, tuple_shapes, rest) = parse_shape_prefix(rhs)?;
    let rest = rest.trim_start();
    let open = rest
        .find('(')
        .with_context(|| format!("{name}: no operand list in {rest:?}"))?;
    let opcode = rest[..open].trim();
    // find matching close paren
    let mut depth = 0i32;
    let mut close = None;
    for (i, c) in rest.char_indices().skip(open) {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    close = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let close = close.with_context(|| format!("{name}: unterminated operand list"))?;
    let operand_text = &rest[open + 1..close];
    let operands: Vec<String> = if operand_text.trim().is_empty() {
        Vec::new()
    } else {
        split_top(operand_text, ',')
            .iter()
            .map(|o| {
                // accept typed operands (`f32[2]{0} %a`): keep the last token
                let t = o.trim();
                strip_pct(t.rsplit(' ').next().unwrap_or(t)).to_string()
            })
            .collect()
    };
    let attrs = attr_map(rest[close + 1..].trim_start_matches(','));

    let op = match opcode {
        "parameter" => Op::Parameter(
            operand_text
                .trim()
                .parse()
                .with_context(|| format!("{name}: bad parameter number"))?,
        ),
        "constant" => {
            let lit = operand_text.trim();
            match shape.ty {
                PrimType::F32 => Op::ConstF32(
                    lit.parse().with_context(|| format!("{name}: bad f32 constant {lit:?}"))?,
                ),
                PrimType::S32 => Op::ConstS32(
                    lit.parse().with_context(|| format!("{name}: bad s32 constant {lit:?}"))?,
                ),
                PrimType::U32 => Op::ConstU32(
                    lit.parse().with_context(|| format!("{name}: bad u32 constant {lit:?}"))?,
                ),
                PrimType::U64 => Op::ConstU64(
                    lit.parse().with_context(|| format!("{name}: bad u64 constant {lit:?}"))?,
                ),
                PrimType::Pred => Op::ConstPred(lit == "true" || lit == "1"),
            }
        }
        "iota" => Op::Iota {
            dim: req_attr(&attrs, "iota_dimension", "iota")?
                .parse()
                .context("iota_dimension")?,
        },
        "convert" => Op::Convert,
        "exponential" => Op::Unary(UnOp::Exp),
        "tanh" => Op::Unary(UnOp::Tanh),
        "negate" => Op::Unary(UnOp::Neg),
        "add" => Op::Binary(BinOp::Add),
        "subtract" => Op::Binary(BinOp::Sub),
        "multiply" => Op::Binary(BinOp::Mul),
        "divide" => Op::Binary(BinOp::Div),
        "maximum" => Op::Binary(BinOp::Max),
        "minimum" => Op::Binary(BinOp::Min),
        "and" => Op::Binary(BinOp::And),
        "or" => Op::Binary(BinOp::Or),
        "compare" => {
            let dir = match req_attr(&attrs, "direction", "compare")? {
                "EQ" => CmpDir::Eq,
                "NE" => CmpDir::Ne,
                "LT" => CmpDir::Lt,
                "LE" => CmpDir::Le,
                "GT" => CmpDir::Gt,
                "GE" => CmpDir::Ge,
                other => bail!("{name}: bad compare direction {other:?}"),
            };
            Op::Compare(dir)
        }
        "select" => Op::Select,
        "dot" => Op::Dot(DotDims {
            lhs_batch: get_attr(&attrs, "lhs_batch_dims")
                .map(parse_usize_list)
                .transpose()?
                .unwrap_or_default(),
            rhs_batch: get_attr(&attrs, "rhs_batch_dims")
                .map(parse_usize_list)
                .transpose()?
                .unwrap_or_default(),
            lhs_contract: parse_usize_list(req_attr(&attrs, "lhs_contracting_dims", "dot")?)?,
            rhs_contract: parse_usize_list(req_attr(&attrs, "rhs_contracting_dims", "dot")?)?,
        }),
        "reshape" => Op::Reshape,
        "broadcast" => Op::Broadcast(
            get_attr(&attrs, "dimensions")
                .map(parse_usize_list)
                .transpose()?
                .unwrap_or_default(),
        ),
        "transpose" => {
            Op::Transpose(parse_usize_list(req_attr(&attrs, "dimensions", "transpose")?)?)
        }
        "slice" => Op::Slice(parse_slice_attr(req_attr(&attrs, "slice", "slice")?)?),
        "concatenate" => Op::Concatenate(
            parse_usize_list(req_attr(&attrs, "dimensions", "concatenate")?)?
                .first()
                .copied()
                .context("concatenate: empty dimensions")?,
        ),
        "gather" => Op::Gather(GatherDims {
            offset_dims: parse_usize_list(req_attr(&attrs, "offset_dims", "gather")?)?,
            collapsed_slice_dims: parse_usize_list(
                req_attr(&attrs, "collapsed_slice_dims", "gather")?,
            )?,
            start_index_map: parse_usize_list(req_attr(&attrs, "start_index_map", "gather")?)?,
            index_vector_dim: req_attr(&attrs, "index_vector_dim", "gather")?
                .parse()
                .context("index_vector_dim")?,
            slice_sizes: parse_usize_list(req_attr(&attrs, "slice_sizes", "gather")?)?,
        }),
        "reduce" => Op::Reduce {
            dims: parse_usize_list(req_attr(&attrs, "dimensions", "reduce")?)?,
            to_apply: strip_pct(req_attr(&attrs, "to_apply", "reduce")?).to_string(),
        },
        "dynamic-update-slice" => Op::DynamicUpdateSlice,
        "dynamic-slice" => Op::DynamicSlice(parse_usize_list(req_attr(
            &attrs,
            "dynamic_slice_sizes",
            "dynamic-slice",
        )?)?),
        "rng-bit-generator" => {
            let algo = req_attr(&attrs, "algorithm", "rng-bit-generator")?;
            if algo != "rng_threefry" {
                bail!("{name}: unsupported rng algorithm {algo:?} (only rng_threefry)");
            }
            Op::RngBitGenerator
        }
        "get-tuple-element" => Op::GetTupleElement(
            req_attr(&attrs, "index", "get-tuple-element")?
                .parse()
                .with_context(|| format!("{name}: bad tuple index"))?,
        ),
        "tuple" => Op::Tuple,
        other => bail!("unsupported HLO opcode {other:?} (instruction {name})"),
    };
    Ok(Instr { name, shape, tuple_shapes, op, operands, is_root })
}

/// Parse full HLO module text.
pub fn parse_module(text: &str) -> Result<HloModule> {
    let mut module_name = String::from("module");
    let mut computations: HashMap<String, Computation> = HashMap::new();
    let mut entry: Option<String> = None;

    let mut current: Option<(String, bool, Vec<Instr>)> = None;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            module_name = rest
                .trim()
                .split([',', ' '])
                .next()
                .unwrap_or("module")
                .to_string();
            continue;
        }
        if line == "}" {
            let (name, is_entry, instrs) =
                current.take().context("stray '}' outside computation")?;
            let comp = finish_computation(name.clone(), instrs)
                .with_context(|| format!("computation {name}"))?;
            if is_entry {
                entry = Some(name.clone());
            }
            if computations.insert(name.clone(), comp).is_some() {
                bail!("line {}: duplicate computation name {name:?}", lineno + 1);
            }
            continue;
        }
        if line.ends_with('{') {
            let header = line.trim_end_matches('{').trim();
            let is_entry = header.starts_with("ENTRY");
            let header = header.trim_start_matches("ENTRY").trim();
            // `%main.42 (p0: f32[...]) -> ... {` or bare `add {`
            let name = strip_pct(header.split(['(', ' ']).next().unwrap_or(header)).to_string();
            if name.is_empty() {
                bail!("line {}: computation with no name", lineno + 1);
            }
            current = Some((name, is_entry, Vec::new()));
            continue;
        }
        let (_, _, instrs) = current
            .as_mut()
            .with_context(|| format!("line {}: instruction outside computation", lineno + 1))?;
        instrs
            .push(parse_instr(line).with_context(|| format!("line {}: {raw:?}", lineno + 1))?);
    }
    if current.is_some() {
        bail!("unterminated computation");
    }
    let entry = entry
        .or_else(|| {
            // single-computation modules need no ENTRY marker
            if computations.len() == 1 {
                computations.keys().next().cloned()
            } else {
                None
            }
        })
        .context("module has no ENTRY computation")?;
    Ok(HloModule { name: module_name, computations, entry })
}

fn finish_computation(name: String, instrs: Vec<Instr>) -> Result<Computation> {
    if instrs.is_empty() {
        bail!("empty computation");
    }
    let mut names = HashSet::with_capacity(instrs.len());
    for ins in &instrs {
        if !names.insert(ins.name.as_str()) {
            bail!("duplicate instruction name {:?}", ins.name);
        }
    }
    let mut params: Vec<(usize, usize)> = Vec::new();
    for (i, ins) in instrs.iter().enumerate() {
        if let Op::Parameter(n) = ins.op {
            params.push((n, i));
        }
    }
    params.sort_unstable();
    for w in params.windows(2) {
        if w[0].0 == w[1].0 {
            bail!("duplicate parameter number {}", w[0].0);
        }
    }
    for (want, (got, _)) in params.iter().enumerate() {
        if *got != want {
            bail!("parameter numbers not dense: {:?}", params.iter().map(|p| p.0).collect::<Vec<_>>());
        }
    }
    // honor an explicit ROOT marker anywhere in the body; a module with
    // none (or several — malformed) falls back to the last instruction
    let marked: Vec<usize> = instrs
        .iter()
        .enumerate()
        .filter(|(_, i)| i.is_root)
        .map(|(i, _)| i)
        .collect();
    let root = match marked.as_slice() {
        [] => instrs.len() - 1,
        [r] => *r,
        more => bail!("multiple ROOT instructions: {more:?}"),
    };
    Ok(Computation {
        name,
        params: params.into_iter().map(|(_, i)| i).collect(),
        instrs,
        root,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule toy

%red_add_f32 {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main {
  %p0 = f32[2,3]{1,0} parameter(0)
  %c = f32[] constant(2.5)
  %cb = f32[2,3] broadcast(%c), dimensions={}
  %m = f32[2,3] multiply(f32[2,3]{1,0} %p0, %cb)
  %z = f32[] constant(0)
  %r = f32[2] reduce(%m, %z), dimensions={1}, to_apply=%red_add_f32
  ROOT %t = (f32[2,3], f32[2]) tuple(%m, %r)
}
"#;

    #[test]
    fn parses_sample_module() {
        let m = parse_module(SAMPLE).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.entry, "main");
        assert_eq!(m.computations.len(), 2);
        let e = m.entry_computation();
        assert_eq!(e.params.len(), 1);
        let root = &e.instrs[e.root];
        assert!(matches!(root.op, Op::Tuple));
        assert_eq!(root.operands, vec!["m", "r"]);
        assert_eq!(root.tuple_shapes.as_ref().unwrap().len(), 2);
        let red = e.instrs.iter().find(|i| i.name == "r").unwrap();
        match &red.op {
            Op::Reduce { dims, to_apply } => {
                assert_eq!(dims, &[1]);
                assert_eq!(to_apply, "red_add_f32");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_gather_and_slice_attrs() {
        let g = parse_instr(
            "%g = f32[4,16] gather(%emb, %idx), offset_dims={1}, collapsed_slice_dims={0}, \
             start_index_map={0}, index_vector_dim=1, slice_sizes={1,16}",
        )
        .unwrap();
        match &g.op {
            Op::Gather(d) => {
                assert_eq!(d.offset_dims, vec![1]);
                assert_eq!(d.slice_sizes, vec![1, 16]);
            }
            other => panic!("{other:?}"),
        }
        let s =
            parse_instr("%s = f32[1,16] slice(%x), slice={[0:1], [0:16]}").unwrap();
        match &s.op {
            Op::Slice(r) => assert_eq!(r, &vec![(0, 1, 1), (0, 16, 1)]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_dynamic_slice_attrs() {
        let d = parse_instr(
            "%d = f32[1,4] dynamic-slice(%x, %i, %j), dynamic_slice_sizes={1,4}",
        )
        .unwrap();
        match &d.op {
            Op::DynamicSlice(sizes) => assert_eq!(sizes, &vec![1, 4]),
            other => panic!("{other:?}"),
        }
        assert_eq!(d.operands, vec!["x", "i", "j"]);
    }

    #[test]
    fn parses_rng_bit_generator_and_gte() {
        let r = parse_instr(
            "%r = (u64[2], u32[8]) rng-bit-generator(%state), algorithm=rng_threefry",
        )
        .unwrap();
        assert!(matches!(r.op, Op::RngBitGenerator));
        assert_eq!(r.operands, vec!["state"]);
        let shapes = r.tuple_shapes.as_ref().unwrap();
        assert_eq!(shapes[0].ty, PrimType::U64);
        assert_eq!(shapes[1].ty, PrimType::U32);
        assert_eq!(shapes[1].dims, vec![8]);
        let g = parse_instr("%g = u32[8] get-tuple-element(%r), index=1").unwrap();
        assert!(matches!(g.op, Op::GetTupleElement(1)));
        // non-threefry algorithms are a named error, not silence
        let e = parse_instr(
            "%r = (u64[2], u32[8]) rng-bit-generator(%s), algorithm=rng_philox",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("rng_philox"));
    }

    #[test]
    fn unsupported_opcode_is_a_named_error() {
        let e = parse_instr("%x = f32[2] cosine(%y)").unwrap_err();
        assert!(format!("{e:#}").contains("cosine"));
    }

    #[test]
    fn scalar_constant_forms() {
        let c = parse_instr("%c = f32[] constant(-1e9)").unwrap();
        assert!(matches!(c.op, Op::ConstF32(v) if v == -1e9));
        let i = parse_instr("%i = s32[] constant(-3)").unwrap();
        assert!(matches!(i.op, Op::ConstS32(-3)));
    }

    #[test]
    fn rejects_duplicate_computation_name() {
        let e = parse_module(
            "HloModule dup\n\
             %f {\n  ROOT %a = f32[] constant(1)\n}\n\
             %f {\n  ROOT %b = f32[] constant(2)\n}\n\
             ENTRY %main {\n  ROOT %c = f32[] constant(3)\n}\n",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("duplicate computation name"), "{e:#}");
    }

    #[test]
    fn rejects_duplicate_instruction_name() {
        let e = parse_module(
            "ENTRY %main {\n  %x = f32[] constant(1)\n  %x = f32[] constant(2)\n\
             \x20 ROOT %y = f32[] add(%x, %x)\n}\n",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("duplicate instruction name"), "{e:#}");
    }

    #[test]
    fn rejects_duplicate_parameter_number() {
        let e = parse_module(
            "ENTRY %main {\n  %p0 = f32[] parameter(0)\n  %q0 = f32[] parameter(0)\n\
             \x20 ROOT %y = f32[] add(%p0, %q0)\n}\n",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("duplicate parameter number"), "{e:#}");
    }

    #[test]
    fn rejects_non_dense_parameter_numbers() {
        let e = parse_module(
            "ENTRY %main {\n  %p0 = f32[] parameter(0)\n  %p2 = f32[] parameter(2)\n\
             \x20 ROOT %y = f32[] add(%p0, %p2)\n}\n",
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("not dense"), "{e:#}");
    }
}
