//! Static HLO verifier: shape/dtype inference and validation over the
//! parsed IR, run *before* a module is ever evaluated.
//!
//! The parser ([`super::parser`]) trusts declared shapes, and the
//! evaluator ([`super::eval`]) discovers disagreements mid-execution —
//! in the worst case as an index panic. This pass re-derives every
//! instruction's shape and dtype from its operands' *declared* shapes
//! using the same semantics the evaluator implements, and reports every
//! disagreement as a structured [`HloDiag`] naming the computation,
//! instruction and the rule that fired. It also checks dataflow
//! (defined-before-use, duplicate names, dense parameter numbering,
//! unused instructions) and attribute validity (slice bounds,
//! permutations, gather/dot dimension numbers, reduce bodies, rng state
//! shape), and cross-checks a module against its `.io.json` manifest
//! ([`verify_manifest`]).
//!
//! Soundness contract (property-tested in `tests/verify_props.rs`):
//! builder-emitted programs always pass, and a program that passes
//! never panics in `eval` on shape-conforming inputs.
//!
//! The interpreter backend runs this at `Backend::compile`
//! (`backend::interp`), the fixture generator on every emitted
//! executable (`backend::fixture`), and the `fasteagle check` CLI on a
//! whole artifact directory.

use std::collections::{HashMap, HashSet};
use std::fmt;

use anyhow::{bail, Result};

use crate::runtime::manifest::ExecManifest;
use crate::runtime::tensor::Dtype;

use super::layout;
use super::parser::{BinOp, Computation, HloModule, Instr, Op, PrimType, Shape, UnOp};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// One verifier finding, anchored to an instruction.
#[derive(Debug, Clone)]
pub struct HloDiag {
    pub severity: Severity,
    pub computation: String,
    /// offending instruction name (empty for computation-level findings)
    pub instruction: String,
    /// stable rule identifier, e.g. `shape/dot` or `dataflow/undefined`
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for HloDiag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        if self.instruction.is_empty() {
            write!(f, "{sev}[{}] {}: {}", self.rule, self.computation, self.message)
        } else {
            write!(
                f,
                "{sev}[{}] {}/%{}: {}",
                self.rule, self.computation, self.instruction, self.message
            )
        }
    }
}

pub fn has_errors(diags: &[HloDiag]) -> bool {
    diags.iter().any(|d| d.severity == Severity::Error)
}

/// Collapse error-severity diagnostics into one `anyhow` error listing
/// every finding (warnings pass). `what` names the module being checked.
pub fn ensure_ok(what: &str, diags: &[HloDiag]) -> Result<()> {
    let errs: Vec<String> = diags
        .iter()
        .filter(|d| d.severity == Severity::Error)
        .map(HloDiag::to_string)
        .collect();
    if errs.is_empty() {
        return Ok(());
    }
    bail!("{what}: {} HLO verifier error(s):\n  {}", errs.len(), errs.join("\n  "))
}

fn is_tuple_valued(ins: &Instr) -> bool {
    matches!(ins.op, Op::Tuple | Op::RngBitGenerator)
}

/// Ops whose `operands` field holds literal text (a parameter number or
/// a constant literal), not instruction names.
fn has_name_operands(op: &Op) -> bool {
    !matches!(
        op,
        Op::Parameter(_)
            | Op::ConstF32(_)
            | Op::ConstS32(_)
            | Op::ConstU32(_)
            | Op::ConstU64(_)
            | Op::ConstPred(_)
    )
}

struct Ck<'a> {
    module: &'a HloModule,
    comp: &'a Computation,
    diags: &'a mut Vec<HloDiag>,
}

impl Ck<'_> {
    fn push(&mut self, severity: Severity, instruction: &str, rule: &'static str, message: String) {
        self.diags.push(HloDiag {
            severity,
            computation: self.comp.name.clone(),
            instruction: instruction.to_string(),
            rule,
            message,
        });
    }

    fn err(&mut self, ins: &Instr, rule: &'static str, message: String) {
        self.push(Severity::Error, &ins.name, rule, message);
    }
}

/// Verify every computation of a parsed module. Returns all findings;
/// use [`ensure_ok`] / [`has_errors`] to gate on error severity.
pub fn verify_module(module: &HloModule) -> Vec<HloDiag> {
    let mut diags = Vec::new();
    if !module.computations.contains_key(&module.entry) {
        diags.push(HloDiag {
            severity: Severity::Error,
            computation: module.entry.clone(),
            instruction: String::new(),
            rule: "dataflow/entry",
            message: format!("entry computation {:?} not found in module", module.entry),
        });
        return diags;
    }
    let mut names: Vec<&str> = module.computations.keys().map(String::as_str).collect();
    names.sort_unstable();
    for name in names {
        if let Some(comp) = module.computations.get(name) {
            let mut ck = Ck { module, comp, diags: &mut diags };
            verify_computation(&mut ck);
        }
    }
    diags
}

fn verify_computation(ck: &mut Ck<'_>) {
    let comp = ck.comp;
    let mut defined: HashMap<&str, &Instr> = HashMap::with_capacity(comp.instrs.len());
    for ins in &comp.instrs {
        if defined.contains_key(ins.name.as_str()) {
            ck.err(
                ins,
                "dataflow/duplicate-name",
                format!("instruction name {:?} defined more than once", ins.name),
            );
        }
        check_instr(ck, ins, &defined);
        defined.insert(ins.name.as_str(), ins);
    }
    check_params(ck);
    check_reachability(ck);
}

/// Parameter numbers must be dense 0..k and unique (the evaluator binds
/// positionally).
fn check_params(ck: &mut Ck<'_>) {
    let mut nums: Vec<(usize, &Instr)> = Vec::new();
    for ins in &ck.comp.instrs {
        if let Op::Parameter(n) = ins.op {
            nums.push((n, ins));
        }
    }
    nums.sort_by_key(|(n, _)| *n);
    let mut seen = HashSet::new();
    for &(n, ins) in &nums {
        if !seen.insert(n) {
            ck.err(ins, "dataflow/param-numbering", format!("duplicate parameter number {n}"));
        }
        if is_tuple_valued(ins) || ins.tuple_shapes.is_some() {
            ck.err(ins, "tuple/param", "tuple-shaped parameters are unsupported".to_string());
        }
    }
    for (want, &(got, ins)) in nums.iter().enumerate() {
        if got != want && seen.len() == nums.len() {
            ck.err(
                ins,
                "dataflow/param-numbering",
                format!(
                    "parameter numbers not dense: {:?}",
                    nums.iter().map(|(n, _)| *n).collect::<Vec<_>>()
                ),
            );
            break;
        }
    }
}

/// Everything not feeding the root (directly or transitively) is dead;
/// flag it as a warning so drifted emitters get noticed.
fn check_reachability(ck: &mut Ck<'_>) {
    let comp = ck.comp;
    let by_name: HashMap<&str, usize> = comp
        .instrs
        .iter()
        .enumerate()
        .map(|(i, ins)| (ins.name.as_str(), i))
        .collect();
    let mut reached = vec![false; comp.instrs.len()];
    let mut stack = vec![comp.root];
    while let Some(i) = stack.pop() {
        if std::mem::replace(&mut reached[i], true) {
            continue;
        }
        let ins = &comp.instrs[i];
        if !has_name_operands(&ins.op) {
            continue;
        }
        for o in &ins.operands {
            if let Some(&j) = by_name.get(o.as_str()) {
                stack.push(j);
            }
        }
    }
    for (i, ins) in comp.instrs.iter().enumerate() {
        if !reached[i] && !matches!(ins.op, Op::Parameter(_)) {
            ck.push(
                Severity::Warning,
                &ins.name,
                "dataflow/unused",
                "instruction does not feed the root".to_string(),
            );
        }
    }
}

fn ty_of(d: Dtype) -> PrimType {
    match d {
        Dtype::F32 => PrimType::F32,
        Dtype::I32 => PrimType::S32,
    }
}

/// Cross-check a module's entry signature against its `.io.json`
/// manifest: parameter count/shape/dtype and root-tuple outputs. Also
/// rejects output dtypes the host boundary cannot carry (u32/u64/pred —
/// `interp::to_host` only moves f32/s32).
pub fn verify_manifest(module: &HloModule, manifest: &ExecManifest) -> Vec<HloDiag> {
    let mut diags = Vec::new();
    let Some(entry) = module.computations.get(&module.entry) else {
        return diags; // verify_module already reported the missing entry
    };
    let mut mdiag = |instruction: &str, rule: &'static str, message: String| {
        diags.push(HloDiag {
            severity: Severity::Error,
            computation: module.entry.clone(),
            instruction: instruction.to_string(),
            rule,
            message,
        });
    };
    if entry.params.len() != manifest.inputs.len() {
        mdiag(
            "",
            "manifest/params",
            format!(
                "{}: module has {} parameters, manifest lists {} inputs",
                manifest.name,
                entry.params.len(),
                manifest.inputs.len()
            ),
        );
        return diags;
    }
    for (i, spec) in manifest.inputs.iter().enumerate() {
        let p = &entry.instrs[entry.params[i]];
        if p.shape.dims != spec.shape || p.shape.ty != ty_of(spec.dtype) {
            mdiag(
                &p.name.clone(),
                "manifest/params",
                format!(
                    "{}: parameter {i} ({:?}) is {:?}/{:?}, manifest says {:?}/{:?}",
                    manifest.name, spec.name, p.shape.ty, p.shape.dims, spec.dtype, spec.shape
                ),
            );
        }
    }
    let root = &entry.instrs[entry.root];
    let parts: Vec<Shape> = if is_tuple_valued(root) {
        root.tuple_shapes.clone().unwrap_or_default()
    } else {
        vec![root.shape.clone()]
    };
    if parts.len() != manifest.outputs.len() {
        mdiag(
            &root.name.clone(),
            "manifest/outputs",
            format!(
                "{}: root produces {} values, manifest lists {} outputs",
                manifest.name,
                parts.len(),
                manifest.outputs.len()
            ),
        );
        return diags;
    }
    for (i, (part, spec)) in parts.iter().zip(&manifest.outputs).enumerate() {
        if !matches!(part.ty, PrimType::F32 | PrimType::S32) {
            mdiag(
                &root.name.clone(),
                "manifest/output-dtype",
                format!(
                    "{}: output {i} ({:?}) is {:?} — the host boundary carries only f32/s32 \
                     (convert before the root)",
                    manifest.name, spec.name, part.ty
                ),
            );
            continue;
        }
        if part.dims != spec.shape || part.ty != ty_of(spec.dtype) {
            mdiag(
                &root.name.clone(),
                "manifest/outputs",
                format!(
                    "{}: output {i} ({:?}) is {:?}/{:?}, manifest says {:?}/{:?}",
                    manifest.name, spec.name, part.ty, part.dims, spec.dtype, spec.shape
                ),
            );
        }
    }
    diags
}

/// Resolve every operand to its defining instruction, or report the
/// first undefined one and bail out of shape checking for this
/// instruction (dataflow errors would otherwise cascade).
fn resolve<'a>(
    ck: &mut Ck<'_>,
    ins: &Instr,
    defined: &HashMap<&str, &'a Instr>,
) -> Option<Vec<&'a Instr>> {
    let mut out = Vec::with_capacity(ins.operands.len());
    for o in &ins.operands {
        match defined.get(o.as_str()) {
            Some(d) => out.push(*d),
            None => {
                ck.err(
                    ins,
                    "dataflow/undefined",
                    format!("operand {o:?} is not defined before use"),
                );
                return None;
            }
        }
    }
    Some(out)
}

fn want_arity(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr], n: usize) -> bool {
    if ops.len() != n {
        ck.err(
            ins,
            "dataflow/operand-count",
            format!("expected {n} operand(s), got {}", ops.len()),
        );
        return false;
    }
    true
}

fn shape_eq(ck: &mut Ck<'_>, ins: &Instr, rule: &'static str, got: &Shape) {
    if ins.shape.dims != got.dims || ins.shape.ty != got.ty {
        ck.err(
            ins,
            rule,
            format!(
                "declared {:?}/{:?}, inferred {:?}/{:?}",
                ins.shape.ty, ins.shape.dims, got.ty, got.dims
            ),
        );
    }
}

fn check_instr(ck: &mut Ck<'_>, ins: &Instr, defined: &HashMap<&str, &Instr>) {
    // tuple discipline: only `tuple` / `rng-bit-generator` may carry a
    // tuple shape, and tuple-valued instructions are consumable only by
    // get-tuple-element (the evaluator never puts them in `env`)
    if is_tuple_valued(ins) {
        if ins.tuple_shapes.is_none() {
            ck.err(ins, "tuple/shape", "tuple-valued instruction lacks a tuple shape".to_string());
            return;
        }
    } else if ins.tuple_shapes.is_some() {
        ck.err(
            ins,
            "tuple/shape",
            "only tuple/rng-bit-generator may be tuple-shaped".to_string(),
        );
        return;
    }
    if !has_name_operands(&ins.op) {
        check_leaf(ck, ins);
        return;
    }
    let Some(ops) = resolve(ck, ins, defined) else { return };
    for o in &ops {
        if is_tuple_valued(o) && !matches!(ins.op, Op::GetTupleElement(_)) {
            ck.err(
                ins,
                "tuple/discipline",
                format!("operand {:?} is tuple-valued; only get-tuple-element may consume it", o.name),
            );
            return;
        }
    }
    match &ins.op {
        Op::Parameter(_)
        | Op::ConstF32(_)
        | Op::ConstS32(_)
        | Op::ConstU32(_)
        | Op::ConstU64(_)
        | Op::ConstPred(_) => unreachable!("leaf ops handled above"),
        Op::Iota { dim } => check_iota(ck, ins, *dim),
        Op::Convert => check_convert(ck, ins, &ops),
        Op::Unary(u) => check_unary(ck, ins, &ops, *u),
        Op::Binary(b) => check_binary(ck, ins, &ops, *b),
        Op::Compare(_) => check_compare(ck, ins, &ops),
        Op::Select => check_select(ck, ins, &ops),
        Op::Dot(_) => check_dot(ck, ins, &ops),
        Op::Reshape => check_reshape(ck, ins, &ops),
        Op::Broadcast(_) => check_broadcast(ck, ins, &ops),
        Op::Transpose(_) => check_transpose(ck, ins, &ops),
        Op::Slice(_) => check_slice(ck, ins, &ops),
        Op::Concatenate(_) => check_concat(ck, ins, &ops),
        Op::Gather(_) => check_gather(ck, ins, &ops),
        Op::Reduce { .. } => check_reduce(ck, ins, &ops),
        Op::DynamicUpdateSlice => check_dus(ck, ins, &ops),
        Op::DynamicSlice(_) => check_dynamic_slice(ck, ins, &ops),
        Op::RngBitGenerator => check_rng(ck, ins, &ops),
        Op::GetTupleElement(_) => check_gte(ck, ins, &ops),
        Op::Tuple => check_tuple(ck, ins, &ops),
    }
}

/// Leaf ops (parameter / constant): the declared shape is the source of
/// truth, but the literal kind must agree with the declared dtype and
/// iota needs a valid dimension.
fn check_leaf(ck: &mut Ck<'_>, ins: &Instr) {
    let want = match &ins.op {
        Op::ConstF32(_) => Some(PrimType::F32),
        Op::ConstS32(_) => Some(PrimType::S32),
        Op::ConstU32(_) => Some(PrimType::U32),
        Op::ConstU64(_) => Some(PrimType::U64),
        Op::ConstPred(_) => Some(PrimType::Pred),
        _ => None,
    };
    if let Some(w) = want {
        if ins.shape.ty != w {
            ck.err(
                ins,
                "dtype/constant",
                format!("{w:?} literal declared as {:?}", ins.shape.ty),
            );
        }
    }
}

fn check_iota(ck: &mut Ck<'_>, ins: &Instr, dim: usize) {
    if !matches!(ins.shape.ty, PrimType::S32 | PrimType::F32) {
        ck.err(ins, "dtype/iota", format!("unsupported iota element type {:?}", ins.shape.ty));
    }
    if dim >= ins.shape.dims.len() {
        ck.err(
            ins,
            "attr/iota",
            format!("iota_dimension {dim} out of range for rank {}", ins.shape.dims.len()),
        );
    }
}

fn check_convert(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    if a.dims != ins.shape.dims {
        ck.err(
            ins,
            "shape/convert",
            format!("operand dims {:?} != declared {:?}", a.dims, ins.shape.dims),
        );
    }
    use PrimType::*;
    let ok = matches!(
        (a.ty, ins.shape.ty),
        (F32, S32) | (S32, F32) | (Pred, F32) | (Pred, S32) | (U32, F32) | (U32, S32) | (U64, U32)
    ) || a.ty == ins.shape.ty;
    if !ok {
        ck.err(ins, "dtype/convert", format!("unsupported convert {:?} -> {:?}", a.ty, ins.shape.ty));
    }
}

fn check_unary(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr], u: UnOp) {
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    let ok = match u {
        UnOp::Exp | UnOp::Tanh => a.ty == PrimType::F32,
        UnOp::Neg => matches!(a.ty, PrimType::F32 | PrimType::S32),
    };
    if !ok {
        ck.err(ins, "dtype/unary", format!("unsupported unary {u:?} on {:?}", a.ty));
        return;
    }
    shape_eq(ck, ins, "shape/unary", a);
}

fn check_binary(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr], b: BinOp) {
    if !want_arity(ck, ins, ops, 2) {
        return;
    }
    let (x, y) = (&ops[0].shape, &ops[1].shape);
    if x.dims != y.dims || x.ty != y.ty {
        ck.err(
            ins,
            "shape/binary",
            format!("operands {:?}/{:?} vs {:?}/{:?} disagree", x.ty, x.dims, y.ty, y.dims),
        );
        return;
    }
    let ok = match b {
        BinOp::And | BinOp::Or => x.ty == PrimType::Pred,
        _ => matches!(x.ty, PrimType::F32 | PrimType::S32),
    };
    if !ok {
        ck.err(ins, "dtype/binary", format!("unsupported {b:?} on {:?}", x.ty));
        return;
    }
    shape_eq(ck, ins, "shape/binary", x);
}

fn check_compare(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    if !want_arity(ck, ins, ops, 2) {
        return;
    }
    let (x, y) = (&ops[0].shape, &ops[1].shape);
    if x.dims != y.dims || x.ty != y.ty {
        ck.err(
            ins,
            "shape/compare",
            format!("operands {:?}/{:?} vs {:?}/{:?} disagree", x.ty, x.dims, y.ty, y.dims),
        );
        return;
    }
    if !matches!(x.ty, PrimType::F32 | PrimType::S32) {
        ck.err(ins, "dtype/compare", format!("unsupported compare on {:?}", x.ty));
        return;
    }
    shape_eq(ck, ins, "shape/compare", &Shape { ty: PrimType::Pred, dims: x.dims.clone() });
}

fn check_select(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    if !want_arity(ck, ins, ops, 3) {
        return;
    }
    let (p, t, f) = (&ops[0].shape, &ops[1].shape, &ops[2].shape);
    if p.ty != PrimType::Pred {
        ck.err(ins, "dtype/select", format!("predicate is {:?}, want pred", p.ty));
        return;
    }
    if p.dims != t.dims || t.dims != f.dims || t.ty != f.ty {
        ck.err(
            ins,
            "shape/select",
            format!("pred {:?} / branches {:?}:{:?} and {:?}:{:?} disagree", p.dims, t.ty, t.dims, f.ty, f.dims),
        );
        return;
    }
    if !matches!(t.ty, PrimType::F32 | PrimType::S32) {
        ck.err(ins, "dtype/select", format!("unsupported select branch type {:?}", t.ty));
        return;
    }
    shape_eq(ck, ins, "shape/select", t);
}

fn check_dot(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Dot(d) = &ins.op else { return };
    if !want_arity(ck, ins, ops, 2) {
        return;
    }
    let (l, r) = (&ops[0].shape, &ops[1].shape);
    if l.ty != PrimType::F32 || r.ty != PrimType::F32 {
        ck.err(ins, "dtype/dot", format!("dot operands must be f32, got {:?}/{:?}", l.ty, r.ty));
        return;
    }
    // dimension-number validation and the output-shape formula live in
    // `layout::dot_layout`, shared with the evaluator and plan compiler;
    // its "attr"/"shape" split maps onto the diagnostic rules here
    match layout::dot_layout(&l.dims, &r.dims, d) {
        Err(e) => {
            let rule = if e.rule == "attr" { "attr/dot" } else { "shape/dot" };
            ck.err(ins, rule, e.msg);
        }
        Ok(lay) => {
            shape_eq(ck, ins, "shape/dot", &Shape { ty: PrimType::F32, dims: lay.out_dims });
        }
    }
}

fn check_reshape(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    if a.ty != ins.shape.ty {
        ck.err(ins, "dtype/reshape", format!("reshape changes dtype {:?} -> {:?}", a.ty, ins.shape.ty));
    }
    if a.numel() != ins.shape.numel() {
        ck.err(
            ins,
            "shape/reshape",
            format!("numel mismatch: {:?} -> {:?}", a.dims, ins.shape.dims),
        );
    }
}

fn check_broadcast(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Broadcast(mapping) = &ins.op else { return };
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    if a.ty != ins.shape.ty {
        ck.err(ins, "dtype/broadcast", format!("dtype {:?} -> {:?}", a.ty, ins.shape.ty));
    }
    if mapping.len() != a.dims.len() {
        ck.err(
            ins,
            "attr/broadcast",
            format!("dimensions {mapping:?} rank-mismatch input {:?}", a.dims),
        );
        return;
    }
    let mut seen = HashSet::new();
    for (in_d, &out_d) in mapping.iter().enumerate() {
        if out_d >= ins.shape.dims.len() {
            ck.err(
                ins,
                "attr/broadcast",
                format!("mapping entry {out_d} out of range for output rank {}", ins.shape.dims.len()),
            );
            return;
        }
        if !seen.insert(out_d) {
            ck.err(ins, "attr/broadcast", format!("duplicate output dim {out_d} in {mapping:?}"));
            return;
        }
        if a.dims[in_d] != ins.shape.dims[out_d] {
            ck.err(
                ins,
                "shape/broadcast",
                format!("mapping {mapping:?}: input {:?} -> output {:?}", a.dims, ins.shape.dims),
            );
            return;
        }
    }
}

fn check_transpose(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Transpose(perm) = &ins.op else { return };
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    if a.ty != ins.shape.ty {
        ck.err(ins, "dtype/transpose", format!("dtype {:?} -> {:?}", a.ty, ins.shape.ty));
    }
    let rank = a.dims.len();
    if perm.len() != rank {
        ck.err(ins, "attr/transpose", format!("permutation {perm:?} rank-mismatch {:?}", a.dims));
        return;
    }
    let mut seen = vec![false; rank];
    for &p in perm {
        if p >= rank || std::mem::replace(&mut seen[p], true) {
            ck.err(ins, "attr/transpose", format!("{perm:?} is not a permutation of 0..{rank}"));
            return;
        }
    }
    let dims: Vec<usize> = perm.iter().map(|&p| a.dims[p]).collect();
    shape_eq(ck, ins, "shape/transpose", &Shape { ty: a.ty, dims });
}

fn check_slice(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Slice(ranges) = &ins.op else { return };
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let a = &ops[0].shape;
    if a.ty != ins.shape.ty {
        ck.err(ins, "dtype/slice", format!("dtype {:?} -> {:?}", a.ty, ins.shape.ty));
    }
    match layout::slice_output_dims(&a.dims, ranges) {
        Err(msg) => ck.err(ins, "attr/slice", msg),
        Ok(dims) => shape_eq(ck, ins, "shape/slice", &Shape { ty: a.ty, dims }),
    }
}

fn check_concat(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Concatenate(dim) = ins.op else { return };
    let Some(first) = ops.first() else {
        ck.err(ins, "dataflow/operand-count", "concatenate of nothing".to_string());
        return;
    };
    let rank = first.shape.dims.len();
    if dim >= rank {
        ck.err(ins, "attr/concatenate", format!("dimension {dim} out of range for rank {rank}"));
        return;
    }
    let mut dims = first.shape.dims.clone();
    dims[dim] = 0;
    for o in ops {
        let s = &o.shape;
        if s.ty != first.shape.ty || s.dims.len() != rank {
            ck.err(
                ins,
                "shape/concatenate",
                format!("operand {:?} ({:?}/{:?}) disagrees with {:?}", o.name, s.ty, s.dims, first.shape),
            );
            return;
        }
        for d in 0..rank {
            if d != dim && s.dims[d] != first.shape.dims[d] {
                ck.err(
                    ins,
                    "shape/concatenate",
                    format!("non-concat dim {d} differs: {:?} vs {:?}", s.dims, first.shape.dims),
                );
                return;
            }
        }
        dims[dim] += s.dims[dim];
    }
    shape_eq(ck, ins, "shape/concatenate", &Shape { ty: first.shape.ty, dims });
}

fn check_gather(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Gather(g) = &ins.op else { return };
    if !want_arity(ck, ins, ops, 2) {
        return;
    }
    let (op, idx) = (&ops[0].shape, &ops[1].shape);
    if idx.ty != PrimType::S32 {
        ck.err(ins, "dtype/gather", format!("indices must be s32, got {:?}", idx.ty));
        return;
    }
    let op_rank = op.dims.len();
    if g.slice_sizes.len() != op_rank {
        ck.err(
            ins,
            "attr/gather",
            format!("slice_sizes {:?} rank-mismatch operand {:?}", g.slice_sizes, op.dims),
        );
        return;
    }
    for (d, (&sz, &od)) in g.slice_sizes.iter().zip(&op.dims).enumerate() {
        if sz > od {
            ck.err(ins, "attr/gather", format!("slice_sizes[{d}] = {sz} exceeds operand dim {od}"));
            return;
        }
    }
    if g.index_vector_dim > idx.dims.len() {
        ck.err(
            ins,
            "attr/gather",
            format!("index_vector_dim {} out of range for indices rank {}", g.index_vector_dim, idx.dims.len()),
        );
        return;
    }
    let ivd_size = if g.index_vector_dim == idx.dims.len() {
        1
    } else {
        idx.dims[g.index_vector_dim]
    };
    if g.start_index_map.len() != ivd_size {
        ck.err(
            ins,
            "attr/gather",
            format!("start_index_map {:?} vs index vector size {ivd_size}", g.start_index_map),
        );
        return;
    }
    if g.start_index_map.iter().any(|&d| d >= op_rank) {
        ck.err(ins, "attr/gather", format!("start_index_map {:?} out of operand rank {op_rank}", g.start_index_map));
        return;
    }
    let mut collapsed = HashSet::new();
    for &d in &g.collapsed_slice_dims {
        if d >= op_rank || !collapsed.insert(d) {
            ck.err(
                ins,
                "attr/gather",
                format!("bad collapsed_slice_dims {:?} for operand rank {op_rank}", g.collapsed_slice_dims),
            );
            return;
        }
        if g.slice_sizes[d] != 1 {
            ck.err(
                ins,
                "attr/gather",
                format!("collapsed dim {d} must have slice size 1, got {}", g.slice_sizes[d]),
            );
            return;
        }
    }
    let offset_op_dims: Vec<usize> =
        (0..op_rank).filter(|d| !collapsed.contains(d)).collect();
    if offset_op_dims.len() != g.offset_dims.len() {
        ck.err(
            ins,
            "attr/gather",
            format!(
                "offset_dims {:?} vs {} uncollapsed operand dims",
                g.offset_dims,
                offset_op_dims.len()
            ),
        );
        return;
    }
    // expected output: batch dims (indices sans the index-vector dim)
    // interleaved with offset dims carrying the slice sizes
    let out_rank = ins.shape.dims.len();
    let mut offset_set = HashSet::new();
    for &o in &g.offset_dims {
        if o >= out_rank || !offset_set.insert(o) {
            ck.err(ins, "attr/gather", format!("bad offset_dims {:?} for output rank {out_rank}", g.offset_dims));
            return;
        }
    }
    let batch_dims: Vec<usize> = (0..idx.dims.len())
        .filter(|&d| d != g.index_vector_dim)
        .map(|d| idx.dims[d])
        .collect();
    if out_rank != batch_dims.len() + g.offset_dims.len() {
        ck.err(
            ins,
            "shape/gather",
            format!(
                "output rank {out_rank} != {} batch dims + {} offset dims",
                batch_dims.len(),
                g.offset_dims.len()
            ),
        );
        return;
    }
    let mut dims = vec![0usize; out_rank];
    for (&o, &d) in g.offset_dims.iter().zip(&offset_op_dims) {
        dims[o] = g.slice_sizes[d];
    }
    let mut batch_it = batch_dims.iter();
    for (d, slot) in dims.iter_mut().enumerate() {
        if !offset_set.contains(&d) {
            // counts already checked: one batch dim per non-offset slot
            if let Some(&b) = batch_it.next() {
                *slot = b;
            }
        }
    }
    shape_eq(ck, ins, "shape/gather", &Shape { ty: op.ty, dims });
}

fn check_reduce(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::Reduce { dims: red_dims, to_apply } = &ins.op else { return };
    if !want_arity(ck, ins, ops, 2) {
        return;
    }
    let (a, init) = (&ops[0].shape, &ops[1].shape);
    if !init.dims.is_empty() {
        ck.err(ins, "shape/reduce", format!("init value must be scalar, got {:?}", init.dims));
        return;
    }
    if init.ty != a.ty {
        ck.err(
            ins,
            "dtype/reduce",
            format!("init dtype {:?} != operand dtype {:?}", init.ty, a.ty),
        );
        return;
    }
    let mut seen = HashSet::new();
    for &d in red_dims {
        if d >= a.dims.len() || !seen.insert(d) {
            ck.err(ins, "attr/reduce", format!("bad dimensions {red_dims:?} for rank {}", a.dims.len()));
            return;
        }
    }
    // the body must be a plain binary combiner over two scalars of the
    // operand dtype, and a combination the evaluator implements
    match ck.module.computations.get(to_apply) {
        None => {
            ck.err(ins, "reduce/body", format!("reduce body {to_apply:?} missing"));
            return;
        }
        Some(body) => {
            let root = &body.instrs[body.root];
            let combo_ok = match root.op {
                Op::Binary(b) => matches!(
                    (a.ty, b),
                    (PrimType::F32, BinOp::Add | BinOp::Mul | BinOp::Max | BinOp::Min)
                        | (PrimType::S32, BinOp::Add | BinOp::Max | BinOp::Min)
                ),
                _ => false,
            };
            if !combo_ok {
                ck.err(
                    ins,
                    "reduce/body",
                    format!("body {to_apply:?} is not a supported binary combiner for {:?}", a.ty),
                );
                return;
            }
            let params_ok = body.params.len() == 2
                && body.params.iter().all(|&p| {
                    let s = &body.instrs[p].shape;
                    s.dims.is_empty() && s.ty == a.ty
                });
            if !params_ok {
                ck.err(
                    ins,
                    "reduce/body",
                    format!("body {to_apply:?} must take two {:?} scalars", a.ty),
                );
                return;
            }
        }
    }
    let dims = layout::reduce_output_dims(&a.dims, red_dims);
    shape_eq(ck, ins, "shape/reduce", &Shape { ty: a.ty, dims });
}

fn check_dus(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Some(op) = ops.first() else {
        ck.err(ins, "dataflow/operand-count", "dynamic-update-slice needs operands".to_string());
        return;
    };
    let rank = op.shape.dims.len();
    if !want_arity(ck, ins, ops, 2 + rank) {
        return;
    }
    let upd = &ops[1].shape;
    if upd.ty != op.shape.ty {
        ck.err(ins, "dtype/dynamic-update-slice", format!("update {:?} != operand {:?}", upd.ty, op.shape.ty));
        return;
    }
    if upd.dims.len() != rank {
        ck.err(
            ins,
            "shape/dynamic-update-slice",
            format!("update rank {:?} != operand rank {:?}", upd.dims, op.shape.dims),
        );
        return;
    }
    for (&ud, &od) in upd.dims.iter().zip(&op.shape.dims) {
        if ud > od {
            ck.err(
                ins,
                "shape/dynamic-update-slice",
                format!("update {:?} exceeds operand {:?}", upd.dims, op.shape.dims),
            );
            return;
        }
    }
    for s in &ops[2..] {
        if !s.shape.dims.is_empty() || s.shape.ty != PrimType::S32 {
            ck.err(
                ins,
                "shape/dynamic-update-slice",
                format!("start {:?} must be a scalar s32, got {:?}/{:?}", s.name, s.shape.ty, s.shape.dims),
            );
            return;
        }
    }
    shape_eq(ck, ins, "shape/dynamic-update-slice", &op.shape);
}

fn check_dynamic_slice(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::DynamicSlice(sizes) = &ins.op else { return };
    let Some(op) = ops.first() else {
        ck.err(ins, "dataflow/operand-count", "dynamic-slice needs operands".to_string());
        return;
    };
    let rank = op.shape.dims.len();
    if !want_arity(ck, ins, ops, 1 + rank) {
        return;
    }
    if sizes.len() != rank {
        ck.err(
            ins,
            "attr/dynamic-slice",
            format!("dynamic_slice_sizes {sizes:?} rank-mismatch operand {:?}", op.shape.dims),
        );
        return;
    }
    for (d, (&sz, &od)) in sizes.iter().zip(&op.shape.dims).enumerate() {
        if sz > od {
            ck.err(ins, "attr/dynamic-slice", format!("size {sz} exceeds dim {d} ({od})"));
            return;
        }
    }
    for s in &ops[1..] {
        if !s.shape.dims.is_empty() || s.shape.ty != PrimType::S32 {
            ck.err(
                ins,
                "shape/dynamic-slice",
                format!("start {:?} must be a scalar s32, got {:?}/{:?}", s.name, s.shape.ty, s.shape.dims),
            );
            return;
        }
    }
    shape_eq(ck, ins, "shape/dynamic-slice", &Shape { ty: op.shape.ty, dims: sizes.clone() });
}

fn check_rng(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let st = &ops[0].shape;
    if st.ty != PrimType::U64 || st.dims != [2] {
        ck.err(
            ins,
            "rng/state",
            format!("state must be u64[2], got {:?}/{:?}", st.ty, st.dims),
        );
        return;
    }
    let Some(shapes) = &ins.tuple_shapes else { return };
    if shapes.len() != 2
        || shapes[0].ty != PrimType::U64
        || shapes[0].dims != [2]
        || shapes[1].ty != PrimType::U32
    {
        ck.err(
            ins,
            "rng/state",
            "output must be the (u64[2] state, u32[...] bits) tuple".to_string(),
        );
    }
}

fn check_gte(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Op::GetTupleElement(k) = ins.op else { return };
    if !want_arity(ck, ins, ops, 1) {
        return;
    }
    let src = ops[0];
    if !is_tuple_valued(src) {
        ck.err(
            ins,
            "tuple/discipline",
            format!("get-tuple-element source {:?} is not tuple-valued", src.name),
        );
        return;
    }
    let Some(parts) = &src.tuple_shapes else { return };
    let Some(part) = parts.get(k) else {
        ck.err(
            ins,
            "tuple/index",
            format!("tuple index {k} out of range for {} parts", parts.len()),
        );
        return;
    };
    shape_eq(ck, ins, "shape/get-tuple-element", part);
}

fn check_tuple(ck: &mut Ck<'_>, ins: &Instr, ops: &[&Instr]) {
    let Some(parts) = &ins.tuple_shapes else { return };
    if parts.len() != ops.len() {
        ck.err(
            ins,
            "tuple/shape",
            format!("{} declared parts for {} operands", parts.len(), ops.len()),
        );
        return;
    }
    for (part, o) in parts.iter().zip(ops) {
        if part.dims != o.shape.dims || part.ty != o.shape.ty {
            ck.err(
                ins,
                "shape/tuple",
                format!(
                    "part for {:?} declared {:?}/{:?}, operand is {:?}/{:?}",
                    o.name, part.ty, part.dims, o.shape.ty, o.shape.dims
                ),
            );
            return;
        }
    }
}
