//! CPU evaluator for parsed HLO modules.
//!
//! Reference-style, deterministic implementation of the op set the model
//! graphs need (dot, elementwise, reshape/broadcast/transpose,
//! slice/concatenate/gather/dynamic-update-slice, select/compare,
//! exp/tanh, reduce, iota, convert, constant, tuple). Every reduction
//! and dot accumulates in a fixed index order, so results are exactly
//! reproducible across runs and across executables that share rows —
//! the property the lossless-acceptance tests lean on.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::layout::{self, linear, next_index, strides};
use super::parser::{
    BinOp, CmpDir, Computation, DotDims, GatherDims, HloModule, Instr, Op, PrimType, Shape,
    UnOp,
};

#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    U64(Vec<u64>),
    Pred(Vec<bool>),
}

impl Buf {
    pub fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::U32(v) => v.len(),
            Buf::U64(v) => v.len(),
            Buf::Pred(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub(crate) fn ty(&self) -> PrimType {
        match self {
            Buf::F32(_) => PrimType::F32,
            Buf::I32(_) => PrimType::S32,
            Buf::U32(_) => PrimType::U32,
            Buf::U64(_) => PrimType::U64,
            Buf::Pred(_) => PrimType::Pred,
        }
    }
}

/// One evaluated array value.
#[derive(Debug, Clone, PartialEq)]
pub struct Value {
    pub dims: Vec<usize>,
    pub buf: Buf,
}

impl Value {
    // Always-on guards (not debug_assert: the tier-1 build is
    // `--release`, where a silently mis-sized buffer miscomputes).
    // Inside `evaluate` the per-instruction `check_shape` catches
    // mismatches first with the instruction named; these cover direct
    // constructors outside the evaluator.
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Value {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "f32 buffer length does not match shape {dims:?}"
        );
        Value { dims, buf: Buf::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Value {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "s32 buffer length does not match shape {dims:?}"
        );
        Value { dims, buf: Buf::I32(data) }
    }

    pub fn u64(dims: Vec<usize>, data: Vec<u64>) -> Value {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "u64 buffer length does not match shape {dims:?}"
        );
        Value { dims, buf: Buf::U64(data) }
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.buf {
            Buf::F32(v) => Ok(v),
            other => bail!("expected f32 buffer, got {:?}", other.ty()),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.buf {
            Buf::I32(v) => Ok(v),
            other => bail!("expected s32 buffer, got {:?}", other.ty()),
        }
    }

    pub fn u32s(&self) -> Result<&[u32]> {
        match &self.buf {
            Buf::U32(v) => Ok(v),
            other => bail!("expected u32 buffer, got {:?}", other.ty()),
        }
    }

    pub fn u64s(&self) -> Result<&[u64]> {
        match &self.buf {
            Buf::U64(v) => Ok(v),
            other => bail!("expected u64 buffer, got {:?}", other.ty()),
        }
    }

    pub(crate) fn preds(&self) -> Result<&[bool]> {
        match &self.buf {
            Buf::Pred(v) => Ok(v),
            other => bail!("expected pred buffer, got {:?}", other.ty()),
        }
    }
}

pub(crate) fn check_shape(v: &Value, shape: &Shape, what: &str) -> Result<()> {
    if v.dims != shape.dims || v.buf.ty() != shape.ty {
        bail!(
            "{what}: value is {:?}/{:?}, instruction says {:?}/{:?}",
            v.buf.ty(),
            v.dims,
            shape.ty,
            shape.dims
        );
    }
    if v.buf.len() != v.numel() {
        bail!(
            "{what}: buffer holds {} element(s) for shape {:?}",
            v.buf.len(),
            v.dims
        );
    }
    Ok(())
}

pub(crate) fn binary_f32(a: &[f32], b: &[f32], op: BinOp) -> Result<Vec<f32>> {
    let f: fn(f32, f32) -> f32 = match op {
        BinOp::Add => |x, y| x + y,
        BinOp::Sub => |x, y| x - y,
        BinOp::Mul => |x, y| x * y,
        BinOp::Div => |x, y| x / y,
        BinOp::Max => f32::max,
        BinOp::Min => f32::min,
        BinOp::And | BinOp::Or => bail!("logical op on f32"),
    };
    Ok(a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect())
}

pub(crate) fn binary_i32(a: &[i32], b: &[i32], op: BinOp) -> Result<Vec<i32>> {
    let f: fn(i32, i32) -> i32 = match op {
        BinOp::Add => |x, y| x.wrapping_add(y),
        BinOp::Sub => |x, y| x.wrapping_sub(y),
        BinOp::Mul => |x, y| x.wrapping_mul(y),
        BinOp::Div => |x, y| if y == 0 { 0 } else { x.wrapping_div(y) },
        BinOp::Max => i32::max,
        BinOp::Min => i32::min,
        BinOp::And | BinOp::Or => bail!("logical op on s32"),
    };
    Ok(a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect())
}

pub(crate) fn cmp<T: PartialOrd + PartialEq + Copy>(a: &[T], b: &[T], dir: CmpDir) -> Vec<bool> {
    let f: fn(T, T) -> bool = match dir {
        CmpDir::Eq => |x, y| x == y,
        CmpDir::Ne => |x, y| x != y,
        CmpDir::Lt => |x, y| x < y,
        CmpDir::Le => |x, y| x <= y,
        CmpDir::Gt => |x, y| x > y,
        CmpDir::Ge => |x, y| x >= y,
    };
    a.iter().zip(b).map(|(&x, &y)| f(x, y)).collect()
}

/// Resolve a reduce body to its binary op (see
/// [`Computation::as_binary_reducer`]).
pub(crate) fn reducer_of(comp: &Computation) -> Result<BinOp> {
    comp.as_binary_reducer()
        .with_context(|| format!("reduce body {:?} is not a plain binary op", comp.name))
}

/// Evaluate the module's entry computation over positional `args`.
/// Returns the root tuple's parts (a single-element vec for non-tuple
/// roots).
///
/// This is the *naive reference* path: a per-call environment keyed by
/// instruction name, one fresh allocation per op, no fusion, no
/// threading. The interpreter backend's hot path is the compiled
/// [`super::plan::ExecPlan`]; this walk stays as the semantics oracle
/// the plan is property-tested bit-identical against (and as the
/// `FE_INTERP_OPT=0` escape hatch).
pub fn evaluate(module: &HloModule, args: &[Arc<Value>]) -> Result<Vec<Value>> {
    let entry = module.entry_computation();
    if args.len() != entry.params.len() {
        bail!(
            "entry {:?} wants {} parameters, got {}",
            entry.name,
            entry.params.len(),
            args.len()
        );
    }
    let mut env: HashMap<&str, Arc<Value>> = HashMap::with_capacity(entry.instrs.len());
    // tuple-valued instructions (tuple, rng-bit-generator) live here;
    // get-tuple-element projects them back into `env`
    let mut tuples: HashMap<&str, Vec<Arc<Value>>> = HashMap::new();
    let mut root_parts: Option<Vec<Value>> = None;
    for (i, ins) in entry.instrs.iter().enumerate() {
        match &ins.op {
            Op::Tuple => {
                let mut parts = Vec::with_capacity(ins.operands.len());
                for o in &ins.operands {
                    let v = env
                        .get(o.as_str())
                        .with_context(|| format!("tuple operand {o:?} undefined"))?;
                    parts.push(Arc::clone(v));
                }
                if i == entry.root {
                    root_parts = Some(parts.iter().map(|v| (**v).clone()).collect());
                }
                tuples.insert(ins.name.as_str(), parts);
                continue;
            }
            Op::RngBitGenerator => {
                let state_name = ins
                    .operands
                    .first()
                    .with_context(|| format!("{}: rng missing state operand", ins.name))?;
                let state = env
                    .get(state_name.as_str())
                    .with_context(|| format!("rng state {state_name:?} undefined"))?;
                let (new_state, bits) = eval_rng_threefry(state, ins)
                    .with_context(|| format!("instruction {:?}", ins.name))?;
                let parts = vec![Arc::new(new_state), Arc::new(bits)];
                if i == entry.root {
                    root_parts = Some(parts.iter().map(|v| (**v).clone()).collect());
                }
                tuples.insert(ins.name.as_str(), parts);
                continue;
            }
            Op::GetTupleElement(k) => {
                let src = ins
                    .operands
                    .first()
                    .with_context(|| format!("{}: gte missing operand", ins.name))?;
                let parts = tuples.get(src.as_str()).with_context(|| {
                    format!("get-tuple-element source {src:?} is not a tuple")
                })?;
                let v = Arc::clone(parts.get(*k).with_context(|| {
                    format!("{}: tuple index {k} out of range", ins.name)
                })?);
                check_shape(&v, &ins.shape, &ins.name)?;
                env.insert(ins.name.as_str(), v);
                continue;
            }
            _ => {}
        }
        // parameters alias the caller's Arc — bound weights stay pinned
        // and per-call args are staged once at the call boundary, never
        // re-copied per instruction; everything else is fresh
        let v = match &ins.op {
            Op::Parameter(n) => Arc::clone(
                args.get(*n)
                    .with_context(|| format!("parameter {n} out of range"))?,
            ),
            _ => Arc::new(
                eval_instr(module, ins, &env)
                    .with_context(|| format!("instruction {:?}", ins.name))?,
            ),
        };
        check_shape(&v, &ins.shape, &ins.name)?;
        env.insert(ins.name.as_str(), v);
    }
    if let Some(parts) = root_parts {
        return Ok(parts);
    }
    let root = &entry.instrs[entry.root];
    Ok(vec![(**env.get(root.name.as_str()).context("root value missing")?).clone()])
}

fn operand<'e>(
    ins: &Instr,
    n: usize,
    env: &'e HashMap<&str, Arc<Value>>,
) -> Result<&'e Arc<Value>> {
    let name = ins
        .operands
        .get(n)
        .with_context(|| format!("missing operand {n}"))?;
    env.get(name.as_str()).with_context(|| format!("operand {name:?} undefined"))
}

fn eval_instr(
    module: &HloModule,
    ins: &Instr,
    env: &HashMap<&str, Arc<Value>>,
) -> Result<Value> {
    let out_dims = ins.shape.dims.clone();
    Ok(match &ins.op {
        Op::Parameter(_) => unreachable!("parameters aliased in evaluate()"),
        // scalar-literal constants splat to their declared shape, as in
        // real XLA printouts (`f32[128]{0} constant(0)`)
        Op::ConstF32(v) => {
            let n = out_dims.iter().product();
            Value::f32(out_dims, vec![*v; n])
        }
        Op::ConstS32(v) => {
            let n = out_dims.iter().product();
            Value::i32(out_dims, vec![*v; n])
        }
        Op::ConstU32(v) => {
            let n = out_dims.iter().product();
            Value { dims: out_dims, buf: Buf::U32(vec![*v; n]) }
        }
        Op::ConstU64(v) => {
            let n = out_dims.iter().product();
            Value { dims: out_dims, buf: Buf::U64(vec![*v; n]) }
        }
        Op::ConstPred(v) => {
            let n = out_dims.iter().product();
            Value { dims: out_dims, buf: Buf::Pred(vec![*v; n]) }
        }
        Op::Iota { dim } => eval_iota(*dim, ins.shape.ty, out_dims)?,
        Op::Convert => eval_convert(operand(ins, 0, env)?, ins.shape.ty, out_dims)?,
        Op::Unary(u) => eval_unary(operand(ins, 0, env)?, *u, out_dims)?,
        Op::Binary(b) => eval_binary(operand(ins, 0, env)?, operand(ins, 1, env)?, *b, out_dims)?,
        Op::Compare(dir) => {
            eval_compare(operand(ins, 0, env)?, operand(ins, 1, env)?, *dir, out_dims)?
        }
        Op::Select => eval_select(
            operand(ins, 0, env)?,
            operand(ins, 1, env)?,
            operand(ins, 2, env)?,
            out_dims,
        )?,
        Op::Dot(d) => eval_dot(operand(ins, 0, env)?, operand(ins, 1, env)?, d, out_dims)?,
        Op::Reshape => {
            let a = operand(ins, 0, env)?;
            if a.numel() != out_dims.iter().product::<usize>() {
                bail!("reshape numel mismatch: {:?} -> {:?}", a.dims, out_dims);
            }
            Value { dims: out_dims, buf: a.buf.clone() }
        }
        Op::Broadcast(mapping) => eval_broadcast(operand(ins, 0, env)?, mapping, out_dims)?,
        Op::Transpose(perm) => eval_transpose(operand(ins, 0, env)?, perm, out_dims)?,
        Op::Slice(ranges) => eval_slice(operand(ins, 0, env)?, ranges, out_dims)?,
        Op::Concatenate(dim) => {
            let vals: Vec<&Value> = (0..ins.operands.len())
                .map(|i| operand(ins, i, env).map(|v| &**v))
                .collect::<Result<Vec<_>>>()?;
            eval_concat(&vals, *dim, out_dims)?
        }
        Op::Gather(g) => eval_gather(operand(ins, 0, env)?, operand(ins, 1, env)?, g, out_dims)?,
        Op::Reduce { dims, to_apply } => {
            let comp = module
                .computations
                .get(to_apply)
                .with_context(|| format!("reduce body {to_apply:?} missing"))?;
            eval_reduce(
                operand(ins, 0, env)?,
                operand(ins, 1, env)?,
                dims,
                reducer_of(comp)?,
                out_dims,
            )?
        }
        Op::DynamicUpdateSlice => {
            let n_idx = ins.operands.len().saturating_sub(2);
            let mut starts = Vec::with_capacity(n_idx);
            for i in 0..n_idx {
                let s = operand(ins, 2 + i, env)?;
                // one scalar start per dimension, as for dynamic-slice —
                // a vector here is a lowering bug, not data to truncate
                if !s.dims.is_empty() {
                    bail!("dynamic-update-slice start {i} is not a scalar: {:?}", s.dims);
                }
                let v = s.i32s().context("dus start index")?;
                starts.push(*v.first().context("empty dus start")? as i64);
            }
            eval_dus(operand(ins, 0, env)?, operand(ins, 1, env)?, &starts)?
        }
        Op::DynamicSlice(sizes) => {
            let n_idx = ins.operands.len().saturating_sub(1);
            let mut starts = Vec::with_capacity(n_idx);
            for i in 0..n_idx {
                let s = operand(ins, 1 + i, env)?;
                // XLA requires one scalar start per dimension — a vector
                // here is a lowering bug, not something to truncate
                if !s.dims.is_empty() {
                    bail!("dynamic-slice start {i} is not a scalar: {:?}", s.dims);
                }
                let v = s.i32s().context("dynamic-slice start index")?;
                starts.push(*v.first().context("empty dynamic-slice start")? as i64);
            }
            eval_dynamic_slice(operand(ins, 0, env)?, &starts, sizes, out_dims)?
        }
        Op::Tuple | Op::RngBitGenerator | Op::GetTupleElement(_) => {
            unreachable!("tuple-valued ops handled in evaluate()")
        }
    })
}

pub(crate) fn eval_iota(dim: usize, ty: PrimType, out_dims: Vec<usize>) -> Result<Value> {
    if dim >= out_dims.len() {
        bail!("iota_dimension {dim} out of range for rank {}", out_dims.len());
    }
    let st = strides(&out_dims);
    let n: usize = out_dims.iter().product();
    let mut data = vec![0i32; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        loop {
            data[linear(&idx, &st)] = idx[dim] as i32;
            if !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    Ok(match ty {
        PrimType::S32 => Value::i32(out_dims, data),
        PrimType::F32 => Value::f32(out_dims, data.iter().map(|&x| x as f32).collect()),
        other => bail!("unsupported iota element type {other:?}"),
    })
}

pub(crate) fn eval_convert(a: &Value, ty: PrimType, out_dims: Vec<usize>) -> Result<Value> {
    let buf = match (&a.buf, ty) {
        (Buf::F32(v), PrimType::S32) => {
            // XLA convert rounds toward zero
            Buf::I32(v.iter().map(|&x| x as i32).collect())
        }
        (Buf::I32(v), PrimType::F32) => Buf::F32(v.iter().map(|&x| x as f32).collect()),
        (Buf::Pred(v), PrimType::F32) => {
            Buf::F32(v.iter().map(|&x| if x { 1.0 } else { 0.0 }).collect())
        }
        (Buf::Pred(v), PrimType::S32) => Buf::I32(v.iter().map(|&x| x as i32).collect()),
        // rng bits flow into the f32/s32 graph world via convert
        (Buf::U32(v), PrimType::F32) => Buf::F32(v.iter().map(|&x| x as f32).collect()),
        (Buf::U32(v), PrimType::S32) => {
            // XLA integral convert wraps (two's-complement reinterpret)
            Buf::I32(v.iter().map(|&x| x as i32).collect())
        }
        (Buf::U64(v), PrimType::U32) => Buf::U32(v.iter().map(|&x| x as u32).collect()),
        (b, t) if b.ty() == t => b.clone(),
        (b, t) => bail!("unsupported convert {:?} -> {t:?}", b.ty()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_unary(a: &Value, u: UnOp, out_dims: Vec<usize>) -> Result<Value> {
    Ok(match (&a.buf, u) {
        (Buf::F32(v), UnOp::Exp) => Value::f32(out_dims, v.iter().map(|x| x.exp()).collect()),
        (Buf::F32(v), UnOp::Tanh) => {
            Value::f32(out_dims, v.iter().map(|x| x.tanh()).collect())
        }
        (Buf::F32(v), UnOp::Neg) => Value::f32(out_dims, v.iter().map(|x| -x).collect()),
        (Buf::I32(v), UnOp::Neg) => {
            Value::i32(out_dims, v.iter().map(|x| x.wrapping_neg()).collect())
        }
        (b, u) => bail!("unsupported unary {u:?} on {:?}", b.ty()),
    })
}

pub(crate) fn eval_binary(x: &Value, y: &Value, b: BinOp, out_dims: Vec<usize>) -> Result<Value> {
    if x.dims != y.dims {
        bail!("binary operand shapes differ: {:?} vs {:?}", x.dims, y.dims);
    }
    let buf = match (&x.buf, &y.buf) {
        (Buf::F32(a), Buf::F32(c)) => Buf::F32(binary_f32(a, c, b)?),
        (Buf::I32(a), Buf::I32(c)) => Buf::I32(binary_i32(a, c, b)?),
        (Buf::Pred(a), Buf::Pred(c)) => match b {
            BinOp::And => Buf::Pred(a.iter().zip(c).map(|(&p, &q)| p && q).collect()),
            BinOp::Or => Buf::Pred(a.iter().zip(c).map(|(&p, &q)| p || q).collect()),
            other => bail!("unsupported pred binary {other:?}"),
        },
        _ => bail!("mixed-dtype binary"),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_compare(
    x: &Value,
    y: &Value,
    dir: CmpDir,
    out_dims: Vec<usize>,
) -> Result<Value> {
    if x.dims != y.dims {
        bail!("compare shapes differ: {:?} vs {:?}", x.dims, y.dims);
    }
    let preds = match (&x.buf, &y.buf) {
        (Buf::F32(a), Buf::F32(b)) => cmp(a, b, dir),
        (Buf::I32(a), Buf::I32(b)) => cmp(a, b, dir),
        _ => bail!("unsupported compare operand types"),
    };
    Ok(Value { dims: out_dims, buf: Buf::Pred(preds) })
}

pub(crate) fn eval_select(p: &Value, t: &Value, f: &Value, out_dims: Vec<usize>) -> Result<Value> {
    if p.dims != t.dims || t.dims != f.dims {
        bail!("select shapes differ");
    }
    let preds = p.preds()?;
    let buf = match (&t.buf, &f.buf) {
        (Buf::F32(a), Buf::F32(b)) => Buf::F32(
            preds
                .iter()
                .zip(a.iter().zip(b))
                .map(|(&c, (&x, &y))| if c { x } else { y })
                .collect(),
        ),
        (Buf::I32(a), Buf::I32(b)) => Buf::I32(
            preds
                .iter()
                .zip(a.iter().zip(b))
                .map(|(&c, (&x, &y))| if c { x } else { y })
                .collect(),
        ),
        _ => bail!("select branch dtypes differ"),
    };
    Ok(Value { dims: out_dims, buf })
}

/// One Threefry-2x32 block (Salmon et al., 20 rounds) — the
/// deterministic counter-based generator behind `rng-bit-generator`
/// with `algorithm=rng_threefry`.
fn threefry2x32(key: [u32; 2], ctr: [u32; 2]) -> [u32; 2] {
    const ROTS: [[u32; 4]; 2] = [[13, 15, 26, 6], [17, 29, 16, 24]];
    let ks = [key[0], key[1], key[0] ^ key[1] ^ 0x1BD1_1BDA];
    let mut x = [ctr[0].wrapping_add(ks[0]), ctr[1].wrapping_add(ks[1])];
    for group in 0..5u32 {
        let rots = ROTS[(group % 2) as usize];
        for &r in &rots {
            x[0] = x[0].wrapping_add(x[1]);
            x[1] = x[1].rotate_left(r) ^ x[0];
        }
        let g = group as usize;
        x[0] = x[0].wrapping_add(ks[(g + 1) % 3]);
        x[1] = x[1].wrapping_add(ks[(g + 2) % 3].wrapping_add(group + 1));
    }
    x
}

/// `rng-bit-generator(algorithm=rng_threefry)`: XLA-style `u64[2]`
/// state interpreted as `[key, counter]`. Block `i` encrypts
/// `counter + i` under the key, yielding 2×u32 of output; the returned
/// state advances the counter by the number of blocks consumed, so
/// chained calls never reuse a counter (determinism *and*
/// independence). Not bit-compatible with XLA's exact stream — but
/// fully deterministic, which is the property the stack needs.
pub(crate) fn eval_rng_threefry(state: &Value, ins: &Instr) -> Result<(Value, Value)> {
    let st = state.u64s().context("rng state must be u64")?;
    if state.dims != [2] {
        bail!("rng-bit-generator state must be u64[2], got {:?}", state.dims);
    }
    let shapes = ins
        .tuple_shapes
        .as_ref()
        .context("rng-bit-generator must be tuple-shaped (state, bits)")?;
    if shapes.len() != 2 || shapes[0].ty != PrimType::U64 || shapes[0].dims != [2] {
        bail!("rng-bit-generator output 0 must be the u64[2] state");
    }
    let out_shape = &shapes[1];
    if out_shape.ty != PrimType::U32 {
        bail!("rng-bit-generator emits u32 bits, shape says {:?}", out_shape.ty);
    }
    let n: usize = out_shape.dims.iter().product();
    let key = [st[0] as u32, (st[0] >> 32) as u32];
    let blocks = n.div_ceil(2);
    let mut bits = Vec::with_capacity(blocks * 2);
    for i in 0..blocks {
        let c = st[1].wrapping_add(i as u64);
        let out = threefry2x32(key, [c as u32, (c >> 32) as u32]);
        bits.push(out[0]);
        bits.push(out[1]);
    }
    bits.truncate(n);
    let new_state =
        Value { dims: vec![2], buf: Buf::U64(vec![st[0], st[1].wrapping_add(blocks as u64)]) };
    let bits_v = Value { dims: out_shape.dims.clone(), buf: Buf::U32(bits) };
    Ok((new_state, bits_v))
}

pub(crate) fn eval_broadcast(a: &Value, mapping: &[usize], out_dims: Vec<usize>) -> Result<Value> {
    if mapping.len() != a.dims.len() {
        bail!("broadcast dims {:?} rank-mismatch input {:?}", mapping, a.dims);
    }
    let out_st = strides(&out_dims);
    let n: usize = out_dims.iter().product();
    let in_st = strides(&a.dims);
    // per-output-dim input stride (0 when the dim is new)
    let mut eff = vec![0usize; out_dims.len()];
    let mut used = vec![false; out_dims.len()];
    for (in_d, &out_d) in mapping.iter().enumerate() {
        if out_d >= out_dims.len() || a.dims[in_d] != out_dims[out_d] {
            bail!("broadcast mapping {mapping:?}: input {:?} -> output {:?}", a.dims, out_dims);
        }
        if std::mem::replace(&mut used[out_d], true) {
            bail!("broadcast mapping {mapping:?} repeats output dim {out_d}");
        }
        eff[out_d] = in_st[in_d];
    }
    let mut src = vec![0usize; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        loop {
            let o = linear(&idx, &out_st);
            src[o] = idx.iter().zip(&eff).map(|(i, s)| i * s).sum();
            if !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    let buf = match &a.buf {
        Buf::F32(v) => Buf::F32(src.iter().map(|&i| v[i]).collect()),
        Buf::I32(v) => Buf::I32(src.iter().map(|&i| v[i]).collect()),
        Buf::U32(v) => Buf::U32(src.iter().map(|&i| v[i]).collect()),
        Buf::U64(v) => Buf::U64(src.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(src.iter().map(|&i| v[i]).collect()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_transpose(a: &Value, perm: &[usize], out_dims: Vec<usize>) -> Result<Value> {
    if perm.len() != a.dims.len() {
        bail!("transpose perm {:?} rank-mismatch {:?}", perm, a.dims);
    }
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        if p >= a.dims.len() || std::mem::replace(&mut seen[p], true) {
            bail!("transpose {perm:?} is not a permutation of 0..{}", a.dims.len());
        }
    }
    if out_dims.len() != perm.len()
        || perm.iter().enumerate().any(|(i, &p)| out_dims[i] != a.dims[p])
    {
        bail!("transpose output {out_dims:?} inconsistent with input {:?} perm {perm:?}", a.dims);
    }
    let in_st = strides(&a.dims);
    let out_st = strides(&out_dims);
    let n = a.numel();
    let mut src = vec![0usize; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        loop {
            // out index i maps to input dim perm[i]
            let mut in_off = 0usize;
            for (i, &p) in perm.iter().enumerate() {
                in_off += idx[i] * in_st[p];
            }
            src[linear(&idx, &out_st)] = in_off;
            if !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    let buf = match &a.buf {
        Buf::F32(v) => Buf::F32(src.iter().map(|&i| v[i]).collect()),
        Buf::I32(v) => Buf::I32(src.iter().map(|&i| v[i]).collect()),
        Buf::U32(v) => Buf::U32(src.iter().map(|&i| v[i]).collect()),
        Buf::U64(v) => Buf::U64(src.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(src.iter().map(|&i| v[i]).collect()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_slice(
    a: &Value,
    ranges: &[(usize, usize, usize)],
    out_dims: Vec<usize>,
) -> Result<Value> {
    let want = match layout::slice_output_dims(&a.dims, ranges) {
        Ok(w) => w,
        Err(e) => bail!("slice over {:?}: {e}", a.dims),
    };
    if want != out_dims {
        bail!("slice output {out_dims:?} != computed {want:?}");
    }
    let in_st = strides(&a.dims);
    let out_st = strides(&out_dims);
    let n: usize = out_dims.iter().product();
    let mut src = vec![0usize; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        loop {
            let mut in_off = 0usize;
            for (d, &i) in idx.iter().enumerate() {
                in_off += (ranges[d].0 + i * ranges[d].2) * in_st[d];
            }
            src[linear(&idx, &out_st)] = in_off;
            if !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    let buf = match &a.buf {
        Buf::F32(v) => Buf::F32(src.iter().map(|&i| v[i]).collect()),
        Buf::I32(v) => Buf::I32(src.iter().map(|&i| v[i]).collect()),
        Buf::U32(v) => Buf::U32(src.iter().map(|&i| v[i]).collect()),
        Buf::U64(v) => Buf::U64(src.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(src.iter().map(|&i| v[i]).collect()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_concat(vals: &[&Value], dim: usize, out_dims: Vec<usize>) -> Result<Value> {
    let first = vals.first().context("empty concatenate")?;
    let rank = first.dims.len();
    if dim >= rank || out_dims.len() != rank {
        bail!("concatenate dim {dim} out of range");
    }
    let mut total = 0usize;
    for v in vals {
        if v.dims.len() != rank {
            bail!("concatenate rank mismatch: {:?} vs {:?}", v.dims, first.dims);
        }
        for d in 0..rank {
            if d != dim && v.dims[d] != out_dims[d] {
                bail!("concatenate non-concat dim {d} differs: {:?} vs {out_dims:?}", v.dims);
            }
        }
        total += v.dims[dim];
    }
    if total != out_dims[dim] {
        bail!("concatenate dim {dim} sums to {total}, output says {}", out_dims[dim]);
    }
    // outer = product of dims before `dim`; each input contributes a
    // contiguous chunk of (its dim size * inner) per outer step
    let outer: usize = out_dims[..dim].iter().product();
    let inner: usize = out_dims[dim + 1..].iter().product();
    macro_rules! concat_t {
        ($variant:ident, $t:ty, $get:ident) => {{
            let mut out: Vec<$t> = Vec::with_capacity(out_dims.iter().product());
            for o in 0..outer {
                for v in vals {
                    let part = match &v.buf {
                        Buf::$variant(d) => d,
                        _ => bail!("concatenate dtype mismatch"),
                    };
                    let chunk = v.dims[dim] * inner;
                    out.extend_from_slice(&part[o * chunk..(o + 1) * chunk]);
                }
            }
            Buf::$variant(out)
        }};
    }
    let buf = match &first.buf {
        Buf::F32(_) => concat_t!(F32, f32, f32s),
        Buf::I32(_) => concat_t!(I32, i32, i32s),
        Buf::U32(_) => concat_t!(U32, u32, u32s),
        Buf::U64(_) => concat_t!(U64, u64, u64s),
        Buf::Pred(_) => concat_t!(Pred, bool, preds),
    };
    Ok(Value { dims: out_dims, buf })
}

/// Standard HLO gather (the general form, per the XLA semantics doc).
pub(crate) fn eval_gather(
    operand: &Value,
    indices: &Value,
    g: &GatherDims,
    out_dims: Vec<usize>,
) -> Result<Value> {
    let idx_vals = indices.i32s().context("gather indices must be s32")?;
    let op_dims = &operand.dims;
    let op_st = strides(op_dims);
    let idx_st = strides(&indices.dims);
    if g.slice_sizes.len() != op_dims.len() {
        bail!("gather: slice_sizes {:?} rank-mismatch operand {op_dims:?}", g.slice_sizes);
    }
    for (d, (&sz, &od)) in g.slice_sizes.iter().zip(op_dims).enumerate() {
        // also guards the unsigned `od - sz` start-clamp below
        if sz > od {
            bail!("gather: slice_sizes[{d}] = {sz} exceeds operand dim {od}");
        }
    }
    if g.index_vector_dim > indices.dims.len() {
        bail!(
            "gather: index_vector_dim {} out of range for indices rank {}",
            g.index_vector_dim,
            indices.dims.len()
        );
    }
    if g.start_index_map.iter().any(|&d| d >= op_dims.len()) {
        bail!("gather: start_index_map {:?} out of operand rank", g.start_index_map);
    }
    // implicit trailing index-vector dim of size 1
    let ivd_size = if g.index_vector_dim == indices.dims.len() {
        1
    } else {
        indices.dims[g.index_vector_dim]
    };
    if g.start_index_map.len() != ivd_size {
        bail!("gather: start_index_map vs index_vector_dim size mismatch");
    }
    // output dims split into batch dims (from indices) and offset dims
    let out_rank = out_dims.len();
    if g.offset_dims.iter().any(|&o| o >= out_rank) {
        bail!("gather: offset_dims {:?} out of output rank {out_rank}", g.offset_dims);
    }
    let batch_out_dims: Vec<usize> =
        (0..out_rank).filter(|d| !g.offset_dims.contains(d)).collect();
    // offset output dims map, in order, to operand dims not collapsed
    let offset_op_dims: Vec<usize> =
        (0..op_dims.len()).filter(|d| !g.collapsed_slice_dims.contains(d)).collect();
    if offset_op_dims.len() != g.offset_dims.len() {
        bail!("gather: offset_dims vs collapsed_slice_dims mismatch");
    }
    for (&o, &d) in g.offset_dims.iter().zip(&offset_op_dims) {
        if out_dims[o] != g.slice_sizes[d] {
            bail!(
                "gather: output dim {o} is {}, slice size for operand dim {d} is {}",
                out_dims[o],
                g.slice_sizes[d]
            );
        }
    }
    let batch_expect: Vec<usize> = (0..indices.dims.len())
        .filter(|&d| d != g.index_vector_dim)
        .map(|d| indices.dims[d])
        .collect();
    let batch_got: Vec<usize> = batch_out_dims.iter().map(|&d| out_dims[d]).collect();
    if batch_got != batch_expect {
        bail!("gather: output batch dims {batch_got:?} != indices batch dims {batch_expect:?}");
    }

    let n: usize = out_dims.iter().product();
    let mut src = vec![0usize; n];
    if n > 0 {
        let out_st = strides(&out_dims);
        let mut idx = vec![0usize; out_rank];
        loop {
            // batch index into start_indices (insert index_vector_dim)
            let mut start_vec = vec![0i64; ivd_size];
            for (k, sv) in start_vec.iter_mut().enumerate() {
                let mut sidx: Vec<usize> = Vec::with_capacity(indices.dims.len());
                let mut b_it = batch_out_dims.iter().map(|&d| idx[d]);
                for d in 0..indices.dims.len() {
                    if d == g.index_vector_dim {
                        sidx.push(k);
                    } else {
                        sidx.push(b_it.next().context("gather batch rank mismatch")?);
                    }
                }
                *sv = idx_vals[linear(&sidx, &idx_st)] as i64;
            }
            // operand index = clamped start + offset
            let mut op_idx = vec![0usize; op_dims.len()];
            for (k, &d) in g.start_index_map.iter().enumerate() {
                let max_start = (op_dims[d] - g.slice_sizes[d]) as i64;
                op_idx[d] = start_vec[k].clamp(0, max_start) as usize;
            }
            for (&o, &d) in g.offset_dims.iter().zip(&offset_op_dims) {
                op_idx[d] += idx[o];
            }
            src[linear(&idx, &out_st)] = linear(&op_idx, &op_st);
            if !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    let buf = match &operand.buf {
        Buf::F32(v) => Buf::F32(src.iter().map(|&i| v[i]).collect()),
        Buf::I32(v) => Buf::I32(src.iter().map(|&i| v[i]).collect()),
        Buf::U32(v) => Buf::U32(src.iter().map(|&i| v[i]).collect()),
        Buf::U64(v) => Buf::U64(src.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(src.iter().map(|&i| v[i]).collect()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_reduce(
    a: &Value,
    init: &Value,
    red_dims: &[usize],
    op: BinOp,
    out_dims: Vec<usize>,
) -> Result<Value> {
    if let Some(&d) = red_dims.iter().find(|&&d| d >= a.dims.len()) {
        bail!("reduce dimension {d} out of range for rank {}", a.dims.len());
    }
    let kept_dims = layout::reduce_output_dims(&a.dims, red_dims);
    if kept_dims != out_dims {
        bail!("reduce output {out_dims:?} != kept dims {kept_dims:?}");
    }
    // Fast path for the overwhelmingly common form in our lowered
    // graphs: a single f32 reduction over the *last* axis (softmax
    // row-sum/row-max). The input rows are contiguous in row-major
    // order, so each output folds one unit-stride slice — no multi-dim
    // index arithmetic per element. The fold applies the operator in
    // the same ascending element order as the general path below
    // (apply(apply(init, x0), x1)...), so results are bit-identical.
    if red_dims.len() == 1
        && !a.dims.is_empty()
        && red_dims[0] == a.dims.len() - 1
        && a.dims[a.dims.len() - 1] > 0
    {
        if let Buf::F32(data) = &a.buf {
            let fast: Option<fn(f32, f32) -> f32> = match op {
                BinOp::Add => Some(|x, y| x + y),
                BinOp::Max => Some(f32::max),
                BinOp::Min => Some(f32::min),
                _ => None,
            };
            if let Some(apply) = fast {
                let init_v = match &init.buf {
                    Buf::F32(v) => *v.first().context("empty reduce init")?,
                    _ => bail!("reduce init dtype mismatch"),
                };
                let k = a.dims[a.dims.len() - 1];
                let n_out: usize = out_dims.iter().product();
                let mut out = Vec::with_capacity(n_out);
                for row in data.chunks_exact(k) {
                    let mut acc = init_v;
                    for &x in row {
                        acc = apply(acc, x);
                    }
                    out.push(acc);
                }
                return Ok(Value { dims: out_dims, buf: Buf::F32(out) });
            }
        }
    }
    let kept = layout::reduce_kept_axes(a.dims.len(), red_dims);
    let out_st = strides(&out_dims);
    let n_out: usize = out_dims.iter().product();

    macro_rules! reduce_t {
        ($variant:ident, $t:ty, $apply:expr) => {{
            let data = match &a.buf {
                Buf::$variant(v) => v,
                _ => bail!("reduce dtype mismatch"),
            };
            let init_v: $t = match &init.buf {
                Buf::$variant(v) => *v.first().context("empty reduce init")?,
                _ => bail!("reduce init dtype mismatch"),
            };
            let mut out = vec![init_v; n_out];
            if a.numel() > 0 {
                let in_st = strides(&a.dims);
                let mut idx = vec![0usize; a.dims.len()];
                let apply: fn($t, $t) -> $t = $apply;
                loop {
                    let mut o = 0usize;
                    for (k, &d) in kept.iter().enumerate() {
                        o += idx[d] * out_st[k];
                    }
                    out[o] = apply(out[o], data[linear(&idx, &in_st)]);
                    if !next_index(&mut idx, &a.dims) {
                        break;
                    }
                }
            }
            Buf::$variant(out)
        }};
    }
    let buf = match (&a.buf, op) {
        (Buf::F32(_), BinOp::Add) => reduce_t!(F32, f32, |x, y| x + y),
        (Buf::F32(_), BinOp::Mul) => reduce_t!(F32, f32, |x, y| x * y),
        (Buf::F32(_), BinOp::Max) => reduce_t!(F32, f32, f32::max),
        (Buf::F32(_), BinOp::Min) => reduce_t!(F32, f32, f32::min),
        (Buf::I32(_), BinOp::Add) => reduce_t!(I32, i32, |x, y| x.wrapping_add(y)),
        (Buf::I32(_), BinOp::Max) => reduce_t!(I32, i32, i32::max),
        (Buf::I32(_), BinOp::Min) => reduce_t!(I32, i32, i32::min),
        (b, op) => bail!("unsupported reduce {op:?} over {:?}", b.ty()),
    };
    Ok(Value { dims: out_dims, buf })
}

pub(crate) fn eval_dus(operand: &Value, update: &Value, starts: &[i64]) -> Result<Value> {
    if starts.len() != operand.dims.len() || update.dims.len() != operand.dims.len() {
        bail!("dynamic-update-slice rank mismatch");
    }
    for (&od, &ud) in operand.dims.iter().zip(&update.dims) {
        if ud > od {
            bail!("dus update {:?} exceeds operand {:?}", update.dims, operand.dims);
        }
    }
    // XLA semantics: starts are clamped so the update fits
    let clamped: Vec<usize> = starts
        .iter()
        .zip(operand.dims.iter().zip(&update.dims))
        .map(|(&s, (&od, &ud))| s.clamp(0, (od - ud) as i64) as usize)
        .collect();
    let op_st = strides(&operand.dims);
    let up_st = strides(&update.dims);
    macro_rules! dus_t {
        ($variant:ident) => {{
            let mut out = match &operand.buf {
                Buf::$variant(v) => v.clone(),
                _ => bail!("dus dtype mismatch"),
            };
            let upd = match &update.buf {
                Buf::$variant(v) => v,
                _ => bail!("dus update dtype mismatch"),
            };
            if update.numel() > 0 {
                let mut idx = vec![0usize; update.dims.len()];
                loop {
                    let mut o = 0usize;
                    for (d, &i) in idx.iter().enumerate() {
                        o += (clamped[d] + i) * op_st[d];
                    }
                    out[o] = upd[linear(&idx, &up_st)];
                    if !next_index(&mut idx, &update.dims) {
                        break;
                    }
                }
            }
            Buf::$variant(out)
        }};
    }
    let buf = match &operand.buf {
        Buf::F32(_) => dus_t!(F32),
        Buf::I32(_) => dus_t!(I32),
        Buf::U32(_) => dus_t!(U32),
        Buf::U64(_) => dus_t!(U64),
        Buf::Pred(_) => dus_t!(Pred),
    };
    Ok(Value { dims: operand.dims.clone(), buf })
}

/// XLA dynamic-slice: `sizes`-shaped window at runtime `starts`,
/// clamped per dimension so the window fits.
pub(crate) fn eval_dynamic_slice(
    a: &Value,
    starts: &[i64],
    sizes: &[usize],
    out_dims: Vec<usize>,
) -> Result<Value> {
    if starts.len() != a.dims.len() || sizes.len() != a.dims.len() {
        bail!("dynamic-slice rank mismatch");
    }
    if out_dims.as_slice() != sizes {
        bail!("dynamic-slice output {:?} != sizes {:?}", out_dims, sizes);
    }
    for (d, (&sz, &od)) in sizes.iter().zip(&a.dims).enumerate() {
        if sz > od {
            bail!("dynamic-slice size {sz} exceeds dim {d} ({od})");
        }
    }
    // XLA semantics: starts are clamped so the slice fits
    let clamped: Vec<usize> = starts
        .iter()
        .zip(a.dims.iter().zip(sizes))
        .map(|(&s, (&od, &sz))| s.clamp(0, (od - sz) as i64) as usize)
        .collect();
    let in_st = strides(&a.dims);
    let out_st = strides(&out_dims);
    let n: usize = out_dims.iter().product();
    let mut src = vec![0usize; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        loop {
            let mut off = 0usize;
            for (d, &i) in idx.iter().enumerate() {
                off += (clamped[d] + i) * in_st[d];
            }
            src[linear(&idx, &out_st)] = off;
            if out_dims.is_empty() || !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    let buf = match &a.buf {
        Buf::F32(v) => Buf::F32(src.iter().map(|&i| v[i]).collect()),
        Buf::I32(v) => Buf::I32(src.iter().map(|&i| v[i]).collect()),
        Buf::U32(v) => Buf::U32(src.iter().map(|&i| v[i]).collect()),
        Buf::U64(v) => Buf::U64(src.iter().map(|&i| v[i]).collect()),
        Buf::Pred(v) => Buf::Pred(src.iter().map(|&i| v[i]).collect()),
    };
    Ok(Value { dims: out_dims, buf })
}

/// Copy `data` (shape `dims`) into a dense row-major buffer whose axes
/// are the concatenation of the three dimension groups — the blocked
/// [batch, rows, cols] layout the dot inner loop wants.
fn pack_dot_operand(data: &[f32], dims: &[usize], groups: [&[usize]; 3]) -> Vec<f32> {
    let st = strides(dims);
    let perm: Vec<usize> = groups.iter().flat_map(|g| g.iter().copied()).collect();
    let out_dims: Vec<usize> = perm.iter().map(|&p| dims[p]).collect();
    let n: usize = out_dims.iter().product();
    let mut out = vec![0f32; n];
    if n > 0 {
        let mut idx = vec![0usize; out_dims.len()];
        let mut o = 0usize;
        loop {
            let mut off = 0usize;
            for (i, &p) in perm.iter().enumerate() {
                off += idx[i] * st[p];
            }
            out[o] = data[off];
            o += 1;
            if out_dims.is_empty() || !next_index(&mut idx, &out_dims) {
                break;
            }
        }
    }
    out
}

/// General dot per dot_dimension_numbers: output dims are batch dims,
/// then lhs free dims, then rhs free dims.
///
/// Fast path: both operands are packed once into dense [B, M, K] /
/// [B, K, N] layouts, then contracted with a blocked i-k-j inner loop
/// (unit-stride over both the rhs row and the output row, so the
/// compiler vectorizes it) instead of re-deriving multi-dim offsets per
/// multiply — this is what lets `--backend interpret` bench lanes scale
/// past the fixture dims. Each output element still accumulates its K
/// terms in ascending row-major contraction order, so results are
/// bit-identical to the naive reference (and across runs — the property
/// the lossless-acceptance tests lean on).
pub fn eval_dot(lhs: &Value, rhs: &Value, d: &DotDims, out_dims: Vec<usize>) -> Result<Value> {
    let a = lhs.f32s().context("dot lhs must be f32")?;
    let b = rhs.f32s().context("dot rhs must be f32")?;
    let lay = match layout::dot_layout(&lhs.dims, &rhs.dims, d) {
        Ok(l) => l,
        Err(e) => bail!("dot: {e}"),
    };
    if lay.out_dims != out_dims {
        bail!("dot output shape {:?} != computed {:?}", out_dims, lay.out_dims);
    }
    let (bsz, m, k, n) = (lay.bsz(), lay.m(), lay.k(), lay.n());
    let pa = pack_dot_operand(
        a,
        &lhs.dims,
        [d.lhs_batch.as_slice(), lay.lhs_free.as_slice(), d.lhs_contract.as_slice()],
    );
    let pb = pack_dot_operand(
        b,
        &rhs.dims,
        [d.rhs_batch.as_slice(), d.rhs_contract.as_slice(), lay.rhs_free.as_slice()],
    );
    let mut out = vec![0f32; bsz * m * n];
    for bb in 0..bsz {
        let ab = &pa[bb * m * k..(bb + 1) * m * k];
        let bmat = &pb[bb * k * n..(bb + 1) * k * n];
        let ob = &mut out[bb * m * n..(bb + 1) * m * n];
        for i in 0..m {
            let arow = &ab[i * k..(i + 1) * k];
            let orow = &mut ob[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &bmat[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    Ok(Value::f32(out_dims, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::hlo::parser::parse_module;

    fn run(text: &str, args: Vec<Value>) -> Vec<Value> {
        let m = parse_module(text).unwrap();
        let args: Vec<Arc<Value>> = args.into_iter().map(Arc::new).collect();
        evaluate(&m, &args).unwrap()
    }

    #[test]
    fn softmax_building_blocks() {
        let text = r#"
HloModule t
%red_max {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %m = f32[] maximum(%a, %b)
}
%red_add {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
ENTRY %main {
  %x = f32[2,3] parameter(0)
  %ninf = f32[] constant(-1e30)
  %zero = f32[] constant(0)
  %mx = f32[2] reduce(%x, %ninf), dimensions={1}, to_apply=%red_max
  %mb = f32[2,3] broadcast(%mx), dimensions={0}
  %sh = f32[2,3] subtract(%x, %mb)
  %e = f32[2,3] exponential(%sh)
  %se = f32[2] reduce(%e, %zero), dimensions={1}, to_apply=%red_add
  %sb = f32[2,3] broadcast(%se), dimensions={0}
  ROOT %p = f32[2,3] divide(%e, %sb)
}
"#;
        let x = Value::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        let out = run(text, vec![x]);
        let p = out[0].f32s().unwrap();
        let s0: f32 = p[..3].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
        for v in &p[3..] {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn last_axis_reduce_fast_path_is_bit_identical() {
        // values chosen so float addition order matters: the fast path
        // must fold in exactly the general path's ascending order
        let text = r#"
HloModule t
%red_add {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
%red_max {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %m = f32[] maximum(%a, %b)
}
ENTRY %main {
  %x = f32[3,5] parameter(0)
  %zero = f32[] constant(0)
  %ninf = f32[] constant(-1e30)
  %s = f32[3] reduce(%x, %zero), dimensions={1}, to_apply=%red_add
  %mx = f32[3] reduce(%x, %ninf), dimensions={1}, to_apply=%red_max
  ROOT %t = (f32[3], f32[3]) tuple(%s, %mx)
}
"#;
        let data: Vec<f32> = (0..15)
            .map(|i| (i as f32) * 1.000001e-3 + if i % 3 == 0 { 1e7 } else { 0.0 })
            .collect();
        let x = Value::f32(vec![3, 5], data.clone());
        let out = run(text, vec![x]);
        // reference: the general path's fold order, by hand
        for r in 0..3 {
            let row = &data[r * 5..(r + 1) * 5];
            let mut sum = 0.0f32;
            let mut mx = -1e30f32;
            for &v in row {
                sum += v;
                mx = mx.max(v);
            }
            assert_eq!(out[0].f32s().unwrap()[r].to_bits(), sum.to_bits());
            assert_eq!(out[1].f32s().unwrap()[r].to_bits(), mx.to_bits());
        }
    }

    #[test]
    fn dot_matmul_matches_naive() {
        let text = r#"
HloModule t
ENTRY %main {
  %a = f32[2,3] parameter(0)
  %b = f32[3,2] parameter(1)
  ROOT %c = f32[2,2] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let a = Value::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Value::f32(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let out = run(text, vec![a, b]);
        assert_eq!(out[0].f32s().unwrap(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn gather_rows_and_dus_roundtrip() {
        let text = r#"
HloModule t
ENTRY %main {
  %table = f32[4,2] parameter(0)
  %idx = s32[3] parameter(1)
  %g = f32[3,2] gather(%table, %idx), offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, index_vector_dim=1, slice_sizes={1,2}
  %start = s32[] parameter(2)
  %z = s32[] constant(0)
  %upd = f32[4,2] dynamic-update-slice(%table, %g, %start, %z)
  ROOT %t = (f32[3,2], f32[4,2]) tuple(%g, %upd)
}
"#;
        let table = Value::f32(vec![4, 2], vec![0., 1., 10., 11., 20., 21., 30., 31.]);
        let idx = Value::i32(vec![3], vec![2, 0, 3]);
        let start = Value::i32(vec![], vec![1]);
        let out = run(text, vec![table, idx, start]);
        assert_eq!(out[0].f32s().unwrap(), &[20., 21., 0., 1., 30., 31.]);
        // rows 1..4 replaced by the gathered rows
        assert_eq!(
            out[1].f32s().unwrap(),
            &[0., 1., 20., 21., 0., 1., 30., 31.]
        );
    }

    #[test]
    fn iota_select_compare_concat() {
        let text = r#"
HloModule t
ENTRY %main {
  %x = f32[4] parameter(0)
  %i = s32[4] iota(), iota_dimension=0
  %two = s32[] constant(2)
  %tb = s32[4] broadcast(%two), dimensions={}
  %p = pred[4] compare(%i, %tb), direction=LT
  %zero = f32[] constant(0)
  %zb = f32[4] broadcast(%zero), dimensions={}
  %sel = f32[4] select(%p, %x, %zb)
  %t = f32[4] transpose(%sel), dimensions={0}
  ROOT %c = f32[8] concatenate(%sel, %t), dimensions={0}
}
"#;
        let x = Value::f32(vec![4], vec![5., 6., 7., 8.]);
        let out = run(text, vec![x]);
        assert_eq!(out[0].f32s().unwrap(), &[5., 6., 0., 0., 5., 6., 0., 0.]);
    }

    #[test]
    fn splat_constants_fill_their_shape() {
        let text = r#"
HloModule t
ENTRY %main {
  %x = f32[2,3] parameter(0)
  %z = f32[2,3] constant(1.5)
  ROOT %s = f32[2,3] add(%x, %z)
}
"#;
        let x = Value::f32(vec![2, 3], vec![0.5; 6]);
        let out = run(text, vec![x]);
        assert_eq!(out[0].f32s().unwrap(), &[2.0; 6]);
    }

    #[test]
    fn dynamic_slice_windows_and_clamps() {
        let text = r#"
HloModule t
ENTRY %main {
  %x = f32[4,3] parameter(0)
  %i = s32[] parameter(1)
  %j = s32[] parameter(2)
  ROOT %d = f32[2,3] dynamic-slice(%x, %i, %j), dynamic_slice_sizes={2,3}
}
"#;
        let x = Value::f32(
            vec![4, 3],
            (0..12).map(|v| v as f32).collect(),
        );
        // start (1, 0): rows 1..3
        let out = run(
            text,
            vec![x.clone(), Value::i32(vec![], vec![1]), Value::i32(vec![], vec![0])],
        );
        assert_eq!(out[0].f32s().unwrap(), &[3., 4., 5., 6., 7., 8.]);
        // start (9, -5) clamps to (2, 0): rows 2..4
        let out = run(
            text,
            vec![x, Value::i32(vec![], vec![9]), Value::i32(vec![], vec![-5])],
        );
        assert_eq!(out[0].f32s().unwrap(), &[6., 7., 8., 9., 10., 11.]);
    }

    #[test]
    fn dot_with_batch_and_free_dims_matches_hand_value() {
        // [2,1,2] x [2,2,3] batched matmul — exercises the packed fast
        // path's batch handling
        let text = r#"
HloModule t
ENTRY %main {
  %a = f32[2,1,2] parameter(0)
  %b = f32[2,2,3] parameter(1)
  ROOT %c = f32[2,1,3] dot(%a, %b), lhs_batch_dims={0}, rhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_contracting_dims={1}
}
"#;
        let a = Value::f32(vec![2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Value::f32(vec![2, 2, 3], (1..=12).map(|v| v as f32).collect());
        let out = run(text, vec![a, b]);
        // batch 0: [1,2] x [[1,2,3],[4,5,6]] = [9,12,15]
        // batch 1: [3,4] x [[7,8,9],[10,11,12]] = [61,68,75]
        assert_eq!(out[0].f32s().unwrap(), &[9., 12., 15., 61., 68., 75.]);
    }

    #[test]
    fn dus_clamps_start_like_xla() {
        let text = r#"
HloModule t
ENTRY %main {
  %x = f32[4] parameter(0)
  %u = f32[2] parameter(1)
  %s = s32[] parameter(2)
  ROOT %o = f32[4] dynamic-update-slice(%x, %u, %s)
}
"#;
        let x = Value::f32(vec![4], vec![0.; 4]);
        let u = Value::f32(vec![2], vec![1., 2.]);
        let s = Value::i32(vec![], vec![9]); // clamped to 2
        let out = run(text, vec![x, u, s]);
        assert_eq!(out[0].f32s().unwrap(), &[0., 0., 1., 2.]);
    }
}
