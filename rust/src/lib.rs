//! # FastEagle — cascaded drafting for lossless speculative-decoding serving
//!
//! Reproduction of *FastEagle: Cascaded Drafting for Accelerating
//! Speculative Decoding* (Huang et al., 2025) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L1** — Pallas kernels (tree attention, fused cascade MLP), authored
//!   in `python/compile/kernels/`, lowered AOT in interpret mode.
//! * **L2** — JAX target model + drafter graphs (`python/compile/`),
//!   lowered once to HLO text under `artifacts/`.
//! * **L3** — this crate: the serving coordinator (request router,
//!   continuous batcher, paged KV, constrained draft trees, lossless
//!   speculative verification) executing the artifacts via PJRT.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod backend;
pub mod bench;
pub mod cache;
pub mod coordinator;
pub mod draft;
pub mod model;
pub mod obs;
pub mod router;
pub mod runtime;
pub mod spec;
pub mod util;
pub mod workload;
