//! Standard speculative sampling (SpS) baseline: an independent tiny
//! draft LM proposing a chain autoregressively (Leviathan et al. /
//! Chen et al.). No target features are used; the LM consumes the
//! committed tokens themselves.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::{KvCache, MaskRow, TargetModel};
use crate::runtime::ArtifactStore;
use crate::util::rng::{argmax, softmax_temp, Pcg64};

use super::{DraftOutput, Drafter, ObserveArgs};

pub struct SpsDrafter {
    lm: TargetModel,
    skv: KvCache,
    chain: usize,
    has_ctx: bool,
    rng: Pcg64,
}

impl SpsDrafter {
    pub fn new(store: Rc<ArtifactStore>) -> Result<SpsDrafter> {
        let lm = TargetModel::open_sps(store)?;
        let skv = lm.new_kv()?;
        let chain = lm.spec.sps_chain;
        Ok(SpsDrafter { lm, skv, chain, has_ctx: false, rng: Pcg64::new(0x595, 0) })
    }
}

impl Drafter for SpsDrafter {
    fn name(&self) -> &str {
        "sps"
    }

    fn depth(&self) -> usize {
        self.chain
    }

    fn kv_layers(&self) -> usize {
        self.lm.spec.sps.n_layers
    }

    fn reset(&mut self) -> Result<()> {
        self.skv = self.lm.new_kv()?;
        self.has_ctx = false;
        Ok(())
    }

    fn observe(&mut self, a: ObserveArgs<'_>) -> Result<()> {
        // Feed the committed anchor tokens through the draft LM.
        let mut done = 0usize;
        let n = a.anchor_tokens.len();
        while done < n {
            let base = self.skv.len(0);
            let take = (n - done).min(32);
            let toks = &a.anchor_tokens[done..done + take];
            let positions: Vec<i32> =
                (0..take).map(|i| (a.first_pos + done + i) as i32).collect();
            let rows: Vec<MaskRow> = (0..take)
                .map(|i| MaskRow { prefix_upto: base + i + 1, extra: vec![] })
                .collect();
            let _ = self.lm.step(&mut self.skv, toks, &positions, &rows)?;
            self.skv.set_len(0, base + take);
            done += take;
        }
        self.has_ctx = true;
        Ok(())
    }

    fn draft(
        &mut self,
        pending: i32,
        anchor_pos: usize,
        temperature: f32,
        max_levels: usize,
    ) -> Result<DraftOutput> {
        if !self.has_ctx {
            return Err(anyhow::anyhow!("draft before observe")).context("sps");
        }
        let base = self.skv.len(0);
        // each chain link costs one draft-LM step — stop at the plan's
        // depth instead of drafting links the tree would drop
        let chain = self.chain.min(max_levels);
        let mut tokens = Vec::with_capacity(chain);
        let mut dists = Vec::with_capacity(chain);
        let mut cur = pending;
        // temp slots base, base+1, ... — rolled back by restoring len
        for s in 0..chain {
            let pos = ((anchor_pos + 1 + s) as i32).min(self.lm.spec.max_seq as i32 - 1);
            let rows = [MaskRow { prefix_upto: base + s + 1, extra: vec![] }];
            self.skv.set_len(0, base + s);
            let out = self.lm.step(&mut self.skv, &[cur], &[pos], &rows)?;
            let mut q = out.logits;
            softmax_temp(&mut q, temperature);
            // the classic SpS chain samples each link from q (greedy in
            // the T=0 limit) — required for exact losslessness
            let tok = if temperature <= 0.0 {
                argmax(&q) as i32
            } else {
                self.rng.categorical(&q) as i32
            };
            tokens.push(tok);
            dists.push(q);
            cur = tok;
        }
        self.skv.set_len(0, base); // rollback temps
        Ok(DraftOutput::Chain(tokens, dists))
    }
}
