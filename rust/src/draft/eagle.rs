//! EAGLE baseline drafters: a single decoder layer that drafts
//! *autoregressively* — a depth-N draft costs 1 `observe` byproduct
//! (level 1) plus N−1 sequential `eg_next` executable calls. This is the
//! per-cycle latency chain FastEagle removes.
//!
//! Two variants share the `eg_next` graph:
//! * `eagle3` — multi-level (l,m,h) feature input, rollout-trained
//!   (EAGLE-3-like; the paper's strongest baseline).
//! * `eagle2` — top-feature-only input, teacher-forced training
//!   (EAGLE-2-like; degrades at depth, Fig. 3).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::{build_mask, KvCache, MaskRow, ModelSpec};
use crate::runtime::tensor::HostTensor;
use crate::runtime::ArtifactStore;
use crate::util::rng::{argmax, softmax_temp};

use super::fasteagle::chunk_plan;
use super::{DraftOutput, Drafter, ObserveArgs};

pub struct EagleDrafter {
    store: Rc<ArtifactStore>,
    spec: ModelSpec,
    wset: String,
    first_prefix: &'static str,
    multi_level: bool,
    ekv: KvCache,
    /// hidden state of the newest anchor (the drafter's f̂ for the
    /// pending token)
    h_last: Vec<f32>,
    /// level-1 draft logits (byproduct of observe)
    q1_logits: Vec<f32>,
    has_pending: bool,
}

impl EagleDrafter {
    pub fn new(store: Rc<ArtifactStore>, wset: &str, multi_level: bool) -> Result<EagleDrafter> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        let ekv = KvCache::zeros(vec![2, 1, spec.max_seq, spec.n_kv_heads, spec.head_dim])?;
        Ok(EagleDrafter {
            store,
            spec,
            wset: wset.to_string(),
            first_prefix: if multi_level { "eg3_first" } else { "eg2_first" },
            multi_level,
            ekv,
            h_last: Vec::new(),
            q1_logits: Vec::new(),
            has_pending: false,
        })
    }

    fn feat_in_dim(&self) -> usize {
        if self.multi_level {
            self.spec.feat_dim
        } else {
            self.spec.d_model
        }
    }

    /// Slice the engine-provided multi-level features down to this
    /// variant's input (eagle2 only sees the top tap).
    fn slice_feats(&self, feats: &[f32], n: usize) -> Vec<f32> {
        let fd = self.spec.feat_dim;
        if self.multi_level {
            feats[..n * fd].to_vec()
        } else {
            let d = self.spec.d_model;
            let mut out = Vec::with_capacity(n * d);
            for i in 0..n {
                out.extend_from_slice(&feats[i * fd + 2 * d..(i + 1) * fd]);
            }
            out
        }
    }
}

impl EagleDrafter {
    /// Batch-engine admission support: expose the per-request state so
    /// it can be copied into a batched state tensor slot.
    pub fn state(&self) -> (&KvCache, &[f32], &[f32]) {
        (&self.ekv, &self.h_last, &self.q1_logits)
    }
}

impl Drafter for EagleDrafter {
    fn name(&self) -> &str {
        &self.wset
    }

    fn depth(&self) -> usize {
        self.spec.draft_depth
    }

    fn kv_layers(&self) -> usize {
        1
    }

    fn reset(&mut self) -> Result<()> {
        self.ekv = KvCache::zeros(self.ekv.tensor().shape.clone())?;
        self.has_pending = false;
        Ok(())
    }

    fn observe(&mut self, a: ObserveArgs<'_>) -> Result<()> {
        let fin = self.feat_in_dim();
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        let n = a.anchor_tokens.len();
        let sliced = self.slice_feats(a.feats, n);
        let mut done = 0usize;
        for t in chunk_plan(n) {
            let real = (n - done).min(t);
            let ctx = self.ekv.len(0);
            let mut feats = vec![0.0f32; t * fin];
            feats[..real * fin].copy_from_slice(&sliced[done * fin..(done + real) * fin]);
            let mut toks = vec![self.spec.pad; t];
            toks[..real].copy_from_slice(&a.next_tokens[done..done + real]);
            let mut pos = vec![0i32; t];
            for i in 0..t {
                let p = (a.first_pos + done + i.min(real.saturating_sub(1))) as i32;
                pos[i] = p.min(self.spec.max_seq as i32 - 1);
            }
            let rows: Vec<MaskRow> = (0..real)
                .map(|i| MaskRow { prefix_upto: ctx + i + 1, extra: vec![] })
                .collect();
            let mask = build_mask(t, c, &rows);
            let feats_t = HostTensor::f32(vec![1, t, fin], feats);
            let toks_t = HostTensor::i32(vec![1, t], toks);
            let pos_t = HostTensor::i32(vec![1, t], pos);
            let ctx_t = HostTensor::i32(vec![1], vec![ctx as i32]);
            let exec = self
                .store
                .bind(&format!("{}_t{}", self.first_prefix, t), &self.wset)?;
            let outs = exec.call(
                &self.store.runtime,
                &[
                    ("feat_in", &feats_t),
                    ("tokens", &toks_t),
                    ("anchor_pos", &pos_t),
                    ("mask", &mask),
                    ("ctx_len", &ctx_t),
                    ("ekv", self.ekv.tensor()),
                ],
            )?;
            let li = exec.out_idx("logits")?;
            let hi = exec.out_idx("h")?;
            let ki = exec.out_idx("ekv")?;
            let row = real - 1;
            self.q1_logits = outs[li].as_f32()?[row * v..(row + 1) * v].to_vec();
            self.h_last = outs[hi].as_f32()?[row * d..(row + 1) * d].to_vec();
            self.has_pending = true;
            let mut outs = outs;
            self.ekv.update_from(outs.swap_remove(ki))?;
            self.ekv.set_len(0, ctx + real);
            done += real;
        }
        Ok(())
    }

    fn draft(
        &mut self,
        _pending: i32,
        anchor_pos: usize,
        temperature: f32,
        max_levels: usize,
    ) -> Result<DraftOutput> {
        if !self.has_pending {
            return Err(anyhow::anyhow!("draft before observe")).context("eagle");
        }
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        // each level past the first costs one sequential eg_next call —
        // stop at the plan's depth instead of drafting throwaway levels
        let n_levels = self.spec.draft_depth.min(max_levels);
        if n_levels == 0 {
            return Ok(DraftOutput::Levels(Vec::new()));
        }
        let mut dists = Vec::with_capacity(n_levels);
        let mut q1 = self.q1_logits.clone();
        softmax_temp(&mut q1, temperature);
        dists.push(q1);
        // N-1 sequential autoregressive steps over temporary entries at
        // slots ctx, ctx+1, ... (rolled back by simply not advancing len)
        let mut h = self.h_last.clone();
        let exec = self.store.bind("eg_next_t1", &self.wset)?;
        let ctx = self.ekv.len(0);
        let mut ekv_tmp = self.ekv.clone();
        for s in 1..n_levels {
            let backbone_tok = argmax(&dists[s - 1]) as i32;
            let pos = ((anchor_pos + s) as i32).min(self.spec.max_seq as i32 - 1);
            let rows = [MaskRow { prefix_upto: ctx + s, extra: vec![] }];
            let mask = build_mask(1, c, &rows);
            let h_t = HostTensor::f32(vec![1, 1, d], h.clone());
            let tok_t = HostTensor::i32(vec![1, 1], vec![backbone_tok]);
            let pos_t = HostTensor::i32(vec![1, 1], vec![pos]);
            let ctx_t = HostTensor::i32(vec![1], vec![(ctx + s - 1) as i32]);
            let outs = exec.call(
                &self.store.runtime,
                &[
                    ("feat_in", &h_t),
                    ("tokens", &tok_t),
                    ("anchor_pos", &pos_t),
                    ("mask", &mask),
                    ("ctx_len", &ctx_t),
                    ("ekv", ekv_tmp.tensor()),
                ],
            )?;
            let li = exec.out_idx("logits")?;
            let hi = exec.out_idx("h")?;
            let ki = exec.out_idx("ekv")?;
            let mut q = outs[li].as_f32()?[..v].to_vec();
            softmax_temp(&mut q, temperature);
            dists.push(q);
            h = outs[hi].as_f32()?[..d].to_vec();
            let mut outs = outs;
            ekv_tmp.update_from(outs.swap_remove(ki))?;
        }
        // ekv_tmp (with temp rows) is dropped: rollback by construction.
        Ok(DraftOutput::Levels(dists))
    }
}
