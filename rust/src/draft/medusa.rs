//! Medusa baseline: K independent MLP heads predicting positions
//! t+2..t+1+K from the anchor's multi-level feature. Stateless (no
//! drafter KV), single executable call per cycle, but no hierarchical
//! refinement — the paper's Table 1/2 show why the cascade wins.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::ModelSpec;
use crate::runtime::tensor::HostTensor;
use crate::runtime::ArtifactStore;
use crate::util::rng::softmax_temp;

use super::{DraftOutput, Drafter, ObserveArgs};

pub struct MedusaDrafter {
    store: Rc<ArtifactStore>,
    spec: ModelSpec,
    anchor_feat: Vec<f32>,
    has_pending: bool,
}

impl MedusaDrafter {
    pub fn new(store: Rc<ArtifactStore>) -> Result<MedusaDrafter> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        Ok(MedusaDrafter { store, spec, anchor_feat: Vec::new(), has_pending: false })
    }
}

impl Drafter for MedusaDrafter {
    fn name(&self) -> &str {
        "medusa"
    }

    fn depth(&self) -> usize {
        self.spec.medusa_heads
    }

    fn kv_layers(&self) -> usize {
        0
    }

    fn reset(&mut self) -> Result<()> {
        self.has_pending = false;
        Ok(())
    }

    fn observe(&mut self, a: ObserveArgs<'_>) -> Result<()> {
        let fd = self.spec.feat_dim;
        let n = a.anchor_tokens.len();
        self.anchor_feat = a.feats[(n - 1) * fd..n * fd].to_vec();
        self.has_pending = true;
        Ok(())
    }

    fn draft(
        &mut self,
        _pending: i32,
        _anchor_pos: usize,
        temperature: f32,
        max_levels: usize,
    ) -> Result<DraftOutput> {
        if !self.has_pending {
            return Err(anyhow::anyhow!("draft before observe")).context("medusa");
        }
        // one head bank call emits every head; the plan bounds how many
        // head distributions are materialized
        let (v, k) = (self.spec.vocab, self.spec.medusa_heads.min(max_levels));
        let feats_t =
            HostTensor::f32(vec![1, 1, self.spec.feat_dim], self.anchor_feat.clone());
        let exec = self.store.bind("medusa", "medusa")?;
        let outs = exec.call(&self.store.runtime, &[("feats", &feats_t)])?;
        let l = outs[exec.out_idx("logits")?].as_f32()?;
        let dists = (0..k)
            .map(|i| {
                let mut d = l[i * v..(i + 1) * v].to_vec();
                softmax_temp(&mut d, temperature);
                d
            })
            .collect();
        Ok(DraftOutput::Levels(dists))
    }
}
