//! No drafter: plain autoregressive decoding (the speedup baseline all
//! methods are normalized against).

use anyhow::Result;

use super::{DraftOutput, Drafter, ObserveArgs};

#[derive(Default)]
pub struct VanillaDrafter;

impl VanillaDrafter {
    pub fn new() -> VanillaDrafter {
        VanillaDrafter
    }
}

impl Drafter for VanillaDrafter {
    fn name(&self) -> &str {
        "vanilla"
    }

    fn depth(&self) -> usize {
        0
    }

    fn kv_layers(&self) -> usize {
        0
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }

    fn observe(&mut self, _a: ObserveArgs<'_>) -> Result<()> {
        Ok(())
    }

    fn draft(
        &mut self,
        _pending: i32,
        _anchor_pos: usize,
        _t: f32,
        _max_levels: usize,
    ) -> Result<DraftOutput> {
        Ok(DraftOutput::None)
    }
}
