//! Drafter implementations behind a common trait.
//!
//! Shared engine↔drafter contract (see also `python/compile/drafters.py`):
//!
//! * After every verification the engine calls `observe` with the
//!   newly-committed tokens: for each new anchor position j it passes the
//!   target's verified feature f_j and the next token (token_{j+1}, with
//!   the pending/bonus token closing the last pair). Drafters with KV
//!   state append **permanent** context entries built from these real
//!   features — EAGLE-3's design philosophy, and what makes FastEagle's
//!   anchors training-consistent.
//! * `draft` produces the per-level draft distributions for the next
//!   cycle. FastEagle emits all N in a single pass (the cascade already
//!   ran over the anchors during `observe` — zero extra forward passes);
//!   EAGLE needs N−1 further sequential `eg_next` calls; SpS runs its own
//!   LM autoregressively; Medusa is a stateless head bank; Vanilla
//!   drafts nothing.

pub mod eagle;
pub mod fasteagle;
pub mod medusa;
pub mod sps;
pub mod vanilla;

use anyhow::Result;

pub use eagle::EagleDrafter;
pub use fasteagle::FastEagleDrafter;
pub use medusa::MedusaDrafter;
pub use sps::SpsDrafter;
pub use vanilla::VanillaDrafter;

/// What a drafter proposes for one cycle.
#[derive(Debug, Clone)]
pub enum DraftOutput {
    /// Per-level distributions (already temperature-adjusted) for
    /// Backbone Expansion.
    Levels(Vec<Vec<f32>>),
    /// A pre-sampled chain (token per level) plus the distribution each
    /// token was drawn from (needed for lossless acceptance).
    Chain(Vec<i32>, Vec<Vec<f32>>),
    /// No draft (vanilla decoding).
    None,
}

/// One new-anchor batch for `observe`.
pub struct ObserveArgs<'a> {
    /// [n, feat_dim] verified target features of the anchors
    pub feats: &'a [f32],
    /// the anchor tokens themselves (committed), length n
    pub anchor_tokens: &'a [i32],
    /// token_{j+1} per anchor (last = the pending token), length n
    pub next_tokens: &'a [i32],
    /// token position of the first anchor
    pub first_pos: usize,
}

pub trait Drafter {
    fn name(&self) -> &str;
    /// draft-tree depth this drafter supports
    fn depth(&self) -> usize;
    /// KV layers held per request (paged-pool accounting; Table 3)
    fn kv_layers(&self) -> usize;
    fn reset(&mut self) -> Result<()>;
    fn observe(&mut self, args: ObserveArgs<'_>) -> Result<()>;
    /// `temperature` shapes the emitted distributions; `anchor_pos` is
    /// the position of the pending token's predecessor; `max_levels` is
    /// the cycle's planned depth — drafters that pay per level (EAGLE's
    /// sequential `eg_next` calls, SpS's LM steps) stop there instead
    /// of drafting levels the plan would throw away.
    fn draft(
        &mut self,
        pending: i32,
        anchor_pos: usize,
        temperature: f32,
        max_levels: usize,
    ) -> Result<DraftOutput>;
}

/// Construct any drafter by its weight-set name.
pub fn make_drafter(
    store: std::rc::Rc<crate::runtime::ArtifactStore>,
    name: &str,
) -> Result<Box<dyn Drafter>> {
    Ok(match name {
        "fasteagle" => Box::new(FastEagleDrafter::new(store, "fasteagle", "fe")?),
        "fasteagle_nofeat" => {
            Box::new(FastEagleDrafter::new(store, "fasteagle_nofeat", "fe")?)
        }
        "fasteagle_par" => Box::new(FastEagleDrafter::new(store, "fasteagle_par", "fe_par")?),
        "eagle3" => Box::new(EagleDrafter::new(store, "eagle3", true)?),
        "eagle2" => Box::new(EagleDrafter::new(store, "eagle2", false)?),
        "medusa" => Box::new(MedusaDrafter::new(store)?),
        "sps" => Box::new(SpsDrafter::new(store)?),
        "vanilla" => Box::new(VanillaDrafter::new()),
        other => anyhow::bail!("unknown drafter {other:?}"),
    })
}
