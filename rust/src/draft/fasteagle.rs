//! FastEagle: the paper's cascaded non-autoregressive drafter.
//!
//! The N-layer cascade runs over the anchor entries during `observe` —
//! layer i's hidden state at the newest anchor already *is* the draft
//! distribution q_{t+i} (paper eqs. 1–2). `draft` therefore costs zero
//! additional forward passes: the entire depth-N draft came out of one
//! executable call, versus EAGLE's N sequential calls. That single-pass
//! structure is the paper's headline contribution.
//!
//! The same struct also serves the two §3.2 training ablations (they
//! share executables, only weights differ) and the "w/o Cascaded
//! Structure" ablation via the `fe_par_*` parallel-head executables.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::model::{build_mask, KvCache, MaskRow, ModelSpec};
use crate::runtime::tensor::HostTensor;
use crate::runtime::ArtifactStore;
use crate::util::rng::softmax_temp;

use super::{DraftOutput, Drafter, ObserveArgs};

pub struct FastEagleDrafter {
    store: Rc<ArtifactStore>,
    spec: ModelSpec,
    wset: String,
    exec_prefix: &'static str,
    dkv: KvCache,
    /// [N, V] logits of the newest anchor's cascade layers
    pending_logits: Vec<f32>,
    has_pending: bool,
}

/// Greedy chunk sizes matching the lowered `*_t{32,8,1}` executables.
pub(crate) fn chunk_plan(mut n: usize) -> Vec<usize> {
    let mut plan = Vec::new();
    while n > 0 {
        // Prefer the largest executable that stays mostly full: a 32-row
        // call only pays off above 8 real rows.
        let t = if n > 8 { 32 } else if n > 1 { 8 } else { 1 };
        plan.push(t);
        n = n.saturating_sub(t);
    }
    plan
}

impl FastEagleDrafter {
    pub fn new(
        store: Rc<ArtifactStore>,
        wset: &str,
        exec_prefix: &'static str,
    ) -> Result<FastEagleDrafter> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        let dkv = KvCache::zeros(vec![
            spec.draft_depth,
            2,
            1,
            spec.max_seq,
            spec.n_kv_heads,
            spec.head_dim,
        ])?;
        Ok(FastEagleDrafter {
            store,
            spec,
            wset: wset.to_string(),
            exec_prefix,
            dkv,
            pending_logits: Vec::new(),
            has_pending: false,
        })
    }
}

impl FastEagleDrafter {
    /// Batch-engine admission support: expose the per-request state so
    /// it can be copied into a batched state tensor slot.
    pub fn state(&self) -> (&KvCache, &[f32]) {
        (&self.dkv, &self.pending_logits)
    }
}

impl Drafter for FastEagleDrafter {
    fn name(&self) -> &str {
        &self.wset
    }

    fn depth(&self) -> usize {
        self.spec.draft_depth
    }

    fn kv_layers(&self) -> usize {
        self.spec.draft_depth
    }

    fn reset(&mut self) -> Result<()> {
        self.dkv = KvCache::zeros(self.dkv.tensor().shape.clone())?;
        self.has_pending = false;
        Ok(())
    }

    fn observe(&mut self, a: ObserveArgs<'_>) -> Result<()> {
        let fd = self.spec.feat_dim;
        let (n_levels, v) = (self.spec.draft_depth, self.spec.vocab);
        let c = self.spec.max_seq;
        let n = a.anchor_tokens.len();
        debug_assert_eq!(a.feats.len(), n * fd);
        debug_assert_eq!(a.next_tokens.len(), n);
        let mut done = 0usize;
        for t in chunk_plan(n) {
            let real = (n - done).min(t);
            let ctx = self.dkv.len(0);
            let mut feats = vec![0.0f32; t * fd];
            feats[..real * fd].copy_from_slice(&a.feats[done * fd..(done + real) * fd]);
            let mut toks = vec![self.spec.pad; t];
            toks[..real].copy_from_slice(&a.next_tokens[done..done + real]);
            let mut pos = vec![0i32; t];
            for i in 0..t {
                let p = (a.first_pos + done + i.min(real.saturating_sub(1))) as i32;
                pos[i] = p.min(self.spec.max_seq as i32 - 1);
            }
            let rows: Vec<MaskRow> = (0..real)
                .map(|i| MaskRow { prefix_upto: ctx + i + 1, extra: vec![] })
                .collect();
            let mask = build_mask(t, c, &rows);
            let feats_t = HostTensor::f32(vec![1, t, fd], feats);
            let toks_t = HostTensor::i32(vec![1, t], toks);
            let pos_t = HostTensor::i32(vec![1, t], pos);
            let ctx_t = HostTensor::i32(vec![1], vec![ctx as i32]);
            let exec = self
                .store
                .bind(&format!("{}_t{}", self.exec_prefix, t), &self.wset)?;
            let outs = exec.call(
                &self.store.runtime,
                &[
                    ("feats", &feats_t),
                    ("next_tokens", &toks_t),
                    ("anchor_pos", &pos_t),
                    ("mask", &mask),
                    ("ctx_len", &ctx_t),
                    ("dkv", self.dkv.tensor()),
                ],
            )?;
            let li = exec.out_idx("logits")?;
            let ki = exec.out_idx("dkv")?;
            // logits [1, t, N, V]: keep the newest real anchor's N rows —
            // they are this cycle's entire draft.
            let l = outs[li].as_f32()?;
            let row = real - 1;
            self.pending_logits =
                l[row * n_levels * v..(row + 1) * n_levels * v].to_vec();
            self.has_pending = true;
            let mut outs = outs;
            self.dkv.update_from(outs.swap_remove(ki))?;
            self.dkv.set_len(0, ctx + real);
            done += real;
        }
        Ok(())
    }

    fn draft(
        &mut self,
        _pending: i32,
        _anchor_pos: usize,
        temperature: f32,
        max_levels: usize,
    ) -> Result<DraftOutput> {
        if !self.has_pending {
            return Err(anyhow::anyhow!("draft before observe")).context("fasteagle");
        }
        let v = self.spec.vocab;
        // the cascade already produced every level during observe —
        // the plan's depth just bounds how many are materialized
        let dists = (0..self.spec.draft_depth.min(max_levels))
            .map(|i| {
                let mut d = self.pending_logits[i * v..(i + 1) * v].to_vec();
                softmax_temp(&mut d, temperature);
                d
            })
            .collect();
        Ok(DraftOutput::Levels(dists))
    }
}

#[cfg(test)]
mod tests {
    use super::chunk_plan;

    #[test]
    fn chunking_covers_exactly() {
        for n in 1..=70 {
            let plan = chunk_plan(n);
            let mut covered = 0usize;
            for t in &plan {
                assert!(matches!(t, 1 | 8 | 32));
                covered += (n - covered).min(*t);
            }
            assert_eq!(covered, n, "n={n} plan={plan:?}");
        }
        assert_eq!(chunk_plan(7), vec![8]);
        assert_eq!(chunk_plan(1), vec![1]);
        assert_eq!(chunk_plan(40), vec![32, 8]);
    }
}
