//! Runtime layer: everything that touches the executable boundary.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (with the L1 Pallas
//! kernels inlined in interpret mode) to HLO text; this module loads,
//! compiles and executes them through a pluggable `crate::backend`
//! (PJRT for serving, the in-process HLO interpreter for CI). Python
//! never runs at serving time.

pub mod client;
pub mod contract;
pub mod manifest;
pub mod registry;
pub mod tensor;
pub mod weights;

pub use crate::backend::BackendKind;
pub use client::{BoundExec, Executable, Runtime};
pub use contract::{ContractIssue, ContractReport};
pub use manifest::{ExecManifest, IoSpec, Kind};
pub use registry::ArtifactStore;
pub use tensor::{Dtype, HostTensor, TensorData};
pub use weights::WeightSet;
