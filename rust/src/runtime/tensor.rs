//! Host tensor type: the CPU-side value that crosses the PJRT boundary.
//!
//! Only the two dtypes the artifact contract uses (f32 data / i32 tokens
//! & indices); conversion to/from `xla::Literal` is a single untyped
//! memcpy in each direction.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn from_str(s: &str) -> Result<Dtype> {
        match s {
            "float32" | "f32" => Ok(Dtype::F32),
            "int32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    fn element_type(self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(numel(&shape), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros(dtype: Dtype, shape: Vec<usize>) -> HostTensor {
        let n = numel(&shape);
        match dtype {
            Dtype::F32 => HostTensor::f32(shape, vec![0.0; n]),
            Dtype::I32 => HostTensor::i32(shape, vec![0; n]),
        }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        numel(&self.shape)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            TensorData::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            TensorData::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            &self.shape,
            self.raw_bytes(),
        )
        .context("literal from host tensor")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::zeros(Dtype::F32, vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32().unwrap().len(), 6);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = HostTensor::scalar_i32(-7);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[-7]);
        assert!(back.shape.is_empty());
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }
}
