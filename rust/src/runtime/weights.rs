//! FEW1 weight-file reader + writer (the python writer lives in
//! `python/compile/fmt.py`; the Rust writer serves the interpreter
//! fixture generator).
//!
//! A weight set is a name → tensor map; the executable wrapper binds the
//! "weight"-kind inputs of an `*.io.json` manifest against it by name,
//! converting each tensor to an `xla::Literal` once and caching it for
//! the life of the process (weights are immutable at serving time).

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{Dtype, HostTensor};

#[derive(Debug)]
pub struct WeightSet {
    pub name: String,
    tensors: HashMap<String, HostTensor>,
}

const MAGIC: &[u8; 4] = b"FEW1";

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

impl WeightSet {
    pub fn load(path: &Path) -> Result<WeightSet> {
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: bad magic {magic:?}");
        }
        let count = read_u32(&mut f)? as usize;
        let mut tensors = HashMap::with_capacity(count);
        for _ in 0..count {
            let nlen = read_u16(&mut f)? as usize;
            let mut nb = vec![0u8; nlen];
            f.read_exact(&mut nb)?;
            let tname = String::from_utf8(nb).context("tensor name utf-8")?;
            let dt = read_u8(&mut f)?;
            let ndim = read_u8(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let n: usize = shape.iter().product();
            let mut raw = vec![0u8; n * 4];
            f.read_exact(&mut raw)?;
            let t = match dt {
                0 => {
                    let mut v = vec![0f32; n];
                    for (i, ch) in raw.chunks_exact(4).enumerate() {
                        v[i] = f32::from_le_bytes(ch.try_into().unwrap());
                    }
                    HostTensor::f32(shape, v)
                }
                1 => {
                    let mut v = vec![0i32; n];
                    for (i, ch) in raw.chunks_exact(4).enumerate() {
                        v[i] = i32::from_le_bytes(ch.try_into().unwrap());
                    }
                    HostTensor::i32(shape, v)
                }
                other => bail!("{path:?}: unknown dtype tag {other}"),
            };
            tensors.insert(tname, t);
        }
        Ok(WeightSet { name, tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn tensor(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.get(name)
    }

    /// Validate shape/dtype of a tensor against a manifest entry.
    pub fn check(&self, name: &str, shape: &[usize], dtype: Dtype) -> Result<()> {
        let t = self
            .tensor(name)
            .with_context(|| format!("weight {name:?} missing from set {:?}", self.name))?;
        if t.shape != shape || t.dtype() != dtype {
            bail!(
                "weight {name:?}: set has {:?}/{:?}, manifest wants {shape:?}/{dtype:?}",
                t.shape,
                t.dtype()
            );
        }
        Ok(())
    }
}

/// Write a FEW1 weight file (the exact format [`WeightSet::load`]
/// reads). Tensor order is preserved on disk; names must be unique.
pub fn write_few(path: &Path, tensors: &[(String, HostTensor)]) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        if name.len() > u16::MAX as usize {
            bail!("tensor name too long: {name:?}");
        }
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let (tag, raw): (u8, Vec<u8>) = match &t.data {
            super::tensor::TensorData::F32(v) => {
                (0, v.iter().flat_map(|x| x.to_le_bytes()).collect())
            }
            super::tensor::TensorData::I32(v) => {
                (1, v.iter().flat_map(|x| x.to_le_bytes()).collect())
            }
        };
        f.write_all(&[tag, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&raw)?;
    }
    // surface write errors here, not as a silent Drop-time flush failure
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_few(path: &Path, tensors: &[(&str, u8, Vec<u32>, Vec<u8>)]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, dt, dims, data) in tensors {
            f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[*dt, dims.len() as u8]).unwrap();
            for d in dims {
                f.write_all(&d.to_le_bytes()).unwrap();
            }
            f.write_all(data).unwrap();
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("few_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("set.few");
        let f32data: Vec<u8> = [1.0f32, -2.5]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let i32data: Vec<u8> = [7i32].iter().flat_map(|v| v.to_le_bytes()).collect();
        write_few(
            &p,
            &[
                ("a/b", 0, vec![2], f32data),
                ("c", 1, vec![1], i32data),
            ],
        );
        let ws = WeightSet::load(&p).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.tensor("a/b").unwrap().as_f32().unwrap(), &[1.0, -2.5]);
        assert_eq!(ws.tensor("c").unwrap().as_i32().unwrap(), &[7]);
        assert!(ws.check("a/b", &[2], Dtype::F32).is_ok());
        assert!(ws.check("a/b", &[3], Dtype::F32).is_err());
    }

    #[test]
    fn writer_reader_roundtrip() {
        let dir = std::env::temp_dir().join("few_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.few");
        write_few(
            &p,
            &[
                ("emb".to_string(), HostTensor::f32(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0])),
                ("ids".to_string(), HostTensor::i32(vec![3], vec![7, -8, 9])),
            ],
        )
        .unwrap();
        let ws = WeightSet::load(&p).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.tensor("emb").unwrap().as_f32().unwrap(), &[1.0, -2.0, 3.5, 0.0]);
        assert_eq!(ws.tensor("ids").unwrap().as_i32().unwrap(), &[7, -8, 9]);
        assert!(ws.check("emb", &[2, 2], Dtype::F32).is_ok());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("few_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.few");
        std::fs::write(&p, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(WeightSet::load(&p).is_err());
    }
}
