//! Artifact store: lazy-compiled executables, cached weight sets and
//! weight-bound executables for one target directory
//! (`artifacts/<target>/`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::client::{BoundExec, Executable, Runtime};
use super::weights::WeightSet;

pub struct ArtifactStore {
    pub runtime: Arc<Runtime>,
    pub dir: PathBuf,
    execs: RefCell<HashMap<String, Rc<Executable>>>,
    weights: RefCell<HashMap<String, Rc<WeightSet>>>,
    bound: RefCell<HashMap<String, Rc<BoundExec>>>,
}

impl ArtifactStore {
    pub fn open(runtime: Arc<Runtime>, dir: PathBuf) -> Result<ArtifactStore> {
        if !dir.join("spec.json").exists() {
            bail!(
                "{dir:?} has no spec.json — run `make artifacts` first (python -m compile.aot)"
            );
        }
        Ok(ArtifactStore {
            runtime,
            dir,
            execs: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            bound: RefCell::new(HashMap::new()),
        })
    }

    pub fn spec_json(&self) -> Result<String> {
        std::fs::read_to_string(self.dir.join("spec.json")).context("read spec.json")
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.dir.join("hlo").join(format!("{name}.hlo.txt")).exists()
    }

    /// Lazily compile (and cache) an executable by name.
    pub fn exec(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let e = Rc::new(self.runtime.load_executable(&self.dir.join("hlo"), name)?);
        self.execs.borrow_mut().insert(name.to_string(), Rc::clone(&e));
        Ok(e)
    }

    /// Lazily load (and cache) a weight set by name (`target`,
    /// `fasteagle`, `eagle3`, ...).
    pub fn weights(&self, set: &str) -> Result<Rc<WeightSet>> {
        if let Some(w) = self.weights.borrow().get(set) {
            return Ok(Rc::clone(w));
        }
        let path = self.dir.join("weights").join(format!("{set}.few"));
        let w = Rc::new(WeightSet::load(&path)?);
        self.weights.borrow_mut().insert(set.to_string(), Rc::clone(&w));
        Ok(w)
    }

    /// Executable bound to a weight set (weights uploaded once).
    pub fn bind(&self, exec_name: &str, wset: &str) -> Result<Rc<BoundExec>> {
        let key = format!("{exec_name}@{wset}");
        if let Some(b) = self.bound.borrow().get(&key) {
            return Ok(Rc::clone(b));
        }
        let e = self.exec(exec_name)?;
        let w = self.weights(wset)?;
        let b = Rc::new(e.bind(&self.runtime, &w)?);
        self.bound.borrow_mut().insert(key, Rc::clone(&b));
        Ok(b)
    }

    pub fn compiled_count(&self) -> usize {
        self.execs.borrow().len()
    }
}
