//! Executable IO manifests (`<exec>.io.json`, written by
//! `python/compile/aot.py`): the flattened parameter order of each
//! lowered HLO module, with a kind tag per input.
//!
//! kind = "weight"  → bound from the active `WeightSet` by name
//! kind = "state"   → per-request state threaded by the caller (KV caches)
//! kind = "arg"     → per-call argument (tokens, masks, positions, ...)

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::Dtype;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Weight,
    State,
    Arg,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub kind: Kind,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ExecManifest {
    pub name: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

fn parse_iospec(v: &Json, with_kind: bool) -> Result<IoSpec> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .context("io entry missing name")?
        .to_string();
    let kind = if with_kind {
        match v.get("kind").and_then(Json::as_str) {
            Some("weight") => Kind::Weight,
            Some("state") => Kind::State,
            Some("arg") => Kind::Arg,
            other => bail!("input {name:?}: bad kind {other:?}"),
        }
    } else {
        Kind::Arg
    };
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .context("io entry missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = Dtype::from_str(
        v.get("dtype").and_then(Json::as_str).context("io entry missing dtype")?,
    )?;
    Ok(IoSpec { name, kind, shape, dtype })
}

impl ExecManifest {
    pub fn parse(text: &str) -> Result<ExecManifest> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .context("manifest missing name")?
            .to_string();
        let inputs = v
            .get("inputs")
            .and_then(Json::as_arr)
            .context("manifest missing inputs")?
            .iter()
            .map(|e| parse_iospec(e, true))
            .collect::<Result<Vec<_>>>()?;
        let outputs = v
            .get("outputs")
            .and_then(Json::as_arr)
            .context("manifest missing outputs")?
            .iter()
            .map(|e| parse_iospec(e, false))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecManifest { name, inputs, outputs })
    }

    pub fn load(path: &Path) -> Result<ExecManifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parse {path:?}"))
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|i| i.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|o| o.name == name)
    }

    /// Names of non-weight inputs, in parameter order.
    pub fn runtime_inputs(&self) -> impl Iterator<Item = &IoSpec> {
        self.inputs.iter().filter(|i| i.kind != Kind::Weight)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "tgt_m1",
      "inputs": [
        {"name": "emb", "kind": "weight", "shape": [272, 192], "dtype": "float32"},
        {"name": "tokens", "kind": "arg", "shape": [1, 1], "dtype": "int32"},
        {"name": "kv", "kind": "state", "shape": [6, 2, 1, 256, 2, 32], "dtype": "float32"}
      ],
      "outputs": [
        {"name": "logits", "shape": [1, 1, 272], "dtype": "float32"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ExecManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tgt_m1");
        assert_eq!(m.inputs.len(), 3);
        assert_eq!(m.inputs[0].kind, Kind::Weight);
        assert_eq!(m.inputs[2].kind, Kind::State);
        assert_eq!(m.inputs[2].numel(), 6 * 2 * 256 * 2 * 32);
        assert_eq!(m.outputs[0].shape, vec![1, 1, 272]);
        assert_eq!(m.input_index("tokens"), Some(1));
        assert_eq!(m.output_index("logits"), Some(0));
        assert_eq!(m.runtime_inputs().count(), 2);
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = SAMPLE.replace("\"arg\"", "\"bogus\"");
        assert!(ExecManifest::parse(&bad).is_err());
    }
}
