//! Engine contract checker: static cross-checks between the
//! [`ModelSpec`] inventory, the per-executable manifests, and the draft
//! shapes the configured planners can reach — run at engine startup
//! ([`crate::model::TargetModel::open`], `BatchEngine::new`) and by
//! `fasteagle check`, so a spec whose lowered `tgt_m{M}[_b{B}]` lanes
//! cannot carry a reachable [`DraftPlan`] fails fast with an actionable
//! report instead of panicking (or silently falling back) mid-serve.

use std::fmt;
use std::path::Path;

use anyhow::{bail, Result};

use crate::backend::hlo::verify::Severity;
use crate::model::ModelSpec;
use crate::spec::plan::{DraftPlan, PlannerKind};

use super::manifest::{ExecManifest, Kind};

/// One contract finding (spec-level, so no instruction anchor —
/// `rule` + `message` name the lane or tensor instead).
#[derive(Debug, Clone)]
pub struct ContractIssue {
    pub severity: Severity,
    /// stable rule identifier, e.g. `lane/b1` or `state/shape`
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for ContractIssue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{sev}[{}] {}", self.rule, self.message)
    }
}

#[derive(Debug, Clone)]
pub struct ContractReport {
    /// target (spec) name the report is about
    pub target: String,
    pub issues: Vec<ContractIssue>,
}

impl ContractReport {
    pub fn new(target: &str) -> ContractReport {
        ContractReport { target: target.to_string(), issues: Vec::new() }
    }

    fn push(&mut self, severity: Severity, rule: &'static str, message: String) {
        self.issues.push(ContractIssue { severity, rule, message });
    }

    pub fn merge(&mut self, other: ContractReport) {
        self.issues.extend(other.issues);
    }

    pub fn has_errors(&self) -> bool {
        self.issues.iter().any(|i| i.severity == Severity::Error)
    }

    pub fn warnings(&self) -> impl Iterator<Item = &ContractIssue> {
        self.issues.iter().filter(|i| i.severity == Severity::Warning)
    }

    /// Bail with the full report when any error-severity issue exists
    /// (warnings alone pass).
    pub fn ensure_ok(&self) -> Result<()> {
        if self.has_errors() {
            bail!("{self}");
        }
        Ok(())
    }
}

impl fmt::Display for ContractReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "engine contract report for target {:?}:", self.target)?;
        for i in &self.issues {
            writeln!(f, "  {i}")?;
        }
        Ok(())
    }
}

/// Single-request (B=1) engine contract: every draft shape the
/// configured planners can reach — for both planner kinds that is the
/// base (default) plan, the adaptive planner only ever shrinks — must
/// map to a lowered `verify_m` lane, and prefill chunks (which ride the
/// same verify executables) must fit one too.
pub fn check_single(spec: &ModelSpec) -> ContractReport {
    let mut r = ContractReport::new(&spec.name);
    let base = DraftPlan::default_for(spec);
    for kind in [PlannerKind::Static, PlannerKind::Adaptive] {
        let rows = kind.envelope(&base).total_rows();
        if spec.verify_m_for(rows).is_none() {
            r.push(
                Severity::Error,
                "lane/b1",
                format!(
                    "{} planner envelope (depth {}, top-k {}) needs a verify lane of \
                     >= {rows} rows, but the lowered B=1 inventory is {:?} — regenerate \
                     artifacts with a large-enough tgt_m, or shrink the draft plan",
                    kind.name(),
                    spec.draft_depth,
                    spec.tree_top_k,
                    spec.verify_ms
                ),
            );
        }
    }
    if spec.verify_m_for(spec.prefill_chunk).is_none() {
        r.push(
            Severity::Error,
            "lane/prefill",
            format!(
                "prefill_chunk {} exceeds every lowered B=1 verify lane {:?}",
                spec.prefill_chunk, spec.verify_ms
            ),
        );
    }
    check_tree_nodes(spec, &mut r);
    r
}

/// Batched-engine contract: the chain-shaped plans the batcher emits
/// (`1 + chain_len` verify rows, which also caps its prefill chunks)
/// must have a lowered lane at the configured batch.
pub fn check_engine(spec: &ModelSpec, batch: usize, chain_len: usize) -> ContractReport {
    let mut r = ContractReport::new(&spec.name);
    if batch > 1 && !spec.batch_sizes.contains(&batch) {
        r.push(
            Severity::Error,
            "lane/batch",
            format!("batch {batch} is not in the spec's batch_sizes {:?}", spec.batch_sizes),
        );
    }
    let rows = 1 + chain_len;
    if spec.verify_m_lowered(rows, batch).is_none() {
        let lanes: Vec<usize> = if batch <= 1 {
            spec.verify_ms.clone()
        } else {
            spec.verify_ms_by_batch
                .iter()
                .find(|(b, _)| *b == batch)
                .map(|(_, ms)| ms.clone())
                .unwrap_or_default()
        };
        r.push(
            Severity::Error,
            "lane/chain",
            format!(
                "chain_len {chain_len} needs a verify lane of >= {rows} rows at batch \
                 {batch}, but the lowered inventory there is {lanes:?} — regenerate \
                 artifacts with a large-enough tgt_m{{M}}_b{batch}, or lower --chain"
            ),
        );
    }
    check_tree_nodes(spec, &mut r);
    r
}

/// Prefix-cache / pool geometry contract: `block_slots` (the sharing
/// granule of the radix index — one node per `block_slots`-token run)
/// must compose with the spec and the lowered verify-lane inventory at
/// the engine's batch. Checked by `BatchEngine::new` when
/// `--prefix-cache` is on, and always by `fasteagle check`, so a
/// mis-sized granule is a structured diagnostic instead of a runtime
/// surprise.
pub fn check_cache(spec: &ModelSpec, block_slots: usize, batch: usize) -> ContractReport {
    let mut r = ContractReport::new(&spec.name);
    if block_slots == 0 {
        r.push(
            Severity::Error,
            "cache/geometry",
            "block_slots must be positive — a zero granule can never index a prefix".to_string(),
        );
        return r;
    }
    if block_slots > spec.max_seq {
        r.push(
            Severity::Error,
            "cache/geometry",
            format!(
                "block_slots {block_slots} exceeds max_seq {} — no prompt can ever fill \
                 one block, so nothing would be published or shared",
                spec.max_seq
            ),
        );
    } else if spec.max_seq % block_slots != 0 {
        r.push(
            Severity::Warning,
            "cache/geometry",
            format!(
                "max_seq {} is not a multiple of block_slots {block_slots} — the tail \
                 partial block of a full-length sequence is never publishable",
                spec.max_seq
            ),
        );
    }
    if spec.feat_dim == 0 {
        r.push(
            Severity::Error,
            "cache/state",
            "feat_dim is 0 — the cache stores per-token drafter features alongside the \
             target KV so each method can rebuild its own drafter state; without a \
             feature stream a warm hit could not seed the post-prefill observe"
                .to_string(),
        );
    }
    // a warm hit resumes chunked prefill at the first uncached token:
    // at least one verify row must be lowered at this batch to carry it
    if spec.verify_m_lowered(1, batch).is_none() {
        r.push(
            Severity::Error,
            "cache/lanes",
            format!(
                "no lowered verify lane at batch {batch} can ingest the post-hit prefill \
                 remainder (>= 1 row needed) — the cache could adopt a prefix but never \
                 finish the prompt"
            ),
        );
    }
    r
}

/// Warn when the on-disk `tree_nodes` JSON field disagrees with the
/// value derived from the default [`DraftPlan`] — the derived value
/// wins, but a drifted spec file should be noticed, not silently
/// discarded.
fn check_tree_nodes(spec: &ModelSpec, r: &mut ContractReport) {
    if let Some(on_disk) = spec.tree_nodes_on_disk {
        if on_disk != spec.tree_nodes {
            r.push(
                Severity::Warning,
                "spec/tree-nodes",
                format!(
                    "spec.json says tree_nodes = {on_disk}, but the default draft plan \
                     (depth {} x top-k {}) derives {} — the derived value is used",
                    spec.draft_depth, spec.tree_top_k, spec.tree_nodes
                ),
            );
        }
    }
}

/// Batch lane an executable was lowered for, from the `_b{B}` name
/// suffix (`tgt_m3_b2`, `fe_t8_b2`); unsuffixed executables are B=1.
fn batch_of(exec: &str) -> usize {
    exec.rsplit_once("_b")
        .and_then(|(_, b)| b.parse().ok())
        .unwrap_or(1)
}

/// Cross-check a manifest's per-request state tensors against the
/// method signatures the engines thread them with: `kv` (target),
/// `dkv` (FastEagle cascade), `ekv` (EAGLE) caches must have exactly
/// the shape the spec's dimensions dictate for the executable's batch.
pub fn check_manifest_states(spec: &ModelSpec, m: &ExecManifest) -> ContractReport {
    let mut r = ContractReport::new(&spec.name);
    let b = batch_of(&m.name);
    // the SpS baseline's separate draft LM (`sps_*`) threads its own,
    // smaller kv cache; everything else uses the target's geometry
    let is_sps = m.name.starts_with("sps");
    let (kv_layers, kv_heads, kv_hd) = if is_sps {
        (spec.sps.n_layers, spec.sps.n_kv_heads, spec.sps.head_dim)
    } else {
        (spec.n_layers, spec.n_kv_heads, spec.head_dim)
    };
    for io in &m.inputs {
        if io.kind != Kind::State {
            continue;
        }
        let kv_tail = [spec.max_seq, kv_heads, kv_hd];
        let want: Option<Vec<usize>> = match io.name.as_str() {
            "kv" => {
                let mut w = vec![kv_layers, 2, b];
                w.extend(kv_tail);
                Some(w)
            }
            "dkv" => {
                let mut w = vec![spec.draft_depth, 2, b];
                w.extend(kv_tail);
                Some(w)
            }
            "ekv" => {
                let mut w = vec![2, b];
                w.extend(kv_tail);
                Some(w)
            }
            _ => None,
        };
        match want {
            Some(w) => {
                if io.shape != w {
                    r.push(
                        Severity::Error,
                        "state/shape",
                        format!(
                            "{}: state tensor {:?} is {:?}, its method signature wants {w:?}",
                            m.name, io.name, io.shape
                        ),
                    );
                }
            }
            None => r.push(
                Severity::Warning,
                "state/unknown",
                format!(
                    "{}: state tensor {:?} ({:?}) is not a known method signature",
                    m.name, io.name, io.shape
                ),
            ),
        }
    }
    r
}

/// Every executable the spec's inventory lists must exist on disk
/// (`hlo/<name>.hlo.txt` + `.io.json`) under the target directory.
pub fn check_inventory(spec: &ModelSpec, target_dir: &Path) -> ContractReport {
    let mut r = ContractReport::new(&spec.name);
    for name in &spec.executables {
        let hlo = target_dir.join("hlo").join(format!("{name}.hlo.txt"));
        let io = target_dir.join("hlo").join(format!("{name}.io.json"));
        if !hlo.is_file() || !io.is_file() {
            r.push(
                Severity::Error,
                "inventory/missing",
                format!("executable {name:?} is listed in spec.json but has no artifact on disk"),
            );
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::tests_sample::SAMPLE;

    #[test]
    fn sample_spec_fails_b1_envelope() {
        // depth 6 x top-k 3 -> 19 rows; the sample's largest lane is 18
        let spec = ModelSpec::parse(SAMPLE).unwrap();
        let r = check_single(&spec);
        assert!(r.has_errors());
        assert!(r.issues.iter().any(|i| i.rule == "lane/b1"), "{r}");
        let text = r.to_string();
        assert!(text.contains("19 rows"), "{text}");
    }

    #[test]
    fn chain_lane_check_per_batch() {
        let spec = ModelSpec::parse(SAMPLE).unwrap();
        // batch 4 lanes are [2, 5]: chain 2 -> 3 rows fits, chain 6 -> 7 rows does not
        assert!(!check_engine(&spec, 4, 2).has_errors());
        let r = check_engine(&spec, 4, 6);
        assert!(r.issues.iter().any(|i| i.rule == "lane/chain"), "{r}");
        // batch 2 has no lowered executables at all
        assert!(check_engine(&spec, 2, 2).issues.iter().any(|i| i.rule == "lane/batch"));
    }

    #[test]
    fn tree_nodes_disagreement_warns() {
        let doctored = SAMPLE.replace("\"prefill_chunk\": 32,", "\"prefill_chunk\": 32, \"tree_nodes\": 999,");
        let spec = ModelSpec::parse(&doctored).unwrap();
        assert_eq!(spec.tree_nodes_on_disk, Some(999));
        let r = check_engine(&spec, 1, 2);
        assert!(
            r.warnings().any(|i| i.rule == "spec/tree-nodes"),
            "{r}"
        );
        // a warning alone is not an error
        assert!(!check_engine(&spec, 4, 2).has_errors());
    }

    #[test]
    fn cache_geometry_rule() {
        let spec = ModelSpec::parse(SAMPLE).unwrap();
        // the default granule divides the sample's max_seq (256)
        assert!(!check_cache(&spec, 16, 1).has_errors());
        assert!(check_cache(&spec, 16, 1).warnings().count() == 0);
        // zero granule and granule > max_seq are hard errors
        assert!(check_cache(&spec, 0, 1).has_errors());
        let r = check_cache(&spec, 512, 1);
        assert!(r.issues.iter().any(|i| i.rule == "cache/geometry"), "{r}");
        assert!(r.to_string().contains("max_seq"), "{r}");
        // a non-dividing granule only warns (tail block never publishes)
        let r = check_cache(&spec, 48, 1);
        assert!(!r.has_errors());
        assert!(r.warnings().any(|i| i.rule == "cache/geometry"), "{r}");
        // a batch with no lowered lanes cannot carry the post-hit prefill
        let r = check_cache(&spec, 16, 2);
        assert!(r.issues.iter().any(|i| i.rule == "cache/lanes"), "{r}");
    }

    #[test]
    fn state_shape_cross_check() {
        let spec = ModelSpec::parse(SAMPLE).unwrap();
        // matches the spec dims (6 layers, 2 kv heads, head 32, seq 256)
        let good = ExecManifest::parse(
            r#"{"name": "tgt_m1", "inputs": [
                {"name": "kv", "kind": "state", "shape": [6, 2, 1, 256, 2, 32], "dtype": "float32"}
              ], "outputs": []}"#,
        )
        .unwrap();
        assert!(!check_manifest_states(&spec, &good).has_errors());
        let bad = ExecManifest::parse(
            r#"{"name": "tgt_m1_b4", "inputs": [
                {"name": "kv", "kind": "state", "shape": [6, 2, 1, 256, 2, 32], "dtype": "float32"}
              ], "outputs": []}"#,
        )
        .unwrap();
        // _b4 executable must thread a batch-4 cache
        let r = check_manifest_states(&spec, &bad);
        assert!(r.issues.iter().any(|i| i.rule == "state/shape"), "{r}");
    }

    #[test]
    fn batch_suffix_parses() {
        assert_eq!(batch_of("tgt_m3"), 1);
        assert_eq!(batch_of("tgt_m3_b2"), 2);
        assert_eq!(batch_of("fe_t8_b16"), 16);
        assert_eq!(batch_of("eg_next_t1"), 1);
    }
}
