//! PJRT runtime: load AOT-lowered HLO text, compile once, execute from
//! the serving hot path. Adapted from /opt/xla-example/load_hlo —
//! HLO *text* is the interchange format (the text parser reassigns the
//! 64-bit instruction ids jax ≥ 0.5 emits, which xla_extension 0.5.1
//! would otherwise reject).
//!
//! Weights are transferred to device buffers **once** per
//! (executable, weight-set) pair (`Executable::bind`); per-call inputs go
//! through `buffer_from_host_buffer` and everything executes via
//! `execute_b`, so the multi-MB parameter tensors never cross the host
//! boundary on the request path.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ExecManifest, Kind};
use super::tensor::{HostTensor, TensorData};
use super::weights::WeightSet;

pub struct Runtime {
    pub(crate) client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub(crate) fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match &t.data {
            TensorData::F32(v) => {
                self.client.buffer_from_host_buffer::<f32>(v, &t.shape, None)
            }
            TensorData::I32(v) => {
                self.client.buffer_from_host_buffer::<i32>(v, &t.shape, None)
            }
        };
        buf.context("host->device transfer")
    }

    /// Load + compile one executable from `<dir>/<name>.hlo.txt` and its
    /// `.io.json` manifest.
    pub fn load_executable(&self, hlo_dir: &Path, name: &str) -> Result<Executable> {
        let hlo_path = hlo_dir.join(format!("{name}.hlo.txt"));
        let io_path = hlo_dir.join(format!("{name}.io.json"));
        let manifest = ExecManifest::load(&io_path)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse {hlo_path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        crate::log_debug!("compiled {name} in {:.0}ms", t0.elapsed().as_secs_f64() * 1e3);
        Ok(Executable { name: name.to_string(), manifest, exe })
    }
}

/// A compiled executable plus its IO manifest.
pub struct Executable {
    pub name: String,
    pub manifest: ExecManifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Pre-transfer a weight set's tensors for this executable's weight
    /// inputs. Fails fast on any name/shape/dtype mismatch.
    pub fn bind(
        self: &std::rc::Rc<Self>,
        rt: &Runtime,
        weights: &WeightSet,
    ) -> Result<BoundExec> {
        let mut wbufs = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            if spec.kind == Kind::Weight {
                weights.check(&spec.name, &spec.shape, spec.dtype)?;
                let t = weights.tensor(&spec.name).unwrap();
                wbufs.push(Some(rt.upload(t)?));
            } else {
                wbufs.push(None);
            }
        }
        Ok(BoundExec { exec: std::rc::Rc::clone(self), wbufs })
    }
}

/// An executable bound to a weight set (weights resident on device).
pub struct BoundExec {
    pub exec: std::rc::Rc<Executable>,
    wbufs: Vec<Option<xla::PjRtBuffer>>,
}

impl BoundExec {
    pub fn name(&self) -> &str {
        &self.exec.name
    }

    pub fn manifest(&self) -> &ExecManifest {
        &self.exec.manifest
    }

    /// `args`: (name, tensor) for every input with kind != weight, in any
    /// order. Missing or shape-mismatched args are hard errors. Returns
    /// host tensors in manifest output order.
    pub fn call(&self, rt: &Runtime, args: &[(&str, &HostTensor)]) -> Result<Vec<HostTensor>> {
        let m = self.manifest();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<isize> = Vec::with_capacity(m.inputs.len());
        for spec in &m.inputs {
            match spec.kind {
                Kind::Weight => order.push(-1),
                Kind::Arg | Kind::State => {
                    let (_, t) = args
                        .iter()
                        .find(|(n, _)| *n == spec.name)
                        .with_context(|| {
                            format!("{}: missing runtime input {:?}", self.name(), spec.name)
                        })?;
                    if t.shape != spec.shape || t.dtype() != spec.dtype {
                        bail!(
                            "{}: input {:?} got {:?}/{:?}, manifest wants {:?}/{:?}",
                            self.name(), spec.name, t.shape, t.dtype(),
                            spec.shape, spec.dtype
                        );
                    }
                    owned.push(rt.upload(t)?);
                    order.push(owned.len() as isize - 1);
                }
            }
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(order.len());
        for (i, w) in order.iter().enumerate() {
            if *w < 0 {
                bufs.push(self.wbufs[i].as_ref().unwrap());
            } else {
                bufs.push(&owned[*w as usize]);
            }
        }
        let result = self
            .exec
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .with_context(|| format!("execute {}", self.name()))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple().context("untuple result")?;
        if parts.len() != m.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name(), parts.len(), m.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.iter().zip(&m.outputs) {
            let t = HostTensor::from_literal(lit)?;
            if t.shape != spec.shape {
                bail!(
                    "{}: output {:?} has shape {:?}, manifest says {:?}",
                    self.name(), spec.name, t.shape, spec.shape
                );
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Index of a named output.
    pub fn out_idx(&self, name: &str) -> Result<usize> {
        self.manifest()
            .output_index(name)
            .with_context(|| format!("{}: no output {name:?}", self.name()))
    }
}
