//! Runtime layer over a pluggable execution [`Backend`]: load an
//! AOT-lowered HLO-text executable + its `.io.json` manifest, compile it
//! once, bind a weight set once, and execute from the serving hot path.
//!
//! The backend is selected at `Runtime` construction:
//! * [`Runtime::cpu`] — PJRT (`backend::pjrt`), the serving path.
//! * [`Runtime::interpreter`] — in-process HLO interpreter
//!   (`backend::interp`), the CI / no-toolchain path.
//! * [`Runtime::from_env`] — `FE_BACKEND=pjrt|interpret` (default pjrt).
//!
//! Manifest validation (names, shapes, dtypes, weight binding) lives
//! here so every backend gets the same hard errors on drifted artifacts.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::{make_backend, Backend, BackendBound, BackendExec, BackendKind};

use super::manifest::{ExecManifest, Kind};
use super::tensor::HostTensor;
use super::weights::WeightSet;

pub struct Runtime {
    backend: Box<dyn Backend>,
    kind: BackendKind,
}

impl Runtime {
    /// PJRT-backed runtime (real bindings when linked, vendored host
    /// stub otherwise).
    pub fn cpu() -> Result<Runtime> {
        Runtime::new(BackendKind::Pjrt)
    }

    /// In-process HLO-interpreter runtime: runs anywhere `cargo test`
    /// runs, no `xla_extension` required.
    pub fn interpreter() -> Result<Runtime> {
        Runtime::new(BackendKind::Interpret)
    }

    pub fn new(kind: BackendKind) -> Result<Runtime> {
        Ok(Runtime { backend: make_backend(kind)?, kind })
    }

    /// Backend from the `FE_BACKEND` env var (`pjrt` when unset).
    pub fn from_env() -> Result<Runtime> {
        match std::env::var("FE_BACKEND") {
            Ok(v) if !v.is_empty() => Runtime::new(BackendKind::from_str(&v)?),
            _ => Runtime::cpu(),
        }
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    pub fn platform(&self) -> String {
        self.backend.platform_name()
    }

    /// Load + compile one executable from `<dir>/<name>.hlo.txt` and its
    /// `.io.json` manifest.
    pub fn load_executable(&self, hlo_dir: &Path, name: &str) -> Result<Executable> {
        let hlo_path = hlo_dir.join(format!("{name}.hlo.txt"));
        let io_path = hlo_dir.join(format!("{name}.io.json"));
        let manifest = ExecManifest::load(&io_path)?;
        let imp = self.backend.compile(&hlo_path, &manifest)?;
        Ok(Executable { name: name.to_string(), manifest, imp })
    }
}

/// A compiled executable plus its IO manifest.
pub struct Executable {
    pub name: String,
    pub manifest: ExecManifest,
    imp: Box<dyn BackendExec>,
}

impl Executable {
    /// Pre-stage a weight set's tensors for this executable's weight
    /// inputs. Fails fast on any name/shape/dtype mismatch.
    pub fn bind(
        self: &std::rc::Rc<Self>,
        _rt: &Runtime,
        weights: &WeightSet,
    ) -> Result<BoundExec> {
        let mut wrefs: Vec<Option<&HostTensor>> =
            Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            if spec.kind == Kind::Weight {
                weights.check(&spec.name, &spec.shape, spec.dtype)?;
                wrefs.push(Some(weights.tensor(&spec.name).unwrap()));
            } else {
                wrefs.push(None);
            }
        }
        let bound = self.imp.bind(&wrefs)?;
        Ok(BoundExec { exec: std::rc::Rc::clone(self), bound })
    }
}

/// An executable bound to a weight set (weights staged backend-side).
pub struct BoundExec {
    pub exec: std::rc::Rc<Executable>,
    bound: Box<dyn BackendBound>,
}

impl BoundExec {
    pub fn name(&self) -> &str {
        &self.exec.name
    }

    pub fn manifest(&self) -> &ExecManifest {
        &self.exec.manifest
    }

    /// `args`: (name, tensor) for every input with kind != weight, in any
    /// order. Missing or shape-mismatched args are hard errors. Returns
    /// host tensors in manifest output order.
    pub fn call(&self, _rt: &Runtime, args: &[(&str, &HostTensor)]) -> Result<Vec<HostTensor>> {
        let _sp = crate::obs::span("execute").label(self.name());
        let m = self.manifest();
        let mut positional: Vec<Option<&HostTensor>> = Vec::with_capacity(m.inputs.len());
        for spec in &m.inputs {
            match spec.kind {
                Kind::Weight => positional.push(None),
                Kind::Arg | Kind::State => {
                    let (_, t) = args
                        .iter()
                        .find(|(n, _)| *n == spec.name)
                        .with_context(|| {
                            format!("{}: missing runtime input {:?}", self.name(), spec.name)
                        })?;
                    if t.shape != spec.shape || t.dtype() != spec.dtype {
                        bail!(
                            "{}: input {:?} got {:?}/{:?}, manifest wants {:?}/{:?}",
                            self.name(),
                            spec.name,
                            t.shape,
                            t.dtype(),
                            spec.shape,
                            spec.dtype
                        );
                    }
                    positional.push(Some(*t));
                }
            }
        }
        let out = self.bound.call(&positional)?;
        if out.len() != m.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name(),
                out.len(),
                m.outputs.len()
            );
        }
        for (t, spec) in out.iter().zip(&m.outputs) {
            if t.shape != spec.shape {
                bail!(
                    "{}: output {:?} has shape {:?}, manifest says {:?}",
                    self.name(),
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
        }
        Ok(out)
    }

    /// Index of a named output.
    pub fn out_idx(&self, name: &str) -> Result<usize> {
        self.manifest()
            .output_index(name)
            .with_context(|| format!("{}: no output {name:?}", self.name()))
    }
}
