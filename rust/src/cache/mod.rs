//! Prefix cache over the paged KV layer.
//!
//! Production engines treat prefix caching and speculative decoding as
//! incompatible (mistral.rs documents both PagedAttention and prefix
//! caching as unsupported with speculative decoding); this subsystem
//! makes them compose losslessly for the EAGLE-family methods by
//! choosing the right unit of sharing:
//!
//! - **Target KV rows** are cached per token. A verified-and-accepted
//!   row is byte-identical to the row a fresh prefill would produce at
//!   the same position (the accepted path attends to exactly the
//!   canonical prefix), so adopted rows continue a generation exactly.
//! - **Drafter state is cached as per-token features**, not drafter KV.
//!   Features are the method-agnostic input of the EAGLE-family
//!   drafters; each method's own KV/feature state (`fe_dkv`/`eg_dkv`
//!   geometry) is rebuilt deterministically by the unchanged
//!   post-prefill observe over the full prompt. One cache therefore
//!   serves fasteagle, eagle3 and vanilla, and warm generations are
//!   byte-identical to cold ones under both greedy and stochastic
//!   decoding (sampler streams are seeded per request and never consume
//!   from prefill).
//!
//! The index is a [`radix::RadixTree`] keyed on `block_slots`-sized
//! token-id runs: one node = one published block run. Pool accounting
//! rides along — each node holds the [`crate::model::paged::BlockPool`]
//! blocks that fund its target-KV rows (`blocks_for(block_slots,
//! n_layers)`; the feature payload rides with the node). Publishing
//! *transfers* blocks from the retiring lease into the index (no
//! allocation, cannot fail); adoption *retains* them into the new lease
//! (refcount up, zero capacity charged); eviction releases the last
//! reference and the blocks return to the free list. A node is pinned
//! while any holder shares its blocks (refcount >= 2), so live leases
//! are never yanked.

pub mod radix;

use std::collections::HashSet;

use anyhow::Result;

use crate::model::kvcache::KvCache;
use crate::model::paged::{BlockPool, Lease};
use radix::RadixTree;

/// Payload of one radix node: the cached rows of one block run.
#[derive(Debug)]
struct BlockPayload {
    /// target KV rows, `[planes, block_slots, row]` (KvCache::read_rows)
    kv_rows: Vec<f32>,
    /// per-token drafter features, `[block_slots, feat_dim]`
    feats: Vec<f32>,
    /// pool blocks funding this run (owned by the index)
    blocks: Vec<u32>,
}

/// Longest-cached-prefix answer for one prompt.
#[derive(Debug, Clone, Default)]
pub struct CacheHit {
    /// cached tokens (a multiple of `block_slots`, < prompt length)
    pub tokens: usize,
    /// pool blocks the hit chain holds (adoptable by sharing)
    pub blocks: usize,
    /// the chain's node ids, root-first
    pub node_ids: Vec<usize>,
}

#[derive(Debug)]
pub struct PrefixCache {
    enabled: bool,
    block_slots: usize,
    /// target KV layers a node's blocks pay for (model `n_layers`)
    kv_layers: usize,
    feat_dim: usize,
    tree: RadixTree<BlockPayload>,
    held_blocks: usize,
}

impl PrefixCache {
    pub fn new(enabled: bool, block_slots: usize, kv_layers: usize, feat_dim: usize) -> Self {
        assert!(block_slots > 0);
        PrefixCache {
            enabled,
            block_slots,
            kv_layers,
            feat_dim,
            tree: RadixTree::new(),
            held_blocks: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn nodes(&self) -> usize {
        self.tree.len()
    }

    /// Pool blocks currently held by the index.
    pub fn held_blocks(&self) -> usize {
        self.held_blocks
    }

    /// Runs of `ptoks` eligible for matching: whole blocks only, and
    /// at least one token is always left to prefill (its verify row
    /// produces the logits that seed the first decode cycle).
    fn usable_runs<'a>(&self, ptoks: &'a [i32]) -> impl Iterator<Item = &'a [i32]> {
        let usable = ptoks.len().saturating_sub(1) / self.block_slots * self.block_slots;
        ptoks[..usable].chunks_exact(self.block_slots)
    }

    fn hit_for(&self, chain: Vec<usize>) -> CacheHit {
        let blocks = chain.iter().map(|&id| self.tree.get(id).payload.blocks.len()).sum();
        CacheHit { tokens: chain.len() * self.block_slots, blocks, node_ids: chain }
    }

    /// Longest cached prefix without disturbing LRU order — the
    /// scheduler's view of pending work.
    pub fn peek(&self, ptoks: &[i32]) -> CacheHit {
        if !self.enabled {
            return CacheHit::default();
        }
        self.hit_for(self.tree.walk(self.usable_runs(ptoks)))
    }

    /// Longest cached prefix, bumping the chain's recency (admission).
    pub fn lookup(&mut self, ptoks: &[i32]) -> CacheHit {
        let hit = self.peek(ptoks);
        self.tree.touch(&hit.node_ids);
        hit
    }

    /// Adopt a hit into a fresh lease: every chain block gains a
    /// reference and joins the lease (shared blocks lead, the fresh
    /// remainder is allocated after them by the caller), and the cached
    /// rows are written into batch lane `b`. Returns the cached
    /// per-token features, ready to seed
    /// [`crate::coordinator::scheduler::PrefillProgress::with_prefix`].
    ///
    /// Shared blocks are read-only from here on; since hits are whole
    /// blocks, the writer's appends land in its own fresh blocks and
    /// the copy-on-write fork (`BlockPool::fork_tail`) stays a guard
    /// for sub-block sharing.
    pub fn adopt(
        &self,
        hit: &CacheHit,
        pool: &mut BlockPool,
        kv: &mut KvCache,
        b: usize,
        lease: &mut Lease,
    ) -> Result<Vec<f32>> {
        let mut feats = Vec::with_capacity(hit.tokens * self.feat_dim);
        for (j, &nid) in hit.node_ids.iter().enumerate() {
            let payload = &self.tree.get(nid).payload;
            kv.write_rows(b, j * self.block_slots, self.block_slots, &payload.kv_rows)?;
            pool.retain(&payload.blocks);
            lease.blocks.extend_from_slice(&payload.blocks);
            feats.extend_from_slice(&payload.feats);
        }
        Ok(feats)
    }

    /// Publish a retiring request's committed prefix: every whole block
    /// run of its rows becomes (or refreshes) an index node. New nodes
    /// take their pool blocks *by transfer from the retiring lease* —
    /// the capacity that funded the rows keeps funding them, so publish
    /// never allocates and never fails for lack of blocks. Returns the
    /// number of newly inserted nodes.
    ///
    /// `row_tokens`/`row_feats` are the per-row input tokens and
    /// features the engine accumulated alongside the KV (prompt rows
    /// from prefill, then each cycle's accepted rows); both are aligned
    /// with `kv.len(b)` by construction.
    pub fn publish(
        &mut self,
        pool: &mut BlockPool,
        lease: &mut Lease,
        row_tokens: &[i32],
        row_feats: &[f32],
        kv: &KvCache,
        b: usize,
    ) -> usize {
        if !self.enabled {
            return 0;
        }
        let rows = row_tokens.len().min(kv.len(b));
        debug_assert_eq!(row_tokens.len() * self.feat_dim, row_feats.len());
        let node_cost = pool.blocks_for(self.block_slots, self.kv_layers);
        let mut cur = None;
        let mut chain = Vec::new();
        let mut inserted = 0usize;
        for (j, run) in row_tokens[..rows / self.block_slots * self.block_slots]
            .chunks_exact(self.block_slots)
            .enumerate()
        {
            let id = match self.tree.child_of(cur, run) {
                Some(id) => id,
                None => {
                    if lease.blocks.len() < node_cost {
                        break; // lease can no longer fund a node (shouldn't happen)
                    }
                    let start = j * self.block_slots;
                    let Ok(kv_rows) = kv.read_rows(b, start, self.block_slots) else {
                        break;
                    };
                    let at = lease.blocks.len() - node_cost;
                    let blocks: Vec<u32> = lease.blocks.split_off(at);
                    self.held_blocks += blocks.len();
                    inserted += 1;
                    let feats = row_feats
                        [start * self.feat_dim..(start + self.block_slots) * self.feat_dim]
                        .to_vec();
                    self.tree.insert(cur, run.to_vec(), BlockPayload { kv_rows, feats, blocks })
                }
            };
            chain.push(id);
            cur = Some(id);
        }
        self.tree.touch(&chain);
        inserted
    }

    /// A node is reclaimable when its whole subtree is unpinned (no
    /// block shared with a live lease) and unprotected (not part of a
    /// chain the scheduler counts on adopting this step).
    fn clean_blocks(&self, id: usize, pool: &BlockPool, protect: &HashSet<usize>) -> (bool, usize) {
        let node = self.tree.get(id);
        let mut clean =
            !protect.contains(&id) && !node.payload.blocks.iter().any(|&b| pool.is_shared(b));
        let mut blocks = 0usize;
        for &child in node.children.values() {
            let (c_clean, c_blocks) = self.clean_blocks(child, pool, protect);
            clean &= c_clean;
            blocks += c_blocks;
        }
        if clean {
            blocks += node.payload.blocks.len();
        }
        (clean, blocks)
    }

    /// Blocks the scheduler may count on reclaiming via
    /// [`evict_lru`](Self::evict_lru) with the same `protect` set — a
    /// conservative (never over-promising) bound, since eviction is
    /// leaf-first and pinned/protected nodes anchor their ancestors.
    pub fn evictable_blocks(&self, pool: &BlockPool, protect: &HashSet<usize>) -> usize {
        self.tree.root_ids().map(|id| self.clean_blocks(id, pool, protect).1).sum()
    }

    /// Evict least-recently-used unpinned leaves until at least `want`
    /// blocks went back to the free list (refcount==0 reclamation —
    /// ordered before preemption in the scheduler). Returns the blocks
    /// actually freed.
    pub fn evict_lru(
        &mut self,
        pool: &mut BlockPool,
        want: usize,
        protect: &HashSet<usize>,
    ) -> usize {
        let mut freed = 0usize;
        while freed < want {
            let victim = self
                .tree
                .ids()
                .filter(|&id| self.tree.is_leaf(id))
                .filter(|id| !protect.contains(id))
                .filter(|&id| {
                    !self.tree.get(id).payload.blocks.iter().any(|&b| pool.is_shared(b))
                })
                .min_by_key(|&id| (self.tree.get(id).last_touch, id));
            let Some(id) = victim else { break };
            let payload = self.tree.remove_leaf(id);
            self.held_blocks -= payload.blocks.len();
            freed += pool.release_blocks(&payload.blocks);
        }
        freed
    }

    /// Release every index-held block (engine shutdown). Returns the
    /// blocks freed.
    pub fn clear(&mut self, pool: &mut BlockPool) -> usize {
        let mut freed = 0usize;
        for payload in self.tree.drain() {
            self.held_blocks -= payload.blocks.len();
            freed += pool.release_blocks(&payload.blocks);
        }
        debug_assert_eq!(self.held_blocks, 0);
        freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BS: usize = 2;
    const LAYERS: usize = 1;
    const FEAT: usize = 3;

    /// kv shaped [planes=2, B=2, S=8, KH=1, hd=2] -> row=2
    fn kv() -> KvCache {
        let mut kv = KvCache::zeros(vec![2, 2, 8, 1, 2]).unwrap();
        let data = kv.tensor_mut_for_tests();
        for (i, v) in data.iter_mut().enumerate() {
            *v = i as f32;
        }
        kv
    }

    fn feats_for(toks: &[i32]) -> Vec<f32> {
        toks.iter().flat_map(|&t| (0..FEAT).map(move |k| (t * 10 + k as i32) as f32)).collect()
    }

    /// Publish `toks` as lane `b`'s committed rows.
    fn publish_all(
        cache: &mut PrefixCache,
        pool: &mut BlockPool,
        kv: &mut KvCache,
        b: usize,
        toks: &[i32],
    ) -> usize {
        let mut lease = Lease::default();
        pool.ensure(&mut lease, 8, LAYERS).unwrap();
        kv.set_len(b, toks.len());
        let n = cache.publish(pool, &mut lease, toks, &feats_for(toks), kv, b);
        pool.release(&mut lease);
        n
    }

    #[test]
    fn publish_then_adopt_roundtrips_rows_and_feats() {
        let mut cache = PrefixCache::new(true, BS, LAYERS, FEAT);
        let mut pool = BlockPool::new(64, BS);
        let mut kv = kv();
        let toks = [5, 6, 7, 8];
        assert_eq!(publish_all(&mut cache, &mut pool, &mut kv, 1, &toks), 2);
        assert_eq!(cache.nodes(), 2);
        let node_cost = pool.blocks_for(BS, LAYERS);
        assert_eq!(cache.held_blocks(), 2 * node_cost);
        assert_eq!(pool.leaked_blocks(), 2 * node_cost, "index holds its blocks");

        // a follow-up prompt sharing the prefix hits both runs (the
        // 5th token stays uncached: the last token always prefills)
        let hit = cache.lookup(&[5, 6, 7, 8, 9]);
        assert_eq!(hit.tokens, 4);
        assert_eq!(hit.blocks, 2 * node_cost);
        // ...but an exact-length prompt must leave one token to prefill
        assert_eq!(cache.peek(&[5, 6, 7, 8]).tokens, 2);
        assert_eq!(cache.peek(&[9, 9]).tokens, 0);

        // adopt into lane 0 of a fresh kv: rows and feats come back
        let mut dst = KvCache::zeros(vec![2, 2, 8, 1, 2]).unwrap();
        let mut lease = Lease::default();
        let feats = cache.adopt(&hit, &mut pool, &mut dst, 0, &mut lease).unwrap();
        assert_eq!(lease.blocks.len(), hit.blocks);
        assert_eq!(feats, feats_for(&toks));
        for slot in 0..4 {
            assert_eq!(dst.row(0, 0, slot), kv.row(0, 1, slot));
            assert_eq!(dst.row(1, 0, slot), kv.row(1, 1, slot));
        }
        // sharing charged no capacity; blocks are pinned while adopted
        assert!(lease.blocks.iter().all(|&blk| pool.is_shared(blk)));
        assert_eq!(cache.evictable_blocks(&pool, &HashSet::new()), 0);
        pool.release(&mut lease);
        assert_eq!(cache.evictable_blocks(&pool, &HashSet::new()), 2 * node_cost);
        assert_eq!(cache.clear(&mut pool), 2 * node_cost);
        assert_eq!(pool.leaked_blocks(), 0);
    }

    #[test]
    fn eviction_is_lru_leaf_first_and_respects_protection() {
        let mut cache = PrefixCache::new(true, BS, LAYERS, FEAT);
        let mut pool = BlockPool::new(64, BS);
        let mut kv = kv();
        let node_cost = pool.blocks_for(BS, LAYERS);
        // two chains: [1,2]->[3,4] and [9,9]
        publish_all(&mut cache, &mut pool, &mut kv, 0, &[1, 2, 3, 4]);
        publish_all(&mut cache, &mut pool, &mut kv, 0, &[9, 9]);
        assert_eq!(cache.nodes(), 3);
        // refresh the [9,9] chain so the deep chain's leaf is LRU
        cache.lookup(&[9, 9, 0]);
        let protect: HashSet<usize> = HashSet::new();
        assert_eq!(cache.evictable_blocks(&pool, &protect), 3 * node_cost);
        let freed = cache.evict_lru(&mut pool, 1, &protect);
        assert_eq!(freed, node_cost, "evicts whole nodes");
        // the [3,4] leaf went first; its parent remains matchable
        assert_eq!(cache.peek(&[1, 2, 3, 4, 0]).tokens, 2);
        assert_eq!(cache.peek(&[9, 9, 0]).tokens, 2);
        // protecting the remaining chains blocks further eviction
        let all: HashSet<usize> = cache.tree.ids().collect();
        assert_eq!(cache.evictable_blocks(&pool, &all), 0);
        assert_eq!(cache.evict_lru(&mut pool, 100, &all), 0);
        // unprotected, everything drains leaf-first
        let freed = cache.evict_lru(&mut pool, 100, &protect);
        assert_eq!(freed, 2 * node_cost);
        assert_eq!(cache.nodes(), 0);
        assert_eq!(pool.leaked_blocks(), 0);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut cache = PrefixCache::new(false, BS, LAYERS, FEAT);
        let mut pool = BlockPool::new(16, BS);
        let mut kv = kv();
        assert_eq!(publish_all(&mut cache, &mut pool, &mut kv, 0, &[1, 2, 3, 4]), 0);
        assert_eq!(cache.nodes(), 0);
        assert_eq!(cache.lookup(&[1, 2, 3]).tokens, 0);
        assert_eq!(pool.leaked_blocks(), 0);
    }

    #[test]
    fn publish_dedups_shared_prefixes() {
        let mut cache = PrefixCache::new(true, BS, LAYERS, FEAT);
        let mut pool = BlockPool::new(64, BS);
        let mut kv = kv();
        assert_eq!(publish_all(&mut cache, &mut pool, &mut kv, 0, &[1, 2, 3, 4]), 2);
        // same prefix, diverging tail: only the new run is inserted
        assert_eq!(publish_all(&mut cache, &mut pool, &mut kv, 0, &[1, 2, 7, 8]), 1);
        assert_eq!(cache.nodes(), 3);
        assert_eq!(cache.peek(&[1, 2, 7, 8, 0]).tokens, 4);
        assert_eq!(cache.peek(&[1, 2, 3, 4, 0]).tokens, 4);
        cache.clear(&mut pool);
        assert_eq!(pool.leaked_blocks(), 0);
    }
}
