//! Radix index over token-id block runs.
//!
//! Keys are fixed-width runs of `block_slots` token ids, so every edge
//! in the trie corresponds to exactly one published KV block per layer
//! plane — a chain of nodes from the root *is* a cached prefix, and the
//! node payloads carry what a new lease adopts. Keeping the granularity
//! at whole blocks means shared blocks are always full: nobody ever
//! appends into a shared block, which is what keeps the
//! copy-on-write fork (`BlockPool::fork_tail`) a guard rather than a
//! hot path.
//!
//! The tree is a slab (`Vec<Option<Node>>` + free list) so node ids
//! stay stable across removals; recency is a logical tick counter, not
//! wall time, so behavior is deterministic under test.

use std::collections::HashMap;

/// One `block_slots` run of a cached prefix.
#[derive(Debug)]
pub struct Node<P> {
    /// the token-id run this edge matches
    pub run: Vec<i32>,
    /// parent node id; `None` means child of the root
    pub parent: Option<usize>,
    /// children keyed by their full run
    pub children: HashMap<Vec<i32>, usize>,
    /// logical recency (larger = more recently used)
    pub last_touch: u64,
    pub payload: P,
}

#[derive(Debug)]
pub struct RadixTree<P> {
    slab: Vec<Option<Node<P>>>,
    free: Vec<usize>,
    roots: HashMap<Vec<i32>, usize>,
    tick: u64,
    live: usize,
}

impl<P> Default for RadixTree<P> {
    fn default() -> Self {
        RadixTree { slab: Vec::new(), free: Vec::new(), roots: HashMap::new(), tick: 0, live: 0 }
    }
}

impl<P> RadixTree<P> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    pub fn get(&self, id: usize) -> &Node<P> {
        self.slab[id].as_ref().expect("live node id")
    }

    /// Resolve the child of `parent` (or of the root) matching `run`.
    pub fn child_of(&self, parent: Option<usize>, run: &[i32]) -> Option<usize> {
        let map = match parent {
            Some(p) => &self.get(p).children,
            None => &self.roots,
        };
        map.get(run).copied()
    }

    /// Walk the longest chain of nodes matching `runs` from the root.
    pub fn walk<'a>(&self, runs: impl Iterator<Item = &'a [i32]>) -> Vec<usize> {
        let mut chain = Vec::new();
        let mut cur = None;
        for run in runs {
            match self.child_of(cur, run) {
                Some(id) => {
                    chain.push(id);
                    cur = Some(id);
                }
                None => break,
            }
        }
        chain
    }

    /// Insert a new child under `parent` (or the root). The run must
    /// not already have a child there.
    pub fn insert(&mut self, parent: Option<usize>, run: Vec<i32>, payload: P) -> usize {
        self.tick += 1;
        let node = Node {
            run: run.clone(),
            parent,
            children: HashMap::new(),
            last_touch: self.tick,
            payload,
        };
        let id = match self.free.pop() {
            Some(i) => {
                self.slab[i] = Some(node);
                i
            }
            None => {
                self.slab.push(Some(node));
                self.slab.len() - 1
            }
        };
        let map = match parent {
            Some(p) => &mut self.slab[p].as_mut().expect("live parent").children,
            None => &mut self.roots,
        };
        let prev = map.insert(run, id);
        debug_assert!(prev.is_none(), "duplicate radix edge");
        self.live += 1;
        id
    }

    /// Bump recency on a chain of node ids (one lookup/publish = one tick).
    pub fn touch(&mut self, chain: &[usize]) {
        self.tick += 1;
        for &id in chain {
            self.slab[id].as_mut().expect("live node id").last_touch = self.tick;
        }
    }

    pub fn is_leaf(&self, id: usize) -> bool {
        self.get(id).children.is_empty()
    }

    /// Remove a leaf and return its payload. Panics on interior nodes —
    /// eviction is leaf-first by construction.
    pub fn remove_leaf(&mut self, id: usize) -> P {
        let node = self.slab[id].take().expect("live node id");
        assert!(node.children.is_empty(), "remove_leaf on interior node");
        let map = match node.parent {
            Some(p) => &mut self.slab[p].as_mut().expect("live parent").children,
            None => &mut self.roots,
        };
        map.remove(&node.run);
        self.free.push(id);
        self.live -= 1;
        node.payload
    }

    /// Ids of all live nodes (arbitrary order).
    pub fn ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.slab.iter().enumerate().filter_map(|(i, n)| n.as_ref().map(|_| i))
    }

    /// Ids of the root's children (chain heads).
    pub fn root_ids(&self) -> impl Iterator<Item = usize> + '_ {
        self.roots.values().copied()
    }

    /// Drain every node's payload (shutdown).
    pub fn drain(&mut self) -> Vec<P> {
        let out = self.slab.drain(..).flatten().map(|n| n.payload).collect();
        self.free.clear();
        self.roots.clear();
        self.live = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runs(tree: &RadixTree<u32>, toks: &[i32], bs: usize) -> Vec<usize> {
        tree.walk(toks.chunks_exact(bs))
    }

    #[test]
    fn walk_matches_longest_prefix() {
        let mut t: RadixTree<u32> = RadixTree::new();
        let a = t.insert(None, vec![1, 2], 10);
        let b = t.insert(Some(a), vec![3, 4], 20);
        t.insert(Some(a), vec![5, 6], 30); // sibling branch
        assert_eq!(runs(&t, &[1, 2, 3, 4, 9, 9], 2), vec![a, b]);
        assert_eq!(runs(&t, &[1, 2, 7, 7], 2), vec![a]);
        assert_eq!(runs(&t, &[9, 9], 2), Vec::<usize>::new());
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn touch_orders_recency() {
        let mut t: RadixTree<u32> = RadixTree::new();
        let a = t.insert(None, vec![1], 0);
        let b = t.insert(None, vec![2], 0);
        assert!(t.get(a).last_touch < t.get(b).last_touch);
        t.touch(&[a]);
        assert!(t.get(a).last_touch > t.get(b).last_touch);
    }

    #[test]
    fn remove_leaf_recycles_ids() {
        let mut t: RadixTree<u32> = RadixTree::new();
        let a = t.insert(None, vec![1], 1);
        let b = t.insert(Some(a), vec![2], 2);
        assert!(!t.is_leaf(a));
        assert_eq!(t.remove_leaf(b), 2);
        assert!(t.is_leaf(a));
        // freed id gets reused; the old edge is gone
        let c = t.insert(None, vec![3], 3);
        assert_eq!(c, b);
        assert_eq!(t.child_of(Some(a), &[2]), None);
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "interior")]
    fn remove_interior_panics() {
        let mut t: RadixTree<u32> = RadixTree::new();
        let a = t.insert(None, vec![1], 1);
        t.insert(Some(a), vec![2], 2);
        t.remove_leaf(a);
    }
}
