//! Serving-level metrics: request latency histograms, token throughput,
//! τ aggregation, admission-control counters and scheduler gauges — the
//! numbers the Table-3 harness and the API server's /stats endpoint
//! report.
//!
//! Admission outcomes are split three ways:
//! * `requests_done` — completed generations;
//! * `requests_rejected` — true sheds (bounded admission queue full, or
//!   the server closing), the HTTP-429 analogue;
//! * `requests_deferred` — requests that had a free slot but had to wait
//!   on the KV block pool; each distinct request is counted **once** no
//!   matter how many scheduler passes it waits through.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::stats::Histogram;

#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub started: Instant,
    pub requests_done: u64,
    /// true sheds: queue full / server closed
    pub requests_rejected: u64,
    /// distinct requests deferred on KV-pool pressure
    pub requests_deferred: u64,
    /// requests answered with an error (admission failure, engine
    /// error, or a stall abort) — so done + failed covers every
    /// admitted-or-aborted request
    pub requests_failed: u64,
    /// requests evicted by an explicit `{"cmd":"cancel"}` (counted
    /// separately from failures: cancellation is client intent)
    pub requests_canceled: u64,
    /// requests answered "deadline exceeded" by the per-step sweep
    pub requests_expired: u64,
    pub tokens_out: u64,
    pub cycles: u64,
    pub tau_sum: f64,
    /// prompt chunks ingested on the batched lane (chunked prefill)
    pub prefill_chunks: u64,
    /// slots paused under pool pressure (lease shrunk, state parked)
    pub preemptions: u64,
    /// parked requests restored into a slot
    pub resumes: u64,
    /// parked-token gauge: committed tokens held by parked requests,
    /// sampled once per scheduler step
    pub parked_tokens: u64,
    pub parked_tokens_peak: u64,
    /// gauge sample count (lets `merge` distinguish "other never
    /// sampled" from "other sampled zero")
    pub parked_samples: u64,
    /// per-slot draft-plan decisions, one sample per run cycle: what
    /// depth/node count the planner chose (the observable trace of
    /// adaptive draft structures — min == max means the shape never
    /// moved)
    pub plan_samples: u64,
    pub plan_depth_sum: u64,
    pub plan_nodes_sum: u64,
    pub plan_depth_min: u64,
    pub plan_depth_max: u64,
    /// rolling acceptance-window means reported by adaptive planners
    pub accept_window_sum: f64,
    pub accept_window_samples: u64,
    /// admissions whose prompt matched a cached prefix (tokens adopted
    /// instead of prefilled)
    pub cache_hits: u64,
    /// admissions that looked up the prefix cache and found nothing
    pub cache_misses: u64,
    /// prompt tokens adopted from the cache (prefill work avoided)
    pub cache_saved_tokens: u64,
    /// pool blocks reclaimed from the cache under pressure
    pub cache_evicted_blocks: u64,
    /// prefix-cache gauges, sampled once per scheduler step
    pub cache_nodes: u64,
    pub cache_blocks: u64,
    pub cache_samples: u64,
    /// arrival -> completion
    pub latency: Histogram,
    /// arrival -> slot admission
    pub queue_wait: Histogram,
    /// arrival -> end of first decode cycle (time-to-first-cycle, the
    /// serving-side TTFT analogue)
    pub ttfc: Histogram,
    /// slot-occupancy gauge: active slots sampled once per scheduler step
    pub occupancy_sum: u64,
    pub occupancy_samples: u64,
    pub occupancy_peak: usize,
    /// per-(method, phase) wall-time histograms over the batched engine's
    /// sections — phases are `"sched"`, `"draft"`, `"verify"`, `"accept"`,
    /// methods are `BatchMethod::name()` strings. Batched sections that
    /// serve several methods at once (the shared verify call) record one
    /// sample per method present, so fasteagle vs eagle3 draft cost stays
    /// comparable per cycle. Always on — independent of the `obs` flight
    /// recorder.
    pub phase_us: BTreeMap<(&'static str, &'static str), Histogram>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            started: Instant::now(),
            requests_done: 0,
            requests_rejected: 0,
            requests_deferred: 0,
            requests_failed: 0,
            requests_canceled: 0,
            requests_expired: 0,
            tokens_out: 0,
            cycles: 0,
            tau_sum: 0.0,
            prefill_chunks: 0,
            preemptions: 0,
            resumes: 0,
            parked_tokens: 0,
            parked_tokens_peak: 0,
            parked_samples: 0,
            plan_samples: 0,
            plan_depth_sum: 0,
            plan_nodes_sum: 0,
            plan_depth_min: u64::MAX,
            plan_depth_max: 0,
            accept_window_sum: 0.0,
            accept_window_samples: 0,
            cache_hits: 0,
            cache_misses: 0,
            cache_saved_tokens: 0,
            cache_evicted_blocks: 0,
            cache_nodes: 0,
            cache_blocks: 0,
            cache_samples: 0,
            latency: Histogram::new(),
            queue_wait: Histogram::new(),
            ttfc: Histogram::new(),
            occupancy_sum: 0,
            occupancy_samples: 0,
            occupancy_peak: 0,
            phase_us: BTreeMap::new(),
        }
    }
}

impl ServingMetrics {
    /// A request moved from the pending queue into an engine slot.
    pub fn record_admitted(&mut self, queue_wait: Duration) {
        self.queue_wait.record_us(queue_wait.as_secs_f64() * 1e6);
    }

    /// A request finished its first decode cycle (`since_arrival` spans
    /// queue wait + prefill + one batched iteration).
    pub fn record_first_cycle(&mut self, since_arrival: Duration) {
        self.ttfc.record_us(since_arrival.as_secs_f64() * 1e6);
    }

    /// Sample the parked-token gauge at one scheduler step.
    pub fn record_parked(&mut self, tokens: usize) {
        self.parked_tokens = tokens as u64;
        self.parked_tokens_peak = self.parked_tokens_peak.max(tokens as u64);
        self.parked_samples += 1;
    }

    /// Record one slot's draft-plan decision for one cycle: planned
    /// depth, planned node count, and (for adaptive planners) the
    /// rolling acceptance-window mean that produced it.
    pub fn record_plan(&mut self, depth: usize, nodes: usize, window_mean: Option<f64>) {
        self.plan_samples += 1;
        self.plan_depth_sum += depth as u64;
        self.plan_nodes_sum += nodes as u64;
        self.plan_depth_min = self.plan_depth_min.min(depth as u64);
        self.plan_depth_max = self.plan_depth_max.max(depth as u64);
        if let Some(w) = window_mean {
            self.accept_window_sum += w;
            self.accept_window_samples += 1;
        }
    }

    /// Record one engine section's wall time under a (method, phase) key.
    pub fn record_phase(&mut self, method: &'static str, phase: &'static str, wall: Duration) {
        self.phase_us
            .entry((method, phase))
            .or_default()
            .record_us(wall.as_secs_f64() * 1e6);
    }

    /// Look up one (method, phase) histogram.
    pub fn phase_hist(&self, method: &str, phase: &str) -> Option<&Histogram> {
        self.phase_us
            .iter()
            .find(|((m, p), _)| *m == method && *p == phase)
            .map(|(_, h)| h)
    }

    /// Sample the prefix-cache gauges at one scheduler step.
    pub fn record_cache_gauges(&mut self, nodes: usize, blocks: usize) {
        self.cache_nodes = nodes as u64;
        self.cache_blocks = blocks as u64;
        self.cache_samples += 1;
    }

    /// Prefix-cache hit rate over admissions that consulted the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Sample the number of occupied slots at one scheduler step.
    pub fn record_occupancy(&mut self, active: usize) {
        self.occupancy_sum += active as u64;
        self.occupancy_samples += 1;
        self.occupancy_peak = self.occupancy_peak.max(active);
    }

    pub fn record_done(
        &mut self,
        new_tokens: usize,
        cycles: usize,
        tau: f64,
        latency: Duration,
    ) {
        self.requests_done += 1;
        self.tokens_out += new_tokens as u64;
        self.cycles += cycles as u64;
        self.tau_sum += tau * cycles as f64;
        self.latency.record_us(latency.as_secs_f64() * 1e6);
    }

    /// Fold another metrics block into this one (counters add,
    /// histograms merge, `started` keeps self's epoch). Lets the engine
    /// record into a lock-free local delta that is merged into a shared
    /// `Mutex<ServingMetrics>` in one short critical section.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.requests_done += other.requests_done;
        self.requests_rejected += other.requests_rejected;
        self.requests_deferred += other.requests_deferred;
        self.requests_failed += other.requests_failed;
        self.requests_canceled += other.requests_canceled;
        self.requests_expired += other.requests_expired;
        self.tokens_out += other.tokens_out;
        self.cycles += other.cycles;
        self.tau_sum += other.tau_sum;
        self.prefill_chunks += other.prefill_chunks;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        if other.parked_samples > 0 {
            self.parked_tokens = other.parked_tokens;
        }
        self.parked_tokens_peak = self.parked_tokens_peak.max(other.parked_tokens_peak);
        self.parked_samples += other.parked_samples;
        self.plan_samples += other.plan_samples;
        self.plan_depth_sum += other.plan_depth_sum;
        self.plan_nodes_sum += other.plan_nodes_sum;
        self.plan_depth_min = self.plan_depth_min.min(other.plan_depth_min);
        self.plan_depth_max = self.plan_depth_max.max(other.plan_depth_max);
        self.accept_window_sum += other.accept_window_sum;
        self.accept_window_samples += other.accept_window_samples;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_saved_tokens += other.cache_saved_tokens;
        self.cache_evicted_blocks += other.cache_evicted_blocks;
        if other.cache_samples > 0 {
            self.cache_nodes = other.cache_nodes;
            self.cache_blocks = other.cache_blocks;
        }
        self.cache_samples += other.cache_samples;
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.ttfc.merge(&other.ttfc);
        self.occupancy_sum += other.occupancy_sum;
        self.occupancy_samples += other.occupancy_samples;
        self.occupancy_peak = self.occupancy_peak.max(other.occupancy_peak);
        for (&key, h) in &other.phase_us {
            self.phase_us.entry(key).or_default().merge(h);
        }
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / el
        }
    }

    pub fn mean_tau(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.tau_sum / self.cycles as f64
        }
    }

    /// Mean occupied slots per scheduler step.
    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy_samples == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.occupancy_samples as f64
        }
    }

    /// Mean planned draft depth per run cycle.
    pub fn mean_plan_depth(&self) -> f64 {
        if self.plan_samples == 0 {
            0.0
        } else {
            self.plan_depth_sum as f64 / self.plan_samples as f64
        }
    }

    /// Mean planned draft-node count per run cycle.
    pub fn mean_plan_nodes(&self) -> f64 {
        if self.plan_samples == 0 {
            0.0
        } else {
            self.plan_nodes_sum as f64 / self.plan_samples as f64
        }
    }

    /// Mean of the adaptive planners' rolling acceptance-window means.
    pub fn mean_accept_window(&self) -> f64 {
        if self.accept_window_samples == 0 {
            0.0
        } else {
            self.accept_window_sum / self.accept_window_samples as f64
        }
    }

    pub fn report(&self) -> String {
        let plan = if self.plan_samples == 0 {
            "plan_d=- plan_n=-".to_string()
        } else {
            format!(
                "plan_d={:.2}[{}-{}] plan_n={:.2} acc_win={:.2}",
                self.mean_plan_depth(),
                self.plan_depth_min,
                self.plan_depth_max,
                self.mean_plan_nodes(),
                self.mean_accept_window(),
            )
        };
        let cache = if self.cache_hits + self.cache_misses == 0 {
            String::new()
        } else {
            format!(
                " cache={}/{} saved={} evicted={}",
                self.cache_hits,
                self.cache_hits + self.cache_misses,
                self.cache_saved_tokens,
                self.cache_evicted_blocks,
            )
        };
        format!(
            "done={} rejected={} deferred={} failed={} canceled={} expired={} tokens={} \
             tok/s={:.1} tau={:.2} \
             p50={:.0}ms p99={:.0}ms wait_p50={:.0}ms ttfc_p50={:.0}ms occ={:.2}/{} \
             pfc={} preempt={} resume={} parked={}/{} {plan}{cache}",
            self.requests_done,
            self.requests_rejected,
            self.requests_deferred,
            self.requests_failed,
            self.requests_canceled,
            self.requests_expired,
            self.tokens_out,
            self.tokens_per_sec(),
            self.mean_tau(),
            self.latency.percentile_us(0.5) / 1e3,
            self.latency.percentile_us(0.99) / 1e3,
            self.queue_wait.percentile_us(0.5) / 1e3,
            self.ttfc.percentile_us(0.5) / 1e3,
            self.mean_occupancy(),
            self.occupancy_peak,
            self.prefill_chunks,
            self.preemptions,
            self.resumes,
            self.parked_tokens,
            self.parked_tokens_peak,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = ServingMetrics::default();
        m.record_admitted(Duration::from_millis(5));
        m.record_done(10, 4, 2.5, Duration::from_millis(100));
        m.record_admitted(Duration::from_millis(1));
        m.record_done(20, 5, 4.0, Duration::from_millis(200));
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.tokens_out, 30);
        let tau = m.mean_tau();
        assert!((tau - (2.5 * 4.0 + 4.0 * 5.0) / 9.0).abs() < 1e-9, "{tau}");
        assert!(m.latency.percentile_us(0.5) > 0.0);
        assert!(m.queue_wait.count() == 2);
        assert!(!m.report().is_empty());
    }

    #[test]
    fn occupancy_gauge() {
        let mut m = ServingMetrics::default();
        m.record_occupancy(1);
        m.record_occupancy(3);
        m.record_occupancy(2);
        assert!((m.mean_occupancy() - 2.0).abs() < 1e-9);
        assert_eq!(m.occupancy_peak, 3);
    }

    #[test]
    fn ttfc_recorded() {
        let mut m = ServingMetrics::default();
        m.record_first_cycle(Duration::from_millis(40));
        assert_eq!(m.ttfc.count(), 1);
        assert!(m.ttfc.percentile_us(0.5) > 30_000.0);
    }

    #[test]
    fn merge_folds_deltas() {
        let mut shared = ServingMetrics::default();
        shared.requests_rejected = 1;
        let mut delta = ServingMetrics::default();
        delta.record_admitted(Duration::from_millis(2));
        delta.record_first_cycle(Duration::from_millis(9));
        delta.record_occupancy(3);
        delta.record_done(5, 2, 2.0, Duration::from_millis(20));
        delta.requests_deferred = 1;
        shared.merge(&delta);
        assert_eq!(shared.requests_done, 1);
        assert_eq!(shared.requests_rejected, 1);
        assert_eq!(shared.requests_deferred, 1);
        assert_eq!(shared.tokens_out, 5);
        assert_eq!(shared.queue_wait.count(), 1);
        assert_eq!(shared.ttfc.count(), 1);
        assert_eq!(shared.occupancy_peak, 3);
        assert!((shared.mean_tau() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn parked_gauge_merges_as_latest_sample_and_peak() {
        let mut shared = ServingMetrics::default();
        shared.record_parked(20);
        let mut delta = ServingMetrics::default();
        delta.record_parked(7);
        delta.preemptions = 1;
        delta.resumes = 1;
        delta.prefill_chunks = 5;
        shared.merge(&delta);
        assert_eq!(shared.parked_tokens, 7, "gauge takes the newer sample");
        assert_eq!(shared.parked_tokens_peak, 20);
        assert_eq!(shared.preemptions, 1);
        assert_eq!(shared.resumes, 1);
        assert_eq!(shared.prefill_chunks, 5);
        // a delta that never sampled the gauge leaves it untouched
        let empty = ServingMetrics::default();
        shared.merge(&empty);
        assert_eq!(shared.parked_tokens, 7);
        let r = shared.report();
        assert!(r.contains("preempt=1") && r.contains("parked=7/20"), "{r}");
    }

    #[test]
    fn plan_gauges_record_and_merge() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.mean_plan_depth(), 0.0);
        assert!(m.report().contains("plan_d=-"), "unsampled plans render as dashes");
        m.record_plan(2, 2, None);
        m.record_plan(1, 1, Some(0.5));
        assert_eq!(m.plan_samples, 2);
        assert!((m.mean_plan_depth() - 1.5).abs() < 1e-9);
        assert!((m.mean_plan_nodes() - 1.5).abs() < 1e-9);
        assert_eq!(m.plan_depth_min, 1);
        assert_eq!(m.plan_depth_max, 2);
        assert!((m.mean_accept_window() - 0.5).abs() < 1e-9);
        let mut delta = ServingMetrics::default();
        delta.record_plan(3, 6, Some(1.5));
        m.merge(&delta);
        assert_eq!(m.plan_samples, 3);
        assert_eq!(m.plan_depth_max, 3);
        assert_eq!(m.plan_depth_min, 1);
        assert!((m.mean_plan_nodes() - 3.0).abs() < 1e-9);
        assert!((m.mean_accept_window() - 1.0).abs() < 1e-9);
        // merging an unsampled delta leaves the min untouched
        m.merge(&ServingMetrics::default());
        assert_eq!(m.plan_depth_min, 1);
        let r = m.report();
        assert!(r.contains("plan_d=2.00[1-3]"), "{r}");
        assert!(r.contains("plan_n=3.00"), "{r}");
    }

    #[test]
    fn phase_histograms_record_per_method_and_merge() {
        let mut m = ServingMetrics::default();
        m.record_phase("fasteagle", "draft", Duration::from_micros(120));
        m.record_phase("fasteagle", "draft", Duration::from_micros(180));
        m.record_phase("eagle3", "draft", Duration::from_micros(900));
        m.record_phase("fasteagle", "verify", Duration::from_micros(400));
        assert_eq!(m.phase_hist("fasteagle", "draft").map(Histogram::count), Some(2));
        assert_eq!(m.phase_hist("eagle3", "draft").map(Histogram::count), Some(1));
        assert!(m.phase_hist("vanilla", "draft").is_none());
        let mut delta = ServingMetrics::default();
        delta.record_phase("fasteagle", "draft", Duration::from_micros(150));
        delta.record_phase("eagle3", "verify", Duration::from_micros(700));
        m.merge(&delta);
        assert_eq!(m.phase_hist("fasteagle", "draft").map(Histogram::count), Some(3));
        assert_eq!(m.phase_hist("eagle3", "verify").map(Histogram::count), Some(1));
        // the two methods stay distinct series
        let fe = m.phase_hist("fasteagle", "draft").expect("fe series").mean_us();
        let eg = m.phase_hist("eagle3", "draft").expect("eg series").mean_us();
        assert!(fe < eg, "fe {fe} vs eg {eg}");
    }

    #[test]
    fn cache_counters_record_and_merge() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert!(!m.report().contains("cache="), "cold engines stay quiet");
        m.cache_hits = 2;
        m.cache_misses = 1;
        m.cache_saved_tokens = 64;
        m.record_cache_gauges(4, 16);
        assert!((m.cache_hit_rate() - 2.0 / 3.0).abs() < 1e-9);
        let mut delta = ServingMetrics::default();
        delta.cache_hits = 1;
        delta.cache_evicted_blocks = 8;
        delta.record_cache_gauges(2, 6);
        m.merge(&delta);
        assert_eq!(m.cache_hits, 3);
        assert_eq!(m.cache_saved_tokens, 64);
        assert_eq!(m.cache_evicted_blocks, 8);
        assert_eq!(m.cache_nodes, 2, "gauge takes the newer sample");
        assert_eq!(m.cache_blocks, 6);
        // an unsampled delta leaves the gauges untouched
        m.merge(&ServingMetrics::default());
        assert_eq!(m.cache_nodes, 2);
        let r = m.report();
        assert!(r.contains("cache=3/4") && r.contains("saved=64"), "{r}");
    }

    #[test]
    fn deferred_and_rejected_are_distinct_counters() {
        let mut m = ServingMetrics::default();
        m.requests_deferred += 1;
        m.requests_rejected += 2;
        assert_eq!(m.requests_deferred, 1);
        assert_eq!(m.requests_rejected, 2);
        let r = m.report();
        assert!(r.contains("rejected=2") && r.contains("deferred=1"), "{r}");
    }

    #[test]
    fn canceled_and_expired_count_and_merge() {
        let mut m = ServingMetrics::default();
        m.requests_canceled += 2;
        m.requests_expired += 1;
        let mut delta = ServingMetrics::default();
        delta.requests_canceled += 1;
        delta.requests_expired += 3;
        m.merge(&delta);
        assert_eq!(m.requests_canceled, 3);
        assert_eq!(m.requests_expired, 4);
        let r = m.report();
        assert!(r.contains("canceled=3") && r.contains("expired=4"), "{r}");
    }
}
