//! Serving-level metrics: request latency histograms, token throughput,
//! τ aggregation — the numbers the Table-3 harness and the API server's
//! /stats endpoint report.

use std::time::{Duration, Instant};

use crate::util::stats::Histogram;

#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub started: Instant,
    pub requests_done: u64,
    pub requests_rejected: u64,
    pub tokens_out: u64,
    pub cycles: u64,
    pub tau_sum: f64,
    pub latency: Histogram,
    pub queue_wait: Histogram,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            started: Instant::now(),
            requests_done: 0,
            requests_rejected: 0,
            tokens_out: 0,
            cycles: 0,
            tau_sum: 0.0,
            latency: Histogram::new(),
            queue_wait: Histogram::new(),
        }
    }
}

impl ServingMetrics {
    pub fn record_done(
        &mut self,
        new_tokens: usize,
        cycles: usize,
        tau: f64,
        latency: Duration,
        queue_wait: Duration,
    ) {
        self.requests_done += 1;
        self.tokens_out += new_tokens as u64;
        self.cycles += cycles as u64;
        self.tau_sum += tau * cycles as f64;
        self.latency.record_us(latency.as_secs_f64() * 1e6);
        self.queue_wait.record_us(queue_wait.as_secs_f64() * 1e6);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let el = self.started.elapsed().as_secs_f64();
        if el <= 0.0 {
            0.0
        } else {
            self.tokens_out as f64 / el
        }
    }

    pub fn mean_tau(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.tau_sum / self.cycles as f64
        }
    }

    pub fn report(&self) -> String {
        format!(
            "done={} rejected={} tokens={} tok/s={:.1} tau={:.2} p50={:.0}ms p99={:.0}ms wait_p50={:.0}ms",
            self.requests_done,
            self.requests_rejected,
            self.tokens_out,
            self.tokens_per_sec(),
            self.mean_tau(),
            self.latency.percentile_us(0.5) / 1e3,
            self.latency.percentile_us(0.99) / 1e3,
            self.queue_wait.percentile_us(0.5) / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = ServingMetrics::default();
        m.record_done(10, 4, 2.5, Duration::from_millis(100), Duration::from_millis(5));
        m.record_done(20, 5, 4.0, Duration::from_millis(200), Duration::from_millis(1));
        assert_eq!(m.requests_done, 2);
        assert_eq!(m.tokens_out, 30);
        let tau = m.mean_tau();
        assert!((tau - (2.5 * 4.0 + 4.0 * 5.0) / 9.0).abs() < 1e-9, "{tau}");
        assert!(m.latency.percentile_us(0.5) > 0.0);
        assert!(!m.report().is_empty());
    }
}
