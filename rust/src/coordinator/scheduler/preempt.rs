//! Preemption mechanics: victim selection and lease-shrink accounting.
//!
//! Under KV-pool pressure the scheduler can *pause* a decoding slot
//! instead of making the incoming request wait: the victim's KV state
//! is parked on the host, its lease is shrunk to exactly the blocks
//! covering its committed tokens ([`crate::model::BlockPool::shrink`]),
//! and the freed blocks (its *shrink gain*) fund the incoming
//! admission. A parked request resumes later — lease grown back with
//! `ensure`, KV copied back verbatim — so no token is ever recomputed
//! and the committed output is byte-identical to an uninterrupted run.

use super::ActiveView;

/// Blocks a preemption would free: the victim keeps only the blocks
/// covering its committed prefix (`committed_cost`) out of its full
/// lease.
pub fn shrink_gain(lease_blocks: usize, committed_cost: usize) -> usize {
    lease_blocks.saturating_sub(committed_cost)
}

/// Default victim rule shared by the built-in policies: only slots with
/// priority *strictly below* the incoming request's are preemptible
/// (equal priority never preempts — that way two equal requests can
/// never thrash each other), lowest priority first, then the largest
/// shrink gain (fewest preemptions to fund the admission), then the
/// highest slot index for determinism.
pub fn lowest_priority_victim(
    candidates: &[ActiveView],
    incoming_priority: i32,
) -> Option<usize> {
    candidates
        .iter()
        .enumerate()
        .filter(|(_, c)| c.priority < incoming_priority)
        .max_by(|(_, a), (_, b)| {
            // max_by with reversed priority = min priority first
            b.priority
                .cmp(&a.priority)
                .then(a.shrink_gain_blocks.cmp(&b.shrink_gain_blocks))
                .then(a.slot.cmp(&b.slot))
        })
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::SlotPhase;

    fn active(slot: usize, priority: i32, gain: usize) -> ActiveView {
        ActiveView {
            slot,
            id: slot as u64,
            priority,
            phase: SlotPhase::Decoding,
            prefill_remaining: 0,
            shrink_gain_blocks: gain,
            finished: false,
        }
    }

    #[test]
    fn gain_is_lease_minus_committed() {
        assert_eq!(shrink_gain(24, 6), 18);
        assert_eq!(shrink_gain(4, 9), 0, "never underflows");
    }

    #[test]
    fn victim_rule_prefers_lowest_priority_then_gain() {
        let c = vec![active(0, 1, 9), active(1, -1, 2), active(2, -1, 5)];
        // incoming at priority 0: only the -1 slots qualify; #2 has more gain
        assert_eq!(lowest_priority_victim(&c, 0), Some(2));
        // incoming at priority 2: slot 0 (priority 1) still loses to the
        // -1 slots — lowest priority is paused first
        assert_eq!(lowest_priority_victim(&c, 2), Some(2));
        // nobody strictly below: no victim
        assert_eq!(lowest_priority_victim(&c, -1), None);
        assert_eq!(lowest_priority_victim(&[], 5), None);
    }
}
