//! Scheduling policies: who admits next, and who gets paused under
//! pool pressure.
//!
//! A [`SchedulerPolicy`] is consulted by [`super::Scheduler::plan`] at
//! every step; it never touches engine state — it only orders the
//! pending queue and picks preemption victims over read-only views, so
//! policies are trivially unit-testable and new ones (deadline-aware,
//! fair-share, AdaEAGLE-style adaptive) slot in without touching the
//! batcher.

use super::preempt::lowest_priority_victim;
use super::{ActiveView, PendingView};

/// Which built-in policy to run; selected with `--policy` on the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// first-come first-served (arrival order, priority only breaks
    /// pool-pressure ties via preemption)
    Fcfs,
    /// shortest-prompt-first within priority classes: higher-priority
    /// requests first, then shorter prompts (cheapest time-to-first-token
    /// first), then arrival order
    Spf,
    /// cache-affinity within priority classes: requests with more
    /// prefix-cache-covered tokens first — they admit while their
    /// chains are hot (and pin them against eviction), and their
    /// shortened prefill reaches first-token fastest
    Cache,
}

impl PolicyKind {
    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fcfs => "fcfs",
            PolicyKind::Spf => "spf",
            PolicyKind::Cache => "cache",
        }
    }

    pub fn from_name(name: &str) -> Option<PolicyKind> {
        Some(match name {
            "fcfs" => PolicyKind::Fcfs,
            "spf" => PolicyKind::Spf,
            "cache" => PolicyKind::Cache,
            _ => return None,
        })
    }

    pub fn build(self) -> Box<dyn SchedulerPolicy> {
        match self {
            PolicyKind::Fcfs => Box::new(FcfsPolicy),
            PolicyKind::Spf => Box::new(ShortestPromptFirst),
            PolicyKind::Cache => Box::new(CacheAffinity),
        }
    }
}

/// Pure decision interface over scheduler views. Implementations must
/// be deterministic: same views, same answers (the preemption
/// byte-identity property tests rely on it).
pub trait SchedulerPolicy {
    fn name(&self) -> &'static str;

    /// Admission order: indices into `pending`, most-preferred first.
    /// The planner honors this order strictly — if the first returned
    /// request cannot be funded (even after preemption), admission
    /// stops for this step, so an order is also a head-of-line
    /// definition.
    fn admission_order(&self, pending: &[PendingView]) -> Vec<usize>;

    /// Choose a victim among `candidates` (active, preemptible slots)
    /// to free pool blocks for `incoming`; `None` declines to preempt.
    /// Returns an index into `candidates`.
    fn preempt_victim(
        &self,
        candidates: &[ActiveView],
        incoming: &PendingView,
    ) -> Option<usize>;
}

/// Arrival order; preempts only strictly lower-priority slots.
pub struct FcfsPolicy;

impl SchedulerPolicy for FcfsPolicy {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn admission_order(&self, pending: &[PendingView]) -> Vec<usize> {
        (0..pending.len()).collect()
    }

    fn preempt_victim(
        &self,
        candidates: &[ActiveView],
        incoming: &PendingView,
    ) -> Option<usize> {
        lowest_priority_victim(candidates, incoming.priority)
    }
}

/// Priority classes first, then shortest prompt (the classic
/// time-to-first-token optimizer for interactive traffic), then
/// arrival order as the deterministic tie-break.
pub struct ShortestPromptFirst;

impl SchedulerPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn admission_order(&self, pending: &[PendingView]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..pending.len()).collect();
        order.sort_by(|&a, &b| {
            let (pa, pb) = (&pending[a], &pending[b]);
            pb.priority
                .cmp(&pa.priority)
                .then(pa.prompt_tokens.cmp(&pb.prompt_tokens))
                .then(a.cmp(&b))
        });
        order
    }

    fn preempt_victim(
        &self,
        candidates: &[ActiveView],
        incoming: &PendingView,
    ) -> Option<usize> {
        lowest_priority_victim(candidates, incoming.priority)
    }
}

/// Priority classes first, then most cached-prefix tokens (admit while
/// the chain is hot — adoption pins its blocks against eviction), then
/// shortest uncached remainder, then arrival order. With a cold cache
/// every request ties at zero cached tokens and this degrades to
/// [`ShortestPromptFirst`] ordering.
pub struct CacheAffinity;

impl SchedulerPolicy for CacheAffinity {
    fn name(&self) -> &'static str {
        "cache"
    }

    fn admission_order(&self, pending: &[PendingView]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..pending.len()).collect();
        order.sort_by(|&a, &b| {
            let (pa, pb) = (&pending[a], &pending[b]);
            let (ra, rb) = (
                pa.prompt_tokens.saturating_sub(pa.cached_tokens),
                pb.prompt_tokens.saturating_sub(pb.cached_tokens),
            );
            pb.priority
                .cmp(&pa.priority)
                .then(pb.cached_tokens.cmp(&pa.cached_tokens))
                .then(ra.cmp(&rb))
                .then(a.cmp(&b))
        });
        order
    }

    fn preempt_victim(
        &self,
        candidates: &[ActiveView],
        incoming: &PendingView,
    ) -> Option<usize> {
        lowest_priority_victim(candidates, incoming.priority)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::SlotPhase;

    fn pending(id: u64, priority: i32, prompt_tokens: usize) -> PendingView {
        PendingView {
            id,
            priority,
            prompt_tokens,
            cost_blocks: 4,
            cached_tokens: 0,
            cached_blocks: 0,
        }
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in [PolicyKind::Fcfs, PolicyKind::Spf, PolicyKind::Cache] {
            assert_eq!(PolicyKind::from_name(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(PolicyKind::from_name("lottery"), None);
    }

    #[test]
    fn fcfs_is_arrival_order() {
        let p = vec![pending(9, 0, 50), pending(1, 5, 2), pending(4, -1, 1)];
        assert_eq!(FcfsPolicy.admission_order(&p), vec![0, 1, 2]);
    }

    #[test]
    fn spf_orders_by_priority_then_prompt_then_arrival() {
        let p = vec![
            pending(0, 0, 50), // long, normal priority
            pending(1, 0, 3),  // short, normal priority
            pending(2, 2, 80), // high priority beats both
            pending(3, 0, 3),  // same as #1 -> arrival order breaks the tie
        ];
        assert_eq!(ShortestPromptFirst.admission_order(&p), vec![2, 1, 3, 0]);
    }

    #[test]
    fn cache_affinity_orders_by_cached_tokens_then_remainder() {
        let cached = |id, priority, prompt, cached| PendingView {
            id,
            priority,
            prompt_tokens: prompt,
            cost_blocks: 4,
            cached_tokens: cached,
            cached_blocks: cached / 4,
        };
        let p = vec![
            cached(0, 0, 100, 0),  // cold, long
            cached(1, 0, 100, 96), // warmest: 4 tokens to prefill
            cached(2, 2, 50, 0),   // high priority still beats warmth
            cached(3, 0, 40, 32),  // warm, but less covered than #1
            cached(4, 0, 10, 0),   // cold, short remainder (10)
        ];
        assert_eq!(CacheAffinity.admission_order(&p), vec![2, 1, 3, 4, 0]);
        // cold cache degrades to spf ordering
        let cold = vec![pending(0, 0, 50), pending(1, 0, 3), pending(2, 2, 80)];
        assert_eq!(
            CacheAffinity.admission_order(&cold),
            ShortestPromptFirst.admission_order(&cold)
        );
    }

    #[test]
    fn preemption_targets_strictly_lower_priority_only() {
        let mk = |slot, priority, gain| ActiveView {
            slot,
            id: slot as u64,
            priority,
            phase: SlotPhase::Decoding,
            prefill_remaining: 0,
            shrink_gain_blocks: gain,
            finished: false,
        };
        let candidates = vec![mk(0, 0, 4), mk(1, -2, 2), mk(2, -2, 8)];
        // equal priority never preempts
        assert_eq!(FcfsPolicy.preempt_victim(&candidates, &pending(9, 0, 4)), None);
        // lowest priority wins; larger shrink gain breaks the tie
        assert_eq!(
            ShortestPromptFirst.preempt_victim(&candidates, &pending(9, 1, 4)),
            Some(2)
        );
    }
}
