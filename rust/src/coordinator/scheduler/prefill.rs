//! Chunked-prefill progress tracking.
//!
//! Admission no longer runs a whole-prompt prefill on the B=1
//! executables (the old head-of-line block): a newly admitted request
//! enters its slot in [`crate::spec::SlotPhase::Prefilling`] carrying a
//! [`PrefillProgress`], and each scheduler step feeds the next
//! fixed-token chunk of its prompt through the *batched* target call —
//! the same call that verifies the decoding slots' trees, so prompt
//! ingestion rides along decode steps instead of stalling them. The
//! per-step chunk is bounded by the verify rows the lowered executable
//! exposes (`max_rows`) and by the engine's configured chunk size.

/// Tokens to ingest for one slot this step: the un-ingested remainder,
/// capped by the configured chunk size and by the batched call's row
/// budget. The single home of the chunk-sizing rule — the planner uses
/// it for both continuing and freshly admitted prefills.
pub fn chunk_for(remaining: usize, cfg_chunk: usize, max_rows: usize) -> usize {
    remaining.min(cfg_chunk).min(max_rows)
}

/// One admitted request's prompt-ingestion state: the (truncated)
/// prompt tokens, how many have landed in the KV prefix, and the
/// per-token features accumulated for the drafter's post-prefill
/// observe.
#[derive(Debug, Clone)]
pub struct PrefillProgress {
    pub ptoks: Vec<i32>,
    pub pos: usize,
    /// [pos, feat_dim] features of every ingested prompt token
    pub feats: Vec<f32>,
}

impl PrefillProgress {
    pub fn new(ptoks: Vec<i32>) -> PrefillProgress {
        PrefillProgress { ptoks, pos: 0, feats: Vec::new() }
    }

    /// Start past a prefix-cache hit: the first `pos` tokens' KV rows
    /// were adopted from the cache, and `feats` carries their cached
    /// per-token features, so chunked prefill begins at the first
    /// uncached token. `pos` must leave at least one token to prefill —
    /// the last prompt token's verify row produces the logits that seed
    /// the first decode cycle.
    pub fn with_prefix(ptoks: Vec<i32>, pos: usize, feats: Vec<f32>) -> PrefillProgress {
        debug_assert!(pos < ptoks.len(), "cache hit must leave >=1 token to prefill");
        PrefillProgress { ptoks, pos, feats }
    }

    pub fn remaining(&self) -> usize {
        self.ptoks.len() - self.pos
    }

    pub fn done(&self) -> bool {
        self.pos >= self.ptoks.len()
    }

    /// Fold one executed chunk into the progress.
    pub fn advance(&mut self, n: usize, chunk_feats: &[f32]) {
        debug_assert!(self.pos + n <= self.ptoks.len());
        self.pos += n;
        self.feats.extend_from_slice(chunk_feats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cap_at_rows_and_config() {
        let mut p = PrefillProgress::new((0..10).collect());
        assert_eq!(p.remaining(), 10);
        assert_eq!(chunk_for(p.remaining(), usize::MAX, 3), 3);
        assert_eq!(chunk_for(p.remaining(), 2, 3), 2);
        p.advance(3, &[0.0; 6]);
        assert_eq!(p.pos, 3);
        assert_eq!(p.feats.len(), 6);
        p.advance(7, &[]);
        assert!(p.done());
        assert_eq!(chunk_for(p.remaining(), usize::MAX, 3), 0);
    }
}
