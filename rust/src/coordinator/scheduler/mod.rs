//! The scheduling brain of the continuous batcher.
//!
//! `BatchEngine` used to decide admission, prefill, eviction and
//! back-pressure inline; now every per-step decision is made here, over
//! read-only views of the engine's state, and handed back as an
//! explicit [`SchedulePlan`] that the engine merely executes:
//!
//! * **admit** — which pending requests take free slots this step, in
//!   [`SchedulerPolicy`] order, each funded by a KV-block lease;
//! * **prefill** — how many prompt tokens each `Prefilling` slot
//!   ingests this step (chunked prefill on the batched lane — see
//!   [`prefill`]);
//! * **preempt** — which decoding slots are paused under pool pressure
//!   so a higher-priority admission can be funded from their shrunk
//!   leases (see [`preempt`]);
//! * **resume** — which parked requests re-enter a slot (they beat
//!   fresh admissions — their shrunk lease already holds blocks);
//! * **run** — which slots execute a draft → verify → commit cycle.
//!
//! The scheduler also owns deferral bookkeeping: a request that had a
//! free slot but could not be funded from the pool counts once in
//! `new_deferrals`, however many steps it waits (the engine folds this
//! into `ServingMetrics::requests_deferred`).

pub mod policy;
pub mod preempt;
pub mod prefill;

use std::collections::HashSet;

use crate::spec::SlotPhase;

pub use policy::{FcfsPolicy, PolicyKind, SchedulerPolicy, ShortestPromptFirst};
pub use prefill::{chunk_for, PrefillProgress};

/// One pending (submitted, not yet admitted) request, as the policy
/// sees it.
#[derive(Debug, Clone)]
pub struct PendingView {
    pub id: u64,
    pub priority: i32,
    /// truncated prompt length — what chunked prefill will ingest
    pub prompt_tokens: usize,
    /// full KV-lease cost (target + this request's drafter layers)
    pub cost_blocks: usize,
    /// prompt tokens the prefix cache already holds (prefill starts
    /// after them)
    pub cached_tokens: usize,
    /// lease blocks a cache hit funds by sharing instead of allocation
    pub cached_blocks: usize,
}

/// One parked (preempted) request awaiting resume. (The parked-token
/// gauge is the engine's own bookkeeping, sampled post-plan in
/// `step_events` — it is deliberately not part of this view.)
#[derive(Debug, Clone)]
pub struct ParkedView {
    pub id: u64,
    pub priority: i32,
    /// blocks needed on top of the shrunk lease it still holds
    pub resume_delta_blocks: usize,
}

/// One occupied slot.
#[derive(Debug, Clone)]
pub struct ActiveView {
    pub slot: usize,
    pub id: u64,
    pub priority: i32,
    pub phase: SlotPhase,
    /// prompt tokens not yet ingested (Prefilling slots)
    pub prefill_remaining: usize,
    /// blocks a preemption of this slot would free
    pub shrink_gain_blocks: usize,
    pub finished: bool,
}

/// Read-only snapshot of everything a step's decisions depend on.
#[derive(Debug, Clone)]
pub struct SchedView {
    pub free_slots: Vec<usize>,
    pub pool_available: usize,
    /// blocks reclaimable from the prefix cache (refcount==0 LRU
    /// chains) — eviction funding, spent before preemption
    pub evictable_blocks: usize,
    /// verify rows the batched call exposes this step — the hard cap on
    /// any slot's prefill chunk
    pub max_rows: usize,
    pub pending: Vec<PendingView>,
    pub parked: Vec<ParkedView>,
    pub active: Vec<ActiveView>,
}

/// What one scheduler step decided. Slot/queue indices refer to the
/// [`SchedView`] the plan was made from; the engine executes sections
/// in order: evict → preempt → resume → admit → (prefill + run).
#[derive(Debug, Default)]
pub struct SchedulePlan {
    /// prefix-cache blocks to evict (LRU, refcount==0) to fund this
    /// step's resumes/admissions — always tried before preemption
    pub evict_blocks: usize,
    /// slots to pause: park state, shrink lease to committed tokens
    pub preempt: Vec<usize>,
    /// (slot, parked-queue index) to restore
    pub resume: Vec<(usize, usize)>,
    /// (slot, pending-queue index) to admit into `Prefilling`
    pub admit: Vec<(usize, usize)>,
    /// (slot, tokens) prompt chunks to ingest this step
    pub prefill: Vec<(usize, usize)>,
    /// slots that run a decode cycle this step
    pub run: Vec<usize>,
    /// distinct requests newly deferred on pool pressure this step
    pub new_deferrals: u64,
}

impl SchedulePlan {
    /// Anything for the batched iteration to do?
    pub fn has_work(&self) -> bool {
        !self.prefill.is_empty() || !self.run.is_empty()
    }
}

#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// max prompt tokens ingested per slot per step (further capped by
    /// the batched call's verify rows)
    pub prefill_chunk: usize,
    /// preemption budget per step (0 disables preemption)
    pub max_preemptions_per_step: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { prefill_chunk: usize::MAX, max_preemptions_per_step: 1 }
    }
}

/// Policy + per-step planning + deferral bookkeeping.
pub struct Scheduler {
    policy: Box<dyn SchedulerPolicy>,
    cfg: SchedConfig,
    /// ids already counted in `requests_deferred` (each distinct
    /// request counts once, however many passes it waits)
    deferred: HashSet<u64>,
}

impl Scheduler {
    pub fn new(kind: PolicyKind, mut cfg: SchedConfig) -> Scheduler {
        // a zero chunk could never finish a prompt — clamp, don't stall
        cfg.prefill_chunk = cfg.prefill_chunk.max(1);
        Scheduler { policy: kind.build(), cfg, deferred: HashSet::new() }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Forget all deferral bookkeeping (engine abort path).
    pub fn clear(&mut self) {
        self.deferred.clear();
    }

    /// Decide one step. Pure over the view except for the deferral set.
    pub fn plan(&mut self, view: &SchedView) -> SchedulePlan {
        let mut span = crate::obs::span("sched");
        let mut plan = SchedulePlan::default();
        let mut avail = view.pool_available;
        let mut evictable = view.evictable_blocks;
        let mut free = view.free_slots.clone();

        // shared funding rule: cover `need` from free blocks, topping
        // up from cache eviction (refcount==0 LRU chains) — cached idle
        // state always yields to live work, and only the shortfall is
        // evicted
        let fund =
            |need: usize, avail: &mut usize, evictable: &mut usize, evict: &mut usize| -> bool {
                if need > *avail + *evictable {
                    return false;
                }
                if need > *avail {
                    let take = need - *avail;
                    *evict += take;
                    *evictable -= take;
                    *avail += take;
                }
                *avail -= need;
                true
            };

        // 1. resumes first: a parked request already holds (and pays
        // for) its committed prefix — finishing it releases everything
        for (pi, parked) in view.parked.iter().enumerate() {
            if free.is_empty() {
                break;
            }
            if fund(parked.resume_delta_blocks, &mut avail, &mut evictable, &mut plan.evict_blocks)
            {
                let slot = free.remove(0);
                plan.resume.push((slot, pi));
                self.deferred.remove(&parked.id);
            }
        }

        // 2. admissions in policy order, preemption as the funding
        // fallback; the policy's head-of-line waits if unfundable
        let order = self.policy.admission_order(&view.pending);
        for qi in order {
            if free.is_empty() {
                break;
            }
            let req = &view.pending[qi];
            // a cache hit funds part of the lease by sharing — only the
            // uncached remainder needs fresh blocks
            let net_cost = req.cost_blocks.saturating_sub(req.cached_blocks);
            let mut funded_by_preemption = false;
            if net_cost > avail + evictable {
                // eviction alone can't cover it: tentative victim
                // selection — committed only if the gains (on top of
                // full eviction) actually fund this admission
                let mut chosen: Vec<&ActiveView> = Vec::new();
                let mut gain = 0usize;
                while net_cost > avail + evictable + gain
                    && plan.preempt.len() + chosen.len() < self.cfg.max_preemptions_per_step
                {
                    let candidates: Vec<ActiveView> = view
                        .active
                        .iter()
                        .filter(|a| {
                            a.phase == SlotPhase::Decoding
                                && !a.finished
                                && a.shrink_gain_blocks > 0
                                && !plan.preempt.contains(&a.slot)
                                && !chosen.iter().any(|c| c.slot == a.slot)
                        })
                        .cloned()
                        .collect();
                    let Some(v) = self.policy.preempt_victim(&candidates, req) else {
                        break;
                    };
                    let victim = view
                        .active
                        .iter()
                        .find(|a| a.slot == candidates[v].slot)
                        .expect("candidate came from the active view");
                    gain += victim.shrink_gain_blocks;
                    chosen.push(victim);
                }
                if net_cost <= avail + evictable + gain {
                    funded_by_preemption = !chosen.is_empty();
                    for victim in chosen {
                        plan.preempt.push(victim.slot);
                        free.push(victim.slot);
                    }
                    avail += gain;
                } else {
                    if self.deferred.insert(req.id) {
                        plan.new_deferrals += 1;
                    }
                    break;
                }
            }
            let funded = fund(net_cost, &mut avail, &mut evictable, &mut plan.evict_blocks);
            debug_assert!(funded, "funding was just established");
            let slot = free.remove(0);
            plan.admit.push((slot, qi));
            self.deferred.remove(&req.id);
            if funded_by_preemption {
                // fence: leftover shrink gain must not fund further
                // admissions this step — a later equal-priority arrival
                // could otherwise run on the parked victim's blocks
                // while the victim (same priority) waits, inverting the
                // strictly-lower-priority preemption contract
                break;
            }
        }

        // 3. per-step work: chunks for every surviving Prefilling slot
        // (including this step's admissions), cycles for every
        // unfinished Decoding slot (including this step's resumes)
        for a in &view.active {
            if plan.preempt.contains(&a.slot) {
                continue;
            }
            match a.phase {
                SlotPhase::Prefilling => {
                    let chunk = chunk_for(
                        a.prefill_remaining,
                        self.cfg.prefill_chunk,
                        view.max_rows,
                    );
                    if chunk > 0 {
                        plan.prefill.push((a.slot, chunk));
                    }
                }
                SlotPhase::Decoding => {
                    if !a.finished {
                        plan.run.push(a.slot);
                    }
                }
            }
        }
        for &(slot, qi) in &plan.admit {
            // a cache hit's tokens are adopted, not ingested: the first
            // chunk starts at the first uncached token
            let p = &view.pending[qi];
            let chunk = chunk_for(
                p.prompt_tokens.saturating_sub(p.cached_tokens),
                self.cfg.prefill_chunk,
                view.max_rows,
            );
            if chunk > 0 {
                plan.prefill.push((slot, chunk));
            }
        }
        for &(slot, _) in &plan.resume {
            plan.run.push(slot);
        }
        span.set_arg((plan.run.len() + plan.admit.len() + plan.prefill.len()) as i64);
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view() -> SchedView {
        SchedView {
            free_slots: vec![],
            pool_available: 0,
            evictable_blocks: 0,
            max_rows: 3,
            pending: Vec::new(),
            parked: Vec::new(),
            active: Vec::new(),
        }
    }

    fn pend(id: u64, priority: i32, prompt: usize, cost: usize) -> PendingView {
        PendingView {
            id,
            priority,
            prompt_tokens: prompt,
            cost_blocks: cost,
            cached_tokens: 0,
            cached_blocks: 0,
        }
    }

    fn decoding(slot: usize, id: u64, priority: i32, gain: usize) -> ActiveView {
        ActiveView {
            slot,
            id,
            priority,
            phase: SlotPhase::Decoding,
            prefill_remaining: 0,
            shrink_gain_blocks: gain,
            finished: false,
        }
    }

    #[test]
    fn admits_in_order_until_slots_or_blocks_run_out() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![0, 1];
        v.pool_available = 10;
        v.pending = vec![pend(1, 0, 5, 4), pend(2, 0, 9, 4), pend(3, 0, 2, 4)];
        let plan = s.plan(&v);
        assert_eq!(plan.admit, vec![(0, 0), (1, 1)]);
        // admitted requests get a first prefill chunk, capped by rows
        assert_eq!(plan.prefill, vec![(0, 3), (1, 3)]);
        assert_eq!(plan.new_deferrals, 0, "slot scarcity is not a deferral");
    }

    /// The old `AdmissionLedger` invariant, now owned by the scheduler:
    /// each distinct pool-starved request counts once, however many
    /// planning passes it waits through.
    #[test]
    fn deferred_admissions_count_once_per_request() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![0];
        v.pool_available = 3; // cannot fund cost 4
        v.pending = vec![pend(7, 0, 5, 4)];
        let mut total = 0;
        for _ in 0..5 {
            let plan = s.plan(&v);
            assert!(plan.admit.is_empty());
            total += plan.new_deferrals;
        }
        assert_eq!(total, 1, "one count per distinct request");
        // blocks free up -> admits without re-counting
        v.pool_available = 4;
        let plan = s.plan(&v);
        assert_eq!(plan.admit, vec![(0, 0)]);
        assert_eq!(plan.new_deferrals, 0);
    }

    #[test]
    fn preempts_lower_priority_to_fund_admission() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 1;
        v.pending = vec![pend(9, 2, 4, 4)];
        v.active = vec![decoding(0, 5, 0, 6)];
        let plan = s.plan(&v);
        assert_eq!(plan.preempt, vec![0]);
        assert_eq!(plan.admit, vec![(1, 0)]);
        // the victim does not also run this step
        assert!(plan.run.is_empty());
    }

    /// Leftover shrink gain is fenced: after a preemption-funded
    /// admission, no further request admits this step — otherwise an
    /// equal-priority later arrival could run on the parked victim's
    /// blocks while the victim waits (priority inversion).
    #[test]
    fn preemption_gain_never_funds_a_second_admission() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 0;
        v.pending = vec![pend(9, 5, 4, 4), pend(8, 0, 4, 4)];
        v.active = vec![decoding(0, 5, 0, 10)]; // gain 10 covers both costs
        let plan = s.plan(&v);
        assert_eq!(plan.preempt, vec![0]);
        assert_eq!(plan.admit, vec![(1, 0)], "only the out-ranking request admits");
        assert_eq!(plan.new_deferrals, 0);
    }

    #[test]
    fn no_pointless_preemption_when_gain_cannot_fund() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 0;
        v.pending = vec![pend(9, 2, 4, 40)];
        v.active = vec![decoding(0, 5, 0, 6)]; // gain 6 < cost 40
        let plan = s.plan(&v);
        assert!(plan.preempt.is_empty(), "don't pause work it can't help");
        assert!(plan.admit.is_empty());
        assert_eq!(plan.new_deferrals, 1);
        assert_eq!(plan.run, vec![0], "the survivor keeps decoding");
    }

    #[test]
    fn equal_priority_never_preempts() {
        let mut s = Scheduler::new(PolicyKind::Spf, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 0;
        v.pending = vec![pend(9, 0, 4, 4)];
        v.active = vec![decoding(0, 5, 0, 8)];
        let plan = s.plan(&v);
        assert!(plan.preempt.is_empty());
        assert_eq!(plan.new_deferrals, 1);
    }

    #[test]
    fn parked_requests_resume_before_fresh_admissions() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![0];
        v.pool_available = 5;
        v.parked = vec![ParkedView {
            id: 3,
            priority: 0,
            resume_delta_blocks: 5,
        }];
        v.pending = vec![pend(8, 0, 4, 4)];
        let plan = s.plan(&v);
        assert_eq!(plan.resume, vec![(0, 0)]);
        assert!(plan.admit.is_empty(), "the lone slot went to the resume");
        assert_eq!(plan.run, vec![0], "resumed slots decode this step");
    }

    #[test]
    fn cache_hit_shrinks_both_funding_and_first_chunk() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![0];
        v.pool_available = 3; // < full cost 10, >= net cost 10-8
        v.pending = vec![PendingView {
            id: 1,
            priority: 0,
            prompt_tokens: 9,
            cost_blocks: 10,
            cached_tokens: 8,
            cached_blocks: 8,
        }];
        let plan = s.plan(&v);
        assert_eq!(plan.admit, vec![(0, 0)], "shared blocks cost nothing");
        // only the single uncached token prefills (max_rows would allow 3)
        assert_eq!(plan.prefill, vec![(0, 1)]);
        assert_eq!(plan.evict_blocks, 0);
        assert!(plan.preempt.is_empty());
    }

    #[test]
    fn eviction_funds_admission_before_preemption() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 1;
        v.evictable_blocks = 5;
        v.pending = vec![pend(9, 2, 4, 4)];
        v.active = vec![decoding(0, 5, 0, 6)]; // would be preemptible
        let plan = s.plan(&v);
        assert_eq!(plan.evict_blocks, 3, "only the shortfall is evicted");
        assert!(plan.preempt.is_empty(), "cache eviction comes before preemption");
        assert_eq!(plan.admit, vec![(1, 0)]);
        assert_eq!(plan.run, vec![0], "the survivor keeps decoding");
    }

    #[test]
    fn preemption_tops_up_what_eviction_cannot_cover() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![1];
        v.pool_available = 0;
        v.evictable_blocks = 2;
        v.pending = vec![pend(9, 2, 4, 6)];
        v.active = vec![decoding(0, 5, 0, 4)];
        let plan = s.plan(&v);
        assert_eq!(plan.preempt, vec![0]);
        assert_eq!(plan.evict_blocks, 2, "eviction budget spent first");
        assert_eq!(plan.admit, vec![(1, 0)]);
    }

    #[test]
    fn eviction_funds_resumes_too() {
        let mut s = Scheduler::new(PolicyKind::Fcfs, SchedConfig::default());
        let mut v = view();
        v.free_slots = vec![0];
        v.pool_available = 2;
        v.evictable_blocks = 3;
        v.parked = vec![ParkedView { id: 3, priority: 0, resume_delta_blocks: 5 }];
        let plan = s.plan(&v);
        assert_eq!(plan.resume, vec![(0, 0)]);
        assert_eq!(plan.evict_blocks, 3);
    }

    #[test]
    fn prefilling_slots_get_chunks_alongside_decoders() {
        let mut s = Scheduler::new(
            PolicyKind::Fcfs,
            SchedConfig { prefill_chunk: 2, ..Default::default() },
        );
        let mut v = view();
        v.active = vec![
            ActiveView {
                slot: 0,
                id: 1,
                priority: 0,
                phase: SlotPhase::Prefilling,
                prefill_remaining: 9,
                shrink_gain_blocks: 0,
                finished: false,
            },
            decoding(1, 2, 0, 4),
        ];
        let plan = s.plan(&v);
        assert_eq!(plan.prefill, vec![(0, 2)], "chunk capped by config");
        assert_eq!(plan.run, vec![1]);
        assert!(plan.has_work());
    }
}
