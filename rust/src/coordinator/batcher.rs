//! Continuous-batching engine over the batched (`*_b{B}`) executables —
//! the vLLM-style serving path behind the paper's Table 3 study
//! (throughput vs batch size, chain length 2, tree disabled).
//!
//! Design mirrors vLLM's loop at miniature scale:
//! * **Admission lane**: new requests prefill on the B=1 executables,
//!   then their KV/drafter state is copied into a free slot of the
//!   batched state tensors.
//! * **Decode loop**: one batched draft (method-specific) + one batched
//!   verification per iteration; per-slot lossless acceptance and KV
//!   compaction on the host.
//! * **Paged admission control**: every request leases KV blocks for the
//!   target's L layers plus its drafter's KV layers (FastEagle N=6 vs
//!   EAGLE 1 vs vanilla 0). When the pool can't cover a request it waits
//!   in the queue — this is the memory-pressure mechanism that caps
//!   FastEagle's batched throughput in Table 3.

use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::draft::{Drafter, EagleDrafter, FastEagleDrafter, ObserveArgs};
use crate::model::{BlockPool, KvCache, Lease, MaskRow, ModelSpec, TargetModel, Tokenizer, NEG};
use crate::runtime::tensor::HostTensor;
use crate::runtime::ArtifactStore;
use crate::spec::{verify_tree, DraftTree, Sampler};

use super::metrics::ServingMetrics;
use super::request::{Request, Response};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMethod {
    Vanilla,
    FastEagle,
    Eagle3,
}

impl BatchMethod {
    pub fn drafter_kv_layers(self, spec: &ModelSpec) -> usize {
        match self {
            BatchMethod::Vanilla => 0,
            BatchMethod::FastEagle => spec.draft_depth,
            BatchMethod::Eagle3 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BatchMethod::Vanilla => "vanilla",
            BatchMethod::FastEagle => "fasteagle",
            BatchMethod::Eagle3 => "eagle3",
        }
    }
}

#[derive(Debug, Clone)]
pub struct BatchConfig {
    pub batch: usize,
    pub method: BatchMethod,
    /// draft chain length per cycle (Table 3: 2)
    pub chain_len: usize,
    pub temperature: f32,
    /// KV block pool (admission control); `None` = unbounded
    pub pool_blocks: Option<usize>,
    pub block_slots: usize,
}

impl BatchConfig {
    pub fn new(batch: usize, method: BatchMethod) -> BatchConfig {
        BatchConfig {
            batch,
            method,
            chain_len: 2,
            temperature: 0.0,
            pool_blocks: None,
            block_slots: 16,
        }
    }
}

struct Slot {
    req: Request,
    sampler: Sampler,
    pending: i32,
    out: Vec<i32>,
    cycles: usize,
    tau_sum: usize,
    lease: Lease,
    // FastEagle per-slot draft state: [N, V] logits from the cascade
    fe_logits: Vec<f32>,
    // EAGLE per-slot draft state
    eg_h: Vec<f32>,
    eg_q1: Vec<f32>,
}

pub struct BatchEngine {
    store: Rc<ArtifactStore>,
    pub spec: ModelSpec,
    cfg: BatchConfig,
    tokenizer: Tokenizer,
    kv: KvCache,
    dkv: Option<KvCache>, // FE: [N,2,B,C,..]; EAGLE: [2,B,C,..]
    slots: Vec<Option<Slot>>,
    pool: BlockPool,
}

/// Batched additive mask [B, T, S] from per-slot row descriptors.
fn build_mask_b(bsz: usize, t: usize, s: usize, rows: &[Vec<MaskRow>]) -> HostTensor {
    let mut data = vec![NEG; bsz * t * s];
    for (b, slot_rows) in rows.iter().enumerate() {
        for i in 0..t {
            let base = (b * t + i) * s;
            match slot_rows.get(i) {
                Some(r) => {
                    let upto = r.prefix_upto.min(s);
                    for v in &mut data[base..base + upto] {
                        *v = 0.0;
                    }
                    for &e in &r.extra {
                        if e < s {
                            data[base + e] = 0.0;
                        }
                    }
                }
                None => data[base] = 0.0, // pad row
            }
        }
    }
    HostTensor::f32(vec![bsz, t, s], data)
}

impl BatchEngine {
    pub fn new(store: Rc<ArtifactStore>, cfg: BatchConfig) -> Result<BatchEngine> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        if cfg.batch > 1 && !spec.batch_sizes.contains(&cfg.batch) {
            bail!(
                "target {:?} has no batch-{} executables (lowered: {:?})",
                spec.name, cfg.batch, spec.batch_sizes
            );
        }
        let b = cfg.batch;
        let kv = KvCache::zeros(vec![
            spec.n_layers, 2, b, spec.max_seq, spec.n_kv_heads, spec.head_dim,
        ])?;
        let dkv = match cfg.method {
            BatchMethod::Vanilla => None,
            BatchMethod::FastEagle => Some(KvCache::zeros(vec![
                spec.draft_depth, 2, b, spec.max_seq, spec.n_kv_heads, spec.head_dim,
            ])?),
            BatchMethod::Eagle3 => Some(KvCache::zeros(vec![
                2, b, spec.max_seq, spec.n_kv_heads, spec.head_dim,
            ])?),
        };
        let tokenizer = Tokenizer::new(spec.bos, spec.eos, spec.pad);
        let pool_blocks = cfg.pool_blocks.unwrap_or(usize::MAX / 4);
        let pool = BlockPool::new(pool_blocks, cfg.block_slots);
        let slots = (0..b).map(|_| None).collect();
        Ok(BatchEngine { store, spec, cfg, tokenizer, kv, dkv, slots, pool })
    }

    fn exec_suffix(&self) -> String {
        if self.cfg.batch == 1 {
            String::new()
        } else {
            format!("_b{}", self.cfg.batch)
        }
    }

    /// Request cost in pool blocks (target + drafter KV layers).
    fn request_blocks(&self) -> usize {
        let drafter_layers = self.cfg.method.drafter_kv_layers(&self.spec);
        self.pool
            .blocks_for(self.spec.max_seq, self.spec.n_layers + drafter_layers)
    }

    /// Prefill one request on the B=1 lane and move its state into slot
    /// `slot_idx`.
    fn admit(&mut self, slot_idx: usize, req: Request, lease: Lease) -> Result<()> {
        let target = TargetModel::open(Rc::clone(&self.store))?;
        let mut kv1 = target.new_kv()?;
        let mut ptoks = self.tokenizer.encode_prompt(&req.prompt);
        let budget = self
            .spec
            .max_seq
            .saturating_sub(req.cfg.max_new_tokens + self.cfg.chain_len + 3);
        if ptoks.len() > budget {
            ptoks = ptoks[ptoks.len() - budget..].to_vec();
        }
        let pre = target.prefill(&mut kv1, &ptoks)?;
        let mut sampler = Sampler::new(self.cfg.temperature, req.cfg.seed ^ req.id);
        let d0 = sampler.dist_from_logits(&pre.last_logits);
        let pending = sampler.sample(&d0);
        let mut next: Vec<i32> = ptoks[1..].to_vec();
        next.push(pending);

        let mut slot = Slot {
            req,
            sampler,
            pending,
            out: Vec::new(),
            cycles: 0,
            tau_sum: 0,
            lease,
            fe_logits: Vec::new(),
            eg_h: Vec::new(),
            eg_q1: Vec::new(),
        };
        self.kv.copy_request_from(slot_idx, &kv1)?;
        match self.cfg.method {
            BatchMethod::Vanilla => {}
            BatchMethod::FastEagle => {
                let mut d =
                    FastEagleDrafter::new(Rc::clone(&self.store), "fasteagle", "fe")?;
                d.observe(ObserveArgs {
                    feats: &pre.feats,
                    anchor_tokens: &ptoks,
                    next_tokens: &next,
                    first_pos: 0,
                })?;
                let (dkv1, logits) = d.state();
                self.dkv.as_mut().unwrap().copy_request_from(slot_idx, dkv1)?;
                slot.fe_logits = logits.to_vec();
            }
            BatchMethod::Eagle3 => {
                let mut d = EagleDrafter::new(Rc::clone(&self.store), "eagle3", true)?;
                d.observe(ObserveArgs {
                    feats: &pre.feats,
                    anchor_tokens: &ptoks,
                    next_tokens: &next,
                    first_pos: 0,
                })?;
                let (ekv1, h, q1) = d.state();
                self.dkv.as_mut().unwrap().copy_request_from(slot_idx, ekv1)?;
                slot.eg_h = h.to_vec();
                slot.eg_q1 = q1.to_vec();
            }
        }
        self.slots[slot_idx] = Some(slot);
        Ok(())
    }

    /// Draft a depth-`chain_len` backbone chain per active slot.
    /// Returns per-slot (tokens, dists).
    fn draft_chains(&mut self) -> Result<Vec<Option<(Vec<i32>, Vec<Vec<f32>>)>>> {
        let bsz = self.cfg.batch;
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        let depth = self.cfg.chain_len;
        let temp = self.cfg.temperature;
        let mut out: Vec<Option<(Vec<i32>, Vec<Vec<f32>>)>> = (0..bsz).map(|_| None).collect();
        match self.cfg.method {
            BatchMethod::Vanilla => {}
            BatchMethod::FastEagle => {
                // the cascade already produced all N levels during observe
                for (b, s) in self.slots.iter_mut().enumerate() {
                    let Some(slot) = s else { continue };
                    let mut toks = Vec::with_capacity(depth);
                    let mut dists = Vec::with_capacity(depth);
                    for lvl in 0..depth.min(self.spec.draft_depth) {
                        let mut q = slot.fe_logits[lvl * v..(lvl + 1) * v].to_vec();
                        crate::util::rng::softmax_temp(&mut q, temp);
                        // chain links are q-samples at T>0 (losslessness)
                        toks.push(slot.sampler.sample(&q));
                        dists.push(q);
                    }
                    out[b] = Some((toks, dists));
                }
            }
            BatchMethod::Eagle3 => {
                // level 1 from observe; levels 2.. via batched eg_next
                let mut hs: Vec<Vec<f32>> = Vec::with_capacity(bsz);
                for (b, s) in self.slots.iter_mut().enumerate() {
                    if let Some(slot) = s {
                        let mut q = slot.eg_q1.clone();
                        crate::util::rng::softmax_temp(&mut q, temp);
                        let tok = slot.sampler.sample(&q);
                        out[b] = Some((vec![tok], vec![q]));
                        hs.push(slot.eg_h.clone());
                    } else {
                        hs.push(vec![0.0; d]);
                    }
                }
                let exec = self
                    .store
                    .bind(&format!("eg_next_t1{}", self.exec_suffix()), "eagle3")?;
                let mut ekv_tmp = self.dkv.as_ref().unwrap().clone();
                for step in 1..depth {
                    let mut feat = vec![0.0f32; bsz * d];
                    let mut toks = vec![self.spec.pad; bsz];
                    let mut pos = vec![0i32; bsz];
                    let mut ctx = vec![0i32; bsz];
                    let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
                    for b in 0..bsz {
                        if let Some((t, _)) = &out[b] {
                            feat[b * d..(b + 1) * d].copy_from_slice(&hs[b]);
                            toks[b] = t[step - 1];
                            let base = ekv_tmp.len(b);
                            pos[b] = ((base + step) as i32).min(c as i32 - 1);
                            ctx[b] = (base + step - 1) as i32;
                            rows[b] =
                                vec![MaskRow { prefix_upto: base + step, extra: vec![] }];
                        }
                    }
                    let mask = build_mask_b(bsz, 1, c, &rows);
                    let feat_t = HostTensor::f32(vec![bsz, 1, d], feat);
                    let tok_t = HostTensor::i32(vec![bsz, 1], toks);
                    let pos_t = HostTensor::i32(vec![bsz, 1], pos);
                    let ctx_t = HostTensor::i32(vec![bsz], ctx);
                    let outs = exec.call(
                        &self.store.runtime,
                        &[
                            ("feat_in", &feat_t),
                            ("tokens", &tok_t),
                            ("anchor_pos", &pos_t),
                            ("mask", &mask),
                            ("ctx_len", &ctx_t),
                            ("ekv", ekv_tmp.tensor()),
                        ],
                    )?;
                    let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                    let hvec = outs[exec.out_idx("h")?].as_f32()?.to_vec();
                    let ki = exec.out_idx("ekv")?;
                    let mut outs = outs;
                    ekv_tmp.update_from(outs.swap_remove(ki))?;
                    for b in 0..bsz {
                        if let Some((t, dd)) = &mut out[b] {
                            let mut q = l[b * v..(b + 1) * v].to_vec();
                            crate::util::rng::softmax_temp(&mut q, temp);
                            let tok = self.slots[b].as_mut().unwrap().sampler.sample(&q);
                            t.push(tok);
                            dd.push(q);
                            hs[b].copy_from_slice(&hvec[b * d..(b + 1) * d]);
                        }
                    }
                }
                // ekv_tmp dropped: temp entries rolled back
            }
        }
        Ok(out)
    }

    /// One batched decode iteration over all active slots. Returns
    /// finished responses.
    fn decode_iteration(&mut self) -> Result<Vec<Response>> {
        let bsz = self.cfg.batch;
        let (v, fd, s) = (self.spec.vocab, self.spec.feat_dim, self.spec.max_seq);
        let m = match self.cfg.method {
            BatchMethod::Vanilla => 1,
            _ => 1 + self.cfg.chain_len,
        };
        let chains = self.draft_chains()?;
        // assemble per-slot trees
        let mut trees: Vec<Option<DraftTree>> = (0..bsz).map(|_| None).collect();
        for b in 0..bsz {
            let Some(slot) = &self.slots[b] else { continue };
            let tree = match (&chains[b], self.cfg.method) {
                (_, BatchMethod::Vanilla) => DraftTree::root_only(slot.pending),
                (Some((toks, dists)), _) => {
                    DraftTree::chain(slot.pending, toks, dists.clone())
                }
                (None, _) => DraftTree::root_only(slot.pending),
            };
            trees[b] = Some(tree);
        }
        // batched verify
        let mut tokens = vec![self.spec.pad; bsz * m];
        let mut pos = vec![0i32; bsz * m];
        let mut ctx = vec![0i32; bsz];
        let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
        for b in 0..bsz {
            let Some(tree) = &trees[b] else { continue };
            let base = self.kv.len(b);
            ctx[b] = base as i32;
            for (i, node) in tree.nodes.iter().enumerate() {
                tokens[b * m + i] = node.token;
                pos[b * m + i] = ((base + node.depth) as i32).min(s as i32 - 1);
            }
            rows[b] = (0..tree.len())
                .map(|i| MaskRow {
                    prefix_upto: base,
                    extra: tree.ancestors(i).iter().map(|&a| base + a).collect(),
                })
                .collect();
        }
        let mask = build_mask_b(bsz, m, s, &rows);
        let exec = self
            .store
            .bind(&format!("tgt_m{m}{}", self.exec_suffix()), "target")?;
        let tok_t = HostTensor::i32(vec![bsz, m], tokens);
        let pos_t = HostTensor::i32(vec![bsz, m], pos);
        let ctx_t = HostTensor::i32(vec![bsz], ctx);
        let outs = exec.call(
            &self.store.runtime,
            &[
                ("tokens", &tok_t),
                ("positions", &pos_t),
                ("mask", &mask),
                ("cache_len", &ctx_t),
                ("kv", self.kv.tensor()),
            ],
        )?;
        let logits = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
        let feats = outs[exec.out_idx("feats")?].as_f32()?.to_vec();
        let ki = exec.out_idx("kv")?;
        let mut outs = outs;
        self.kv.update_from(outs.swap_remove(ki))?;

        // per-slot acceptance + commit
        let mut observe_feats: Vec<Vec<f32>> = vec![vec![]; bsz];
        let mut observe_anchor: Vec<Vec<i32>> = vec![vec![]; bsz];
        let mut observe_next: Vec<Vec<i32>> = vec![vec![]; bsz];
        let mut observe_first: Vec<usize> = vec![0; bsz];
        let mut finished = Vec::new();
        for b in 0..bsz {
            let Some(tree) = &trees[b] else { continue };
            let base = self.kv.len(b);
            let slot = self.slots[b].as_mut().unwrap();
            let target_dists: Vec<Vec<f32>> = (0..tree.len())
                .map(|i| {
                    slot.sampler
                        .dist_from_logits(&logits[(b * m + i) * v..(b * m + i + 1) * v])
                })
                .collect();
            let acc = verify_tree(tree, &target_dists, &mut slot.sampler);
            self.kv.compact(b, base, &acc.accepted_slots)?;
            slot.cycles += 1;
            slot.tau_sum += acc.accepted_slots.len();
            let acc_tokens: Vec<i32> = acc
                .accepted_slots
                .iter()
                .map(|&sl| tree.nodes[sl].token)
                .collect();
            let mut f = Vec::with_capacity(acc.accepted_slots.len() * fd);
            for &sl in &acc.accepted_slots {
                f.extend_from_slice(&feats[(b * m + sl) * fd..(b * m + sl + 1) * fd]);
            }
            let mut next: Vec<i32> = acc_tokens[1..].to_vec();
            next.push(acc.bonus);
            observe_feats[b] = f;
            observe_anchor[b] = acc_tokens.clone();
            observe_next[b] = next;
            observe_first[b] = base;
            slot.pending = acc.bonus;
            slot.out.extend_from_slice(&acc_tokens);
        }

        // batched drafter observe over the newly committed anchors
        self.batched_observe(&observe_feats, &observe_next, &observe_first)?;

        // retire finished slots
        for b in 0..bsz {
            let done = match &self.slots[b] {
                Some(slot) => {
                    slot.out.len() >= slot.req.cfg.max_new_tokens
                        || self.kv.len(b) + m + 2 > s
                }
                None => false,
            };
            if done {
                let mut slot = self.slots[b].take().unwrap();
                self.pool.release(&mut slot.lease);
                self.kv.set_len(b, 0);
                if let Some(dkv) = self.dkv.as_mut() {
                    dkv.set_len(b, 0);
                }
                slot.out.truncate(slot.req.cfg.max_new_tokens);
                finished.push(Response {
                    id: slot.req.id,
                    text: self.tokenizer.decode(&slot.out),
                    new_tokens: slot.out.len(),
                    tau: if slot.cycles > 0 {
                        slot.tau_sum as f64 / slot.cycles as f64
                    } else {
                        0.0
                    },
                    cycles: slot.cycles,
                    latency_ms: slot.req.arrival.elapsed().as_secs_f64() * 1e3,
                    gen_ms: 0.0,
                    error: None,
                });
            }
        }
        Ok(finished)
    }

    /// Batched `observe` (FE cascade / EAGLE first-step) over each slot's
    /// newly committed anchors, updating per-slot draft state.
    fn batched_observe(
        &mut self,
        feats: &[Vec<f32>],
        next: &[Vec<i32>],
        first_pos: &[usize],
    ) -> Result<()> {
        if matches!(self.cfg.method, BatchMethod::Vanilla) {
            return Ok(());
        }
        let bsz = self.cfg.batch;
        let fd = self.spec.feat_dim;
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        let n_max = next.iter().map(|x| x.len()).max().unwrap_or(0);
        if n_max == 0 {
            return Ok(());
        }
        let t = if n_max > 8 { 32 } else if n_max > 1 { 8 } else { 1 };
        let suffix = self.exec_suffix();
        let dkv = self.dkv.as_mut().unwrap();
        let mut feat_in = vec![0.0f32; bsz * t * fd];
        let mut toks = vec![self.spec.pad; bsz * t];
        let mut pos = vec![0i32; bsz * t];
        let mut ctx = vec![0i32; bsz];
        let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
        for b in 0..bsz {
            if self.slots[b].is_none() || next[b].is_empty() {
                continue;
            }
            let n = next[b].len();
            let base = dkv.len(b);
            ctx[b] = base as i32;
            feat_in[b * t * fd..(b * t + n) * fd].copy_from_slice(&feats[b]);
            toks[b * t..b * t + n].copy_from_slice(&next[b]);
            for i in 0..n {
                pos[b * t + i] = ((first_pos[b] + i) as i32).min(c as i32 - 1);
            }
            rows[b] = (0..n)
                .map(|i| MaskRow { prefix_upto: base + i + 1, extra: vec![] })
                .collect();
        }
        let mask = build_mask_b(bsz, t, c, &rows);
        let feat_t = HostTensor::f32(vec![bsz, t, fd], feat_in);
        let tok_t = HostTensor::i32(vec![bsz, t], toks);
        let pos_t = HostTensor::i32(vec![bsz, t], pos);
        let ctx_t = HostTensor::i32(vec![bsz], ctx);
        match self.cfg.method {
            BatchMethod::FastEagle => {
                let exec = self.store.bind(&format!("fe_t{t}{suffix}"), "fasteagle")?;
                let outs = exec.call(
                    &self.store.runtime,
                    &[
                        ("feats", &feat_t),
                        ("next_tokens", &tok_t),
                        ("anchor_pos", &pos_t),
                        ("mask", &mask),
                        ("ctx_len", &ctx_t),
                        ("dkv", dkv.tensor()),
                    ],
                )?;
                let n_lvl = self.spec.draft_depth;
                let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                let ki = exec.out_idx("dkv")?;
                let mut outs = outs;
                dkv.update_from(outs.swap_remove(ki))?;
                for b in 0..bsz {
                    if self.slots[b].is_none() || next[b].is_empty() {
                        continue;
                    }
                    let n = next[b].len();
                    let row = b * t + (n - 1);
                    let slot = self.slots[b].as_mut().unwrap();
                    slot.fe_logits = l[row * n_lvl * v..(row + 1) * n_lvl * v].to_vec();
                    let base = dkv.len(b);
                    dkv.set_len(b, base + n);
                }
            }
            BatchMethod::Eagle3 => {
                let exec =
                    self.store.bind(&format!("eg3_first_t{t}{suffix}"), "eagle3")?;
                let outs = exec.call(
                    &self.store.runtime,
                    &[
                        ("feat_in", &feat_t),
                        ("tokens", &tok_t),
                        ("anchor_pos", &pos_t),
                        ("mask", &mask),
                        ("ctx_len", &ctx_t),
                        ("ekv", dkv.tensor()),
                    ],
                )?;
                let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                let h = outs[exec.out_idx("h")?].as_f32()?.to_vec();
                let ki = exec.out_idx("ekv")?;
                let mut outs = outs;
                dkv.update_from(outs.swap_remove(ki))?;
                for b in 0..bsz {
                    if self.slots[b].is_none() || next[b].is_empty() {
                        continue;
                    }
                    let n = next[b].len();
                    let row = b * t + (n - 1);
                    let slot = self.slots[b].as_mut().unwrap();
                    slot.eg_q1 = l[row * v..(row + 1) * v].to_vec();
                    slot.eg_h = h[row * d..(row + 1) * d].to_vec();
                    let base = dkv.len(b);
                    dkv.set_len(b, base + n);
                }
            }
            BatchMethod::Vanilla => unreachable!(),
        }
        Ok(())
    }

    /// Run a closed workload to completion; returns responses + metrics.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<(Vec<Response>, ServingMetrics)> {
        let mut queue: VecDeque<Request> = requests.into();
        let mut responses = Vec::new();
        let mut metrics = ServingMetrics::default();
        let t0 = Instant::now();
        loop {
            // admission
            for b in 0..self.cfg.batch {
                if self.slots[b].is_some() || queue.is_empty() {
                    continue;
                }
                let cost = self.request_blocks();
                if !self.pool.can_alloc(cost) {
                    metrics.requests_rejected += 1; // deferred, really
                    break;
                }
                let mut lease = Lease::default();
                self.pool.alloc(cost, &mut lease).context("lease")?;
                let req = queue.pop_front().unwrap();
                self.admit(b, req, lease)?;
            }
            if self.slots.iter().all(|s| s.is_none()) {
                if queue.is_empty() {
                    break;
                }
                bail!("no slot admissible but queue non-empty (pool too small?)");
            }
            for r in self.decode_iteration()? {
                metrics.record_done(
                    r.new_tokens,
                    r.cycles,
                    r.tau,
                    std::time::Duration::from_secs_f64(r.latency_ms / 1e3),
                    std::time::Duration::ZERO,
                );
                responses.push(r);
            }
        }
        let _ = t0;
        Ok((responses, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_mask_rows_and_padding() {
        let rows = vec![
            vec![MaskRow { prefix_upto: 2, extra: vec![3] }],
            vec![], // inactive slot: all pad rows
        ];
        let m = build_mask_b(2, 2, 4, &rows);
        let d = m.as_f32().unwrap();
        // slot 0 row 0: slots 0,1,3 visible
        assert_eq!(&d[0..4], &[0.0, 0.0, NEG, 0.0]);
        // slot 0 row 1 is padding: slot 0 only
        assert_eq!(&d[4..8], &[0.0, NEG, NEG, NEG]);
        // slot 1 rows: padding
        assert_eq!(&d[8..12], &[0.0, NEG, NEG, NEG]);
        assert_eq!(&d[12..16], &[0.0, NEG, NEG, NEG]);
    }

    #[test]
    fn method_kv_accounting() {
        let spec = crate::model::ModelSpec::parse(
            crate::model::spec::tests_sample::SAMPLE).unwrap();
        assert_eq!(BatchMethod::Vanilla.drafter_kv_layers(&spec), 0);
        assert_eq!(BatchMethod::Eagle3.drafter_kv_layers(&spec), 1);
        assert_eq!(BatchMethod::FastEagle.drafter_kv_layers(&spec), spec.draft_depth);
    }
}
