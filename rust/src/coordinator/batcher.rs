//! Continuous-batching engine over the batched (`*_b{B}`) executables —
//! the vLLM-style serving path behind both the live TCP server and the
//! paper's Table 3 study (throughput vs batch size, chain length 2,
//! tree disabled).
//!
//! Design mirrors vLLM's single-scheduler loop at miniature scale, with
//! the *decisions* carved out into [`super::scheduler`]: each
//! [`BatchEngine::step`] asks the [`Scheduler`] for a [`SchedulePlan`]
//! (admit / chunk-prefill / run / preempt / resume over read-only
//! views) and merely executes it — one batched iteration per step —
//! returning whichever requests completed
//! ([`BatchEngine::step_events`] additionally reports every slot's
//! per-cycle [`SlotEvent`] — what the server's streaming frames are
//! made of). The closed-workload [`BatchEngine::run`] used by the
//! benches is a thin wrapper that submits everything up front and steps
//! until drained — the serving loop and the benchmark exercise the same
//! code path.
//!
//! Each slot drives the same [`SlotCycle`] core as the single-request
//! `GenSession` (prompt budget, tree build from `DraftOutput`, mask-row
//! construction, lossless accept, commit bookkeeping) — only the
//! forward passes are batched here.
//!
//! * **Chunked prefill on the batched lane**: admission is cheap (a KV
//!   lease plus a [`SlotPhase::Prefilling`] slot); the prompt is then
//!   ingested in fixed-token chunks that ride the *same* batched target
//!   call that verifies the decoding slots' trees, so a long prompt
//!   never head-of-line-blocks decode progress. Generation parameters
//!   (temperature, seed, max_new_tokens, stop_on_eos) are honored
//!   **per request** — each slot carries its own sampler — and so are
//!   the **method** (one pool serves fasteagle, eagle3 and vanilla
//!   slots side by side) and the scheduling **priority**.
//! * **Decode loop**: one batched draft per drafting method + one
//!   batched verification per iteration; per-slot lossless acceptance
//!   and KV compaction on the host.
//! * **Preemption with lease shrinking**: under pool pressure the
//!   policy can pause a lower-priority decoding slot — its KV state is
//!   parked on the host, its lease shrunk to exactly the committed
//!   prefix — and resume it later with no recomputation (the committed
//!   output is byte-identical to an uninterrupted run).
//! * **Slot eviction**: a finished request's KV lease is released and
//!   its lane zeroed in the same iteration it completes, so queued work
//!   can be admitted on the very next step.
//! * **Paged admission control**: every request leases KV blocks for the
//!   target's L layers plus **its own method's** drafter KV layers
//!   (FastEagle N=6 vs EAGLE 1 vs vanilla 0). When the pool can't cover
//!   a request it waits in the queue — this is the memory-pressure
//!   mechanism that caps FastEagle's batched throughput in Table 3.
//!   Each distinct request's deferral is counted once
//!   (`requests_deferred`), no matter how many scheduler passes it
//!   waits through — that bookkeeping lives in the scheduler now.

use std::collections::{HashSet, VecDeque};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cache::PrefixCache;
use crate::draft::{DraftOutput, Drafter, EagleDrafter, FastEagleDrafter, ObserveArgs};
use crate::model::{BlockPool, KvCache, Lease, MaskRow, ModelSpec, Tokenizer, NEG};
use crate::runtime::tensor::HostTensor;
use crate::runtime::ArtifactStore;
use crate::spec::{
    prompt_budget, truncate_prompt, verify_rows, DraftConfig, DraftPlan, DraftTree, SlotCycle,
    SlotPhase,
};

use super::metrics::ServingMetrics;
use super::request::{Request, Response};
use super::scheduler::{
    preempt::shrink_gain, ActiveView, ParkedView, PendingView, PolicyKind, PrefillProgress,
    SchedConfig, SchedView, SchedulePlan, Scheduler,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMethod {
    Vanilla,
    FastEagle,
    Eagle3,
}

impl BatchMethod {
    pub fn drafter_kv_layers(self, spec: &ModelSpec) -> usize {
        match self {
            BatchMethod::Vanilla => 0,
            BatchMethod::FastEagle => spec.draft_depth,
            BatchMethod::Eagle3 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BatchMethod::Vanilla => "vanilla",
            BatchMethod::FastEagle => "fasteagle",
            BatchMethod::Eagle3 => "eagle3",
        }
    }

    pub fn from_name(name: &str) -> Option<BatchMethod> {
        Some(match name {
            "vanilla" => BatchMethod::Vanilla,
            "fasteagle" => BatchMethod::FastEagle,
            "eagle3" => BatchMethod::Eagle3,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone)]
pub struct BatchConfig {
    pub batch: usize,
    /// default method for requests that don't carry their own
    /// (`Request::method`); a pool can mix methods across slots
    pub method: BatchMethod,
    /// draft chain length per cycle (Table 3: 2). Engine-wide because it
    /// fixes the lowered executable shapes — the hard ceiling every
    /// per-slot [`DraftPlan`] is clamped to; everything else
    /// (temperature, seed, max_new_tokens, stop_on_eos, method,
    /// priority, draft plan) is per-request.
    pub chain_len: usize,
    /// serving-wide draft-plan defaults (`--planner`, `--draft-depth`,
    /// ...); a request's own `"draft"` object overrides field-wise
    pub draft: DraftConfig,
    /// KV block pool (admission control); `None` = unbounded
    pub pool_blocks: Option<usize>,
    pub block_slots: usize,
    /// scheduling policy (`--policy fcfs|spf`)
    pub policy: PolicyKind,
    /// max prompt tokens ingested per slot per step; the batched call's
    /// verify rows (`1 + chain_len`) are a further hard cap
    pub prefill_chunk: usize,
    /// preemption budget per scheduler step (0 disables preemption)
    pub max_preemptions_per_step: usize,
    /// prefix cache (`--prefix-cache`): retired requests publish their
    /// committed prefix into a radix index; admissions adopt the longest
    /// cached prefix by block sharing and prefill only the remainder
    pub prefix_cache: bool,
}

impl BatchConfig {
    pub fn new(batch: usize, method: BatchMethod) -> BatchConfig {
        BatchConfig {
            batch,
            method,
            chain_len: 2,
            draft: DraftConfig::default(),
            pool_blocks: None,
            block_slots: 16,
            policy: PolicyKind::Fcfs,
            prefill_chunk: usize::MAX,
            max_preemptions_per_step: 1,
            prefix_cache: false,
        }
    }
}

struct Slot {
    req: Request,
    method: BatchMethod,
    /// prompt-ingestion progress; `Some` while the slot is Prefilling
    prefill: Option<PrefillProgress>,
    /// the shared per-request cycle core (sampler, pending token,
    /// committed output, termination) — same state machine as
    /// `GenSession`; `Some` once Decoding
    cycle: Option<SlotCycle>,
    /// when the request (re-)entered its slot; `gen_ms_accum` carries
    /// generation time from before a preemption
    admitted_at: Instant,
    gen_ms_accum: f64,
    lease: Lease,
    // FastEagle per-slot draft state: [N, V] logits from the cascade
    fe_logits: Vec<f32>,
    // EAGLE per-slot draft state
    eg_h: Vec<f32>,
    eg_q1: Vec<f32>,
    /// per-KV-row input tokens (prompt, then each cycle's accepted
    /// rows) — what the prefix cache keys on; tracked only when the
    /// cache is enabled and the request didn't opt out
    row_tokens: Vec<i32>,
    /// per-KV-row target features, aligned with `row_tokens`
    row_feats: Vec<f32>,
}

impl Slot {
    fn phase(&self) -> SlotPhase {
        if self.prefill.is_some() {
            SlotPhase::Prefilling
        } else {
            SlotPhase::Decoding
        }
    }

    fn finished(&self) -> bool {
        self.cycle.as_ref().map(|c| c.finished()).unwrap_or(false)
    }
}

/// A preempted request's complete state, parked on the host: KV +
/// drafter tensors for its committed prefix, the live `SlotCycle`
/// (sampler stream included, so the stochastic output is unchanged by
/// the pause), and the shrunk lease that still pays for the parked
/// rows.
struct Parked {
    req: Request,
    method: BatchMethod,
    cycle: SlotCycle,
    kv: KvCache,
    fe_dkv: Option<KvCache>,
    eg_dkv: Option<KvCache>,
    fe_logits: Vec<f32>,
    eg_h: Vec<f32>,
    eg_q1: Vec<f32>,
    lease: Lease,
    gen_ms_accum: f64,
    row_tokens: Vec<i32>,
    row_feats: Vec<f32>,
}

/// Where [`BatchEngine::cancel`] found (and evicted) the request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelOutcome {
    /// still queued — removed before ever touching a slot
    Pending,
    /// occupying a slot (prefilling or decoding) — slot evicted, lease
    /// released, lanes zeroed
    Active,
    /// preempted and parked — parked state dropped, lease released
    Parked,
    /// unknown id (never submitted, already completed, or already
    /// canceled) — nothing to do
    NotFound,
}

impl CancelOutcome {
    pub fn name(self) -> &'static str {
        match self {
            CancelOutcome::Pending => "pending",
            CancelOutcome::Active => "active",
            CancelOutcome::Parked => "parked",
            CancelOutcome::NotFound => "not_found",
        }
    }

    /// True when the cancel actually evicted a live request.
    pub fn found(self) -> bool {
        !matches!(self, CancelOutcome::NotFound)
    }
}

/// One slot's cycle outcome within a [`BatchEngine::step_events`] —
/// the per-cycle progress the streaming protocol forwards to clients.
/// Carries raw token ids only; consumers that want text decode on
/// demand ([`BatchEngine::decode`]) so non-streaming callers pay
/// nothing per cycle.
#[derive(Debug, Clone)]
pub struct SlotEvent {
    pub id: u64,
    /// 1-based cycle index for this request
    pub cycle: usize,
    /// tokens committed this cycle (post eos/max_new truncation)
    pub tokens: Vec<i32>,
    /// accepted path length including the root
    pub accepted_len: usize,
    pub finished: bool,
}

/// What one scheduler step produced.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// completed (or failed) requests
    pub finished: Vec<Response>,
    /// one event per active slot that ran a cycle this step
    pub events: Vec<SlotEvent>,
}

pub struct BatchEngine {
    store: Rc<ArtifactStore>,
    pub spec: ModelSpec,
    cfg: BatchConfig,
    tokenizer: Tokenizer,
    kv: KvCache,
    /// FastEagle batched drafter state [N,2,B,C,..]; allocated on the
    /// first fasteagle admission (mixed pools may never need it)
    fe_dkv: Option<KvCache>,
    /// EAGLE batched drafter state [2,B,C,..]; allocated on the first
    /// eagle3 admission
    eg_dkv: Option<KvCache>,
    slots: Vec<Option<Slot>>,
    pool: BlockPool,
    /// submitted but not yet admitted to a slot
    pending: VecDeque<Request>,
    /// preempted requests awaiting resume (state parked on the host)
    parked: VecDeque<Parked>,
    scheduler: Scheduler,
    /// prefix cache (inert unless `cfg.prefix_cache`)
    cache: PrefixCache,
}

/// Batched additive mask [B, T, S] from per-slot row descriptors.
fn build_mask_b(bsz: usize, t: usize, s: usize, rows: &[Vec<MaskRow>]) -> HostTensor {
    let mut data = vec![NEG; bsz * t * s];
    for (b, slot_rows) in rows.iter().enumerate() {
        for i in 0..t {
            let base = (b * t + i) * s;
            match slot_rows.get(i) {
                Some(r) => {
                    let upto = r.prefix_upto.min(s);
                    for v in &mut data[base..base + upto] {
                        *v = 0.0;
                    }
                    for &e in &r.extra {
                        if e < s {
                            data[base + e] = 0.0;
                        }
                    }
                }
                None => data[base] = 0.0, // pad row
            }
        }
    }
    HostTensor::f32(vec![bsz, t, s], data)
}

impl BatchEngine {
    pub fn new(store: Rc<ArtifactStore>, cfg: BatchConfig) -> Result<BatchEngine> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        if cfg.batch > 1 && !spec.batch_sizes.contains(&cfg.batch) {
            bail!(
                "target {:?} has no batch-{} executables (lowered: {:?})",
                spec.name, cfg.batch, spec.batch_sizes
            );
        }
        // engine contract: the chain-shaped plans this engine will emit
        // (and the prefill chunks they cap) must have a lowered verify
        // lane at this batch — fail at startup, not mid-serve
        let report = crate::runtime::contract::check_engine(&spec, cfg.batch, cfg.chain_len);
        report.ensure_ok()?;
        for w in report.warnings() {
            eprintln!("[{}] contract: {w}", spec.name);
        }
        if cfg.prefix_cache {
            let report =
                crate::runtime::contract::check_cache(&spec, cfg.block_slots, cfg.batch);
            report.ensure_ok()?;
            for w in report.warnings() {
                eprintln!("[{}] contract: {w}", spec.name);
            }
        }
        let b = cfg.batch;
        let kv = KvCache::zeros(vec![
            spec.n_layers, 2, b, spec.max_seq, spec.n_kv_heads, spec.head_dim,
        ])?;
        let tokenizer = Tokenizer::new(spec.bos, spec.eos, spec.pad);
        let pool_blocks = cfg.pool_blocks.unwrap_or(usize::MAX / 4);
        let pool = BlockPool::new(pool_blocks, cfg.block_slots);
        let slots = (0..b).map(|_| None).collect();
        let scheduler = Scheduler::new(
            cfg.policy,
            SchedConfig {
                prefill_chunk: cfg.prefill_chunk,
                max_preemptions_per_step: cfg.max_preemptions_per_step,
            },
        );
        let cache =
            PrefixCache::new(cfg.prefix_cache, cfg.block_slots, spec.n_layers, spec.feat_dim);
        Ok(BatchEngine {
            store,
            spec,
            cfg,
            tokenizer,
            kv,
            fe_dkv: None,
            eg_dkv: None,
            slots,
            pool,
            pending: VecDeque::new(),
            parked: VecDeque::new(),
            scheduler,
            cache,
        })
    }

    /// The engine's default method (requests may override per-request).
    pub fn method(&self) -> BatchMethod {
        self.cfg.method
    }

    /// Active scheduling policy name (observability).
    pub fn policy_name(&self) -> &'static str {
        self.scheduler.policy_name()
    }

    /// Decode committed tokens with this engine's tokenizer — how
    /// streaming consumers turn [`SlotEvent::tokens`] into frame text.
    pub fn decode(&self, tokens: &[i32]) -> String {
        self.tokenizer.decode(tokens)
    }

    pub fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn method_of(&self, req: &Request) -> BatchMethod {
        req.method.unwrap_or(self.cfg.method)
    }

    /// Enqueue a request for admission on a future [`step`](Self::step).
    pub fn submit(&mut self, req: Request) {
        self.pending.push_back(req);
    }

    /// Occupied slots.
    pub fn active_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Submitted requests not yet admitted to a slot.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Preempted requests parked awaiting resume.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Lifecycle phase of one slot (`None` = free) — test/observability
    /// hook for the chunked-prefill and preemption paths.
    pub fn slot_phase(&self, b: usize) -> Option<SlotPhase> {
        self.slots.get(b).and_then(|s| s.as_ref()).map(|s| s.phase())
    }

    pub fn has_work(&self) -> bool {
        self.active_len() > 0 || !self.pending.is_empty() || !self.parked.is_empty()
    }

    /// How many more requests the engine wants queued internally: enough
    /// to fill every slot. Callers keep the rest in their own bounded
    /// queue so capacity-based shedding stays effective.
    pub fn admission_room(&self) -> usize {
        self.cfg
            .batch
            .saturating_sub(self.active_len() + self.pending.len())
    }

    /// Free blocks in the KV pool (admission-control observability).
    pub fn pool_available(&self) -> usize {
        self.pool.available()
    }

    pub fn pool_total(&self) -> usize {
        self.pool.total()
    }

    /// Pool blocks issued but not yet returned (leases + cache shares).
    /// After every request retires/cancels and [`release_cache`]
    /// (Self::release_cache) runs, this must be zero — the invariant the
    /// cancellation tests and drained replicas assert.
    pub fn leaked_blocks(&self) -> usize {
        self.pool.leaked_blocks()
    }

    /// Drop every prefix-cache entry, returning its blocks to the pool
    /// (test/observability hook for the leak accounting above).
    pub fn release_cache(&mut self) {
        self.cache.clear(&mut self.pool);
    }

    fn exec_suffix(&self) -> String {
        if self.cfg.batch == 1 {
            String::new()
        } else {
            format!("_b{}", self.cfg.batch)
        }
    }

    /// One request's cost in pool blocks (target + that method's drafter
    /// KV layers) — the per-method lease accounting mixed fleets rely on.
    pub fn request_blocks(&self, method: BatchMethod) -> usize {
        let drafter_layers = method.drafter_kv_layers(&self.spec);
        self.pool
            .blocks_for(self.spec.max_seq, self.spec.n_layers + drafter_layers)
    }

    /// Target + drafter KV layers a request's lease pays for.
    fn lease_layers(&self, method: BatchMethod) -> usize {
        self.spec.n_layers + method.drafter_kv_layers(&self.spec)
    }

    /// The exact prompt token ids a request will prefill — encode,
    /// budget-truncate, degenerate-budget BOS fallback. Shared by the
    /// scheduler view's cache peek and the admission path so both see
    /// the same cache key.
    fn prompt_ids(&self, req: &Request) -> Vec<i32> {
        let mut ptoks = self.tokenizer.encode_prompt(&req.prompt);
        let budget = prompt_budget(
            self.spec.max_seq,
            req.cfg.max_new_tokens,
            self.cfg.chain_len + 3,
        );
        truncate_prompt(&mut ptoks, budget);
        if ptoks.is_empty() {
            // degenerate budget (max_new ~ max_seq): keep one row so the
            // slot still produces last-token logits
            ptoks.push(self.spec.bos);
        }
        ptoks
    }

    /// Radix nodes this step's plan may count on adopting: the union of
    /// every pending request's current longest-prefix chain. Eviction
    /// must not reclaim these — the scheduler already funded admissions
    /// with their shared blocks.
    fn protect_set(&self) -> HashSet<usize> {
        let mut protect = HashSet::new();
        if self.cache.enabled() {
            for r in &self.pending {
                if r.cache {
                    protect.extend(self.cache.peek(&self.prompt_ids(r)).node_ids);
                }
            }
        }
        protect
    }

    /// Prefix-cache gauge snapshot: `(nodes, held_blocks)`.
    pub fn cache_usage(&self) -> (usize, usize) {
        (self.cache.nodes(), self.cache.held_blocks())
    }

    fn ensure_fe_dkv(&mut self) -> Result<&mut KvCache> {
        if self.fe_dkv.is_none() {
            self.fe_dkv = Some(KvCache::zeros(vec![
                self.spec.draft_depth,
                2,
                self.cfg.batch,
                self.spec.max_seq,
                self.spec.n_kv_heads,
                self.spec.head_dim,
            ])?);
        }
        Ok(self.fe_dkv.as_mut().unwrap())
    }

    fn ensure_eg_dkv(&mut self) -> Result<&mut KvCache> {
        if self.eg_dkv.is_none() {
            self.eg_dkv = Some(KvCache::zeros(vec![
                2,
                self.cfg.batch,
                self.spec.max_seq,
                self.spec.n_kv_heads,
                self.spec.head_dim,
            ])?);
        }
        Ok(self.eg_dkv.as_mut().unwrap())
    }

    /// Verify rows the batched call exposes per step — the hard cap on
    /// a slot's prefill chunk and on any slot's [`DraftPlan`] rows.
    fn max_rows(&self) -> usize {
        1 + self.cfg.chain_len
    }

    /// Resolve a request's draft knobs into the batched lane's base
    /// plan. The batched executables verify chains (one candidate per
    /// level, `1 + chain_len` rows), so the plan is a chain clamped to
    /// the engine's chain length; `top_k` is ignored on this lane.
    /// Vanilla slots plan a root-only draft.
    fn base_plan(&self, method: BatchMethod, draft: &DraftConfig) -> DraftPlan {
        let native = match method {
            BatchMethod::Vanilla => 0,
            BatchMethod::FastEagle | BatchMethod::Eagle3 => self.cfg.chain_len,
        };
        let mut plan = DraftPlan::chain_of(draft.depth.unwrap_or(native));
        if let Some(b) = draft.budget {
            plan.node_budget = plan.node_budget.min(b);
        }
        plan.clamp_to(self.cfg.chain_len, self.max_rows() - 1);
        plan
    }

    /// Snapshot the engine state for the scheduler.
    fn sched_view(&self) -> SchedView {
        let bsz = self.cfg.batch;
        let free_slots: Vec<usize> =
            (0..bsz).filter(|&b| self.slots[b].is_none()).collect();
        let mut protect: HashSet<usize> = HashSet::new();
        let pending: Vec<PendingView> = self
            .pending
            .iter()
            .map(|r| {
                let budget = prompt_budget(
                    self.spec.max_seq,
                    r.cfg.max_new_tokens,
                    self.cfg.chain_len + 3,
                );
                let (cached_tokens, cached_blocks) = if self.cache.enabled() && r.cache {
                    let hit = self.cache.peek(&self.prompt_ids(r));
                    protect.extend(hit.node_ids.iter().copied());
                    (hit.tokens, hit.blocks)
                } else {
                    (0, 0)
                };
                PendingView {
                    id: r.id,
                    priority: r.priority,
                    // byte tokenizer: prompt bytes + BOS, pre-truncation cap
                    prompt_tokens: (r.prompt.len() + 1).min(budget.max(1)),
                    cost_blocks: self.request_blocks(self.method_of(r)),
                    cached_tokens,
                    cached_blocks,
                }
            })
            .collect();
        let parked: Vec<ParkedView> = self
            .parked
            .iter()
            .map(|p| ParkedView {
                id: p.req.id,
                priority: p.req.priority,
                resume_delta_blocks: self
                    .request_blocks(p.method)
                    .saturating_sub(p.lease.blocks.len()),
            })
            .collect();
        let active: Vec<ActiveView> = (0..bsz)
            .filter_map(|b| {
                let slot = self.slots[b].as_ref()?;
                let committed_cost = self
                    .pool
                    .blocks_for(self.kv.len(b), self.lease_layers(slot.method));
                Some(ActiveView {
                    slot: b,
                    id: slot.req.id,
                    priority: slot.req.priority,
                    phase: slot.phase(),
                    prefill_remaining: slot
                        .prefill
                        .as_ref()
                        .map(|p| p.remaining())
                        .unwrap_or(0),
                    shrink_gain_blocks: match slot.phase() {
                        SlotPhase::Decoding => {
                            shrink_gain(slot.lease.blocks.len(), committed_cost)
                        }
                        SlotPhase::Prefilling => 0,
                    },
                    finished: slot.finished(),
                })
            })
            .collect();
        SchedView {
            free_slots,
            pool_available: self.pool.available(),
            evictable_blocks: self.cache.evictable_blocks(&self.pool, &protect),
            max_rows: self.max_rows(),
            pending,
            parked,
            active,
        }
    }

    /// Place a pending request into a free slot as `Prefilling`. Cheap:
    /// no forward pass — the prompt is ingested chunk by chunk on the
    /// batched lane by subsequent iterations. With the prefix cache on,
    /// the longest cached prefix is adopted first (shared blocks join
    /// the lease, cached KV rows and features land in the lane) and
    /// only the uncached remainder is allocated and prefilled — the
    /// scheduler funded exactly that remainder.
    fn admit_request(
        &mut self,
        slot_idx: usize,
        req: Request,
        metrics: &mut ServingMetrics,
    ) -> Result<()> {
        let method = self.method_of(&req);
        let ptoks = self.prompt_ids(&req);
        self.kv.set_len(slot_idx, 0);
        let mut lease = Lease::default();
        let mut adopted: Option<(usize, Vec<f32>)> = None;
        if self.cache.enabled() && req.cache {
            let t_lookup = Instant::now();
            let hit = self.cache.lookup(&ptoks);
            crate::obs::span_from("cache_lookup", t_lookup)
                .tid(slot_idx as u32)
                .req(req.id)
                .arg(hit.tokens as i64)
                .emit();
            if hit.tokens > 0 {
                let t_adopt = Instant::now();
                let feats = self.cache.adopt(
                    &hit,
                    &mut self.pool,
                    &mut self.kv,
                    slot_idx,
                    &mut lease,
                )?;
                self.kv.set_len(slot_idx, hit.tokens);
                metrics.cache_hits += 1;
                metrics.cache_saved_tokens += hit.tokens as u64;
                crate::obs::span_from("cache_adopt", t_adopt)
                    .tid(slot_idx as u32)
                    .req(req.id)
                    .arg(hit.tokens as i64)
                    .emit();
                adopted = Some((hit.tokens, feats));
            } else {
                metrics.cache_misses += 1;
            }
        }
        let cost = self.request_blocks(method);
        self.pool
            .alloc(cost - lease.blocks.len(), &mut lease)
            .expect("scheduler checked pool availability");
        let prefill = match adopted {
            Some((pos, feats)) => PrefillProgress::with_prefix(ptoks, pos, feats),
            None => PrefillProgress::new(ptoks),
        };
        self.slots[slot_idx] = Some(Slot {
            req,
            method,
            prefill: Some(prefill),
            cycle: None,
            admitted_at: Instant::now(),
            gen_ms_accum: 0.0,
            lease,
            fe_logits: Vec::new(),
            eg_h: Vec::new(),
            eg_q1: Vec::new(),
            row_tokens: Vec::new(),
            row_feats: Vec::new(),
        });
        Ok(())
    }

    /// Pause a decoding slot under pool pressure: park its KV/drafter
    /// state on the host, shrink its lease to the committed prefix, and
    /// queue it for resume. The sampler stream travels with the
    /// `SlotCycle`, so the eventual output is byte-identical to an
    /// uninterrupted run.
    fn park_slot(&mut self, b: usize, metrics: &mut ServingMetrics) -> Result<()> {
        let mut slot = self.slots[b].take().expect("preempt of empty slot");
        let committed = self.kv.len(b);
        let kv = self.kv.extract_request(b)?;
        self.kv.set_len(b, 0);
        let fe_dkv = match (&slot.method, self.fe_dkv.as_mut()) {
            (BatchMethod::FastEagle, Some(d)) => {
                let parked = d.extract_request(b)?;
                d.set_len(b, 0);
                Some(parked)
            }
            _ => None,
        };
        let eg_dkv = match (&slot.method, self.eg_dkv.as_mut()) {
            (BatchMethod::Eagle3, Some(d)) => {
                let parked = d.extract_request(b)?;
                d.set_len(b, 0);
                Some(parked)
            }
            _ => None,
        };
        let layers = self.lease_layers(slot.method);
        self.pool.shrink(&mut slot.lease, committed, layers);
        metrics.preemptions += 1;
        crate::obs::mark("preempt", b as u32, slot.req.id, committed as i64);
        self.parked.push_back(Parked {
            cycle: slot.cycle.take().expect("only decoding slots are preempted"),
            req: slot.req,
            method: slot.method,
            kv,
            fe_dkv,
            eg_dkv,
            fe_logits: slot.fe_logits,
            eg_h: slot.eg_h,
            eg_q1: slot.eg_q1,
            lease: slot.lease,
            gen_ms_accum: slot.gen_ms_accum
                + slot.admitted_at.elapsed().as_secs_f64() * 1e3,
            row_tokens: slot.row_tokens,
            row_feats: slot.row_feats,
        });
        Ok(())
    }

    /// Restore a parked request into a free slot: grow the lease back
    /// to full cost and copy its KV/drafter state into the lane.
    fn resume_parked(
        &mut self,
        slot_idx: usize,
        parked_idx: usize,
        metrics: &mut ServingMetrics,
    ) -> Result<()> {
        let p = self
            .parked
            .remove(parked_idx)
            .expect("resume of missing parked entry");
        let layers = self.lease_layers(p.method);
        let mut lease = p.lease;
        self.pool.ensure(&mut lease, self.spec.max_seq, layers)?;
        self.kv.copy_request_from(slot_idx, &p.kv)?;
        if let Some(d) = &p.fe_dkv {
            self.ensure_fe_dkv()?.copy_request_from(slot_idx, d)?;
        }
        if let Some(d) = &p.eg_dkv {
            self.ensure_eg_dkv()?.copy_request_from(slot_idx, d)?;
        }
        crate::obs::mark("resume", slot_idx as u32, p.req.id, 0);
        self.slots[slot_idx] = Some(Slot {
            req: p.req,
            method: p.method,
            prefill: None,
            cycle: Some(p.cycle),
            admitted_at: Instant::now(),
            gen_ms_accum: p.gen_ms_accum,
            lease,
            fe_logits: p.fe_logits,
            eg_h: p.eg_h,
            eg_q1: p.eg_q1,
            row_tokens: p.row_tokens,
            row_feats: p.row_feats,
        });
        metrics.resumes += 1;
        Ok(())
    }

    /// One draft per running slot, dispatched by the slot's method and
    /// sized by the slot's per-cycle plan (`plan_depths[b]` = chain
    /// levels this cycle, 0 for vanilla): FastEagle chains come
    /// straight off the cascade logits produced during observe (zero
    /// executable calls), EAGLE slots share one batched autoregressive
    /// loop that each slot exits at its own planned depth, vanilla
    /// slots draft nothing.
    fn draft_outputs(
        &mut self,
        run: &[usize],
        plan_depths: &[usize],
        metrics: &mut ServingMetrics,
    ) -> Result<Vec<Option<DraftOutput>>> {
        let bsz = self.cfg.batch;
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        let mut in_run = vec![false; bsz];
        for &b in run {
            in_run[b] = true;
        }
        let mut out: Vec<Option<DraftOutput>> = (0..bsz).map(|_| None).collect();
        // host-side methods first (no executable calls); FastEagle's
        // whole draft cost is this loop — the cascade already ran
        let t_host = Instant::now();
        let mut any_fe = false;
        for (b, s) in self.slots.iter_mut().enumerate() {
            let Some(slot) = s else { continue };
            if !in_run[b] {
                continue;
            }
            match slot.method {
                BatchMethod::Vanilla => out[b] = Some(DraftOutput::None),
                BatchMethod::FastEagle => {
                    any_fe = true;
                    // the cascade already produced all N levels during
                    // observe; the plan says how many to use this cycle
                    let depth = plan_depths[b];
                    let temp = slot.req.cfg.temperature;
                    let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                    let mut toks = Vec::with_capacity(depth);
                    let mut dists = Vec::with_capacity(depth);
                    for lvl in 0..depth.min(self.spec.draft_depth) {
                        let mut q = slot.fe_logits[lvl * v..(lvl + 1) * v].to_vec();
                        crate::util::rng::softmax_temp(&mut q, temp);
                        // chain links are q-samples at T>0 (losslessness)
                        toks.push(cycle.sampler.sample(&q));
                        dists.push(q);
                    }
                    out[b] = Some(DraftOutput::Chain(toks, dists));
                }
                BatchMethod::Eagle3 => {}
            }
        }
        if any_fe {
            metrics.record_phase("fasteagle", "draft", t_host.elapsed());
        }
        // EAGLE slots: level 1 from observe; levels 2.. via batched
        // eg_next, each slot stopping at its own planned depth
        let t_eg = Instant::now();
        let mut eg_chains: Vec<Option<(Vec<i32>, Vec<Vec<f32>>)>> =
            (0..bsz).map(|_| None).collect();
        let mut hs: Vec<Vec<f32>> = Vec::with_capacity(bsz);
        let mut eg_max = 0usize;
        for (b, s) in self.slots.iter_mut().enumerate() {
            match s {
                Some(slot)
                    if in_run[b]
                        && slot.method == BatchMethod::Eagle3
                        && plan_depths[b] > 0 =>
                {
                    let mut q = slot.eg_q1.clone();
                    crate::util::rng::softmax_temp(&mut q, slot.req.cfg.temperature);
                    let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                    let tok = cycle.sampler.sample(&q);
                    eg_chains[b] = Some((vec![tok], vec![q]));
                    hs.push(slot.eg_h.clone());
                    eg_max = eg_max.max(plan_depths[b]);
                }
                _ => hs.push(vec![0.0; d]),
            }
        }
        if eg_max > 1 {
            let suffix = self.exec_suffix();
            let exec = self.store.bind(&format!("eg_next_t1{suffix}"), "eagle3")?;
            let mut ekv_tmp = self.eg_dkv.as_ref().expect("eagle slot admitted").clone();
            for step in 1..eg_max {
                let mut feat = vec![0.0f32; bsz * d];
                let mut toks = vec![self.spec.pad; bsz];
                let mut pos = vec![0i32; bsz];
                let mut ctx = vec![0i32; bsz];
                let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
                for b in 0..bsz {
                    // slots whose plan ends before this level ride along
                    // as pad rows (their chain is already complete)
                    if step >= plan_depths[b] {
                        continue;
                    }
                    if let Some((t, _)) = &eg_chains[b] {
                        feat[b * d..(b + 1) * d].copy_from_slice(&hs[b]);
                        toks[b] = t[step - 1];
                        let base = ekv_tmp.len(b);
                        pos[b] = ((base + step) as i32).min(c as i32 - 1);
                        ctx[b] = (base + step - 1) as i32;
                        rows[b] =
                            vec![MaskRow { prefix_upto: base + step, extra: vec![] }];
                    }
                }
                let mask = build_mask_b(bsz, 1, c, &rows);
                let feat_t = HostTensor::f32(vec![bsz, 1, d], feat);
                let tok_t = HostTensor::i32(vec![bsz, 1], toks);
                let pos_t = HostTensor::i32(vec![bsz, 1], pos);
                let ctx_t = HostTensor::i32(vec![bsz], ctx);
                let outs = exec.call(
                    &self.store.runtime,
                    &[
                        ("feat_in", &feat_t),
                        ("tokens", &tok_t),
                        ("anchor_pos", &pos_t),
                        ("mask", &mask),
                        ("ctx_len", &ctx_t),
                        ("ekv", ekv_tmp.tensor()),
                    ],
                )?;
                let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                let hvec = outs[exec.out_idx("h")?].as_f32()?.to_vec();
                let ki = exec.out_idx("ekv")?;
                let mut outs = outs;
                ekv_tmp.update_from(outs.swap_remove(ki))?;
                for b in 0..bsz {
                    if step >= plan_depths[b] {
                        continue;
                    }
                    if let Some((t, dd)) = &mut eg_chains[b] {
                        let slot = self.slots[b].as_mut().unwrap();
                        let mut q = l[b * v..(b + 1) * v].to_vec();
                        crate::util::rng::softmax_temp(&mut q, slot.req.cfg.temperature);
                        let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                        let tok = cycle.sampler.sample(&q);
                        t.push(tok);
                        dd.push(q);
                        hs[b].copy_from_slice(&hvec[b * d..(b + 1) * d]);
                    }
                }
            }
            // ekv_tmp dropped: temp entries rolled back
        }
        if eg_chains.iter().any(Option::is_some) {
            metrics.record_phase("eagle3", "draft", t_eg.elapsed());
        }
        for (b, chain) in eg_chains.into_iter().enumerate() {
            if let Some((toks, dists)) = chain {
                out[b] = Some(DraftOutput::Chain(toks, dists));
            }
        }
        Ok(out)
    }

    /// A finished prompt ingestion: start the slot's cycle core from the
    /// last prompt token's logits and run the drafter's prompt observe
    /// over the accumulated features. The observe runs on the B=1
    /// drafter executables and its state is copied into the batch lane
    /// — the batched observe call writes rows into *every* lane, so
    /// using it for a single slot would corrupt the other slots'
    /// drafter KV. (The expensive part — the target forward over the
    /// prompt — already happened chunk by chunk on the batched lane.)
    /// Errors here are per-request (missing drafter weights, say) — the
    /// caller fails that request without poisoning the pool.
    fn finalize_prefill(&mut self, b: usize, last_logits: &[f32]) -> Result<()> {
        let (ptoks, feats, method, mut cfg) = {
            let slot = self.slots[b].as_mut().expect("prefill slot");
            let pf = slot.prefill.take().expect("finalize of non-prefilling slot");
            if self.cache.enabled() && slot.req.cache {
                // seed the publishable row history with the prompt rows
                // (adopted prefix included — `with_prefix` carried its
                // cached features); decode cycles append accepted rows
                slot.row_tokens = pf.ptoks.clone();
                slot.row_feats = pf.feats.clone();
            }
            (pf.ptoks, pf.feats, slot.method, slot.req.cfg.clone())
        };
        // request knobs over serving defaults, resolved to this lane's
        // chain-shaped base plan
        cfg.draft = cfg.draft.merged(&self.cfg.draft);
        let base = self.base_plan(method, &cfg.draft);
        let cycle = SlotCycle::start(cfg, base, last_logits);
        let mut next: Vec<i32> = ptoks[1..].to_vec();
        next.push(cycle.pending);
        match method {
            BatchMethod::Vanilla => {}
            BatchMethod::FastEagle => {
                let mut d =
                    FastEagleDrafter::new(Rc::clone(&self.store), "fasteagle", "fe")?;
                d.observe(ObserveArgs {
                    feats: &feats,
                    anchor_tokens: &ptoks,
                    next_tokens: &next,
                    first_pos: 0,
                })?;
                let (dkv1, logits) = d.state();
                let fe_logits = logits.to_vec();
                self.ensure_fe_dkv()?.copy_request_from(b, dkv1)?;
                self.slots[b].as_mut().unwrap().fe_logits = fe_logits;
            }
            BatchMethod::Eagle3 => {
                let mut d = EagleDrafter::new(Rc::clone(&self.store), "eagle3", true)?;
                d.observe(ObserveArgs {
                    feats: &feats,
                    anchor_tokens: &ptoks,
                    next_tokens: &next,
                    first_pos: 0,
                })?;
                let (ekv1, h, q1) = d.state();
                let (eg_h, eg_q1) = (h.to_vec(), q1.to_vec());
                self.ensure_eg_dkv()?.copy_request_from(b, ekv1)?;
                let slot = self.slots[b].as_mut().unwrap();
                slot.eg_h = eg_h;
                slot.eg_q1 = eg_q1;
            }
        }
        self.slots[b].as_mut().unwrap().cycle = Some(cycle);
        Ok(())
    }

    /// Evict an occupied slot without retiring it: release the lease
    /// (share-aware, so blocks adopted from the prefix cache survive
    /// under the cache's own refs) and zero the slot's KV/drafter
    /// lanes. Shared by the failure, cancel and deadline paths.
    fn evict_slot(&mut self, b: usize) -> Request {
        let mut slot = self.slots[b].take().expect("evicting an empty slot");
        self.pool.release(&mut slot.lease);
        self.kv.set_len(b, 0);
        if let Some(dkv) = self.fe_dkv.as_mut() {
            dkv.set_len(b, 0);
        }
        if let Some(dkv) = self.eg_dkv.as_mut() {
            dkv.set_len(b, 0);
        }
        slot.req
    }

    /// Evict a slot whose drafter setup failed: release its lease and
    /// answer the request with an error instead of poisoning the engine.
    fn fail_slot(&mut self, b: usize, err: String, metrics: &mut ServingMetrics) -> Response {
        let req = self.evict_slot(b);
        metrics.requests_failed += 1;
        crate::obs::mark("failed", b as u32, req.id, 0);
        crate::log_warn!("request {} failed: {err}", req.id);
        Response::error(req.id, err)
    }

    /// Cancel one request wherever it lives — pending queue, an active
    /// slot (mid-prefill or mid-decode), or the parked set — releasing
    /// its KV lease and zeroing its lanes. Blocks shared with the
    /// prefix cache stay cached (release is refcounted); blocks owned
    /// solely by the request return to the pool immediately. Safe only
    /// between steps (the server's engine loop), never mid-iteration.
    pub fn cancel(&mut self, id: u64, metrics: &mut ServingMetrics) -> CancelOutcome {
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            self.pending.remove(i);
            metrics.requests_canceled += 1;
            crate::obs::mark("cancel", 0, id, 0);
            return CancelOutcome::Pending;
        }
        let active = (0..self.cfg.batch)
            .find(|&b| matches!(&self.slots[b], Some(s) if s.req.id == id));
        if let Some(b) = active {
            self.evict_slot(b);
            metrics.requests_canceled += 1;
            crate::obs::mark("cancel", b as u32, id, 0);
            return CancelOutcome::Active;
        }
        if let Some(i) = self.parked.iter().position(|p| p.req.id == id) {
            let mut p = self.parked.remove(i).expect("indexed parked entry");
            self.pool.release(&mut p.lease);
            metrics.requests_canceled += 1;
            crate::obs::mark("cancel", 0, id, 0);
            return CancelOutcome::Parked;
        }
        CancelOutcome::NotFound
    }

    /// Sweep every pending, active and parked request against its
    /// deadline, evicting the expired ones and answering each with a
    /// structured "deadline exceeded" error. Runs at the top of every
    /// step, so deadlines bind at admission *and* mid-generation.
    fn expire_deadlines(&mut self, metrics: &mut ServingMetrics) -> Vec<Response> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].expired() {
                let r = self.pending.remove(i).expect("indexed pending entry");
                metrics.requests_expired += 1;
                crate::obs::mark("expired", 0, r.id, 0);
                out.push(Response::error(r.id, "deadline exceeded"));
            } else {
                i += 1;
            }
        }
        for b in 0..self.cfg.batch {
            if matches!(&self.slots[b], Some(s) if s.req.expired()) {
                let req = self.evict_slot(b);
                metrics.requests_expired += 1;
                crate::obs::mark("expired", b as u32, req.id, 0);
                out.push(Response::error(req.id, "deadline exceeded"));
            }
        }
        let mut i = 0;
        while i < self.parked.len() {
            if self.parked[i].req.expired() {
                let mut p = self.parked.remove(i).expect("indexed parked entry");
                self.pool.release(&mut p.lease);
                metrics.requests_expired += 1;
                crate::obs::mark("expired", 0, p.req.id, 0);
                out.push(Response::error(p.req.id, "deadline exceeded"));
            } else {
                i += 1;
            }
        }
        out
    }

    /// One batched iteration executing a plan's `prefill` + `run`
    /// sections, then retiring finished slots (lease released, lane
    /// zeroed) so the next admission pass can reuse them.
    fn iteration(
        &mut self,
        plan: &SchedulePlan,
        metrics: &mut ServingMetrics,
    ) -> Result<(Vec<Response>, Vec<SlotEvent>)> {
        let bsz = self.cfg.batch;
        let (v, fd, s) = (self.spec.vocab, self.spec.feat_dim, self.spec.max_seq);
        let eos_tok = self.spec.eos;
        let mut finished = Vec::new();
        let mut events = Vec::new();
        let t_cycle = Instant::now();
        if plan.has_work() {
            // per-slot cycle plans first: each running slot's planner
            // sizes this cycle's draft (adaptive slots shrink/grow here)
            let mut plan_depths = vec![0usize; bsz];
            let mut rows_needed = 1usize;
            let mut run_methods: Vec<&'static str> = Vec::new();
            for &b in &plan.run {
                let slot = self.slots[b].as_mut().expect("run slot occupied");
                let method = slot.method;
                let req_id = slot.req.id;
                let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                let (depth, nodes) = {
                    let p = cycle.begin_cycle();
                    match method {
                        BatchMethod::Vanilla => (0, 0),
                        // chain plans: the budget caps the chain too
                        _ => (p.depth.min(p.node_budget), p.total_rows() - 1),
                    }
                };
                metrics.record_plan(depth, nodes, cycle.accept_window_mean());
                crate::obs::mark("plan", b as u32, req_id, depth as i64);
                if !run_methods.contains(&method.name()) {
                    run_methods.push(method.name());
                }
                plan_depths[b] = depth;
                rows_needed = rows_needed.max(1 + depth);
            }
            // verification rows this iteration: the smallest lowered
            // verify-M covering the largest planned row count and every
            // prefill chunk (mixed pools pad the unused rows)
            for &(_, n) in &plan.prefill {
                rows_needed = rows_needed.max(n);
            }
            // the startup contract check guarantees the chain lane exists,
            // so a miss here is a real inventory hole — fail loudly instead
            // of silently falling back to a lane that may not fit
            let m = self.spec.verify_m_lowered(rows_needed, self.cfg.batch).with_context(|| {
                format!(
                    "no lowered verify lane covers {rows_needed} rows at batch {} \
                     (B=1 lanes: {:?}, batched: {:?})",
                    self.cfg.batch, self.spec.verify_ms, self.spec.verify_ms_by_batch
                )
            })?;
            let t_draft = Instant::now();
            let drafts = self.draft_outputs(&plan.run, &plan_depths, metrics)?;
            if crate::obs::enabled() {
                let d_draft = t_draft.elapsed();
                for &b in &plan.run {
                    let slot = self.slots[b].as_ref().expect("run slot occupied");
                    crate::obs::span_from("draft", t_draft)
                        .dur(d_draft)
                        .tid(b as u32)
                        .req(slot.req.id)
                        .arg(plan_depths[b] as i64)
                        .emit();
                }
            }
            // assemble per-slot trees through the shared cycle core
            let mut trees: Vec<Option<DraftTree>> = (0..bsz).map(|_| None).collect();
            for &b in &plan.run {
                let slot = self.slots[b].as_mut().expect("run slot occupied");
                let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                let draft = drafts[b].clone().unwrap_or(DraftOutput::None);
                trees[b] = Some(cycle.build_tree(draft));
            }
            // batched call: tree rows for decoders, prompt-chunk rows for
            // prefilling slots
            let mut tokens = vec![self.spec.pad; bsz * m];
            let mut pos = vec![0i32; bsz * m];
            let mut ctx = vec![0i32; bsz];
            let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
            for b in 0..bsz {
                let Some(tree) = &trees[b] else { continue };
                let base = self.kv.len(b);
                ctx[b] = base as i32;
                let (toks, ps, rws) = verify_rows(tree, base, s);
                tokens[b * m..b * m + tree.len()].copy_from_slice(&toks);
                pos[b * m..b * m + tree.len()].copy_from_slice(&ps);
                rows[b] = rws;
            }
            for &(b, n) in &plan.prefill {
                let slot = self.slots[b].as_ref().expect("prefill slot occupied");
                let pf = slot.prefill.as_ref().expect("prefill slot is prefilling");
                let base = pf.pos;
                debug_assert_eq!(self.kv.len(b), base, "prefill pos tracks kv len");
                debug_assert!(n <= m, "chunk exceeds verify rows");
                ctx[b] = base as i32;
                for i in 0..n {
                    tokens[b * m + i] = pf.ptoks[base + i];
                    pos[b * m + i] = ((base + i) as i32).min(s as i32 - 1);
                }
                rows[b] = (0..n)
                    .map(|i| MaskRow { prefix_upto: base + i + 1, extra: vec![] })
                    .collect();
            }
            let mask = build_mask_b(bsz, m, s, &rows);
            // the verify-input tokens double as the cache's per-row keys
            let row_toks = if self.cache.enabled() { tokens.clone() } else { Vec::new() };
            let exec_name = format!("tgt_m{m}{}", self.exec_suffix());
            let t_verify = Instant::now();
            let exec = self.store.bind(&exec_name, "target")?;
            let tok_t = HostTensor::i32(vec![bsz, m], tokens);
            let pos_t = HostTensor::i32(vec![bsz, m], pos);
            let ctx_t = HostTensor::i32(vec![bsz], ctx);
            let outs = exec.call(
                &self.store.runtime,
                &[
                    ("tokens", &tok_t),
                    ("positions", &pos_t),
                    ("mask", &mask),
                    ("cache_len", &ctx_t),
                    ("kv", self.kv.tensor()),
                ],
            )?;
            let logits = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
            let feats = outs[exec.out_idx("feats")?].as_f32()?.to_vec();
            let ki = exec.out_idx("kv")?;
            let mut outs = outs;
            self.kv.update_from(outs.swap_remove(ki))?;
            let d_verify = t_verify.elapsed();
            // the verify call is shared by every method in the batch:
            // record its wall time once per method present this cycle
            for &name in &run_methods {
                metrics.record_phase(name, "verify", d_verify);
            }
            if crate::obs::enabled() {
                for &b in &plan.run {
                    let slot = self.slots[b].as_ref().expect("run slot occupied");
                    let tree_rows =
                        trees[b].as_ref().map(|t| t.len() as i64).unwrap_or(0);
                    crate::obs::span_from("verify", t_verify)
                        .dur(d_verify)
                        .tid(b as u32)
                        .req(slot.req.id)
                        .arg(tree_rows)
                        .label(&exec_name)
                        .emit();
                }
                for &(b, n) in &plan.prefill {
                    let slot = self.slots[b].as_ref().expect("prefill slot occupied");
                    crate::obs::span_from("prefill", t_verify)
                        .dur(d_verify)
                        .tid(b as u32)
                        .req(slot.req.id)
                        .arg(n as i64)
                        .label(&exec_name)
                        .emit();
                }
            }

            // per-slot acceptance + commit through the shared cycle core
            let mut observe_feats: Vec<Vec<f32>> = vec![vec![]; bsz];
            let mut observe_next: Vec<Vec<i32>> = vec![vec![]; bsz];
            let mut observe_first: Vec<usize> = vec![0; bsz];
            for b in 0..bsz {
                let Some(tree) = &trees[b] else { continue };
                let t_accept = Instant::now();
                let base = self.kv.len(b);
                let slot = self.slots[b].as_mut().unwrap();
                let method_name = slot.method.name();
                let req_id = slot.req.id;
                let cycle = slot.cycle.as_mut().expect("run slot is decoding");
                let acc = cycle.accept(
                    tree,
                    &logits[b * m * v..(b * m + tree.len()) * v],
                    v,
                );
                self.kv.compact(b, base, &acc.accepted_slots)?;
                let slot = self.slots[b].as_mut().unwrap();
                let cycle = slot.cycle.as_mut().unwrap();
                if cycle.metrics.cycles == 1 {
                    metrics.record_first_cycle(slot.req.arrival.elapsed());
                }
                let commit = cycle.commit(tree, &acc, eos_tok);
                let mut f = Vec::with_capacity(acc.accepted_slots.len() * fd);
                for &sl in &acc.accepted_slots {
                    f.extend_from_slice(&feats[(b * m + sl) * fd..(b * m + sl + 1) * fd]);
                }
                if !row_toks.is_empty() && slot.req.cache {
                    // accepted rows extend the publishable history; rows
                    // past an EOS/max_new truncation are harmless — a
                    // later radix match simply stops at the divergence
                    for &sl in &acc.accepted_slots {
                        slot.row_tokens.push(row_toks[b * m + sl]);
                    }
                    slot.row_feats.extend_from_slice(&f);
                }
                observe_feats[b] = f;
                observe_next[b] = commit.observe_next;
                observe_first[b] = base;
                events.push(SlotEvent {
                    id: slot.req.id,
                    cycle: cycle.metrics.cycles,
                    tokens: commit.committed,
                    accepted_len: acc.accepted_slots.len(),
                    finished: commit.finished,
                });
                metrics.record_phase(method_name, "accept", t_accept.elapsed());
                crate::obs::span_from("accept", t_accept)
                    .tid(b as u32)
                    .req(req_id)
                    .arg(acc.accepted_slots.len() as i64)
                    .emit();
            }

            // batched drafter observe over the newly committed anchors
            self.batched_observe(&observe_feats, &observe_next, &observe_first)?;
            if crate::obs::enabled() {
                // one cycle span per running slot wrapping plan ->
                // draft -> verify -> accept -> observe
                let d_cycle = t_cycle.elapsed();
                for &b in &plan.run {
                    let slot = self.slots[b].as_ref().expect("run slot occupied");
                    crate::obs::span_from("cycle", t_cycle)
                        .dur(d_cycle)
                        .tid(b as u32)
                        .req(slot.req.id)
                        .emit();
                }
            }

            // prefilling slots: fold the chunk in; on the last chunk,
            // seed the cycle core and observe the prompt. This runs
            // strictly AFTER the batched observe above: that call
            // writes rows into every lane of the method's state tensor
            // (non-members get pad rows at ctx 0), so a lane must not
            // receive its freshly observed prompt state until the
            // step's batched writes are done — otherwise rows 0..t of
            // the new prefix would be silently overwritten.
            for &(b, n) in &plan.prefill {
                metrics.prefill_chunks += 1;
                let (base, done) = {
                    let slot = self.slots[b].as_mut().unwrap();
                    let pf = slot.prefill.as_mut().unwrap();
                    let base = pf.pos;
                    pf.advance(n, &feats[(b * m) * fd..(b * m + n) * fd]);
                    (base, pf.done())
                };
                self.kv.set_len(b, base + n);
                if done {
                    let last = logits[(b * m + n - 1) * v..(b * m + n) * v].to_vec();
                    if let Err(e) = self.finalize_prefill(b, &last) {
                        finished.push(self.fail_slot(b, format!("{e:#}"), metrics));
                    }
                }
            }
        }

        // retire finished slots: release the KV lease immediately so the
        // next admission pass can hand the blocks to queued work
        let margin = self.max_rows() + 2;
        for b in 0..bsz {
            let done = match &self.slots[b] {
                Some(slot) => {
                    slot.finished()
                        || (slot.cycle.is_some() && self.kv.len(b) + margin > s)
                }
                None => false,
            };
            if done {
                let mut slot = self.slots[b].take().unwrap();
                if let Some(cycle) = slot.cycle.as_mut() {
                    cycle.finish();
                }
                if self.cache.enabled() && slot.req.cache {
                    // publish before release: new index nodes take their
                    // blocks by transfer from this lease
                    let t_pub = Instant::now();
                    let inserted = self.cache.publish(
                        &mut self.pool,
                        &mut slot.lease,
                        &slot.row_tokens,
                        &slot.row_feats,
                        &self.kv,
                        b,
                    );
                    crate::obs::span_from("cache_publish", t_pub)
                        .tid(b as u32)
                        .req(slot.req.id)
                        .arg(inserted as i64)
                        .emit();
                }
                self.pool.release(&mut slot.lease);
                self.kv.set_len(b, 0);
                match slot.method {
                    BatchMethod::FastEagle => {
                        if let Some(dkv) = self.fe_dkv.as_mut() {
                            dkv.set_len(b, 0);
                        }
                    }
                    BatchMethod::Eagle3 => {
                        if let Some(dkv) = self.eg_dkv.as_mut() {
                            dkv.set_len(b, 0);
                        }
                    }
                    BatchMethod::Vanilla => {}
                }
                for ev in events.iter_mut().filter(|e| e.id == slot.req.id) {
                    ev.finished = true;
                }
                let cycle = slot.cycle.expect("retired slot has a cycle");
                let cycles = cycle.metrics.cycles;
                crate::obs::mark("done", b as u32, slot.req.id, cycle.out.len() as i64);
                finished.push(Response {
                    id: slot.req.id,
                    text: self.tokenizer.decode(&cycle.out),
                    new_tokens: cycle.out.len(),
                    tau: cycle.metrics.tau(),
                    cycles,
                    latency_ms: slot.req.arrival.elapsed().as_secs_f64() * 1e3,
                    gen_ms: slot.gen_ms_accum
                        + slot.admitted_at.elapsed().as_secs_f64() * 1e3,
                    error: None,
                });
            }
        }
        Ok((finished, events))
    }

    /// Batched `observe` (FE cascade / EAGLE first-step) over each slot's
    /// newly committed anchors, one call per drafting method present in
    /// the pool, updating per-slot draft state.
    fn batched_observe(
        &mut self,
        feats: &[Vec<f32>],
        next: &[Vec<i32>],
        first_pos: &[usize],
    ) -> Result<()> {
        self.observe_method(BatchMethod::FastEagle, feats, next, first_pos)?;
        self.observe_method(BatchMethod::Eagle3, feats, next, first_pos)
    }

    fn observe_method(
        &mut self,
        method: BatchMethod,
        feats: &[Vec<f32>],
        next: &[Vec<i32>],
        first_pos: &[usize],
    ) -> Result<()> {
        if method == BatchMethod::Vanilla {
            return Ok(());
        }
        let bsz = self.cfg.batch;
        let fd = self.spec.feat_dim;
        let (v, d, c) = (self.spec.vocab, self.spec.d_model, self.spec.max_seq);
        let members: Vec<usize> = (0..bsz)
            .filter(|&b| {
                matches!(&self.slots[b], Some(slot) if slot.method == method)
                    && !next[b].is_empty()
            })
            .collect();
        let n_max = members.iter().map(|&b| next[b].len()).max().unwrap_or(0);
        if n_max == 0 {
            return Ok(());
        }
        let t = if n_max > 8 { 32 } else if n_max > 1 { 8 } else { 1 };
        let suffix = self.exec_suffix();
        let dkv = match method {
            BatchMethod::FastEagle => self.fe_dkv.as_mut().expect("fe slot admitted"),
            BatchMethod::Eagle3 => self.eg_dkv.as_mut().expect("eagle slot admitted"),
            BatchMethod::Vanilla => unreachable!(),
        };
        let mut feat_in = vec![0.0f32; bsz * t * fd];
        let mut toks = vec![self.spec.pad; bsz * t];
        let mut pos = vec![0i32; bsz * t];
        let mut ctx = vec![0i32; bsz];
        let mut rows: Vec<Vec<MaskRow>> = vec![vec![]; bsz];
        for &b in &members {
            let n = next[b].len();
            let base = dkv.len(b);
            ctx[b] = base as i32;
            feat_in[b * t * fd..(b * t + n) * fd].copy_from_slice(&feats[b]);
            toks[b * t..b * t + n].copy_from_slice(&next[b]);
            for i in 0..n {
                pos[b * t + i] = ((first_pos[b] + i) as i32).min(c as i32 - 1);
            }
            rows[b] = (0..n)
                .map(|i| MaskRow { prefix_upto: base + i + 1, extra: vec![] })
                .collect();
        }
        let mask = build_mask_b(bsz, t, c, &rows);
        let feat_t = HostTensor::f32(vec![bsz, t, fd], feat_in);
        let tok_t = HostTensor::i32(vec![bsz, t], toks);
        let pos_t = HostTensor::i32(vec![bsz, t], pos);
        let ctx_t = HostTensor::i32(vec![bsz], ctx);
        match method {
            BatchMethod::FastEagle => {
                let exec = self.store.bind(&format!("fe_t{t}{suffix}"), "fasteagle")?;
                let outs = exec.call(
                    &self.store.runtime,
                    &[
                        ("feats", &feat_t),
                        ("next_tokens", &tok_t),
                        ("anchor_pos", &pos_t),
                        ("mask", &mask),
                        ("ctx_len", &ctx_t),
                        ("dkv", dkv.tensor()),
                    ],
                )?;
                let n_lvl = self.spec.draft_depth;
                let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                let ki = exec.out_idx("dkv")?;
                let mut outs = outs;
                dkv.update_from(outs.swap_remove(ki))?;
                for &b in &members {
                    let n = next[b].len();
                    let row = b * t + (n - 1);
                    let slot = self.slots[b].as_mut().unwrap();
                    slot.fe_logits = l[row * n_lvl * v..(row + 1) * n_lvl * v].to_vec();
                    let base = dkv.len(b);
                    dkv.set_len(b, base + n);
                }
            }
            BatchMethod::Eagle3 => {
                let exec =
                    self.store.bind(&format!("eg3_first_t{t}{suffix}"), "eagle3")?;
                let outs = exec.call(
                    &self.store.runtime,
                    &[
                        ("feat_in", &feat_t),
                        ("tokens", &tok_t),
                        ("anchor_pos", &pos_t),
                        ("mask", &mask),
                        ("ctx_len", &ctx_t),
                        ("ekv", dkv.tensor()),
                    ],
                )?;
                let l = outs[exec.out_idx("logits")?].as_f32()?.to_vec();
                let h = outs[exec.out_idx("h")?].as_f32()?.to_vec();
                let ki = exec.out_idx("ekv")?;
                let mut outs = outs;
                dkv.update_from(outs.swap_remove(ki))?;
                for &b in &members {
                    let n = next[b].len();
                    let row = b * t + (n - 1);
                    let slot = self.slots[b].as_mut().unwrap();
                    slot.eg_q1 = l[row * v..(row + 1) * v].to_vec();
                    slot.eg_h = h[row * d..(row + 1) * d].to_vec();
                    let base = dkv.len(b);
                    dkv.set_len(b, base + n);
                }
            }
            BatchMethod::Vanilla => unreachable!(),
        }
        Ok(())
    }

    /// One scheduler step: ask the scheduler for a plan (admissions,
    /// prefill chunks, preemptions, resumes, runs) and execute it.
    /// Returns the responses that completed this step (possibly empty).
    /// Metrics — queue wait, deferrals, occupancy, time-to-first-cycle,
    /// preemptions/resumes, the parked-token gauge, completions — are
    /// recorded into `metrics`.
    pub fn step(&mut self, metrics: &mut ServingMetrics) -> Result<Vec<Response>> {
        Ok(self.step_events(metrics)?.finished)
    }

    /// Like [`step`](Self::step), but additionally reports every active
    /// slot's per-cycle [`SlotEvent`] — the engine-side source of the
    /// protocol's streaming `tokens` frames.
    pub fn step_events(&mut self, metrics: &mut ServingMetrics) -> Result<StepOutcome> {
        // deadline sweep first, so the scheduler never plans (or funds)
        // work for a request that has already missed its deadline
        let expired = self.expire_deadlines(metrics);
        let t_sched = Instant::now();
        let view = self.sched_view();
        let plan = self.scheduler.plan(&view);
        // attributed to the engine's default method: the scheduler runs
        // once per step for the whole batch, not per request
        metrics.record_phase(self.cfg.method.name(), "sched", t_sched.elapsed());
        metrics.requests_deferred += plan.new_deferrals;

        // execute the plan: evict -> preempt -> resume -> admit, then
        // iterate. Eviction runs first because the plan funded resumes
        // and admissions partly from reclaimable cache blocks; the
        // protect set mirrors the one behind the view's
        // `evictable_blocks`, so pending hits survive to adoption.
        if plan.evict_blocks > 0 {
            let t_evict = Instant::now();
            let protect = self.protect_set();
            let freed = self.cache.evict_lru(&mut self.pool, plan.evict_blocks, &protect);
            metrics.cache_evicted_blocks += freed as u64;
            crate::obs::span_from("cache_evict", t_evict).arg(freed as i64).emit();
        }
        for &b in &plan.preempt {
            self.park_slot(b, metrics)?;
        }
        {
            // resolve resume indices against the live deque: remove the
            // highest indices first so earlier ones stay valid
            let mut resumes: Vec<(usize, usize)> = plan.resume.clone();
            resumes.sort_by(|a, b| b.1.cmp(&a.1));
            for (slot, pidx) in resumes {
                self.resume_parked(slot, pidx, metrics)?;
            }
        }
        {
            let mut admits: Vec<(usize, usize)> = plan.admit.clone();
            admits.sort_by(|a, b| b.1.cmp(&a.1));
            for (slot, qidx) in admits {
                let req = self
                    .pending
                    .remove(qidx)
                    .expect("admitted request left the queue");
                // queue wait ends at the admission decision
                metrics.record_admitted(req.arrival.elapsed());
                // queue spans live on dedicated lanes: a request can wait
                // while its eventual slot still runs the previous occupant
                let queue_tid = crate::obs::QUEUE_TID_BASE
                    + (req.id % crate::obs::QUEUE_LANES) as u32;
                crate::obs::span_from("queue", req.arrival)
                    .tid(queue_tid)
                    .req(req.id)
                    .emit();
                crate::obs::mark("admit", slot as u32, req.id, 0);
                self.admit_request(slot, req, metrics)?;
            }
        }
        let parked_tokens: usize = self.parked.iter().map(|p| p.kv.len(0)).sum();
        metrics.record_parked(parked_tokens);
        if self.cache.enabled() {
            metrics.record_cache_gauges(self.cache.nodes(), self.cache.held_blocks());
        }
        if self.slots.iter().all(|s| s.is_none()) {
            return Ok(StepOutcome { finished: expired, events: Vec::new() });
        }
        metrics.record_occupancy(self.active_len());
        let (mut finished, events) = self.iteration(&plan, metrics)?;
        finished.splice(0..0, expired);
        for r in &finished {
            if r.error.is_none() {
                metrics.record_done(
                    r.new_tokens,
                    r.cycles,
                    r.tau,
                    Duration::from_secs_f64(r.latency_ms / 1e3),
                );
            }
        }
        Ok(StepOutcome { finished, events })
    }

    /// True when the last step made no progress and never can: it
    /// returned no responses, every slot is free, and the waiting work
    /// (pending or parked) still could not be placed — the planner runs
    /// before every iteration, so an empty engine with waiting work
    /// means nothing was fundable. Shared by [`run`](Self::run), the
    /// TCP server, and the trace drivers so the stall invariant lives
    /// in one place.
    pub fn stalled(&self, last_step: &[Response]) -> bool {
        last_step.is_empty()
            && self.active_len() == 0
            && (!self.pending.is_empty() || !self.parked.is_empty())
    }

    /// Drop every pending, parked and active request (releasing KV
    /// leases) and return their ids — the server's failure path when a
    /// step errors, so it can answer each in-flight connection instead
    /// of dying.
    pub fn abort_all(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        for b in 0..self.cfg.batch {
            if let Some(mut slot) = self.slots[b].take() {
                self.pool.release(&mut slot.lease);
                self.kv.set_len(b, 0);
                if let Some(dkv) = self.fe_dkv.as_mut() {
                    dkv.set_len(b, 0);
                }
                if let Some(dkv) = self.eg_dkv.as_mut() {
                    dkv.set_len(b, 0);
                }
                ids.push(slot.req.id);
            }
        }
        for mut p in self.parked.drain(..) {
            self.pool.release(&mut p.lease);
            ids.push(p.req.id);
        }
        for r in self.pending.drain(..) {
            ids.push(r.id);
        }
        self.scheduler.clear();
        ids
    }

    /// Run a closed workload to completion; returns responses + metrics.
    /// Thin wrapper over the serving loop: submit everything, then
    /// [`step`](Self::step) until drained — benches exercise the same
    /// scheduler as the live server. Unlike the server (which answers
    /// the failed connection and keeps serving), a closed workload
    /// treats any per-request failure as a hard error so benches can't
    /// silently record a broken configuration as ~0 throughput.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<(Vec<Response>, ServingMetrics)> {
        let mut metrics = ServingMetrics::default();
        for r in requests {
            self.submit(r);
        }
        let mut responses = Vec::new();
        while self.has_work() {
            let done = self.step(&mut metrics)?;
            if self.stalled(&done) {
                bail!("no slot admissible but queue non-empty (pool too small?)");
            }
            if let Some(err) = done.iter().find_map(|r| r.error.as_deref()) {
                bail!("request failed in closed workload: {err}");
            }
            responses.extend(done);
        }
        Ok((responses, metrics))
    }
}

impl Drop for BatchEngine {
    /// Shutdown accounting check: after every lease and cache-held
    /// share is returned, the pool must have zero outstanding blocks —
    /// debug builds assert it so silent lease leaks die in tests.
    fn drop(&mut self) {
        self.abort_all();
        self.cache.clear(&mut self.pool);
        if !std::thread::panicking() {
            debug_assert_eq!(
                self.pool.leaked_blocks(),
                0,
                "engine shutdown stranded pool blocks"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batched_mask_rows_and_padding() {
        let rows = vec![
            vec![MaskRow { prefix_upto: 2, extra: vec![3] }],
            vec![], // inactive slot: all pad rows
        ];
        let m = build_mask_b(2, 2, 4, &rows);
        let d = m.as_f32().unwrap();
        // slot 0 row 0: slots 0,1,3 visible
        assert_eq!(&d[0..4], &[0.0, 0.0, NEG, 0.0]);
        // slot 0 row 1 is padding: slot 0 only
        assert_eq!(&d[4..8], &[0.0, NEG, NEG, NEG]);
        // slot 1 rows: padding
        assert_eq!(&d[8..12], &[0.0, NEG, NEG, NEG]);
        assert_eq!(&d[12..16], &[0.0, NEG, NEG, NEG]);
    }

    #[test]
    fn method_kv_accounting() {
        let spec = crate::model::ModelSpec::parse(
            crate::model::spec::tests_sample::SAMPLE).unwrap();
        assert_eq!(BatchMethod::Vanilla.drafter_kv_layers(&spec), 0);
        assert_eq!(BatchMethod::Eagle3.drafter_kv_layers(&spec), 1);
        assert_eq!(BatchMethod::FastEagle.drafter_kv_layers(&spec), spec.draft_depth);
    }

    #[test]
    fn method_names_roundtrip() {
        for m in [BatchMethod::Vanilla, BatchMethod::FastEagle, BatchMethod::Eagle3] {
            assert_eq!(BatchMethod::from_name(m.name()), Some(m));
        }
        assert_eq!(BatchMethod::from_name("medusa"), None);
    }
}
