//! Bounded admission queue between the I/O threads (TCP connections,
//! workload drivers) and the single engine thread. Back-pressure by
//! blocking or rejecting at capacity.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a non-blocking enqueue was refused. `Full` is the HTTP-429
/// analogue (shed and tell the client to retry); `Closed` means the
/// server is shutting down — callers must branch on the two (the TCP
/// server replies "queue full" vs "server shutting down").
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    Full(T),
    Closed(T),
}

impl<T> PushError<T> {
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(x) | PushError::Closed(x) => x,
        }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, PushError::Closed(_))
    }
}

pub struct AdmissionQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    peak_depth: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(capacity: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
                peak_depth: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking enqueue; the error variant tells the caller whether
    /// to shed (`Full`) or wind the connection down (`Closed`).
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        let d = g.items.len();
        g.peak_depth = g.peak_depth.max(d);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking enqueue with back-pressure.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while !g.closed && g.items.len() >= self.capacity {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.items.push_back(item);
        let d = g.items.len();
        g.peak_depth = g.peak_depth.max(d);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking dequeue; None once closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Dequeue with timeout; None on timeout or closed+drained.
    pub fn pop_timeout(&self, d: Duration) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(x) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(x);
            }
            if g.closed {
                return None;
            }
            let (ng, res) = self.not_empty.wait_timeout(g, d).unwrap();
            g = ng;
            if res.timed_out() {
                return g.items.pop_front();
            }
        }
    }

    /// Drain up to `n` items without blocking (continuous-batching
    /// admission).
    pub fn drain_up_to(&self, n: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.items.len());
        let out: Vec<T> = g.items.drain(..take).collect();
        drop(g);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Remove and return the first queued item matching `pred` (request
    /// cancellation before admission). Leaves the rest in order.
    pub fn remove_first<F: FnMut(&T) -> bool>(&self, mut pred: F) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let idx = g.items.iter().position(&mut pred)?;
        let out = g.items.remove(idx);
        drop(g);
        self.not_full.notify_one();
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn peak_depth(&self) -> usize {
        self.inner.lock().unwrap().peak_depth
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(10);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_rejects_at_capacity() {
        let q = AdmissionQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn try_push_distinguishes_closed_from_full() {
        let q = AdmissionQueue::new(1);
        q.close();
        let err = q.try_push(9).unwrap_err();
        assert!(err.is_closed());
        assert_eq!(err.into_inner(), 9);
        // a full-but-open queue sheds instead
        let q = AdmissionQueue::new(1);
        q.try_push(1).unwrap();
        let err = q.try_push(2).unwrap_err();
        assert!(!err.is_closed());
    }

    #[test]
    fn close_unblocks_pop() {
        let q = Arc::new(AdmissionQueue::<i32>::new(2));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn cross_thread_producer_consumer() {
        let q = Arc::new(AdmissionQueue::new(4));
        let q2 = Arc::clone(&q);
        let prod = std::thread::spawn(move || {
            for i in 0..100 {
                q2.push(i).unwrap();
            }
            q2.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        prod.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn remove_first_plucks_matching_item() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.remove_first(|&x| x == 3), Some(3));
        assert_eq!(q.remove_first(|&x| x == 3), None);
        assert_eq!(q.drain_up_to(10), vec![0, 1, 2, 4]);
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        let got = q.drain_up_to(3);
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }
}
