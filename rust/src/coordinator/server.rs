//! TCP JSON-lines API server: thread-per-connection I/O feeding a single
//! engine thread through the admission queue (the PJRT state is
//! deliberately single-threaded; on this 1-core testbed the engine is
//! the bottleneck anyway, exactly like a GPU worker in vLLM's
//! single-scheduler design).
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new": 64, "temperature": 0.0, "seed": 1}
//!   <- {"id": .., "text": "...", "tau": .., "new_tokens": .., ...}
//!   -> {"cmd": "stats"}   <- serving metrics
//!   -> {"cmd": "shutdown"}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::spec::Engine;
use crate::util::json::Json;

use super::metrics::ServingMetrics;
use super::queue::AdmissionQueue;
use super::request::{Request, Response};

type ReplyTx = std::sync::mpsc::Sender<Response>;

pub struct ServerConfig {
    pub addr: String,
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7399".into(), queue_capacity: 64 }
    }
}

pub struct Server {
    cfg: ServerConfig,
    queue: Arc<AdmissionQueue<(Request, ReplyTx)>>,
    metrics: Arc<Mutex<ServingMetrics>>,
    shutdown: Arc<AtomicBool>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(cfg: ServerConfig) -> Server {
        Server {
            queue: Arc::new(AdmissionQueue::new(cfg.queue_capacity)),
            metrics: Arc::new(Mutex::new(ServingMetrics::default())),
            shutdown: Arc::new(AtomicBool::new(false)),
            next_id: AtomicU64::new(1),
            cfg,
        }
    }

    /// Serve until a shutdown command arrives. `engine` runs on the
    /// calling thread; accept/connection threads are spawned internally.
    pub fn serve(&self, mut engine: Engine) -> Result<ServingMetrics> {
        let listener =
            TcpListener::bind(&self.cfg.addr).with_context(|| self.cfg.addr.clone())?;
        listener.set_nonblocking(true)?;
        crate::log_info!(
            "serving {} (drafter={}) on {}",
            engine.target.spec.name,
            engine.drafter.name(),
            self.cfg.addr
        );
        // accept loop on a helper thread
        let q = Arc::clone(&self.queue);
        let sd = Arc::clone(&self.shutdown);
        let metrics = Arc::clone(&self.metrics);
        let next = Arc::new(AtomicU64::new(1));
        let accept_handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !sd.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let q = Arc::clone(&q);
                        let sd = Arc::clone(&sd);
                        let metrics = Arc::clone(&metrics);
                        let next = Arc::clone(&next);
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, q, sd, metrics, next);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        // engine loop (this thread)
        while !self.shutdown.load(Ordering::Relaxed) {
            let Some((req, tx)) =
                self.queue.pop_timeout(std::time::Duration::from_millis(50))
            else {
                continue;
            };
            let wait = req.arrival.elapsed();
            let t0 = Instant::now();
            let resp = match engine.generate(&req.prompt, &req.cfg) {
                Ok(r) => Response {
                    id: req.id,
                    text: r.text,
                    new_tokens: r.metrics.new_tokens,
                    tau: r.metrics.tau(),
                    cycles: r.metrics.cycles,
                    latency_ms: req.arrival.elapsed().as_secs_f64() * 1e3,
                    gen_ms: t0.elapsed().as_secs_f64() * 1e3,
                    error: None,
                },
                Err(e) => Response {
                    id: req.id,
                    text: String::new(),
                    new_tokens: 0,
                    tau: 0.0,
                    cycles: 0,
                    latency_ms: req.arrival.elapsed().as_secs_f64() * 1e3,
                    gen_ms: 0.0,
                    error: Some(format!("{e:#}")),
                },
            };
            {
                let mut m = self.metrics.lock().unwrap();
                m.record_done(
                    resp.new_tokens,
                    resp.cycles,
                    resp.tau,
                    std::time::Duration::from_secs_f64(resp.latency_ms / 1e3),
                    wait,
                );
            }
            let _ = tx.send(resp);
        }
        self.queue.close();
        let _ = accept_handle.join();
        let m = self.metrics.lock().unwrap().clone();
        Ok(m)
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<AdmissionQueue<(Request, ReplyTx)>>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServingMetrics>>,
    next_id: Arc<AtomicU64>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match Json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![("error", Json::str(&format!("{e}")))]).to_string())?;
                continue;
            }
        };
        match v.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            Some("stats") => {
                let m = metrics.lock().unwrap();
                let j = Json::obj(vec![
                    ("requests_done", Json::num(m.requests_done as f64)),
                    ("tokens_out", Json::num(m.tokens_out as f64)),
                    ("tok_per_sec", Json::num(m.tokens_per_sec())),
                    ("mean_tau", Json::num(m.mean_tau())),
                    ("p50_ms", Json::num(m.latency.percentile_us(0.5) / 1e3)),
                    ("p99_ms", Json::num(m.latency.percentile_us(0.99) / 1e3)),
                ]);
                writeln!(writer, "{}", j.to_string())?;
                continue;
            }
            _ => {}
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        match Request::from_json(id, &v) {
            Some(req) => {
                let (tx, rx) = std::sync::mpsc::channel();
                if queue.try_push((req, tx)).is_err() {
                    let mut m = metrics.lock().unwrap();
                    m.requests_rejected += 1;
                    drop(m);
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![("error", Json::str("queue full"))]).to_string()
                    )?;
                    continue;
                }
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", resp.to_json().to_string())?,
                    Err(_) => {
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![("error", Json::str("server shutting down"))])
                                .to_string()
                        )?;
                        return Ok(());
                    }
                }
            }
            None => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str("missing prompt"))]).to_string()
                )?;
            }
        }
    }
}
