//! TCP JSON-lines API server: thread-per-connection I/O feeding the
//! continuous-batching engine on a single engine thread (the PJRT state
//! is deliberately single-threaded; on this 1-core testbed the engine is
//! the bottleneck anyway, exactly like a GPU worker in vLLM's
//! single-scheduler design).
//!
//! The engine thread drains the bounded [`AdmissionQueue`] into
//! [`BatchEngine::step_events`], so up to `batch` requests decode
//! concurrently and each connection is answered the moment its slot
//! completes — requests finish out of admission order when their
//! lengths differ. A request with `"stream": true` additionally
//! receives one `{"event":"tokens",...}` frame per decode cycle before
//! its final response — the per-cycle [`SlotEvent`]s the engine already
//! produces, forwarded over the same connection.
//! Back-pressure is two-staged: the engine keeps at most `batch`
//! requests internally; everything beyond that waits in the bounded
//! queue, and past its capacity `try_push` sheds with a "queue full"
//! reply (HTTP-429 analogue) distinct from the shutdown path.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new": 64, "temperature": 0.0, "seed": 1,
//!       "method": "fasteagle", "stream": false}
//!   <- {"event": "tokens", "id": .., "cycle": .., "tokens": [..],
//!       "text": "..", "accepted": ..}    (per cycle, stream mode only)
//!   <- {"id": .., "text": "...", "tau": .., "new_tokens": .., ...}
//!   -> {"cmd": "stats"}   <- serving metrics
//!   -> {"cmd": "shutdown"}

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::batcher::{BatchEngine, SlotEvent};
use super::metrics::ServingMetrics;
use super::queue::{AdmissionQueue, PushError};
use super::request::{Request, Response};

/// What the engine thread sends back per request: zero or more
/// streaming frames, then exactly one final response.
enum Reply {
    Frame(Json),
    Done(Response),
}

type ReplyTx = std::sync::mpsc::Sender<Reply>;

fn frame_json(ev: &SlotEvent, text: &str) -> Json {
    Json::obj(vec![
        ("event", Json::str("tokens")),
        ("id", Json::num(ev.id as f64)),
        ("cycle", Json::num(ev.cycle as f64)),
        ("tokens", Json::Arr(ev.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
        ("text", Json::str(text)),
        ("accepted", Json::num(ev.accepted_len as f64)),
    ])
}

pub struct ServerConfig {
    pub addr: String,
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7399".into(), queue_capacity: 64 }
    }
}

pub struct Server {
    cfg: ServerConfig,
    queue: Arc<AdmissionQueue<(Request, ReplyTx)>>,
    metrics: Arc<Mutex<ServingMetrics>>,
    shutdown: Arc<AtomicBool>,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(cfg: ServerConfig) -> Server {
        Server {
            queue: Arc::new(AdmissionQueue::new(cfg.queue_capacity)),
            metrics: Arc::new(Mutex::new(ServingMetrics::default())),
            shutdown: Arc::new(AtomicBool::new(false)),
            next_id: AtomicU64::new(1),
            cfg,
        }
    }

    /// Serve until a shutdown command arrives. The continuous-batching
    /// `engine` runs on the calling thread; accept/connection threads
    /// are spawned internally.
    pub fn serve(&self, mut engine: BatchEngine) -> Result<ServingMetrics> {
        let listener =
            TcpListener::bind(&self.cfg.addr).with_context(|| self.cfg.addr.clone())?;
        listener.set_nonblocking(true)?;
        crate::log_info!(
            "serving {} (default method={}, batch={}) on {}",
            engine.spec.name,
            engine.method().name(),
            engine.batch(),
            self.cfg.addr
        );
        // accept loop on a helper thread
        let q = Arc::clone(&self.queue);
        let sd = Arc::clone(&self.shutdown);
        let metrics = Arc::clone(&self.metrics);
        let next = Arc::new(AtomicU64::new(1));
        let accept_handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !sd.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let q = Arc::clone(&q);
                        let sd = Arc::clone(&sd);
                        let metrics = Arc::clone(&metrics);
                        let next = Arc::clone(&next);
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, q, sd, metrics, next);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });

        // engine loop (this thread): drain the admission queue into the
        // batcher, step it, reply per-slot as requests complete — and
        // forward per-cycle token frames to streaming requests
        let mut inflight: HashMap<u64, ReplyTx> = HashMap::new();
        let mut streaming: HashSet<u64> = HashSet::new();
        while !self.shutdown.load(Ordering::Relaxed) {
            // admit up to the engine's slot count; the rest stays in the
            // bounded queue so capacity shedding keeps working
            let mut drained = self.queue.drain_up_to(engine.admission_room());
            if drained.is_empty() && !engine.has_work() {
                // idle: block briefly for the next request
                match self.queue.pop_timeout(Duration::from_millis(50)) {
                    Some(item) => drained.push(item),
                    None => continue,
                }
            }
            for (req, tx) in drained {
                if req.stream {
                    streaming.insert(req.id);
                }
                inflight.insert(req.id, tx);
                engine.submit(req);
            }
            if !engine.has_work() {
                continue;
            }
            // record into a local delta so conn threads (stats, shed
            // counting) never wait a whole decode iteration for the lock
            let mut delta = ServingMetrics::default();
            let step = engine.step_events(&mut delta);
            self.metrics.lock().unwrap().merge(&delta);
            match step {
                Ok(outcome) => {
                    // per-cycle frames first, so every frame of a request
                    // precedes its final response on the wire; decode
                    // only for streaming requests so everyone else pays
                    // nothing per cycle
                    for ev in &outcome.events {
                        if ev.tokens.is_empty() || !streaming.contains(&ev.id) {
                            continue;
                        }
                        if let Some(tx) = inflight.get(&ev.id) {
                            let text = engine.decode(&ev.tokens);
                            let _ = tx.send(Reply::Frame(frame_json(ev, &text)));
                        }
                    }
                    let done = outcome.finished;
                    let stalled = engine.stalled(&done);
                    for resp in done {
                        streaming.remove(&resp.id);
                        if let Some(tx) = inflight.remove(&resp.id) {
                            let _ = tx.send(Reply::Done(resp));
                        }
                    }
                    // a stalled engine means the head request can never
                    // admit (the whole pool is free and still too small)
                    // — fail the queued requests rather than spin forever
                    if stalled {
                        let ids = engine.abort_all();
                        self.metrics.lock().unwrap().requests_failed += ids.len() as u64;
                        for id in ids {
                            streaming.remove(&id);
                            if let Some(tx) = inflight.remove(&id) {
                                let _ = tx.send(Reply::Done(Response::error(
                                    id,
                                    "request exceeds KV pool capacity",
                                )));
                            }
                        }
                    }
                }
                Err(e) => {
                    crate::log_warn!("engine step failed: {e:#}");
                    let ids = engine.abort_all();
                    self.metrics.lock().unwrap().requests_failed += ids.len() as u64;
                    for id in ids {
                        streaming.remove(&id);
                        if let Some(tx) = inflight.remove(&id) {
                            let _ = tx.send(Reply::Done(Response::error(id, format!("{e:#}"))));
                        }
                    }
                }
            }
        }
        self.queue.close();
        // Drop every reply channel (queued and in-flight) *before*
        // joining the connection threads: each blocked `rx.recv()` then
        // errors and its connection answers "server shutting down" —
        // otherwise join would wait on connections that wait on us.
        drop(self.queue.drain_up_to(usize::MAX));
        drop(inflight);
        let _ = accept_handle.join();
        let m = self.metrics.lock().unwrap().clone();
        Ok(m)
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<AdmissionQueue<(Request, ReplyTx)>>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServingMetrics>>,
    next_id: Arc<AtomicU64>,
) -> Result<()> {
    // a read timeout lets idle keep-alive connections notice shutdown:
    // without it, a client that simply stays connected would block this
    // thread in read_line forever and serve() could never join it
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // accumulate raw bytes across timeout retries: a slow sender's
        // partial line survives even when the split lands inside a
        // multibyte character (read_line would drop such bytes)
        loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match Json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![("error", Json::str(&format!("{e}")))]).to_string())?;
                continue;
            }
        };
        match v.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            Some("stats") => {
                let m = metrics.lock().unwrap();
                let j = Json::obj(vec![
                    ("requests_done", Json::num(m.requests_done as f64)),
                    ("requests_rejected", Json::num(m.requests_rejected as f64)),
                    ("requests_deferred", Json::num(m.requests_deferred as f64)),
                    ("requests_failed", Json::num(m.requests_failed as f64)),
                    ("tokens_out", Json::num(m.tokens_out as f64)),
                    ("tok_per_sec", Json::num(m.tokens_per_sec())),
                    ("mean_tau", Json::num(m.mean_tau())),
                    ("mean_occupancy", Json::num(m.mean_occupancy())),
                    ("peak_occupancy", Json::num(m.occupancy_peak as f64)),
                    ("p50_ms", Json::num(m.latency.percentile_us(0.5) / 1e3)),
                    ("p99_ms", Json::num(m.latency.percentile_us(0.99) / 1e3)),
                    ("wait_p50_ms", Json::num(m.queue_wait.percentile_us(0.5) / 1e3)),
                    ("ttfc_p50_ms", Json::num(m.ttfc.percentile_us(0.5) / 1e3)),
                ]);
                writeln!(writer, "{}", j.to_string())?;
                continue;
            }
            _ => {}
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        match Request::from_json(id, &v) {
            Some(req) => {
                let (tx, rx) = std::sync::mpsc::channel();
                match queue.try_push((req, tx)) {
                    Ok(()) => {}
                    Err(PushError::Full(_)) => {
                        // shed: the bounded queue is the 429 analogue
                        let mut m = metrics.lock().unwrap();
                        m.requests_rejected += 1;
                        drop(m);
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![("error", Json::str("queue full"))]).to_string()
                        )?;
                        continue;
                    }
                    Err(PushError::Closed(_)) => {
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![("error", Json::str("server shutting down"))])
                                .to_string()
                        )?;
                        return Ok(());
                    }
                }
                // zero or more streaming frames, then the final response
                loop {
                    match rx.recv() {
                        Ok(Reply::Frame(j)) => writeln!(writer, "{}", j.to_string())?,
                        Ok(Reply::Done(resp)) => {
                            writeln!(writer, "{}", resp.to_json().to_string())?;
                            break;
                        }
                        Err(_) => {
                            writeln!(
                                writer,
                                "{}",
                                Json::obj(vec![("error", Json::str("server shutting down"))])
                                    .to_string()
                            )?;
                            return Ok(());
                        }
                    }
                }
            }
            None => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str("missing prompt"))]).to_string()
                )?;
            }
        }
    }
}
