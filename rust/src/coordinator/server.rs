//! TCP JSON-lines API server: thread-per-connection I/O feeding the
//! continuous-batching engine on a single engine thread (the PJRT state
//! is deliberately single-threaded; on this 1-core testbed the engine is
//! the bottleneck anyway, exactly like a GPU worker in vLLM's
//! single-scheduler design).
//!
//! The engine thread drains the bounded [`AdmissionQueue`] into
//! [`BatchEngine::step_events`], so up to `batch` requests decode
//! concurrently and each connection is answered the moment its slot
//! completes — requests finish out of admission order when their
//! lengths differ. A request with `"stream": true` additionally
//! receives one `{"event":"tokens",...}` frame per decode cycle before
//! its final response — the per-cycle [`SlotEvent`]s the engine already
//! produces, forwarded over the same connection.
//!
//! Back-pressure is three-staged: the engine keeps at most `batch`
//! requests internally; everything beyond that waits in the bounded
//! queue, and past its capacity `try_push` sheds with a "queue full"
//! reply (HTTP-429 analogue) distinct from the shutdown path. Per
//! connection, at most `frame_queue` streaming frames may sit
//! undelivered at once — when a slow consumer falls behind, the
//! [`FrameGate`] coalesces its subsequent cycles into one merged frame
//! instead of queueing without bound, so one stalled client costs O(its
//! own output), never O(frames × cycles). Coalescing only merges
//! frames; every committed token is still delivered exactly once.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "...", "max_new": 64, "temperature": 0.0, "seed": 1,
//!       "method": "fasteagle", "stream": false, "priority": 0,
//!       "draft": {"planner": "static"|"adaptive", "depth": N,
//!                 "top_k": N, "budget": N}}
//!      (malformed fields are answered with {"error": ..., "field": ...})
//!   <- {"event": "tokens", "id": .., "cycle": .., "tokens": [..],
//!       "text": "..", "accepted": ..}    (per cycle, stream mode only)
//!   <- {"id": .., "text": "...", "tau": .., "new_tokens": .., ...}
//!   -> {"cmd": "stats"}   <- serving metrics (incl. per-phase timing)
//!   -> {"cmd": "trace"}   <- flight-recorder dump, Chrome trace-event
//!                            JSON on one line (empty when tracing off)
//!   -> {"cmd": "metrics"} <- Prometheus text exposition over multiple
//!                            lines, terminated by a "# EOF" line
//!   -> {"cmd": "shutdown"}

// The server must not panic on a poisoned lock or stray unwrap: every
// fallible path should shed or reply with an error instead (CI promotes
// these to hard errors via `-D warnings`).
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::batcher::{BatchEngine, SlotEvent};
use super::metrics::ServingMetrics;
use super::queue::{AdmissionQueue, PushError};
use super::request::{Request, Response};

/// Lifecycle verbs a connection thread asks the engine thread to run
/// (conn threads never touch the engine directly). The reply channel
/// carries the structured JSON answer back to the requesting
/// connection.
enum Control {
    Cancel { id: u64, reply: std::sync::mpsc::Sender<Json> },
}

/// What the engine thread sends back per request: zero or more
/// streaming frames, then exactly one final response.
enum Reply {
    Frame(Json),
    Done(Response),
}

/// The engine thread's handle to one connection: the reply channel plus
/// the number of streaming frames queued but not yet written to the
/// socket (incremented on send, decremented by the connection thread
/// after each write) — the signal the [`FrameGate`] throttles on.
struct ConnReply {
    tx: std::sync::mpsc::Sender<Reply>,
    queued_frames: Arc<AtomicUsize>,
}

/// Per-request streaming flow control: when a connection already has
/// `cap` undelivered frames, further cycles are *coalesced* into one
/// pending frame per request (tokens concatenated, accepted counts
/// summed, cycle index advanced to the newest) instead of queued. The
/// merged frame goes out as soon as the consumer drains below the cap
/// — or at request completion via [`flush`](FrameGate::flush) — so the
/// stream always delivers every committed token exactly once, in
/// order, with bounded memory per connection.
struct FrameGate {
    cap: usize,
    backlog: HashMap<u64, SlotEvent>,
}

impl FrameGate {
    fn new(cap: usize) -> FrameGate {
        FrameGate { cap, backlog: HashMap::new() }
    }

    fn fold(&mut self, ev: &SlotEvent) {
        let entry = self.backlog.entry(ev.id).or_insert_with(|| SlotEvent {
            id: ev.id,
            cycle: ev.cycle,
            tokens: Vec::new(),
            accepted_len: 0,
            finished: false,
        });
        entry.tokens.extend_from_slice(&ev.tokens);
        entry.cycle = ev.cycle;
        entry.accepted_len += ev.accepted_len;
        entry.finished |= ev.finished;
    }

    /// Offer one cycle event given the connection's current queue
    /// depth. Returns the (possibly merged) frame to send now, or
    /// `None` when the consumer is at capacity and the event was
    /// coalesced into its backlog.
    fn offer(&mut self, ev: &SlotEvent, queued: usize) -> Option<SlotEvent> {
        self.fold(ev);
        if queued < self.cap {
            self.backlog.remove(&ev.id)
        } else {
            None
        }
    }

    /// Drain the request's remaining backlog (request completion): the
    /// final merged frame is always delivered so the concatenated
    /// frames cover every committed token.
    fn flush(&mut self, id: u64) -> Option<SlotEvent> {
        self.backlog.remove(&id)
    }

    /// Drop any backlog (error/abort paths).
    fn forget(&mut self, id: u64) {
        self.backlog.remove(&id);
    }
}

fn frame_json(ev: &SlotEvent, text: &str) -> Json {
    Json::obj(vec![
        ("event", Json::str("tokens")),
        ("id", Json::num(ev.id as f64)),
        ("cycle", Json::num(ev.cycle as f64)),
        ("tokens", Json::Arr(ev.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
        ("text", Json::str(text)),
        ("accepted", Json::num(ev.accepted_len as f64)),
    ])
}

pub struct ServerConfig {
    pub addr: String,
    pub queue_capacity: usize,
    /// max undelivered streaming frames per connection before cycles
    /// coalesce (0 = coalesce everything into one frame at completion)
    pub frame_queue: usize,
    /// fleet identity reported by `stats` — how a router (and an
    /// operator) tells replicas apart; 0 for a standalone server
    pub replica_id: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7399".into(),
            queue_capacity: 64,
            frame_queue: 16,
            replica_id: 0,
        }
    }
}

/// Everything a connection thread needs, bundled so accept can hand
/// one `Arc` to each spawned thread.
struct ConnShared {
    queue: Arc<AdmissionQueue<(Request, ConnReply)>>,
    shutdown: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServingMetrics>>,
    next_id: Arc<AtomicU64>,
    control: Arc<Mutex<VecDeque<Control>>>,
    /// occupied engine slots, refreshed by the engine loop each step
    active_slots: Arc<AtomicUsize>,
    /// engine-internal pending + parked, refreshed alongside
    engine_backlog: Arc<AtomicUsize>,
    replica_id: usize,
    started: Instant,
}

pub struct Server {
    cfg: ServerConfig,
    queue: Arc<AdmissionQueue<(Request, ConnReply)>>,
    metrics: Arc<Mutex<ServingMetrics>>,
    shutdown: Arc<AtomicBool>,
    /// drain mode: admission refused with a structured error, in-flight
    /// work finishes, then `serve` returns cleanly (rolling restarts)
    draining: Arc<AtomicBool>,
    control: Arc<Mutex<VecDeque<Control>>>,
    active_slots: Arc<AtomicUsize>,
    engine_backlog: Arc<AtomicUsize>,
    started: Instant,
    next_id: AtomicU64,
}

impl Server {
    pub fn new(cfg: ServerConfig) -> Server {
        Server {
            queue: Arc::new(AdmissionQueue::new(cfg.queue_capacity)),
            metrics: Arc::new(Mutex::new(ServingMetrics::default())),
            shutdown: Arc::new(AtomicBool::new(false)),
            draining: Arc::new(AtomicBool::new(false)),
            control: Arc::new(Mutex::new(VecDeque::new())),
            active_slots: Arc::new(AtomicUsize::new(0)),
            engine_backlog: Arc::new(AtomicUsize::new(0)),
            started: Instant::now(),
            next_id: AtomicU64::new(1),
            cfg,
        }
    }

    /// Serve until a shutdown command arrives (or a drain completes).
    /// The continuous-batching `engine` runs on the calling thread;
    /// accept/connection threads are spawned internally. A bind failure
    /// is an ordinary error (the caller exits non-zero with the
    /// message), never a panic.
    pub fn serve(&self, engine: BatchEngine) -> Result<ServingMetrics> {
        let listener = TcpListener::bind(&self.cfg.addr)
            .with_context(|| format!("bind {}", self.cfg.addr))?;
        self.serve_on(listener, engine)
    }

    /// Like [`serve`](Self::serve) but over a pre-bound listener — how
    /// the router's `--spawn` mode runs replicas on OS-assigned ports
    /// it already knows the address of.
    pub fn serve_on(&self, listener: TcpListener, mut engine: BatchEngine) -> Result<ServingMetrics> {
        listener.set_nonblocking(true)?;
        crate::log_info!(
            "serving {} (default method={}, batch={}, policy={}, replica={}) on {}",
            engine.spec.name,
            engine.method().name(),
            engine.batch(),
            engine.policy_name(),
            self.cfg.replica_id,
            self.cfg.addr
        );
        // accept loop on a helper thread
        let sd = Arc::clone(&self.shutdown);
        let shared = Arc::new(ConnShared {
            queue: Arc::clone(&self.queue),
            shutdown: Arc::clone(&self.shutdown),
            draining: Arc::clone(&self.draining),
            metrics: Arc::clone(&self.metrics),
            next_id: Arc::new(AtomicU64::new(1)),
            control: Arc::clone(&self.control),
            active_slots: Arc::clone(&self.active_slots),
            engine_backlog: Arc::clone(&self.engine_backlog),
            replica_id: self.cfg.replica_id,
            started: self.started,
        });
        let accept_handle = std::thread::spawn(move || {
            let mut conns = Vec::new();
            while !sd.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let shared = Arc::clone(&shared);
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, shared);
                        }));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            // close the listener *before* joining connection threads: a
            // drain/shutdown must not race a late accept() — once the
            // loop exits, no new connection can sneak in while we wait
            // for the existing ones to wind down
            drop(listener);
            for c in conns {
                let _ = c.join();
            }
        });

        // engine loop (this thread): drain the admission queue into the
        // batcher, step it, reply per-slot as requests complete — and
        // forward per-cycle token frames to streaming requests, gated by
        // each connection's undelivered-frame count
        let mut inflight: HashMap<u64, ConnReply> = HashMap::new();
        let mut streaming: HashSet<u64> = HashSet::new();
        let mut gate = FrameGate::new(self.cfg.frame_queue);
        while !self.shutdown.load(Ordering::Relaxed) {
            // lifecycle verbs first: a cancel acts before this step's
            // scheduling and is answered even while the engine idles
            let ctl: Vec<Control> = {
                let mut q = self
                    .control
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                q.drain(..).collect()
            };
            for c in ctl {
                let Control::Cancel { id, reply } = c;
                let mut delta = ServingMetrics::default();
                let outcome = engine.cancel(id, &mut delta);
                self.metrics
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .merge(&delta);
                let was = if outcome.found() {
                    streaming.remove(&id);
                    gate.forget(id);
                    if let Some(conn) = inflight.remove(&id) {
                        let _ = conn.tx.send(Reply::Done(Response::error(id, "canceled")));
                    }
                    Some(outcome.name())
                } else if let Some((req, conn)) =
                    self.queue.remove_first(|(r, _)| r.id == id)
                {
                    // still in the admission queue: never reached the
                    // engine, so account for it here
                    self.metrics
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .requests_canceled += 1;
                    let _ = conn.tx.send(Reply::Done(Response::error(req.id, "canceled")));
                    Some("queued")
                } else {
                    None
                };
                let _ = reply.send(Json::obj(vec![
                    ("ok", Json::Bool(was.is_some())),
                    ("req", Json::num(id as f64)),
                    ("was", Json::str(was.unwrap_or("not_found"))),
                ]));
            }
            // fleet gauges for the stats reply, refreshed once per step
            self.active_slots.store(engine.active_len(), Ordering::Relaxed);
            self.engine_backlog
                .store(engine.pending_len() + engine.parked_len(), Ordering::Relaxed);
            // a drain completes once nothing is queued, running, or
            // awaiting its final reply — then serve() returns cleanly
            if self.draining.load(Ordering::Relaxed)
                && self.queue.is_empty()
                && !engine.has_work()
                && inflight.is_empty()
            {
                break;
            }
            // admit up to the engine's slot count; the rest stays in the
            // bounded queue so capacity shedding keeps working
            let mut drained = self.queue.drain_up_to(engine.admission_room());
            if drained.is_empty() && !engine.has_work() {
                // idle: block briefly for the next request
                match self.queue.pop_timeout(Duration::from_millis(50)) {
                    Some(item) => drained.push(item),
                    None => continue,
                }
            }
            for (req, tx) in drained {
                if req.stream {
                    streaming.insert(req.id);
                }
                inflight.insert(req.id, tx);
                engine.submit(req);
            }
            if !engine.has_work() {
                continue;
            }
            // record into a local delta so conn threads (stats, shed
            // counting) never wait a whole decode iteration for the lock
            let mut delta = ServingMetrics::default();
            let step = engine.step_events(&mut delta);
            // a poisoned metrics lock (a panicked conn thread) must not
            // take the engine down with it — counters stay best-effort
            self.metrics
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .merge(&delta);
            match step {
                Ok(outcome) => {
                    // per-cycle frames first, so every frame of a request
                    // precedes its final response on the wire; decode
                    // only for streaming requests so everyone else pays
                    // nothing per cycle
                    for ev in &outcome.events {
                        if ev.tokens.is_empty() || !streaming.contains(&ev.id) {
                            continue;
                        }
                        if let Some(conn) = inflight.get(&ev.id) {
                            let queued = conn.queued_frames.load(Ordering::Relaxed);
                            if let Some(merged) = gate.offer(ev, queued) {
                                let text = engine.decode(&merged.tokens);
                                conn.queued_frames.fetch_add(1, Ordering::Relaxed);
                                let _ = conn
                                    .tx
                                    .send(Reply::Frame(frame_json(&merged, &text)));
                            }
                        }
                    }
                    let done = outcome.finished;
                    let stalled = engine.stalled(&done);
                    for resp in done {
                        streaming.remove(&resp.id);
                        if let Some(conn) = inflight.remove(&resp.id) {
                            // a slow consumer's coalesced backlog still
                            // goes out before its final response
                            if let Some(merged) = gate.flush(resp.id) {
                                let text = engine.decode(&merged.tokens);
                                conn.queued_frames.fetch_add(1, Ordering::Relaxed);
                                let _ = conn
                                    .tx
                                    .send(Reply::Frame(frame_json(&merged, &text)));
                            }
                            let _ = conn.tx.send(Reply::Done(resp));
                        }
                    }
                    // a stalled engine means the head request can never
                    // admit (the whole pool is free and still too small)
                    // — fail the queued requests rather than spin forever
                    if stalled {
                        let ids = engine.abort_all();
                        self.metrics
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner)
                            .requests_failed += ids.len() as u64;
                        for id in ids {
                            streaming.remove(&id);
                            gate.forget(id);
                            if let Some(conn) = inflight.remove(&id) {
                                let _ = conn.tx.send(Reply::Done(Response::error(
                                    id,
                                    "request exceeds KV pool capacity",
                                )));
                            }
                        }
                    }
                }
                Err(e) => {
                    crate::log_warn!("engine step failed: {e:#}");
                    let ids = engine.abort_all();
                    self.metrics
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .requests_failed += ids.len() as u64;
                    for id in ids {
                        streaming.remove(&id);
                        gate.forget(id);
                        if let Some(conn) = inflight.remove(&id) {
                            let _ =
                                conn.tx.send(Reply::Done(Response::error(id, format!("{e:#}"))));
                        }
                    }
                }
            }
        }
        // a drain exit reaches here with shutdown still false: raise it
        // so the accept thread stops and idle keep-alives wind down
        self.shutdown.store(true, Ordering::Relaxed);
        self.queue.close();
        // Drop every reply channel (queued and in-flight) *before*
        // joining the connection threads: each blocked `rx.recv()` then
        // errors and its connection answers "server shutting down" —
        // otherwise join would wait on connections that wait on us.
        drop(self.queue.drain_up_to(usize::MAX));
        drop(inflight);
        // cancel verbs that raced the exit: dropping their reply senders
        // unblocks the waiting connection threads
        self.control
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clear();
        let _ = accept_handle.join();
        // prove the clean exit: abort whatever was still running, hand
        // the prefix cache's blocks back, and demand the pool balances —
        // a leak here is a refcount bug worth a non-zero exit
        drop(engine.abort_all());
        engine.release_cache();
        let leaked = engine.leaked_blocks();
        if leaked > 0 {
            anyhow::bail!("exit with {leaked} leaked KV pool blocks");
        }
        let m = self
            .metrics
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone();
        Ok(m)
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    pub fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }
}

/// Per-phase timing summary for the stats reply:
/// `{method: {phase: {count, mean_us, p50_us, p99_us}}}`.
fn phase_stats_json(m: &ServingMetrics) -> Json {
    let mut methods: std::collections::BTreeMap<String, Json> = std::collections::BTreeMap::new();
    for (&(method, phase), h) in &m.phase_us {
        let entry = Json::obj(vec![
            ("count", Json::num(h.count() as f64)),
            ("mean_us", Json::num(h.mean_us())),
            ("p50_us", Json::num(h.percentile_us(0.5))),
            ("p99_us", Json::num(h.percentile_us(0.99))),
        ]);
        let slot = methods
            .entry(method.to_string())
            .or_insert_with(|| Json::Obj(Default::default()));
        if let Json::Obj(phases) = slot {
            phases.insert(phase.to_string(), entry);
        }
    }
    Json::Obj(methods)
}

fn handle_conn(stream: TcpStream, shared: Arc<ConnShared>) -> Result<()> {
    let ConnShared { queue, shutdown, metrics, next_id, .. } = &*shared;
    // a read timeout lets idle keep-alive connections notice shutdown:
    // without it, a client that simply stays connected would block this
    // thread in read_line forever and serve() could never join it
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // accumulate raw bytes across timeout retries: a slow sender's
        // partial line survives even when the split lands inside a
        // multibyte character (read_line would drop such bytes)
        loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match Json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![("error", Json::str(&format!("{e}")))]).to_string())?;
                continue;
            }
        };
        if let Some(cmd) = v.get("cmd") {
            let Some(cmd) = cmd.as_str() else {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("error", Json::str("cmd must be a string")),
                        ("field", Json::str("cmd")),
                    ])
                    .to_string()
                )?;
                continue;
            };
            match cmd {
            "shutdown" => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            "drain" => {
                // stop admission; in-flight work finishes, then serve()
                // returns cleanly. stats/metrics stay answerable so an
                // operator (or the router) can watch the drain progress.
                shared.draining.store(true, Ordering::Relaxed);
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("draining", Json::Bool(true)),
                    ])
                    .to_string()
                )?;
                continue;
            }
            "cancel" => {
                let id = match v.get("req").and_then(Json::as_i64) {
                    Some(n) if n >= 1 => n as u64,
                    _ => {
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![
                                ("error", Json::str("cancel needs a positive integer req id")),
                                ("field", Json::str("req")),
                            ])
                            .to_string()
                        )?;
                        continue;
                    }
                };
                let (tx, rx) = std::sync::mpsc::channel();
                shared
                    .control
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .push_back(Control::Cancel { id, reply: tx });
                // the engine loop answers within one step (≤50ms idle
                // tick); the timeout only fires if it died underneath us
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(j) => writeln!(writer, "{}", j.to_string())?,
                    Err(_) => writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![("error", Json::str("server shutting down"))])
                            .to_string()
                    )?,
                }
                continue;
            }
            "stats" => {
                let m = metrics.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                let j = Json::obj(vec![
                    ("replica_id", Json::num(shared.replica_id as f64)),
                    ("uptime_ms", Json::num(shared.started.elapsed().as_millis() as f64)),
                    ("draining", Json::Bool(shared.draining.load(Ordering::Relaxed))),
                    ("active", Json::num(shared.active_slots.load(Ordering::Relaxed) as f64)),
                    (
                        "queued",
                        Json::num(
                            (queue.len() + shared.engine_backlog.load(Ordering::Relaxed))
                                as f64,
                        ),
                    ),
                    ("requests_done", Json::num(m.requests_done as f64)),
                    ("requests_rejected", Json::num(m.requests_rejected as f64)),
                    ("requests_deferred", Json::num(m.requests_deferred as f64)),
                    ("requests_failed", Json::num(m.requests_failed as f64)),
                    ("requests_canceled", Json::num(m.requests_canceled as f64)),
                    ("requests_expired", Json::num(m.requests_expired as f64)),
                    ("tokens_out", Json::num(m.tokens_out as f64)),
                    ("tok_per_sec", Json::num(m.tokens_per_sec())),
                    ("mean_tau", Json::num(m.mean_tau())),
                    ("mean_occupancy", Json::num(m.mean_occupancy())),
                    ("peak_occupancy", Json::num(m.occupancy_peak as f64)),
                    ("prefill_chunks", Json::num(m.prefill_chunks as f64)),
                    ("preemptions", Json::num(m.preemptions as f64)),
                    ("resumes", Json::num(m.resumes as f64)),
                    ("parked_tokens", Json::num(m.parked_tokens as f64)),
                    ("cache_hits", Json::num(m.cache_hits as f64)),
                    ("cache_misses", Json::num(m.cache_misses as f64)),
                    ("cache_saved_tokens", Json::num(m.cache_saved_tokens as f64)),
                    ("cache_evicted_blocks", Json::num(m.cache_evicted_blocks as f64)),
                    ("cache_hit_rate", Json::num(m.cache_hit_rate())),
                    ("plan_depth_mean", Json::num(m.mean_plan_depth())),
                    ("plan_nodes_mean", Json::num(m.mean_plan_nodes())),
                    ("accept_window_mean", Json::num(m.mean_accept_window())),
                    ("p50_ms", Json::num(m.latency.percentile_us(0.5) / 1e3)),
                    ("p99_ms", Json::num(m.latency.percentile_us(0.99) / 1e3)),
                    ("wait_p50_ms", Json::num(m.queue_wait.percentile_us(0.5) / 1e3)),
                    ("ttfc_p50_ms", Json::num(m.ttfc.percentile_us(0.5) / 1e3)),
                    ("phase_us", phase_stats_json(&m)),
                ]);
                writeln!(writer, "{}", j.to_string())?;
                continue;
            }
            "trace" => {
                // one line of Chrome trace-event JSON; "{\"traceEvents\":[]...}"
                // when the recorder is disabled or empty
                writeln!(writer, "{}", crate::obs::chrome_trace_json())?;
                continue;
            }
            "metrics" => {
                // render under the lock, write after releasing it so a
                // slow client never stalls the stats path
                let text = {
                    let m = metrics.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    crate::obs::prom::render(&m)
                };
                writer.write_all(text.as_bytes())?;
                writer.flush()?;
                continue;
            }
            other => {
                // unknown verbs are a protocol error, never a generation
                // request: name the verb and list what the server speaks
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        (
                            "error",
                            Json::str(&format!(
                                "unknown cmd {other:?} (stats|trace|metrics|cancel|drain|shutdown)"
                            )),
                        ),
                        ("field", Json::str("cmd")),
                    ])
                    .to_string()
                )?;
                continue;
            }
            }
        }
        if shared.draining.load(Ordering::Relaxed) {
            // admission is closed for good on this replica; a router
            // keys on "draining" to reroute instead of retrying here
            writeln!(
                writer,
                "{}",
                Json::obj(vec![
                    ("error", Json::str("server draining")),
                    ("draining", Json::Bool(true)),
                ])
                .to_string()
            )?;
            continue;
        }
        // the router forwards requests with its own global id so frames
        // and finals match across the fleet; direct clients omit "id"
        // and get a server-assigned one
        let id = match v.get("id") {
            None => next_id.fetch_add(1, Ordering::Relaxed),
            Some(j) => match j.as_i64() {
                Some(n) if n >= 1 => {
                    let id = n as u64;
                    // keep server-assigned ids clear of explicit ones
                    next_id.fetch_max(id + 1, Ordering::Relaxed);
                    id
                }
                _ => {
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("error", Json::str("id must be a positive integer")),
                            ("field", Json::str("id")),
                        ])
                        .to_string()
                    )?;
                    continue;
                }
            },
        };
        match Request::from_json(id, &v) {
            Ok(req) => {
                let (tx, rx) = std::sync::mpsc::channel();
                let queued_frames = Arc::new(AtomicUsize::new(0));
                let conn =
                    ConnReply { tx, queued_frames: Arc::clone(&queued_frames) };
                match queue.try_push((req, conn)) {
                    Ok(()) => {}
                    Err(PushError::Full(_)) => {
                        // shed: the bounded queue is the 429 analogue
                        let mut m =
                            metrics.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                        m.requests_rejected += 1;
                        drop(m);
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![("error", Json::str("queue full"))]).to_string()
                        )?;
                        continue;
                    }
                    Err(PushError::Closed(_)) => {
                        writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![("error", Json::str("server shutting down"))])
                                .to_string()
                        )?;
                        return Ok(());
                    }
                }
                // zero or more streaming frames, then the final response
                loop {
                    match rx.recv() {
                        Ok(Reply::Frame(j)) => {
                            writeln!(writer, "{}", j.to_string())?;
                            // delivered: open the gate for the next frame
                            queued_frames.fetch_sub(1, Ordering::Relaxed);
                        }
                        Ok(Reply::Done(resp)) => {
                            writeln!(writer, "{}", resp.to_json().to_string())?;
                            break;
                        }
                        Err(_) => {
                            writeln!(
                                writer,
                                "{}",
                                Json::obj(vec![("error", Json::str("server shutting down"))])
                                    .to_string()
                            )?;
                            return Ok(());
                        }
                    }
                }
            }
            Err(e) => {
                // structured parse failure: name the field and the why,
                // so clients can fix the request instead of guessing
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("error", Json::str(&format!("invalid request: {e}"))),
                        ("field", Json::str(e.field)),
                    ])
                    .to_string()
                )?;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, cycle: usize, tokens: &[i32]) -> SlotEvent {
        SlotEvent {
            id,
            cycle,
            tokens: tokens.to_vec(),
            accepted_len: tokens.len(),
            finished: false,
        }
    }

    /// A consumer at capacity gets its cycles coalesced; once it drains,
    /// one merged frame carries everything — no token lost or repeated.
    #[test]
    fn frame_gate_coalesces_when_consumer_lags() {
        let mut g = FrameGate::new(2);
        // queue has room: frames pass through immediately
        let out = g.offer(&ev(7, 1, &[1, 2]), 0).expect("room -> send");
        assert_eq!(out.tokens, vec![1, 2]);
        // consumer at cap: two cycles coalesce into backlog
        assert!(g.offer(&ev(7, 2, &[3]), 2).is_none());
        assert!(g.offer(&ev(7, 3, &[4, 5]), 2).is_none());
        // consumer drains below cap: next cycle flushes the whole merge
        let merged = g.offer(&ev(7, 4, &[6]), 1).expect("room again");
        assert_eq!(merged.tokens, vec![3, 4, 5, 6]);
        assert_eq!(merged.cycle, 4, "cycle index advances to the newest");
        assert_eq!(merged.accepted_len, 4);
        // nothing left pending
        assert!(g.flush(7).is_none());
    }

    /// Completion always drains the backlog, so concatenated frames
    /// cover every committed token even for a never-draining consumer.
    #[test]
    fn frame_gate_flushes_backlog_on_completion() {
        let mut g = FrameGate::new(0); // cap 0: nothing passes inline
        assert!(g.offer(&ev(3, 1, &[10]), 0).is_none());
        assert!(g.offer(&ev(3, 2, &[11, 12]), 0).is_none());
        let fin = g.flush(3).expect("backlog flushes at completion");
        assert_eq!(fin.tokens, vec![10, 11, 12]);
        // per-request isolation: another id is untouched
        assert!(g.offer(&ev(4, 1, &[1]), 0).is_none());
        g.forget(4);
        assert!(g.flush(4).is_none(), "forget drops the backlog");
    }
}
