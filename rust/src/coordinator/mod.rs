//! Serving coordinator (L3): admission queue, scheduler (policies,
//! chunked prefill, preemption), continuous batcher over the batched
//! executables, TCP JSON API server, serving metrics.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchConfig, BatchEngine, BatchMethod, CancelOutcome, SlotEvent, StepOutcome};
pub use metrics::ServingMetrics;
pub use queue::{AdmissionQueue, PushError};
pub use request::{ParseError, Request, Response};
pub use scheduler::{PolicyKind, SchedulePlan, Scheduler, SchedulerPolicy};
pub use server::{Server, ServerConfig};
