//! Serving coordinator (L3): admission queue, continuous batcher over
//! the batched executables, TCP JSON API server, serving metrics.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod server;

pub use batcher::{BatchConfig, BatchEngine, BatchMethod, SlotEvent, StepOutcome};
pub use metrics::ServingMetrics;
pub use queue::{AdmissionQueue, PushError};
pub use request::{Request, Response};
pub use server::{Server, ServerConfig};
