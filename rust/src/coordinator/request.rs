//! Request/response types for the serving coordinator.

use std::time::Instant;

use crate::spec::GenConfig;
use crate::util::json::Json;

use super::batcher::BatchMethod;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub cfg: GenConfig,
    /// speculative method for this request; `None` uses the engine's
    /// default — one pool can serve mixed-method fleets
    pub method: Option<BatchMethod>,
    /// opt-in incremental `{"event":"tokens",...}` frames per cycle
    pub stream: bool,
    /// scheduling priority (higher = more urgent; default 0). The
    /// policy uses it for admission ordering and as the preemption
    /// threshold: only strictly lower-priority slots may be paused to
    /// fund this request's admission.
    pub priority: i32,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            cfg: GenConfig::default(),
            method: None,
            stream: false,
            priority: 0,
            arrival: Instant::now(),
        }
    }

    /// Parse an API request line: {"prompt": "...", "max_new": 64,
    /// "temperature": 0.0, "seed": 1, "method": "fasteagle",
    /// "stream": false, "priority": 0}.
    ///
    /// An explicit `seed` pins the sampling stream (same seed + prompt
    /// reproduces exactly); omitting it derives a per-request seed from
    /// the id so concurrent stochastic requests sample diversely
    /// instead of all sharing the default-0 stream. An unknown `method`
    /// value falls back to the server's default method.
    pub fn from_json(id: u64, v: &Json) -> Option<Request> {
        let prompt = v.get("prompt")?.as_str()?.to_string();
        let mut cfg = GenConfig::default();
        if let Some(m) = v.get("max_new").and_then(Json::as_usize) {
            cfg.max_new_tokens = m;
        }
        if let Some(t) = v.get("temperature").and_then(Json::as_f64) {
            cfg.temperature = t as f32;
        }
        match v.get("seed").and_then(Json::as_i64) {
            Some(s) => cfg.seed = s as u64,
            None => cfg.seed = id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        }
        if let Some(e) = v.get("stop_on_eos").and_then(Json::as_bool) {
            cfg.stop_on_eos = e;
        }
        let method = v
            .get("method")
            .and_then(Json::as_str)
            .and_then(BatchMethod::from_name);
        let stream = v.get("stream").and_then(Json::as_bool).unwrap_or(false);
        let priority = v.get("priority").and_then(Json::as_i64).unwrap_or(0) as i32;
        Some(Request { id, prompt, cfg, method, stream, priority, arrival: Instant::now() })
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub new_tokens: usize,
    pub tau: f64,
    pub cycles: usize,
    /// time from arrival to completion
    pub latency_ms: f64,
    /// generation wall time only
    pub gen_ms: f64,
    pub error: Option<String>,
}

impl Response {
    /// A failure reply carrying no generated text.
    pub fn error(id: u64, msg: impl Into<String>) -> Response {
        Response {
            id,
            text: String::new(),
            new_tokens: 0,
            tau: 0.0,
            cycles: 0,
            latency_ms: 0.0,
            gen_ms: 0.0,
            error: Some(msg.into()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("text", Json::str(&self.text)),
            ("new_tokens", Json::num(self.new_tokens as f64)),
            ("tau", Json::num(self.tau)),
            ("cycles", Json::num(self.cycles as f64)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("gen_ms", Json::num(self.gen_ms)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_from_json() {
        let v = Json::parse(r#"{"prompt":"hi","max_new":10,"temperature":1.0}"#).unwrap();
        let r = Request::from_json(3, &v).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.cfg.max_new_tokens, 10);
        assert!((r.cfg.temperature - 1.0).abs() < 1e-6);
        assert_eq!(r.method, None);
        assert!(!r.stream);
        assert!(Request::from_json(0, &Json::parse("{}").unwrap()).is_none());
    }

    #[test]
    fn request_method_and_stream_flags() {
        let v = Json::parse(
            r#"{"prompt":"p","method":"vanilla","stream":true,"priority":3}"#,
        )
        .unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.method, Some(BatchMethod::Vanilla));
        assert!(r.stream);
        assert_eq!(r.priority, 3);
        // priority defaults to 0 (and accepts negatives)
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        assert_eq!(Request::from_json(1, &v).unwrap().priority, 0);
        let v = Json::parse(r#"{"prompt":"p","priority":-2}"#).unwrap();
        assert_eq!(Request::from_json(1, &v).unwrap().priority, -2);
        // unknown method values fall back to the engine default
        let v = Json::parse(r#"{"prompt":"p","method":"warp-drive"}"#).unwrap();
        assert_eq!(Request::from_json(2, &v).unwrap().method, None);
    }

    #[test]
    fn omitted_seed_differs_per_request_but_explicit_seed_pins() {
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        let a = Request::from_json(1, &v).unwrap();
        let b = Request::from_json(2, &v).unwrap();
        assert_ne!(a.cfg.seed, b.cfg.seed, "default seeds must diverge per request");
        let v = Json::parse(r#"{"prompt":"p","seed":7}"#).unwrap();
        let a = Request::from_json(1, &v).unwrap();
        let b = Request::from_json(2, &v).unwrap();
        assert_eq!(a.cfg.seed, 7);
        assert_eq!(b.cfg.seed, 7, "explicit seed pins the stream across ids");
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            id: 1,
            text: "ok".into(),
            new_tokens: 2,
            tau: 3.5,
            cycles: 4,
            latency_ms: 10.0,
            gen_ms: 8.0,
            error: None,
        };
        let j = r.to_json().to_string();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("text").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("tau").unwrap().as_f64(), Some(3.5));
    }
}
