//! Request/response types for the serving coordinator.

use std::time::{Duration, Instant};

use crate::spec::{DraftConfig, GenConfig, PlannerKind};
use crate::util::json::Json;

use super::batcher::BatchMethod;

/// Upper bound on `"deadline_ms"` (24h): like the draft knobs, the
/// parse boundary rejects nonsense instead of letting a typo smuggle in
/// an effectively-infinite (or instantly-expired zero) deadline.
pub const MAX_DEADLINE_MS: u64 = 86_400_000;

/// `"priority"` must sit in `[-MAX_PRIORITY_ABS, MAX_PRIORITY_ABS]` —
/// bounded at the parse boundary so a stray i64 can't overflow the i32
/// scheduler ordering or starve the fleet behind one absurd value.
pub const MAX_PRIORITY_ABS: i64 = 1_000_000;

/// A structured request-parse failure: which field was bad and why.
/// The server echoes both back in the JSON error reply, so malformed
/// requests die with a reason instead of a bare "missing prompt".
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// dotted field path (e.g. `"draft.depth"`)
    pub field: &'static str,
    pub reason: String,
}

impl ParseError {
    fn new(field: &'static str, reason: impl Into<String>) -> ParseError {
        ParseError { field, reason: reason.into() }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.field, self.reason)
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub cfg: GenConfig,
    /// speculative method for this request; `None` uses the engine's
    /// default — one pool can serve mixed-method fleets
    pub method: Option<BatchMethod>,
    /// opt-in incremental `{"event":"tokens",...}` frames per cycle
    pub stream: bool,
    /// scheduling priority (higher = more urgent; default 0). The
    /// policy uses it for admission ordering and as the preemption
    /// threshold: only strictly lower-priority slots may be paused to
    /// fund this request's admission.
    pub priority: i32,
    /// prefix-cache participation (default true): `"cache": false`
    /// opts this request out of both adopting cached prefixes and
    /// publishing its own — for privacy-sensitive prompts or A/B
    /// measurement. No effect when the engine's cache is off.
    pub cache: bool,
    /// completion deadline relative to arrival (`"deadline_ms"`): the
    /// engine sweeps pending, parked and active requests every step and
    /// answers expired ones with a structured "deadline exceeded" error
    /// — enforced at admission *and* mid-generation. `None` = no limit.
    pub deadline: Option<Duration>,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: impl Into<String>) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            cfg: GenConfig::default(),
            method: None,
            stream: false,
            priority: 0,
            cache: true,
            deadline: None,
            arrival: Instant::now(),
        }
    }

    /// Time left before this request's deadline, `None` when unlimited.
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_sub(self.arrival.elapsed()))
    }

    /// True once the deadline has passed.
    pub fn expired(&self) -> bool {
        self.remaining().is_some_and(|r| r.is_zero())
    }

    /// Parse an API request line: {"prompt": "...", "max_new": 64,
    /// "temperature": 0.0, "seed": 1, "method": "fasteagle",
    /// "stream": false, "priority": 0, "cache": true,
    /// "deadline_ms": 5000,
    /// "draft": {"planner": "static"|"adaptive", "depth": N,
    ///           "top_k": N, "budget": N}}.
    ///
    /// Every present field is validated; a malformed one returns a
    /// [`ParseError`] naming the field and the reason (sent back in the
    /// server's error reply). Unset `"draft"` fields fall back to the
    /// serving defaults and ultimately to the model spec.
    ///
    /// An explicit `seed` pins the sampling stream (same seed + prompt
    /// reproduces exactly); omitting it derives a per-request seed from
    /// the id so concurrent stochastic requests sample diversely
    /// instead of all sharing the default-0 stream.
    pub fn from_json(id: u64, v: &Json) -> Result<Request, ParseError> {
        let prompt = match v.get("prompt") {
            None => return Err(ParseError::new("prompt", "required")),
            Some(p) => p
                .as_str()
                .ok_or_else(|| ParseError::new("prompt", "must be a string"))?
                .to_string(),
        };
        let mut cfg = GenConfig::default();
        if let Some(m) = v.get("max_new") {
            cfg.max_new_tokens = m
                .as_usize()
                .ok_or_else(|| ParseError::new("max_new", "must be a non-negative integer"))?;
        }
        if let Some(t) = v.get("temperature") {
            cfg.temperature = t
                .as_f64()
                .ok_or_else(|| ParseError::new("temperature", "must be a number"))?
                as f32;
        }
        match v.get("seed") {
            Some(s) => {
                cfg.seed = s
                    .as_i64()
                    .ok_or_else(|| ParseError::new("seed", "must be an integer"))?
                    as u64
            }
            None => cfg.seed = id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        }
        if let Some(e) = v.get("stop_on_eos") {
            cfg.stop_on_eos = e
                .as_bool()
                .ok_or_else(|| ParseError::new("stop_on_eos", "must be a boolean"))?;
        }
        cfg.draft = Self::parse_draft(v.get("draft"))?;
        let method = match v.get("method") {
            None => None,
            Some(m) => {
                let name = m
                    .as_str()
                    .ok_or_else(|| ParseError::new("method", "must be a string"))?;
                Some(BatchMethod::from_name(name).ok_or_else(|| {
                    ParseError::new(
                        "method",
                        format!("unknown method {name:?} (vanilla|eagle3|fasteagle)"),
                    )
                })?)
            }
        };
        let stream = match v.get("stream") {
            None => false,
            Some(s) => s
                .as_bool()
                .ok_or_else(|| ParseError::new("stream", "must be a boolean"))?,
        };
        let priority = match v.get("priority") {
            None => 0,
            Some(p) => match p.as_i64() {
                Some(n) if (-MAX_PRIORITY_ABS..=MAX_PRIORITY_ABS).contains(&n) => n as i32,
                _ => {
                    return Err(ParseError::new(
                        "priority",
                        format!("must be an integer in -{MAX_PRIORITY_ABS}..={MAX_PRIORITY_ABS}"),
                    ))
                }
            },
        };
        let cache = match v.get("cache") {
            None => true,
            Some(c) => c
                .as_bool()
                .ok_or_else(|| ParseError::new("cache", "must be a boolean"))?,
        };
        let deadline = match v.get("deadline_ms") {
            None => None,
            Some(d) => match d.as_i64() {
                Some(ms) if (1..=MAX_DEADLINE_MS as i64).contains(&ms) => {
                    Some(Duration::from_millis(ms as u64))
                }
                _ => {
                    return Err(ParseError::new(
                        "deadline_ms",
                        format!("must be an integer in 1..={MAX_DEADLINE_MS}"),
                    ))
                }
            },
        };
        Ok(Request {
            id,
            prompt,
            cfg,
            method,
            stream,
            priority,
            cache,
            deadline,
            arrival: Instant::now(),
        })
    }

    /// Validate the optional `"draft"` object into a [`DraftConfig`].
    fn parse_draft(v: Option<&Json>) -> Result<DraftConfig, ParseError> {
        let mut out = DraftConfig::default();
        let Some(v) = v else { return Ok(out) };
        let obj = v
            .as_obj()
            .ok_or_else(|| ParseError::new("draft", "must be an object"))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "planner" | "depth" | "top_k" | "budget") {
                return Err(ParseError::new(
                    "draft",
                    format!("unknown key {key:?} (planner|depth|top_k|budget)"),
                ));
            }
        }
        if let Some(p) = obj.get("planner") {
            let name = p
                .as_str()
                .ok_or_else(|| ParseError::new("draft.planner", "must be a string"))?;
            out.planner = Some(PlannerKind::from_name(name).ok_or_else(|| {
                ParseError::new(
                    "draft.planner",
                    format!("unknown planner {name:?} (static|adaptive)"),
                )
            })?);
        }
        let pos_int = |v: &Json, field: &'static str| -> Result<usize, ParseError> {
            match v.as_usize() {
                Some(n) if (1..=crate::spec::plan::MAX_DRAFT_KNOB).contains(&n) => Ok(n),
                _ => Err(ParseError::new(
                    field,
                    format!("must be an integer in 1..={}", crate::spec::plan::MAX_DRAFT_KNOB),
                )),
            }
        };
        if let Some(d) = obj.get("depth") {
            out.depth = Some(pos_int(d, "draft.depth")?);
        }
        if let Some(k) = obj.get("top_k") {
            out.top_k = Some(pos_int(k, "draft.top_k")?);
        }
        if let Some(b) = obj.get("budget") {
            out.budget = Some(pos_int(b, "draft.budget")?);
        }
        Ok(out)
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub new_tokens: usize,
    pub tau: f64,
    pub cycles: usize,
    /// time from arrival to completion
    pub latency_ms: f64,
    /// generation wall time only
    pub gen_ms: f64,
    pub error: Option<String>,
}

impl Response {
    /// A failure reply carrying no generated text.
    pub fn error(id: u64, msg: impl Into<String>) -> Response {
        Response {
            id,
            text: String::new(),
            new_tokens: 0,
            tau: 0.0,
            cycles: 0,
            latency_ms: 0.0,
            gen_ms: 0.0,
            error: Some(msg.into()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::num(self.id as f64)),
            ("text", Json::str(&self.text)),
            ("new_tokens", Json::num(self.new_tokens as f64)),
            ("tau", Json::num(self.tau)),
            ("cycles", Json::num(self.cycles as f64)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("gen_ms", Json::num(self.gen_ms)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_from_json() {
        let v = Json::parse(r#"{"prompt":"hi","max_new":10,"temperature":1.0}"#).unwrap();
        let r = Request::from_json(3, &v).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.cfg.max_new_tokens, 10);
        assert!((r.cfg.temperature - 1.0).abs() < 1e-6);
        assert_eq!(r.method, None);
        assert!(!r.stream);
        assert_eq!(r.cfg.draft, DraftConfig::default());
        let err = Request::from_json(0, &Json::parse("{}").unwrap()).unwrap_err();
        assert_eq!(err.field, "prompt");
        assert_eq!(err.reason, "required");
    }

    #[test]
    fn request_method_and_stream_flags() {
        let v = Json::parse(
            r#"{"prompt":"p","method":"vanilla","stream":true,"priority":3}"#,
        )
        .unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.method, Some(BatchMethod::Vanilla));
        assert!(r.stream);
        assert_eq!(r.priority, 3);
        // priority defaults to 0 (and accepts negatives)
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        assert_eq!(Request::from_json(1, &v).unwrap().priority, 0);
        let v = Json::parse(r#"{"prompt":"p","priority":-2}"#).unwrap();
        assert_eq!(Request::from_json(1, &v).unwrap().priority, -2);
        // cache participation defaults on; "cache": false opts out
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        assert!(Request::from_json(1, &v).unwrap().cache);
        let v = Json::parse(r#"{"prompt":"p","cache":false}"#).unwrap();
        assert!(!Request::from_json(1, &v).unwrap().cache);
        // unknown method values die with a structured reason
        let v = Json::parse(r#"{"prompt":"p","method":"warp-drive"}"#).unwrap();
        let err = Request::from_json(2, &v).unwrap_err();
        assert_eq!(err.field, "method");
        assert!(err.reason.contains("warp-drive"), "{err}");
    }

    #[test]
    fn malformed_fields_name_themselves() {
        for (line, field) in [
            (r#"{"prompt":7}"#, "prompt"),
            (r#"{"prompt":"p","max_new":-3}"#, "max_new"),
            (r#"{"prompt":"p","temperature":"hot"}"#, "temperature"),
            (r#"{"prompt":"p","seed":"x"}"#, "seed"),
            (r#"{"prompt":"p","stream":"yes"}"#, "stream"),
            (r#"{"prompt":"p","stop_on_eos":1}"#, "stop_on_eos"),
            (r#"{"prompt":"p","priority":"high"}"#, "priority"),
            (r#"{"prompt":"p","priority":2000000}"#, "priority"),
            (r#"{"prompt":"p","priority":-2000000}"#, "priority"),
            (r#"{"prompt":"p","cache":"warm"}"#, "cache"),
            (r#"{"prompt":"p","deadline_ms":"soon"}"#, "deadline_ms"),
            (r#"{"prompt":"p","deadline_ms":0}"#, "deadline_ms"),
            (r#"{"prompt":"p","deadline_ms":-5}"#, "deadline_ms"),
            (r#"{"prompt":"p","deadline_ms":90000000}"#, "deadline_ms"),
        ] {
            let v = Json::parse(line).unwrap();
            let err = Request::from_json(1, &v).unwrap_err();
            assert_eq!(err.field, field, "{line}");
            assert!(!err.reason.is_empty());
        }
    }

    #[test]
    fn draft_object_parses_and_validates() {
        let v = Json::parse(
            r#"{"prompt":"p","draft":{"planner":"adaptive","depth":4,"top_k":2,"budget":6}}"#,
        )
        .unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.cfg.draft.planner, Some(crate::spec::PlannerKind::Adaptive));
        assert_eq!(r.cfg.draft.depth, Some(4));
        assert_eq!(r.cfg.draft.top_k, Some(2));
        assert_eq!(r.cfg.draft.budget, Some(6));
        // partial objects leave the rest unset
        let v = Json::parse(r#"{"prompt":"p","draft":{"planner":"static"}}"#).unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.cfg.draft.planner, Some(crate::spec::PlannerKind::Static));
        assert_eq!(r.cfg.draft.depth, None);
        // malformed drafts die with the offending field
        for (line, field) in [
            (r#"{"prompt":"p","draft":"adaptive"}"#, "draft"),
            (r#"{"prompt":"p","draft":{"plan":"x"}}"#, "draft"),
            (r#"{"prompt":"p","draft":{"planner":"magic"}}"#, "draft.planner"),
            (r#"{"prompt":"p","draft":{"planner":3}}"#, "draft.planner"),
            (r#"{"prompt":"p","draft":{"depth":0}}"#, "draft.depth"),
            (r#"{"prompt":"p","draft":{"top_k":-1}}"#, "draft.top_k"),
            (r#"{"prompt":"p","draft":{"budget":"big"}}"#, "draft.budget"),
        ] {
            let v = Json::parse(line).unwrap();
            let err = Request::from_json(1, &v).unwrap_err();
            assert_eq!(err.field, field, "{line}");
        }
    }

    #[test]
    fn deadline_parses_and_expires() {
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.deadline, None);
        assert!(!r.expired(), "no deadline never expires");
        let v = Json::parse(r#"{"prompt":"p","deadline_ms":250}"#).unwrap();
        let r = Request::from_json(1, &v).unwrap();
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        assert!(!r.expired());
        assert!(r.remaining().unwrap() <= Duration::from_millis(250));
        let mut r = r;
        r.arrival = Instant::now() - Duration::from_millis(500);
        assert!(r.expired(), "past-deadline request reports expired");
        assert_eq!(r.remaining(), Some(Duration::ZERO));
    }

    #[test]
    fn omitted_seed_differs_per_request_but_explicit_seed_pins() {
        let v = Json::parse(r#"{"prompt":"p"}"#).unwrap();
        let a = Request::from_json(1, &v).unwrap();
        let b = Request::from_json(2, &v).unwrap();
        assert_ne!(a.cfg.seed, b.cfg.seed, "default seeds must diverge per request");
        let v = Json::parse(r#"{"prompt":"p","seed":7}"#).unwrap();
        let a = Request::from_json(1, &v).unwrap();
        let b = Request::from_json(2, &v).unwrap();
        assert_eq!(a.cfg.seed, 7);
        assert_eq!(b.cfg.seed, 7, "explicit seed pins the stream across ids");
    }

    #[test]
    fn response_roundtrip() {
        let r = Response {
            id: 1,
            text: "ok".into(),
            new_tokens: 2,
            tau: 3.5,
            cycles: 4,
            latency_ms: 10.0,
            gen_ms: 8.0,
            error: None,
        };
        let j = r.to_json().to_string();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("text").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("tau").unwrap().as_f64(), Some(3.5));
    }
}
