//! Open-loop TCP serving bench: tail latency vs offered load, per
//! scheduling policy × draft planner.
//!
//! For each (policy, planner, arrival rate) cell this harness boots the
//! real TCP server (`coordinator/server.rs`) over a continuous-batching
//! engine with that cell's default [`PlannerKind`], replays a Poisson
//! trace against it through [`crate::workload::replay_trace_tcp`] —
//! real connections, streaming on, TTFT marked at the first `tokens`
//! frame — and reports p50/p95/p99 TTFT plus per-token decode latency,
//! the served acceptance length (τ), and the plan gauges
//! (`plan_depth_mean`/`plan_nodes_mean` from the server's stats
//! endpoint). This is the ROADMAP's open-loop serving study plus the
//! DraftPlan study: the static planner pays a fixed draft cost per
//! cycle, the adaptive planner trades draft cost against acceptance
//! per slot — the table shows acceptance length vs draft cost per cell.

use std::io::{BufRead, BufReader, Write};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchConfig, BatchEngine, BatchMethod, PolicyKind, Server, ServerConfig};
use crate::runtime::{ArtifactStore, Runtime};
use crate::spec::PlannerKind;
use crate::util::json::Json;
use crate::util::stats::percentile_sorted;
use crate::workload::{
    batched_serving_target, chat_sessions, poisson_trace, replay_chat_tcp, replay_trace_tcp,
    replay_trace_tcp_text, ChatSession, ChatTurnStat,
};

use super::harness::{render_table, write_report, BenchEnv};

const BASE_PORT: u16 = 7461;

struct Cell {
    policy: PolicyKind,
    planner: PlannerKind,
    rate: f64,
    done: usize,
    shed: usize,
    ttft_p50: f64,
    ttft_p95: f64,
    ttft_p99: f64,
    tok_p50: f64,
    tok_p95: f64,
    /// served acceptance length (mean τ) and plan gauges from the
    /// server's stats endpoint — acceptance vs draft cost per cell
    tau: f64,
    plan_depth_mean: f64,
    plan_nodes_mean: f64,
    /// per-phase p50 wall time (µs) for the fasteagle method, from the
    /// server's always-on phase histograms
    draft_us_p50: f64,
    verify_us_p50: f64,
    accept_us_p50: f64,
    sched_us_p50: f64,
    /// Prometheus exposition captured before shutdown (the sweep
    /// persists the final cell's dump under bench_out/)
    prom_text: String,
    /// Chrome trace JSON, captured only when the flight recorder is
    /// armed (FE_TRACE=1)
    trace_text: Option<String>,
    server_report: String,
}

fn percentiles(mut xs: Vec<f64>) -> (f64, f64, f64) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        percentile_sorted(&xs, 0.5),
        percentile_sorted(&xs, 0.95),
        percentile_sorted(&xs, 0.99),
    )
}

/// Everything shared across the bench's (policy, planner, rate) cells.
struct CellSetup<'a> {
    kind: crate::backend::BackendKind,
    dir: &'a std::path::Path,
    batch: usize,
    prompts: &'a [String],
    n: usize,
    max_new: usize,
}

/// One JSON-line query against a live server (stats, shutdown).
fn server_query(addr: &str, line: &str) -> Result<Json> {
    let s = std::net::TcpStream::connect(addr)?;
    let mut w = s.try_clone()?;
    writeln!(w, "{line}")?;
    let mut out = String::new();
    BufReader::new(s).read_line(&mut out)?;
    Json::parse(out.trim()).map_err(|e| anyhow::anyhow!("bad server reply: {e}"))
}

/// Multi-line query (the Prometheus `metrics` command): accumulate
/// lines through the `# EOF` terminator.
fn server_query_text(addr: &str, line: &str) -> Result<String> {
    let s = std::net::TcpStream::connect(addr)?;
    let mut w = s.try_clone()?;
    writeln!(w, "{line}")?;
    let mut reader = BufReader::new(s);
    let mut out = String::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            anyhow::bail!("server closed before the # EOF terminator");
        }
        let done = l.trim_end() == "# EOF";
        out.push_str(&l);
        if done {
            return Ok(out);
        }
    }
}

fn run_cell(
    setup: &CellSetup,
    policy: PolicyKind,
    planner: PlannerKind,
    rate: f64,
    port: u16,
) -> Result<Cell> {
    // per-cell traces: drop events from the previous cell's server (it
    // has already been joined, so no thread is mid-record)
    if crate::obs::enabled() {
        crate::obs::reset();
    }
    let addr = format!("127.0.0.1:{port}");
    let kind = setup.kind;
    let batch = setup.batch;
    let dir2 = setup.dir.to_path_buf();
    let addr2 = addr.clone();
    let server_thread = std::thread::spawn(move || -> Result<String> {
        let rt = Arc::new(Runtime::new(kind)?);
        let store = Rc::new(ArtifactStore::open(rt, dir2)?);
        let mut cfg = BatchConfig::new(batch, BatchMethod::FastEagle);
        cfg.policy = policy;
        cfg.draft.planner = Some(planner);
        let engine = BatchEngine::new(Rc::clone(&store), cfg)?;
        let server = Server::new(ServerConfig {
            addr: addr2,
            queue_capacity: 64,
            ..Default::default()
        });
        let m = server.serve(engine)?;
        Ok(m.report())
    });
    // wait for the listener; if the server thread already died, surface
    // its real error instead of a generic timeout
    let mut up = false;
    for _ in 0..600 {
        if std::net::TcpStream::connect(&addr).is_ok() {
            up = true;
            break;
        }
        if server_thread.is_finished() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    if !up {
        if server_thread.is_finished() {
            return match server_thread.join() {
                Ok(Ok(_)) => {
                    Err(anyhow::anyhow!("bench server exited before serving on {addr}"))
                }
                Ok(Err(e)) => {
                    Err(e.context(format!("bench server failed to start on {addr}")))
                }
                Err(_) => Err(anyhow::anyhow!("bench server thread panicked")),
            };
        }
        anyhow::bail!("bench server did not start on {addr}");
    }

    let trace = poisson_trace(setup.prompts, setup.n, rate, setup.max_new, 42);
    let stats = replay_trace_tcp(&addr, &trace)?;

    // collect the plan gauges before shutting the server down
    let server_stats = server_query(&addr, r#"{"cmd":"stats"}"#)?;
    let stat = |key: &str| server_stats.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let (tau, plan_depth_mean, plan_nodes_mean) =
        (stat("mean_tau"), stat("plan_depth_mean"), stat("plan_nodes_mean"));
    let phase_p50 = |phase: &str| {
        server_stats
            .path(&format!("phase_us.fasteagle.{phase}.p50_us"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    let (draft_us_p50, verify_us_p50, accept_us_p50, sched_us_p50) = (
        phase_p50("draft"),
        phase_p50("verify"),
        phase_p50("accept"),
        phase_p50("sched"),
    );
    // export surfaces, captured before shutdown so the sweep can
    // persist the final cell's dumps under bench_out/
    let prom_text = server_query_text(&addr, r#"{"cmd":"metrics"}"#)?;
    let trace_text = if crate::obs::enabled() {
        Some(server_query(&addr, r#"{"cmd":"trace"}"#)?.to_string())
    } else {
        None
    };
    // shutdown: the write must land (or the join below never returns),
    // but the reply is best-effort — it can be lost to the teardown
    // race and a failed read must not discard the sweep
    {
        let s = std::net::TcpStream::connect(&addr)?;
        let mut w = s.try_clone()?;
        writeln!(w, "{}", r#"{"cmd":"shutdown"}"#)?;
        let mut line = String::new();
        let _ = BufReader::new(s).read_line(&mut line);
    }
    let server_report = server_thread
        .join()
        .map_err(|_| anyhow::anyhow!("server thread panicked"))??;

    let ok: Vec<_> = stats.iter().filter(|s| s.error.is_none()).collect();
    let shed = stats.len() - ok.len();
    if ok.is_empty() {
        anyhow::bail!("open-loop bench completed zero requests");
    }
    let (ttft_p50, ttft_p95, ttft_p99) =
        percentiles(ok.iter().map(|s| s.ttft_ms).collect());
    let (tok_p50, tok_p95, _) =
        percentiles(ok.iter().map(|s| s.per_token_ms()).collect());
    Ok(Cell {
        policy,
        planner,
        rate,
        done: ok.len(),
        shed,
        ttft_p50,
        ttft_p95,
        ttft_p99,
        tok_p50,
        tok_p95,
        tau,
        plan_depth_mean,
        plan_nodes_mean,
        draft_us_p50,
        verify_us_p50,
        accept_us_p50,
        sched_us_p50,
        prom_text,
        trace_text,
        server_report,
    })
}

/// One leg of the warm-vs-cold prefix-cache study: a fresh server with
/// the cache off or on, the multi-turn chat trace replayed through it,
/// and the cache counters read back before shutdown.
struct CacheRun {
    turns: Vec<ChatTurnStat>,
    hits: f64,
    misses: f64,
    saved_tokens: f64,
    hit_rate: f64,
    prefill_chunks: f64,
    prom_text: String,
    server_report: String,
}

fn run_cache_leg(
    setup: &CellSetup,
    sessions: &[ChatSession],
    enabled: bool,
    port: u16,
) -> Result<CacheRun> {
    let addr = format!("127.0.0.1:{port}");
    let kind = setup.kind;
    let batch = setup.batch;
    let dir2 = setup.dir.to_path_buf();
    let addr2 = addr.clone();
    let server_thread = std::thread::spawn(move || -> Result<String> {
        let rt = Arc::new(Runtime::new(kind)?);
        let store = Rc::new(ArtifactStore::open(rt, dir2)?);
        let mut cfg = BatchConfig::new(batch, BatchMethod::FastEagle);
        cfg.prefix_cache = enabled;
        if enabled {
            // cache-aware admission only makes sense with a cache to hit
            cfg.policy = PolicyKind::Cache;
        }
        let engine = BatchEngine::new(Rc::clone(&store), cfg)?;
        let server = Server::new(ServerConfig {
            addr: addr2,
            queue_capacity: 64,
            ..Default::default()
        });
        let m = server.serve(engine)?;
        Ok(m.report())
    });
    let mut up = false;
    for _ in 0..600 {
        if std::net::TcpStream::connect(&addr).is_ok() {
            up = true;
            break;
        }
        if server_thread.is_finished() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    if !up {
        if server_thread.is_finished() {
            return match server_thread.join() {
                Ok(Ok(_)) => {
                    Err(anyhow::anyhow!("cache bench server exited before serving on {addr}"))
                }
                Ok(Err(e)) => {
                    Err(e.context(format!("cache bench server failed to start on {addr}")))
                }
                Err(_) => Err(anyhow::anyhow!("cache bench server thread panicked")),
            };
        }
        anyhow::bail!("cache bench server did not start on {addr}");
    }
    let turns = replay_chat_tcp(&addr, sessions)?;
    let server_stats = server_query(&addr, r#"{"cmd":"stats"}"#)?;
    let stat = |key: &str| server_stats.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let prom_text = server_query_text(&addr, r#"{"cmd":"metrics"}"#)?;
    {
        let s = std::net::TcpStream::connect(&addr)?;
        let mut w = s.try_clone()?;
        writeln!(w, "{}", r#"{"cmd":"shutdown"}"#)?;
        let mut line = String::new();
        let _ = BufReader::new(s).read_line(&mut line);
    }
    let server_report = server_thread
        .join()
        .map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    Ok(CacheRun {
        hits: stat("cache_hits"),
        misses: stat("cache_misses"),
        saved_tokens: stat("cache_saved_tokens"),
        hit_rate: stat("cache_hit_rate"),
        prefill_chunks: stat("prefill_chunks"),
        turns,
        prom_text,
        server_report,
    })
}

pub fn run(env: &BenchEnv) -> Result<()> {
    let Some((dir, batch)) = batched_serving_target(&env.artifacts) else {
        println!("bench serve: no serving target under {:?}; skipping", env.artifacts);
        return Ok(());
    };
    let prompts = env.prompts("dialog", 8).context("dialog prompts")?;
    let (n, max_new, rates): (usize, usize, Vec<f64>) = if env.quick {
        (8, 12, vec![4.0])
    } else {
        (24, 32, vec![1.0, 4.0, 16.0])
    };

    let setup = CellSetup {
        kind: env.runtime.kind(),
        dir: &dir,
        batch,
        prompts: &prompts,
        n,
        max_new,
    };
    let mut rows = Vec::new();
    let mut report = Vec::new();
    let mut points = Vec::new();
    let mut last_prom: Option<String> = None;
    let mut last_trace: Option<String> = None;
    let mut port = BASE_PORT;
    for policy in [PolicyKind::Fcfs, PolicyKind::Spf] {
        for planner in [PlannerKind::Static, PlannerKind::Adaptive] {
            for &rate in &rates {
                let cell = run_cell(&setup, policy, planner, rate, port)?;
                port += 1;
                println!(
                    "serve[{}/{} @ {:>5.1} req/s]: {}",
                    cell.policy.name(),
                    cell.planner.name(),
                    rate,
                    cell.server_report
                );
                rows.push(vec![
                    cell.policy.name().to_string(),
                    cell.planner.name().to_string(),
                    format!("{:.1}", cell.rate),
                    format!("{}", cell.done),
                    format!("{}", cell.shed),
                    format!("{:.0}", cell.ttft_p50),
                    format!("{:.0}", cell.ttft_p95),
                    format!("{:.0}", cell.ttft_p99),
                    format!("{:.1}", cell.tok_p50),
                    format!("{:.1}", cell.tok_p95),
                    format!("{:.2}", cell.tau),
                    format!("{:.2}", cell.plan_depth_mean),
                    format!("{:.2}", cell.plan_nodes_mean),
                    format!("{:.0}", cell.draft_us_p50),
                    format!("{:.0}", cell.verify_us_p50),
                ]);
                report.push(Json::obj(vec![
                    ("policy", Json::str(policy.name())),
                    ("planner", Json::str(planner.name())),
                    ("rate_per_sec", Json::num(rate)),
                    ("done", Json::num(cell.done as f64)),
                    ("shed", Json::num(cell.shed as f64)),
                    ("ttft_p50_ms", Json::num(cell.ttft_p50)),
                    ("ttft_p95_ms", Json::num(cell.ttft_p95)),
                    ("ttft_p99_ms", Json::num(cell.ttft_p99)),
                    ("per_token_p50_ms", Json::num(cell.tok_p50)),
                    ("per_token_p95_ms", Json::num(cell.tok_p95)),
                    ("mean_tau", Json::num(cell.tau)),
                    ("plan_depth_mean", Json::num(cell.plan_depth_mean)),
                    ("plan_nodes_mean", Json::num(cell.plan_nodes_mean)),
                    ("draft_us_p50", Json::num(cell.draft_us_p50)),
                    ("verify_us_p50", Json::num(cell.verify_us_p50)),
                    ("accept_us_p50", Json::num(cell.accept_us_p50)),
                    ("sched_us_p50", Json::num(cell.sched_us_p50)),
                ]));
                points.push(Json::obj(vec![
                    ("policy", Json::str(policy.name())),
                    ("planner", Json::str(planner.name())),
                    ("rate_per_sec", Json::num(rate)),
                    ("ttft_p50_ms", Json::num(cell.ttft_p50)),
                    ("per_token_p50_ms", Json::num(cell.tok_p50)),
                    ("tau", Json::num(cell.tau)),
                    ("draft_us_p50", Json::num(cell.draft_us_p50)),
                    ("verify_us_p50", Json::num(cell.verify_us_p50)),
                ]));
                last_prom = Some(cell.prom_text.clone());
                if cell.trace_text.is_some() {
                    last_trace = cell.trace_text.clone();
                }
            }
        }
    }

    println!(
        "\n=== Open-loop TCP serving: TTFT / per-token latency / draft cost \
         vs offered load ==="
    );
    let headers: Vec<String> = [
        "policy", "planner", "req/s", "done", "shed", "ttft_p50", "ttft_p95",
        "ttft_p99", "tok_p50", "tok_p95", "tau", "plan_d", "plan_n",
        "draft_us", "verify_us",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    println!("{}", render_table(&headers, &rows));
    println!(
        "(TTFT and per-token figures in ms from scheduled arrival; tau = mean \
         accepted length per cycle, plan_d/plan_n = mean planned depth/nodes, \
         draft_us/verify_us = per-phase p50 wall time)"
    );
    let path = write_report("serve_open_loop", &Json::Arr(report))?;
    println!("report -> {path:?}");

    // persist the final cell's export surfaces + a compact trajectory
    // point (the format BENCH_serve.json accumulates across PRs)
    let out_dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(out_dir)?;
    if let Some(text) = &last_prom {
        let p = out_dir.join("serve_metrics.prom");
        std::fs::write(&p, text)?;
        println!("prometheus -> {p:?}");
    }
    if let Some(text) = &last_trace {
        let p = out_dir.join("serve_trace.json");
        std::fs::write(&p, text)?;
        println!("chrome trace -> {p:?} (load in chrome://tracing or ui.perfetto.dev)");
    }
    let point = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("bench", Json::str("serve_open_loop")),
        ("quick", Json::Bool(env.quick)),
        ("backend", Json::str(&env.runtime.platform())),
        ("batch", Json::num(batch as f64)),
        ("requests_per_cell", Json::num(n as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("cells", Json::Arr(points)),
    ]);
    let p = write_report("BENCH_serve_point", &point)?;
    println!("trajectory point -> {p:?}");

    // warm-vs-cold prefix cache study: the same multi-turn chat trace
    // replayed through two fresh servers — cache off, then cache on
    // with cache-aware admission — comparing follow-up-turn TTFT, hit
    // rate, prefill work, and (hard requirement) byte-identical replies
    let (sessions_n, turns_n, chat_max_new) = if env.quick { (2, 3, 8) } else { (3, 3, 12) };
    let sessions = chat_sessions(&prompts, sessions_n, turns_n, chat_max_new, 77);
    let cold = run_cache_leg(&setup, &sessions, false, port)?;
    let warm = run_cache_leg(&setup, &sessions, true, port + 1)?;
    let identical = cold.turns.len() == warm.turns.len()
        && cold.turns.iter().zip(&warm.turns).all(|(c, w)| c.text == w.text);
    if !identical {
        anyhow::bail!("prefix cache changed generated bytes on the chat trace");
    }
    // follow-up turns (t > 0) are where the cache can skip prefill
    let followup_ttft = |ts: &[ChatTurnStat]| {
        let v: Vec<f64> = ts.iter().filter(|t| t.turn > 0).map(|t| t.ttft_ms).collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let (cold_ttft, warm_ttft) = (followup_ttft(&cold.turns), followup_ttft(&warm.turns));
    println!("\n=== Prefix cache: warm vs cold on a multi-turn chat trace ===");
    println!("cold: {}", cold.server_report);
    println!("warm: {}", warm.server_report);
    println!(
        "hit rate {:.0}% ({} hits / {} misses), {} prompt tokens adopted, prefill \
         chunks {} -> {}, follow-up TTFT mean {:.0}ms -> {:.0}ms, replies \
         byte-identical",
        warm.hit_rate * 100.0,
        warm.hits,
        warm.misses,
        warm.saved_tokens,
        cold.prefill_chunks,
        warm.prefill_chunks,
        cold_ttft,
        warm_ttft,
    );
    let cache_report = Json::obj(vec![
        ("sessions", Json::num(sessions_n as f64)),
        ("turns", Json::num(turns_n as f64)),
        ("max_new", Json::num(chat_max_new as f64)),
        ("hits", Json::num(warm.hits)),
        ("misses", Json::num(warm.misses)),
        ("hit_rate", Json::num(warm.hit_rate)),
        ("saved_tokens", Json::num(warm.saved_tokens)),
        ("cold_prefill_chunks", Json::num(cold.prefill_chunks)),
        ("warm_prefill_chunks", Json::num(warm.prefill_chunks)),
        ("cold_followup_ttft_mean_ms", Json::num(cold_ttft)),
        ("warm_followup_ttft_mean_ms", Json::num(warm_ttft)),
        ("byte_identical", Json::Bool(identical)),
    ]);
    let p = write_report("serve_cache", &cache_report)?;
    println!("cache report -> {p:?}");
    let p = out_dir.join("serve_cache_metrics.prom");
    std::fs::write(&p, &warm.prom_text)?;
    println!("cache prometheus -> {p:?}");

    // chaos lane: two replicas behind the router, one killed mid-trace
    run_chaos(&setup, env, port + 2)?;
    Ok(())
}

/// Boot one default-config FastEagle replica for the chaos fleet; the
/// thread returns the server's metrics report at clean exit, so a
/// successful join doubles as the drained-exit leak check (`serve`
/// bails if any pool block is still out).
fn spawn_chaos_replica(
    setup: &CellSetup,
    addr: String,
    replica_id: usize,
) -> std::thread::JoinHandle<Result<String>> {
    let kind = setup.kind;
    let batch = setup.batch;
    let dir = setup.dir.to_path_buf();
    std::thread::spawn(move || -> Result<String> {
        let rt = Arc::new(Runtime::new(kind)?);
        let store = Rc::new(ArtifactStore::open(rt, dir)?);
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )?;
        let server = Server::new(ServerConfig {
            addr,
            queue_capacity: 64,
            replica_id,
            ..Default::default()
        });
        let m = server.serve(engine)?;
        Ok(m.report())
    })
}

/// Wait until something accepts connections on `addr`; bail early if
/// the serving thread already died (its error surfaces at join time).
fn wait_up<T>(addr: &str, thread: &std::thread::JoinHandle<T>) -> Result<()> {
    for _ in 0..600 {
        if std::net::TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        if thread.is_finished() {
            anyhow::bail!("chaos server on {addr} exited before serving");
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    anyhow::bail!("chaos server did not start on {addr}")
}

/// Fire-and-forget shutdown: the write must land, the reply is
/// best-effort (it races the listener teardown).
fn send_shutdown(addr: &str) -> Result<()> {
    let s = std::net::TcpStream::connect(addr)?;
    let mut w = s.try_clone()?;
    writeln!(w, "{}", r#"{"cmd":"shutdown"}"#)?;
    let mut line = String::new();
    let _ = BufReader::new(s).read_line(&mut line);
    Ok(())
}

/// The chaos lane: the same Poisson trace is run once against a single
/// healthy server (the byte-identity reference) and once against a
/// two-replica fleet behind the round-robin router with replica B shot
/// mid-trace. Hard requirements: at least one request survives, every
/// survivor's bytes match the reference, and every casualty carries a
/// structured router error — never a raw dropped connection.
fn run_chaos(setup: &CellSetup, env: &BenchEnv, base_port: u16) -> Result<()> {
    use std::time::Duration;

    use crate::router::{make_policy, query_line, Router, RouterConfig};

    let (n, max_new, rate) = if env.quick { (8, 12, 4.0) } else { (16, 24, 8.0) };
    let trace = poisson_trace(setup.prompts, n, rate, max_new, 43);

    // reference leg: one healthy server, no router
    let ref_addr = format!("127.0.0.1:{base_port}");
    let ref_thread = spawn_chaos_replica(setup, ref_addr.clone(), 0);
    wait_up(&ref_addr, &ref_thread)?;
    let reference = replay_trace_tcp_text(&ref_addr, &trace)?;
    send_shutdown(&ref_addr)?;
    ref_thread
        .join()
        .map_err(|_| anyhow::anyhow!("chaos reference server panicked"))??;
    if let Some(r) = reference.iter().find(|r| r.stat.error.is_some()) {
        anyhow::bail!("chaos reference run failed: {:?}", r.stat.error);
    }

    // the fleet: replicas A and B behind a round-robin router
    let addr_a = format!("127.0.0.1:{}", base_port + 1);
    let addr_b = format!("127.0.0.1:{}", base_port + 2);
    let raddr = format!("127.0.0.1:{}", base_port + 3);
    let ta = spawn_chaos_replica(setup, addr_a.clone(), 1);
    wait_up(&addr_a, &ta)?;
    let tb = spawn_chaos_replica(setup, addr_b.clone(), 2);
    wait_up(&addr_b, &tb)?;
    let router = Arc::new(Router::new(
        RouterConfig { addr: raddr.clone(), poll_ms: 100, ..Default::default() },
        vec![addr_a.clone(), addr_b.clone()],
        make_policy("rr").context("rr policy")?,
    ));
    let r2 = Arc::clone(&router);
    let router_thread = std::thread::spawn(move || r2.serve());
    wait_up(&raddr, &router_thread)?;

    // the assassin: halfway through the arrival window, shoot replica B
    // with a direct shutdown — requests in flight there become
    // mid-stream casualties, queued ones get retried on A
    let half = trace.last().map(|t| t.at / 2).unwrap_or(Duration::ZERO);
    let kb = addr_b.clone();
    let killer = std::thread::spawn(move || -> Result<()> {
        std::thread::sleep(half);
        query_line(&kb, r#"{"cmd":"shutdown"}"#, Duration::from_secs(10))?;
        Ok(())
    });
    let routed = replay_trace_tcp_text(&raddr, &trace)?;
    killer
        .join()
        .map_err(|_| anyhow::anyhow!("chaos killer thread panicked"))?
        .context("killing replica B")?;
    let b_report = tb
        .join()
        .map_err(|_| anyhow::anyhow!("chaos replica B panicked"))??;

    // the verdict, request by request: survivors must be byte-identical
    // to the reference, casualties must die structured
    let mut survivors = 0usize;
    let mut casualties = 0usize;
    for (r, want) in routed.iter().zip(&reference) {
        match &r.stat.error {
            None => {
                if r.text != want.text {
                    anyhow::bail!(
                        "chaos: request {} survived with different bytes \
                         (got {:?}, want {:?})",
                        r.stat.index,
                        r.text,
                        want.text
                    );
                }
                survivors += 1;
            }
            Some(e) => {
                let structured = e.contains("replica failed")
                    || e.contains("no replica")
                    || e.contains("draining");
                if !structured {
                    anyhow::bail!("chaos: unstructured casualty error: {e}");
                }
                casualties += 1;
            }
        }
    }
    if survivors == 0 {
        anyhow::bail!("chaos: zero requests survived the replica kill");
    }

    // fleet observability after the kill: B marked dead in the merged
    // exposition (either the forward failure or the 100ms poller caught
    // it long before the trace drained)
    let stats = server_query(&raddr, r#"{"cmd":"stats"}"#)?;
    let stat = |key: &str| stats.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let (requests, retries, midstream) =
        (stat("requests"), stat("retries"), stat("midstream_failures"));
    let prom = server_query_text(&raddr, r#"{"cmd":"metrics"}"#)?;
    if !prom.contains("fe_router_replica_up{replica=\"1\"} 0") {
        anyhow::bail!("chaos: router never marked the killed replica dead");
    }

    send_shutdown(&raddr)?;
    router_thread
        .join()
        .map_err(|_| anyhow::anyhow!("chaos router thread panicked"))??;
    send_shutdown(&addr_a)?;
    let a_report = ta
        .join()
        .map_err(|_| anyhow::anyhow!("chaos replica A panicked"))??;

    println!("\n=== Chaos lane: replica killed mid-trace behind the router ===");
    println!("replica A (survivor): {a_report}");
    println!("replica B (killed):   {b_report}");
    println!(
        "{survivors}/{n} requests survived byte-identical, {casualties} structured \
         casualties; router saw {requests:.0} requests, {retries:.0} retries, \
         {midstream:.0} mid-stream failures"
    );
    let report = Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("rate_per_sec", Json::num(rate)),
        ("max_new", Json::num(max_new as f64)),
        ("survivors", Json::num(survivors as f64)),
        ("casualties", Json::num(casualties as f64)),
        ("byte_identical", Json::Bool(true)),
        ("router_requests", Json::num(requests)),
        ("router_retries", Json::num(retries)),
        ("router_midstream_failures", Json::num(midstream)),
    ]);
    let p = write_report("serve_chaos", &report)?;
    println!("chaos report -> {p:?}");
    Ok(())
}
