//! Table 2 — component ablations on the "base" target at T=0:
//!   Full            = fasteagle weights, constrained tree
//!   w/o Constrained Tree = fasteagle weights, chain (k=1)
//!   w/o Cascaded Structure = fasteagle_par weights (parallel heads)
//!   w/o Feature Loss = fasteagle_nofeat weights (CE-only training)
//! Tasks: dialog (MT-Bench stand-in) and math (GSM8K stand-in), as in
//! the paper.

use anyhow::Result;

use crate::spec::{DraftConfig, GenConfig};
use crate::util::json::Json;
use crate::workload::paper_name;

use super::harness::{has_weights, render_table, run_method, write_report, BenchEnv};

const TARGET: &str = "base";
const TASKS2: [&str; 2] = ["dialog", "math"];

pub fn run(env: &BenchEnv) -> Result<()> {
    let (n_prompts, max_new) = env.scale();
    let variants: [(&str, &str, bool); 4] = [
        ("Our Method (Full)", "fasteagle", true),
        ("w/o Constrained Tree", "fasteagle", false),
        ("w/o Cascaded Structure", "fasteagle_par", true),
        ("w/o Feature Loss", "fasteagle_nofeat", true),
    ];
    let mut base_tps = Vec::new();
    for task in TASKS2 {
        let prompts = env.prompts(task, n_prompts)?;
        let cfg = GenConfig { max_new_tokens: max_new, ..Default::default() };
        base_tps.push(run_method(env, TARGET, "vanilla", &prompts, &cfg)?.tok_per_sec);
    }
    let headers: Vec<String> = std::iter::once("Method".to_string())
        .chain(TASKS2.iter().flat_map(|t| {
            [format!("{} spd", paper_name(t)), "τ".to_string()]
        }))
        .collect();
    let mut rows = Vec::new();
    let mut report = Vec::new();
    for (label, wset, use_tree) in variants {
        if !has_weights(env, TARGET, wset) {
            println!("table2: weight set {wset:?} not built — skipping {label:?}");
            continue;
        }
        let mut row = vec![label.to_string()];
        let mut cells = Vec::new();
        for (i, task) in TASKS2.iter().enumerate() {
            let prompts = env.prompts(task, n_prompts)?;
            // "w/o Constrained Tree" plans a chain: top-k 1
            let top_k = if use_tree { None } else { Some(1) };
            let cfg = GenConfig {
                max_new_tokens: max_new,
                draft: DraftConfig { top_k, ..Default::default() },
                ..Default::default()
            };
            let agg = run_method(env, TARGET, wset, &prompts, &cfg)?;
            let spd = agg.tok_per_sec / base_tps[i].max(1e-9);
            row.push(format!("{spd:.2}x"));
            row.push(format!("{:.2}", agg.tau));
            cells.push(Json::obj(vec![
                ("task", Json::str(task)),
                ("speedup", Json::num(spd)),
                ("tau", Json::num(agg.tau)),
            ]));
        }
        rows.push(row);
        report.push(Json::obj(vec![
            ("variant", Json::str(label)),
            ("cells", Json::Arr(cells)),
        ]));
    }
    println!("\n=== Table 2 (ablations, {TARGET}, T=0) ===");
    println!("{}", render_table(&headers, &rows));
    let path = write_report("table2", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
