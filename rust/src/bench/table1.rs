//! Table 1 — speedup ratio + average acceptance length τ for every
//! (target, method, task, temperature) cell, mirroring the paper's main
//! result. Methods SpS and Medusa only appear for the Vicuna-13B
//! stand-in ("base"), exactly as in the paper; speedups are normalized
//! against vanilla autoregressive decoding measured on the same testbed,
//! task and temperature.

use anyhow::Result;

use crate::spec::GenConfig;
use crate::util::json::Json;
use crate::workload::{paper_name, TASKS};

use super::harness::{has_weights, render_table, run_method, write_report, BenchEnv};

fn methods_for(target: &str) -> Vec<&'static str> {
    if target == "base" {
        vec!["sps", "medusa", "eagle3", "fasteagle"]
    } else {
        vec!["eagle3", "fasteagle"]
    }
}

pub fn run(env: &BenchEnv) -> Result<()> {
    let (n_prompts, max_new) = env.scale();
    let temps = [0.0f32, 1.0f32];
    let targets = env.targets()?;
    let mut report = Vec::new();

    for &temp in &temps {
        println!("\n=== Table 1 (Temperature={temp}) ===");
        let headers: Vec<String> = std::iter::once("model/method".to_string())
            .chain(TASKS.iter().flat_map(|(t, _)| {
                [format!("{}⟂spd", paper_name(t)), "τ".to_string()]
            }))
            .chain(["mean spd".to_string(), "mean τ".to_string()])
            .collect();
        let headers: Vec<String> =
            headers.into_iter().map(|h| h.replace('⟂', " ")).collect();
        let mut rows = Vec::new();
        for target in &targets {
            // vanilla baseline per task
            let mut base_tps = Vec::new();
            for (task, _) in TASKS.iter() {
                let prompts = env.prompts(task, n_prompts)?;
                let cfg = GenConfig {
                    temperature: temp,
                    max_new_tokens: max_new,
                    ..Default::default()
                };
                let agg = run_method(env, target, "vanilla", &prompts, &cfg)?;
                base_tps.push(agg.tok_per_sec);
            }
            // methods that exist for this target (weight sets on disk)
            for method in methods_for(target) {
                if !has_weights(env, target, method) {
                    continue;
                }
                // Methods that relax acceptance (Medusa) are greedy-only
                // in the paper; SpS appears in both temp sections.
                if temp > 0.0 && method == "medusa" {
                    continue;
                }
                let mut row = vec![format!("{target}/{method}")];
                let mut spd_sum = 0.0;
                let mut tau_sum = 0.0;
                let mut cells = Vec::new();
                for (i, (task, _)) in TASKS.iter().enumerate() {
                    let prompts = env.prompts(task, n_prompts)?;
                    let cfg = GenConfig {
                        temperature: temp,
                        max_new_tokens: max_new,
                        ..Default::default()
                    };
                    let agg = run_method(env, target, method, &prompts, &cfg)?;
                    let spd = agg.tok_per_sec / base_tps[i].max(1e-9);
                    spd_sum += spd;
                    tau_sum += agg.tau;
                    row.push(format!("{spd:.2}x"));
                    row.push(format!("{:.2}", agg.tau));
                    cells.push(Json::obj(vec![
                        ("task", Json::str(task)),
                        ("speedup", Json::num(spd)),
                        ("tau", Json::num(agg.tau)),
                        ("tok_per_sec", Json::num(agg.tok_per_sec)),
                        ("first_cycle_ms", Json::num(agg.first_cycle_ms)),
                        ("baseline_tok_per_sec", Json::num(base_tps[i])),
                    ]));
                }
                let n = TASKS.len() as f64;
                row.push(format!("{:.2}x", spd_sum / n));
                row.push(format!("{:.2}", tau_sum / n));
                rows.push(row);
                report.push(Json::obj(vec![
                    ("target", Json::str(target)),
                    ("method", Json::str(method)),
                    ("temperature", Json::num(temp as f64)),
                    ("mean_speedup", Json::num(spd_sum / n)),
                    ("mean_tau", Json::num(tau_sum / n)),
                    ("cells", Json::Arr(cells)),
                ]));
            }
        }
        println!("{}", render_table(&headers, &rows));
    }
    let path = write_report("table1", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
