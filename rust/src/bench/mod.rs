//! Benchmark harnesses regenerating every table and figure in the
//! paper's evaluation section (see DESIGN.md §Experiment-index):
//! `table1` (main speedup/τ matrix), `table2` (ablations), `table3`
//! (batched throughput in the continuous batcher), `fig3` (per-depth
//! acceptance), plus `microbench` (per-executable latency).
//!
//! Invoked both by `fasteagle bench <name>` and by the `cargo bench`
//! targets in `rust/benches/`.

pub mod depth;
pub mod fig3;
pub mod harness;
pub mod microbench;
pub mod serving;
pub mod table1;
pub mod table2;
pub mod table3;

pub use harness::BenchEnv;

use anyhow::Result;

/// Validate a `--backend` flag (if present) and export it as
/// `FE_BACKEND` for [`BenchEnv::open`]. Single home for the
/// backend-export contract, shared by the CLI `bench` command and the
/// `cargo bench` entrypoints.
pub fn export_backend(args: &crate::util::cli::Args) -> Result<()> {
    if let Some(b) = args.get("backend") {
        crate::backend::BackendKind::from_str(b)?;
        std::env::set_var("FE_BACKEND", b);
    }
    Ok(())
}

/// Shared `cargo bench` entrypoint plumbing: honor `FE_BENCH_QUICK=1` or
/// `-- --quick`, validate + export `-- --backend pjrt|interpret`, then
/// run the named harness. Exits non-zero on failure so `cargo bench`
/// reports it.
pub fn bench_main(name: &str) {
    let args = crate::util::cli::Args::from_env();
    let quick =
        std::env::var("FE_BENCH_QUICK").as_deref() == Ok("1") || args.bool_flag("quick");
    if let Err(e) = export_backend(&args) {
        eprintln!("{name}: {e:#}");
        std::process::exit(2);
    }
    if let Err(e) = run_named(name, quick) {
        eprintln!("{name} failed: {e:#}");
        std::process::exit(1);
    }
}

pub fn run_named(name: &str, quick: bool) -> Result<()> {
    let Some(env) = BenchEnv::open(quick)? else {
        println!("bench {name}: artifacts/ missing — run `make artifacts` first; skipping");
        return Ok(());
    };
    match name {
        "table1" => table1::run(&env),
        "table2" => table2::run(&env),
        "table3" => table3::run(&env),
        "fig3" => fig3::run(&env),
        "microbench" | "micro" => microbench::run(&env),
        "depth" => depth::run(&env),
        "serve" => serving::run(&env),
        "all" => {
            table1::run(&env)?;
            table2::run(&env)?;
            table3::run(&env)?;
            fig3::run(&env)?;
            depth::run(&env)?;
            serving::run(&env)?;
            microbench::run(&env)
        }
        other => anyhow::bail!("unknown bench {other:?}"),
    }
}
