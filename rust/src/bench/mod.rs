//! Benchmark harnesses regenerating every table and figure in the
//! paper's evaluation section (see DESIGN.md §Experiment-index):
//! `table1` (main speedup/τ matrix), `table2` (ablations), `table3`
//! (batched throughput in the continuous batcher), `fig3` (per-depth
//! acceptance), plus `microbench` (per-executable latency).
//!
//! Invoked both by `fasteagle bench <name>` and by the `cargo bench`
//! targets in `rust/benches/`.

pub mod depth;
pub mod fig3;
pub mod harness;
pub mod microbench;
pub mod table1;
pub mod table2;
pub mod table3;

pub use harness::BenchEnv;

use anyhow::Result;

pub fn run_named(name: &str, quick: bool) -> Result<()> {
    let Some(env) = BenchEnv::open(quick)? else {
        println!("bench {name}: artifacts/ missing — run `make artifacts` first; skipping");
        return Ok(());
    };
    match name {
        "table1" => table1::run(&env),
        "table2" => table2::run(&env),
        "table3" => table3::run(&env),
        "fig3" => fig3::run(&env),
        "microbench" => microbench::run(&env),
        "depth" => depth::run(&env),
        "all" => {
            table1::run(&env)?;
            table2::run(&env)?;
            table3::run(&env)?;
            fig3::run(&env)?;
            depth::run(&env)?;
            microbench::run(&env)
        }
        other => anyhow::bail!("unknown bench {other:?}"),
    }
}
