//! Shared benchmark harness: run one (target, drafter, task, temp)
//! configuration over a prompt set, aggregate metrics, compute speedups
//! against the vanilla baseline, and render paper-style tables.
//!
//! criterion is unavailable offline (DESIGN.md §Substitutions), so the
//! `cargo bench` targets are thin `harness = false` binaries over this
//! module; results are also written as JSON under `bench_out/`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{BatchEngine, Request, Response, ServingMetrics};
use crate::draft::make_drafter;
use crate::model::TargetModel;
use crate::runtime::{ArtifactStore, Runtime};
use crate::spec::{Engine, GenConfig, GenMetrics};
use crate::util::json::Json;

pub struct BenchEnv {
    pub runtime: Arc<Runtime>,
    pub artifacts: PathBuf,
    pub quick: bool,
    stores: std::cell::RefCell<BTreeMap<String, Rc<ArtifactStore>>>,
}

impl BenchEnv {
    /// Backend comes from `FE_BACKEND` (the CLI's `--backend` flag
    /// exports it). `None` when artifacts are missing on the PJRT
    /// backend (benches skip gracefully); on the interpreter backend a
    /// missing tree is generated on the fly — that lane runs everywhere.
    pub fn open(quick: bool) -> Result<Option<BenchEnv>> {
        let runtime = Arc::new(Runtime::from_env()?);
        let mut artifacts = artifacts_root();
        if !artifacts.join("manifest.json").exists() {
            if runtime.kind() != crate::backend::BackendKind::Interpret {
                return Ok(None);
            }
            // regenerate every run: generation is cheap and a cached
            // tree from an older fixture generator would silently drift
            artifacts = PathBuf::from("bench_out").join("fixture_artifacts");
            crate::backend::fixture::generate_tree(&artifacts, 0)?;
            println!("bench: no artifacts; using interpreter fixture at {artifacts:?}");
        }
        Ok(Some(BenchEnv { runtime, artifacts, quick, stores: Default::default() }))
    }

    pub fn store(&self, target: &str) -> Result<Rc<ArtifactStore>> {
        if let Some(s) = self.stores.borrow().get(target) {
            return Ok(Rc::clone(s));
        }
        let s = Rc::new(ArtifactStore::open(
            Arc::clone(&self.runtime),
            self.artifacts.join(target),
        )?);
        self.stores.borrow_mut().insert(target.to_string(), Rc::clone(&s));
        Ok(s)
    }

    pub fn targets(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.artifacts.join("manifest.json"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(v.get("targets")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|t| t.as_str().map(String::from)).collect())
            .unwrap_or_default())
    }

    pub fn prompts(&self, task: &str, n: usize) -> Result<Vec<String>> {
        let all = crate::workload::load_prompts(&self.artifacts, task)?;
        Ok(all.into_iter().take(n).collect())
    }

    /// prompts per config / tokens per generation for this run size
    pub fn scale(&self) -> (usize, usize) {
        if self.quick {
            (2, 32)
        } else {
            (6, 64)
        }
    }
}

pub fn artifacts_root() -> PathBuf {
    std::env::var("FE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Whether `<target>/weights/<set>.few` exists — fixture trees ship
/// only a subset of the paper's drafter variants, so benches skip the
/// rest instead of hard-failing.
pub fn has_weights(env: &BenchEnv, target: &str, set: &str) -> bool {
    env.artifacts
        .join(target)
        .join("weights")
        .join(format!("{set}.few"))
        .exists()
}

#[derive(Debug, Clone)]
pub struct MethodAgg {
    pub method: String,
    pub tok_per_sec: f64,
    pub tau: f64,
    /// mean wall time (ms) until the first cycle committed tokens — the
    /// streaming time-to-first-tokens analogue, measured by driving the
    /// per-cycle `GenSession` API directly
    pub first_cycle_ms: f64,
    pub metrics: GenMetrics,
}

/// Run one method over a prompt set on the single-request engine,
/// driving the step-wise `GenSession` API (the same cycles
/// `Engine::generate` drains, plus per-cycle visibility for the
/// time-to-first-tokens stat). The first prompt is run twice: the extra
/// pass warms the lazy executable compilation out of the measurement.
pub fn run_method(
    env: &BenchEnv,
    target: &str,
    drafter: &str,
    prompts: &[String],
    cfg: &GenConfig,
) -> Result<MethodAgg> {
    let store = env.store(target)?;
    let tm = TargetModel::open(Rc::clone(&store))?;
    let dr = make_drafter(Rc::clone(&store), drafter)?;
    let mut engine = Engine::new(tm, dr);
    // Warmup must touch every executable the measured runs will use
    // (chunked observes hit fe_t1/fe_t8/fe_t32 depending on per-cycle
    // acceptance), or a lazy ~2s PJRT compile lands inside the
    // measurement. Two full-length warm generations cover the space.
    let mut warm_cfg = cfg.clone();
    warm_cfg.max_new_tokens = cfg.max_new_tokens.min(32);
    engine.generate(&prompts[0], &warm_cfg).context("warmup")?;
    warm_cfg.seed ^= 0x5eed;
    engine
        .generate(prompts.last().unwrap(), &warm_cfg)
        .context("warmup2")?;
    let mut agg = GenMetrics::default();
    let mut first_ms_sum = 0.0f64;
    let mut first_ms_n = 0usize;
    for (i, p) in prompts.iter().enumerate() {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64);
        let t0 = std::time::Instant::now();
        let mut session = engine.start_session(p, &c)?;
        let mut first: Option<f64> = None;
        while !session.finished() {
            let ev = session.step()?;
            if first.is_none() && !ev.committed_tokens.is_empty() {
                first = Some(t0.elapsed().as_secs_f64() * 1e3);
            }
        }
        if let Some(ms) = first {
            first_ms_sum += ms;
            first_ms_n += 1;
        }
        let r = session.finish();
        agg.merge(&r.metrics);
    }
    Ok(MethodAgg {
        method: drafter.to_string(),
        tok_per_sec: agg.tokens_per_sec(),
        tau: agg.tau(),
        first_cycle_ms: if first_ms_n > 0 {
            first_ms_sum / first_ms_n as f64
        } else {
            0.0
        },
        metrics: agg,
    })
}

/// Run a closed workload through the continuous batcher's serving loop
/// (`BatchEngine::run` is a thin wrapper over `step()`): one full warm
/// pass so every executable — including the chunk-size drafter variants
/// — compiles outside the measurement, then the measured pass. Returns
/// (tok/s, responses, serving metrics).
pub fn run_batch_closed(
    eng: &mut BatchEngine,
    make_reqs: impl Fn() -> Vec<Request>,
) -> Result<(f64, Vec<Response>, ServingMetrics)> {
    let _ = eng.run(make_reqs())?;
    let t0 = std::time::Instant::now();
    let (resps, metrics) = eng.run(make_reqs())?;
    let total_tokens: usize = resps.iter().map(|r| r.new_tokens).sum();
    Ok((
        total_tokens as f64 / t0.elapsed().as_secs_f64(),
        resps,
        metrics,
    ))
}

/// Write a JSON report under bench_out/.
pub fn write_report(name: &str, value: &Json) -> Result<PathBuf> {
    let dir = Path::new("bench_out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string())?;
    Ok(path)
}

/// Render an aligned text table.
pub fn render_table(headers: &[String], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let line = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = String::new();
    out.push_str(&line(headers));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["a".into(), "col".into()],
            &[vec!["1".into(), "2.00x".into()], vec!["22".into(), "3".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].ends_with("2.00x"));
    }
}
