//! Fig. 3 — per-depth acceptance rate on the dialog task (MT-Bench
//! stand-in) at T=0 for FastEagle vs EAGLE-3-like vs EAGLE-2-like.
//! Expected shape (paper): FastEagle high with a mild decline, EAGLE-3
//! most stable, EAGLE-2 degrades substantially with depth.

use anyhow::Result;

use crate::spec::GenConfig;
use crate::util::json::Json;

use super::harness::{has_weights, render_table, run_method, write_report, BenchEnv};

const TARGET: &str = "base";
const METHODS: [&str; 3] = ["fasteagle", "eagle3", "eagle2"];

pub fn run(env: &BenchEnv) -> Result<()> {
    let (n_prompts, max_new) = env.scale();
    let n_prompts = (n_prompts * 2).max(4); // acceptance curves need samples
    let prompts = env.prompts("dialog", n_prompts)?;
    let cfg = GenConfig { max_new_tokens: max_new, ..Default::default() };
    let mut depth_max = 0;
    let mut results = Vec::new();
    for m in METHODS {
        if !has_weights(env, TARGET, m) {
            println!("fig3: weight set {m:?} not built — skipping");
            continue;
        }
        let agg = run_method(env, TARGET, m, &prompts, &cfg)?;
        depth_max = depth_max.max(agg.metrics.depth_attempts.len());
        results.push(agg);
    }
    let headers: Vec<String> = std::iter::once("method".to_string())
        .chain((1..=depth_max).map(|d| format!("depth {d}")))
        .collect();
    let mut rows = Vec::new();
    let mut report = Vec::new();
    for agg in &results {
        let mut row = vec![agg.method.clone()];
        let mut series = Vec::new();
        for d in 1..=depth_max {
            match agg.metrics.accept_rate(d) {
                Some(r) => {
                    row.push(format!("{r:.2}"));
                    series.push(Json::num(r));
                }
                None => {
                    row.push("-".into());
                    series.push(Json::Null);
                }
            }
        }
        rows.push(row);
        report.push(Json::obj(vec![
            ("method", Json::str(&agg.method)),
            ("accept_rate_by_depth", Json::Arr(series)),
            ("tau", Json::num(agg.tau)),
        ]));
    }
    println!("\n=== Fig. 3 (acceptance rate by draft depth, dialog, T=0) ===");
    println!("{}", render_table(&headers, &rows));
    let path = write_report("fig3", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
