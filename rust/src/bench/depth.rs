//! Extension ablation (beyond the paper's tables): τ and speedup as a
//! function of draft depth 1..N for FastEagle and EAGLE-3. The cascade
//! emits all N levels in one pass regardless of the depth used, so
//! FastEagle's drafting cost is *flat* in depth while EAGLE-3's grows by
//! one sequential call per level — this sweep makes the paper's
//! latency-structure argument directly visible on one axis.

use anyhow::Result;

use crate::spec::{DraftConfig, GenConfig};
use crate::util::json::Json;

use super::harness::{render_table, run_method, write_report, BenchEnv};

const TARGET: &str = "base";

pub fn run(env: &BenchEnv) -> Result<()> {
    let (n_prompts, max_new) = env.scale();
    let prompts = env.prompts("dialog", n_prompts)?;
    let base = run_method(
        env,
        TARGET,
        "vanilla",
        &prompts,
        &GenConfig { max_new_tokens: max_new, ..Default::default() },
    )?
    .tok_per_sec;

    let depths = [1usize, 2, 3, 4, 6];
    let headers: Vec<String> = std::iter::once("method".to_string())
        .chain(depths.iter().map(|d| format!("depth {d}")))
        .collect();
    let mut rows = Vec::new();
    let mut report = Vec::new();
    for method in ["fasteagle", "eagle3"] {
        let mut row = vec![method.to_string()];
        let mut series = Vec::new();
        for &d in &depths {
            let cfg = GenConfig {
                max_new_tokens: max_new,
                draft: DraftConfig { depth: Some(d), ..Default::default() },
                ..Default::default()
            };
            let agg = run_method(env, TARGET, method, &prompts, &cfg)?;
            let spd = agg.tok_per_sec / base.max(1e-9);
            row.push(format!("{spd:.2}x/{:.2}", agg.tau));
            series.push(Json::obj(vec![
                ("depth", Json::num(d as f64)),
                ("speedup", Json::num(spd)),
                ("tau", Json::num(agg.tau)),
            ]));
        }
        rows.push(row);
        report.push(Json::obj(vec![
            ("method", Json::str(method)),
            ("series", Json::Arr(series)),
        ]));
    }
    println!("\n=== Depth sweep (speedup/τ vs draft depth, {TARGET}, dialog, T=0) ===");
    println!("{}", render_table(&headers, &rows));
    let path = write_report("depth", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
