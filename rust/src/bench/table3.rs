//! Table 3 — batched throughput improvement over vanilla at the same
//! batch size, on the LLaMA-3.1-8B stand-in ("mid") with the continuous
//! batcher: chain length 2, tree disabled (the paper's vLLM setup),
//! under a fixed KV block budget.
//!
//! FastEagle's per-request state includes N=6 drafter KV layers vs
//! EAGLE's 1, so under the shared block budget it saturates at a smaller
//! concurrent batch — reproducing the paper's observation that FastEagle
//! peaks earlier (batch 32) than EAGLE-3 (batch 56), scaled to our
//! testbed's batch range.

use anyhow::Result;

use crate::coordinator::{BatchConfig, BatchEngine, BatchMethod, Request};
use crate::util::json::Json;

use super::harness::{render_table, run_batch_closed, write_report, BenchEnv};

const TARGET: &str = "mid";

pub fn run(env: &BenchEnv) -> Result<()> {
    if !env.artifacts.join(TARGET).join("spec.json").exists() {
        println!("table3: target {TARGET:?} not built — skipping");
        return Ok(());
    }
    let store = env.store(TARGET)?;
    let spec = crate::model::ModelSpec::parse(&store.spec_json()?)?;
    let mut batches: Vec<usize> = vec![1];
    batches.extend(spec.batch_sizes.iter().copied().filter(|&b| b > 1));
    let (reqs_per_slot, max_new) = if env.quick { (1, 24) } else { (2, 48) };
    let prompts = env.prompts("dialog", 16)?;

    // Fixed KV budget: enough blocks for a full vanilla batch at the
    // largest size — the same "GPU memory" for every method.
    let block_slots = 16;
    let bmax = *batches.iter().max().unwrap();
    let probe = crate::model::BlockPool::new(1, block_slots);
    let budget = bmax * probe.blocks_for(spec.max_seq, spec.n_layers + 1);

    let methods = [BatchMethod::Vanilla, BatchMethod::Eagle3, BatchMethod::FastEagle];
    // throughput[method][batch], plus the scheduler-side pressure gauges
    let mut tps = vec![vec![0.0f64; batches.len()]; methods.len()];
    let mut deferred = vec![vec![0u64; batches.len()]; methods.len()];
    let mut occupancy = vec![vec![0.0f64; batches.len()]; methods.len()];
    for (mi, &method) in methods.iter().enumerate() {
        for (bi, &b) in batches.iter().enumerate() {
            let mut cfg = BatchConfig::new(b, method);
            cfg.chain_len = 2;
            cfg.pool_blocks = Some(budget);
            cfg.block_slots = block_slots;
            let mut eng = BatchEngine::new(std::rc::Rc::clone(&store), cfg)?;
            let n_req = b * reqs_per_slot;
            let make_reqs = || -> Vec<Request> {
                (0..n_req)
                    .map(|i| {
                        let mut r =
                            Request::new(i as u64, prompts[i % prompts.len()].clone());
                        r.cfg.max_new_tokens = max_new;
                        r
                    })
                    .collect()
            };
            let (tput, _resps, m) = run_batch_closed(&mut eng, make_reqs)?;
            tps[mi][bi] = tput;
            deferred[mi][bi] = m.requests_deferred;
            occupancy[mi][bi] = m.mean_occupancy();
        }
    }

    let headers: Vec<String> = std::iter::once("method".to_string())
        .chain(batches.iter().map(|b| format!("b={b}")))
        .collect();
    let mut rows = Vec::new();
    let mut report = Vec::new();
    for (mi, &method) in methods.iter().enumerate() {
        let mut row = vec![method.name().to_string()];
        let mut series = Vec::new();
        for (bi, _) in batches.iter().enumerate() {
            if mi == 0 {
                row.push(format!("{:.1} t/s", tps[0][bi]));
                series.push(Json::num(tps[0][bi]));
            } else {
                let imp = tps[mi][bi] / tps[0][bi].max(1e-9);
                row.push(format!("{imp:.2}x"));
                series.push(Json::num(imp));
            }
        }
        rows.push(row);
        report.push(Json::obj(vec![
            ("method", Json::str(method.name())),
            ("batches", Json::Arr(batches.iter().map(|&b| Json::num(b as f64)).collect())),
            ("values", Json::Arr(series)),
            (
                "deferred",
                Json::Arr(deferred[mi].iter().map(|&x| Json::num(x as f64)).collect()),
            ),
            (
                "mean_occupancy",
                Json::Arr(occupancy[mi].iter().map(|&x| Json::num(x)).collect()),
            ),
        ]));
    }
    println!("\n=== Table 3 (batched throughput vs vanilla, {TARGET}, chain=2, no tree) ===");
    println!("KV block budget: {budget} blocks (vanilla-sized at b={bmax})");
    println!("{}", render_table(&headers, &rows));

    // scheduler-side pressure gauges (previously JSON-only): how many
    // distinct requests waited on the KV pool, and the mean occupied
    // slots per decode step — the mechanism behind the throughput curve
    let gauge_headers: Vec<String> = std::iter::once("method".to_string())
        .chain(batches.iter().map(|b| format!("b={b} defer/occ")))
        .collect();
    let gauge_rows: Vec<Vec<String>> = methods
        .iter()
        .enumerate()
        .map(|(mi, &method)| {
            std::iter::once(method.name().to_string())
                .chain(
                    batches
                        .iter()
                        .enumerate()
                        .map(|(bi, _)| format!("{}/{:.2}", deferred[mi][bi], occupancy[mi][bi])),
                )
                .collect()
        })
        .collect();
    println!("--- scheduler pressure (requests_deferred / mean slot occupancy) ---");
    println!("{}", render_table(&gauge_headers, &gauge_rows));

    let path = write_report("table3", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
